"""Per-kernel validation: shape/dtype sweeps, interpret=True vs the pure-jnp
oracle in ``repro.kernels.ref`` (deliverable c)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention, schedule_props as fa_props
from repro.kernels.ssd_scan import ssd_scan
from repro.models import ssm as ssm_mod

KEY = jax.random.PRNGKey(42)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

FA_CASES = [
    # B, H, KVH, Sq, Skv, dh, causal, window, dtype
    (2, 4, 2, 256, 256, 64, True, None, jnp.float32),
    (1, 4, 4, 128, 128, 32, True, None, jnp.float32),   # MHA
    (1, 8, 1, 128, 128, 64, True, None, jnp.float32),   # MQA
    (2, 8, 2, 256, 256, 64, True, 64, jnp.float32),     # SWA
    (1, 2, 1, 128, 256, 64, False, None, jnp.float32),  # cross/bidir
    (2, 4, 2, 256, 256, 64, True, None, jnp.bfloat16),
    (1, 4, 2, 256, 256, 128, True, 128, jnp.bfloat16),
]


@pytest.mark.parametrize("case", FA_CASES,
                         ids=[f"fa{i}" for i in range(len(FA_CASES))])
def test_flash_attention_matches_ref(case):
    B, H, KVH, Sq, Skv, dh, causal, window, dtype = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, Sq, dh), dtype)
    k = jax.random.normal(ks[1], (B, KVH, Skv, dh), dtype)
    v = jax.random.normal(ks[2], (B, KVH, Skv, dh), dtype)
    o = flash_attention(q, k, v, causal=causal, window=window,
                        block_q=64, block_k=64, interpret=True)
    r = ref.attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), **_tol(dtype))


def test_flash_attention_block_shape_invariance():
    """Output must not depend on the BlockSpec tiling."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 256, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 256, 64), jnp.float32)
    outs = [flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
            for bq, bk in ((64, 64), (128, 64), (64, 128), (256, 256))]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-5, rtol=1e-5)


def test_flash_attention_schedule_props_skip_count():
    """Causal block-skip: executed pairs ≈ half of all pairs."""
    p_c = fa_props(1, 1, 1, 512, 512, 64, causal=True,
                   block_q=64, block_k=64)
    p_f = fa_props(1, 1, 1, 512, 512, 64, causal=False,
                   block_q=64, block_k=64)
    from repro.core import properties as props
    assert p_c[props.mxu_key(16)] < 0.6 * p_f[props.mxu_key(16)]
    assert p_c[props.BARRIER] == p_f[props.BARRIER]  # grid still walks


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    # Bz, H, G, L, P, N, chunk, dtype
    (2, 4, 1, 256, 32, 16, 64, jnp.float32),
    (1, 4, 2, 128, 64, 32, 32, jnp.float32),
    (2, 2, 2, 128, 16, 64, 128, jnp.float32),
    (1, 4, 1, 256, 64, 128, 64, jnp.float32),  # mamba2-370m-like ratios
    (2, 4, 1, 256, 32, 16, 64, jnp.bfloat16),
]


def _ssd_inputs(Bz, H, G, L, P, N, dtype):
    ks = jax.random.split(KEY, 5)
    x = (jax.random.normal(ks[0], (Bz, H, L, P), jnp.float32) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bz, H, L), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.3)
    B = jax.random.normal(ks[3], (Bz, G, L, N), jnp.float32) * 0.3
    C = jax.random.normal(ks[4], (Bz, G, L, N), jnp.float32) * 0.3
    return x, dt, A, B, C


@pytest.mark.parametrize("case", SSD_CASES,
                         ids=[f"ssd{i}" for i in range(len(SSD_CASES))])
def test_ssd_scan_matches_naive_recurrence(case):
    Bz, H, G, L, P, N, chunk, dtype = case
    x, dt, A, B, C = _ssd_inputs(Bz, H, G, L, P, N, dtype)
    y, h = ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    yr, hr = ref.ssd(x, dt, A, B, C)
    tol = dict(atol=3e-2, rtol=3e-2) if dtype == jnp.bfloat16 \
        else dict(atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               atol=5e-4, rtol=5e-4)


def test_ssd_scan_matches_xla_production_path():
    """Kernel ≡ the chunked XLA path used by the models (same math)."""
    Bz, H, G, L, P, N = 2, 4, 1, 256, 32, 16
    x, dt, A, B, C = _ssd_inputs(Bz, H, G, L, P, N, jnp.float32)
    y_k, h_k = ssd_scan(x, dt, A, B, C, chunk=64, interpret=True)
    # _ssd_chunked uses (B, L, H, P) layout
    y_x, h_x = ssm_mod._ssd_chunked(
        x.transpose(0, 2, 1, 3), dt.transpose(0, 2, 1), A,
        B.transpose(0, 2, 1, 3), C.transpose(0, 2, 1, 3), chunk=64)
    np.testing.assert_allclose(np.asarray(y_k),
                               np.asarray(y_x.transpose(0, 2, 1, 3)),
                               atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_x),
                               atol=5e-4, rtol=5e-4)


def test_ssd_chunk_invariance():
    Bz, H, G, L, P, N = 1, 2, 1, 256, 16, 16
    x, dt, A, B, C = _ssd_inputs(Bz, H, G, L, P, N, jnp.float32)
    outs = [ssd_scan(x, dt, A, B, C, chunk=c, interpret=True)[0]
            for c in (32, 64, 128, 256)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# Matmul / transpose (measurement-kernel classes)
# ---------------------------------------------------------------------------

MM_CASES = [
    (256, 384, 512, 128, jnp.float32),
    (128, 128, 128, 128, jnp.float32),
    (512, 256, 256, 64, jnp.float32),
    (256, 2048, 256, 128, jnp.float32),   # skinny (n = l = m/8)
    (256, 256, 256, 128, jnp.bfloat16),
]


@pytest.mark.parametrize("case", MM_CASES,
                         ids=[f"mm{i}" for i in range(len(MM_CASES))])
def test_matmul_matches_ref(case):
    M, K, N, blk, dtype = case
    ks = jax.random.split(KEY, 2)
    a = jax.random.normal(ks[0], (M, K), dtype)
    b = jax.random.normal(ks[1], (K, N), dtype)
    o = ops.matmul(a, b, block_m=blk, block_n=blk, block_k=blk,
                   interpret=True)
    r = ref.matmul(a, b)
    tol = dict(atol=1.0, rtol=3e-2) if dtype == jnp.bfloat16 \
        else dict(atol=1e-3, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), **tol)


@pytest.mark.parametrize("shape,blk", [((256, 256), 128), ((512, 256), 128),
                                       ((128, 384), 64)])
def test_transpose_matches_ref(shape, blk):
    x = jax.random.normal(KEY, shape, jnp.float32)
    o = ops.transpose(x, block=blk, interpret=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(x.T))
