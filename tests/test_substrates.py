"""Substrate tests: data pipeline, checkpointing, straggler, elastic."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.configs.registry import ARCHS
from repro.data.pipeline import DataConfig, PackedLoader
from repro.distributed import elastic
from repro.runtime.straggler import StragglerMonitor


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------


def _dc(**kw):
    base = dict(vocab_size=256, seq_len=64, global_batch=8, seed=7)
    base.update(kw)
    return DataConfig(**base)


def test_data_deterministic_and_seekable():
    l1, l2 = PackedLoader(_dc()), PackedLoader(_dc())
    b_a = l1.batch(5)
    _ = l1.batch(0), l1.batch(3)        # call order must not matter
    b_b = l2.batch(5)
    for k in b_a:
        np.testing.assert_array_equal(b_a[k], b_b[k])


def test_data_rank_sharding_partitions_batch():
    cfg = _dc()
    full = PackedLoader(cfg).batch(2)
    parts = [PackedLoader(cfg).batch(2, rank=r, n_ranks=4) for r in range(4)]
    np.testing.assert_array_equal(
        full["tokens"], np.concatenate([p["tokens"] for p in parts]))


def test_data_shapes_and_ranges():
    cfg = _dc()
    b = PackedLoader(cfg).batch(0)
    assert b["tokens"].shape == (8, 64) and b["labels"].shape == (8, 64)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 256
    assert 0.2 < b["loss_mask"].mean() <= 1.0


def test_data_labels_are_shifted_tokens():
    b = PackedLoader(_dc()).batch(1)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_data_codebooks():
    b = PackedLoader(_dc(n_codebooks=4)).batch(0)
    assert b["tokens"].shape == (8, 64, 4)


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "b": {"c": jnp.arange(7, dtype=jnp.int32),
                  "d": jnp.float32(3.5)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    store.save(str(tmp_path), 3, t)
    restored, manifest = store.restore(str(tmp_path), jax.tree.map(jnp.zeros_like, t))
    assert manifest["step"] == 3
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, restored)


def test_checkpoint_latest_and_prune(tmp_path):
    t = _tree()
    for s in (1, 5, 9, 12):
        store.save(str(tmp_path), s, t)
    assert store.latest_step(str(tmp_path)) == 12
    store.prune(str(tmp_path), keep=2)
    assert store.latest_step(str(tmp_path)) == 12
    assert sorted(int(d[5:]) for d in os.listdir(tmp_path)
                  if d.startswith("step_")) == [9, 12]


def test_checkpoint_atomic_no_partial_visible(tmp_path):
    """A stale .tmp dir (simulated crash) must be invisible to latest_step."""
    os.makedirs(tmp_path / "step_00000099.tmp")
    assert store.latest_step(str(tmp_path)) is None
    store.save(str(tmp_path), 1, _tree())
    assert store.latest_step(str(tmp_path)) == 1


def test_checkpoint_corruption_detected(tmp_path):
    t = _tree()
    d = store.save(str(tmp_path), 2, t)
    # corrupt one leaf
    fn = os.path.join(d, "leaf_00000.npy")
    arr = np.load(fn)
    arr.flat[0] += 1.0
    np.save(fn, arr)
    with pytest.raises(AssertionError, match="corrupt"):
        store.restore(str(tmp_path), t)


def test_async_checkpointer(tmp_path):
    ck = store.AsyncCheckpointer(str(tmp_path), keep=2)
    t = _tree()
    for s in (10, 20):
        ck.save(s, jax.tree.map(lambda x: x + s, t))
    ck.wait()
    restored, _ = store.restore(str(tmp_path), t, 20)
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.asarray(t["a"]) + 20)


# ---------------------------------------------------------------------------
# Straggler monitor
# ---------------------------------------------------------------------------


def test_straggler_flags_slow_host():
    m = StragglerMonitor(n_hosts=8, predicted_step_s=0.1, k=2.0, ewma=0.0)
    evs = m.observe(0, [0.1] * 7 + [0.5])
    assert len(evs) == 1 and evs[0].host == 7
    assert m.healthy_mask().sum() == 7
    assert m.rescale_weight() == pytest.approx(8 / 7)


def test_straggler_no_false_positives():
    m = StragglerMonitor(n_hosts=4, predicted_step_s=0.1, k=2.0)
    for s in range(5):
        assert m.observe(s, [0.1, 0.11, 0.09, 0.12]) == []


def test_straggler_ewma_recovers():
    m = StragglerMonitor(n_hosts=4, predicted_step_s=0.1, k=2.0, ewma=0.5)
    m.observe(0, [0.1, 0.1, 0.1, 1.0])
    assert not m.healthy_mask()[3]
    for s in range(1, 10):
        m.observe(s, [0.1, 0.1, 0.1, 0.1])
    assert m.healthy_mask().all()


# ---------------------------------------------------------------------------
# Elastic re-planning
# ---------------------------------------------------------------------------


def test_elastic_replan_ranks_feasible_meshes():
    from repro.configs.base import SHAPES
    cfg = ARCHS["smollm-360m"]
    opts = elastic.replan(cfg, SHAPES["train_4k"], 64)
    assert opts, "no options returned"
    assert all(o.shape["data"] * o.shape["model"] == 64 for o in opts)
    # training feasibility: batch divides dp
    assert all(256 % o.shape["data"] == 0 for o in opts)
    assert opts[0].predicted_step_s == min(o.predicted_step_s for o in opts)


def test_elastic_on_failure_shrinks_to_power_of_two():
    from repro.configs.base import SHAPES
    cfg = ARCHS["smollm-360m"]
    opt = elastic.on_failure(cfg, SHAPES["train_4k"], 256, lost=3)
    n = opt.shape["data"] * opt.shape["model"]
    assert n == 128  # largest power of two ≤ 253
