"""Fitting + linear-model tests (paper §2, §4.3) incl. hypothesis
property-based checks on the model's invariants."""
from __future__ import annotations

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import fit
from repro.core import properties as props
from repro.core.model import LinearCostModel, geomean, relative_error


def _synthetic(n_kernels=40, n_props=6, seed=0, noise=0.0):
    rng = np.random.default_rng(seed)
    keys = [f"p{i}" for i in range(n_props)]
    true_w = rng.uniform(1e-9, 1e-6, n_props)
    pvs, times = [], []
    for _ in range(n_kernels):
        counts = rng.integers(0, 10 ** 6, n_props).astype(float)
        counts[rng.random(n_props) < 0.3] = 0.0
        t = float(counts @ true_w) + 1e-7
        t *= 1.0 + noise * rng.standard_normal()
        pvs.append(dict(zip(keys, counts)))
        times.append(max(t, 1e-9))
    return pvs, times, keys, true_w


def test_fit_recovers_exact_synthetic_weights():
    pvs, times, keys, true_w = _synthetic()
    m = fit.fit_relative(pvs, times, keys=keys)
    pred = m.predict_many(pvs)
    errs = [relative_error(p, t) for p, t in zip(pred, times)]
    assert geomean(errs) < 1e-3


def test_fit_is_relative_not_absolute():
    """Two kernels, one 1000× slower: relative fit must not sacrifice the
    fast kernel's relative accuracy (absolute LS would)."""
    pvs = [{"a": 1.0}, {"a": 1.0, "b": 1.0}]
    times = [1e-6, 1e-3]
    m = fit.fit_relative(pvs, times)
    assert relative_error(m.predict(pvs[0]), times[0]) < 1e-6
    assert relative_error(m.predict(pvs[1]), times[1]) < 1e-6


def test_fit_allows_negative_weights():
    """Paper Table 2 has negative fitted weights (min(L,S), local loads) —
    NNLS must be opt-in, not forced."""
    pvs = [{"a": 2.0, "b": 1.0}, {"a": 4.0, "b": 1.0}, {"a": 1.0}]
    times = [3e-6, 7e-6, 2e-6]  # implies b negative
    m = fit.fit_relative(pvs, times)
    w = dict(zip(m.keys, m.weights))
    assert w["b"] < 0


def test_fit_nonneg_projects():
    pvs, times, keys, _ = _synthetic(seed=3)
    m = fit.fit_relative(pvs, times, keys=keys, nonneg=True)
    assert (m.weights >= 0).all()


@given(st.floats(1e-9, 1e-3), st.floats(1.5, 100.0))
@settings(max_examples=50, deadline=None)
def test_relative_error_properties(t, factor):
    assert relative_error(t, t) == 0
    assert relative_error(t * factor, t) == pytest.approx(factor - 1)


@given(st.lists(st.floats(0.01, 10.0), min_size=1, max_size=20))
@settings(max_examples=100, deadline=None)
def test_geomean_bounds(xs):
    g = geomean(xs)
    assert min(xs) - 1e-9 <= g <= max(xs) + 1e-9


def test_model_predict_is_inner_product_and_breakdown_sums():
    keys = ["x", "y", "z"]
    m = LinearCostModel(keys=keys, weights=np.array([1e-9, 2e-9, -1e-9]))
    pv = {"x": 10.0, "y": 5.0, "z": 3.0, "unknown": 99.0}
    expect = 10e-9 + 10e-9 - 3e-9
    assert m.predict(pv) == pytest.approx(expect)
    assert sum(m.breakdown(pv).values()) == pytest.approx(expect)


def test_model_save_load_roundtrip(tmp_path):
    m = LinearCostModel(keys=["a", "b"], weights=np.array([1.5e-9, 2.5e-9]),
                        device="test", meta={"k": 1})
    p = str(tmp_path / "m.json")
    m.save(p)
    m2 = LinearCostModel.load(p)
    assert m2.keys == m.keys and m2.device == "test"
    np.testing.assert_allclose(m2.weights, m.weights)


def test_finalize_adds_minls_and_const():
    pv = props.finalize({
        props.mem_key("load", 32, "s1"): 100.0,
        props.mem_key("store", 32, "s1"): 40.0,
        "zero": 0.0,
    })
    assert pv[props.minls_key(32)] == 40.0
    assert pv[props.CONST1] == 1.0
    assert "zero" not in pv


def test_condition_report_flags_collinearity():
    pvs = [{"a": float(i), "b": 2.0 * i} for i in range(1, 6)]
    rep = fit.condition_report(pvs, [1e-6 * i for i in range(1, 6)])
    assert rep["rank"] < rep["n_cols"]


# ---------------------------------------------------------------------------
# predictor-level invariants
# ---------------------------------------------------------------------------


def test_predictor_monotone_in_devices():
    from repro.configs.base import SHAPES
    from repro.configs.registry import ARCHS
    from repro.core import predictor
    from repro.distributed.plan import Plan
    cfg = ARCHS["glm4-9b"]
    plan = Plan(dp_axes=("data",))
    t_small = predictor.predict_step(cfg, SHAPES["train_4k"], plan,
                                     {"data": 8, "model": 8}).seconds
    t_big = predictor.predict_step(cfg, SHAPES["train_4k"], plan,
                                   {"data": 16, "model": 16}).seconds
    assert t_big < t_small


def test_predictor_compression_reduces_collective_term():
    from repro.configs.base import SHAPES
    from repro.configs.registry import ARCHS
    from repro.core import predictor
    from repro.distributed.plan import Plan
    cfg = ARCHS["llama3.2-3b"]
    mesh = {"data": 16, "model": 16}
    base = Plan(dp_axes=("data",), fsdp=False)
    comp = base.with_(compression="int8_ef")
    t0 = predictor.predict_step(cfg, SHAPES["train_4k"], base, mesh)
    t1 = predictor.predict_step(cfg, SHAPES["train_4k"], comp, mesh)
    assert t1.terms["collective"] < t0.terms["collective"]


def test_feasibility_rejects_remat_none_at_405b():
    from repro.configs.base import SHAPES
    from repro.configs.registry import ARCHS
    from repro.core import predictor
    from repro.distributed.plan import Plan
    cfg = ARCHS["llama3-405b"]
    mesh = {"data": 16, "model": 16}
    bad = Plan(dp_axes=("data",), fsdp=False, remat_policy="none",
               microbatches=1)
    good = Plan(dp_axes=("data",), fsdp=True, remat_policy="full",
                microbatches=16)
    assert not predictor.feasible(cfg, SHAPES["train_4k"], bad, mesh)
    assert predictor.feasible(cfg, SHAPES["train_4k"], good, mesh)
