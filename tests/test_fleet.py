"""Churn-tolerant heterogeneous fleet (ISSUE 10).

The load-bearing guarantees pinned here:

  * the ``FaultPlan`` grammar speaks fleet-scoped pool churn
    (``pool_shrink@5:pool=a100,k=2`` / ``pool_grow`` / pool-attributed
    ``device_loss``) and those faults NEVER leak into the per-trainer
    ``step_begin`` hook;
  * ``registry.load_models`` batch-loads per-device models with the
    hardened per-device fallback, degrading only the corrupt pool;
  * ``elastic.replan``/``on_failure`` accept a heterogeneous pool
    descriptor, with the int signature bit-identical to the 1-pool case;
  * same manifest + same ``FaultPlan`` seed ⇒ byte-identical placement
    history; an EMPTY fleet plan ⇒ placements identical to the bare
    allocator;
  * the degradation ladder replans → migrates → shrinks → pauses, with
    hysteresis against rebalance thrash;
  * a migrated training job's checkpoint handoff resumes with exact
    batch semantics: final history ≡ the fault-free run at rtol 1e-5.
"""
import json
import os

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.calibration import registry, seeds
from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS
from repro.core.model import LinearCostModel
from repro.core.workload import WorkloadSpec
from repro.data.pipeline import DataConfig
from repro.distributed import elastic
from repro.launch.fleet import (FleetAllocator, JobSpec, Manifest,
                                Placement, PoolSpec, demo_manifest,
                                load_manifest)
from repro.runtime.faults import (Fault, FaultInjector, FaultPlan,
                                  corrupt_file)
from repro.runtime.fleet_supervisor import (FleetSupervisor, SimJobRunner,
                                            TrainerJobRunner)
from repro.runtime.trainer import Trainer, TrainerConfig

_ARCH = "smollm-360m"


# ---------------------------------------------------------------------------
# Fleet-scoped fault grammar
# ---------------------------------------------------------------------------


def test_pool_fault_grammar_and_roundtrip(tmp_path):
    p = FaultPlan.parse(
        "pool_shrink@5:pool=a100,k=2;pool_grow@9:pool=v5e,count=4;"
        "device_loss@7:pool=h100", seed=7)
    shrink, loss, grow = p.faults
    assert (shrink.kind, shrink.step, shrink.pool, shrink.count) == \
        ("pool_shrink", 5, "a100", 2)          # k= aliases count=
    assert (grow.kind, grow.pool, grow.count) == ("pool_grow", "v5e", 4)
    assert loss.pool == "h100" and loss.fleet_scoped
    assert shrink.fleet_scoped and grow.fleet_scoped
    assert not Fault("device_loss", 7).fleet_scoped
    path = str(tmp_path / "plan.json")
    p.save(path)
    assert FaultPlan.load(path) == p
    with pytest.raises(ValueError):
        Fault(kind="slowdown", step=1, pool="a100")   # pool= is fleet-only


def test_fleet_events_one_shot_and_trainer_isolation():
    plan = FaultPlan.parse(
        "pool_shrink@3:pool=a100,k=2;device_loss@3:pool=a100;"
        "device_loss@5", seed=0)
    inj = FaultInjector(plan)
    # fleet-scoped churn must NOT raise from the per-trainer hook …
    inj.step_begin(3)
    evs = inj.fleet_events(3)
    assert sorted(f.kind for f in evs) == ["device_loss", "pool_shrink"]
    assert inj.fleet_events(3) == []            # one-shot
    # … while an unattributed device_loss still does
    from repro.runtime.faults import DeviceLossError
    with pytest.raises(DeviceLossError):
        inj.step_begin(5)
    assert inj.fleet_events(5) == []
    # empty plan: no bookkeeping, no events
    assert FaultInjector(FaultPlan()).fleet_events(0) == []


# ---------------------------------------------------------------------------
# Registry batch loader
# ---------------------------------------------------------------------------


def test_load_models_batch_degrades_only_corrupt_pool(tmp_path, capsys):
    d = str(tmp_path)
    os.makedirs(d, exist_ok=True)
    # a fitted gpu-a100 file, then corrupt it: load must fall back to the
    # analytic seed for THAT device only
    m = seeds.ANALYTIC_SEEDS["gpu-a100"]()
    registry.save_model(LinearCostModel(
        keys=list(m.keys), weights=m.weights.copy(), device="gpu-a100",
        meta={}), d)
    corrupt_file(registry._model_path(d, "gpu-a100"), mode="truncate")
    models = registry.load_models(["gpu-a100", "tpu-v5e", "gpu-a100"], d)
    assert set(models) == {"gpu-a100", "tpu-v5e"}
    assert models["gpu-a100"].meta.get("source") == "datasheet-seed"
    assert models["tpu-v5e"].meta.get("source") == "datasheet-seed"
    out = capsys.readouterr().out
    rollups = [l for l in out.splitlines()
               if l.startswith("[registry]") and "fallbacks=" in l]
    assert len(rollups) == 1                    # ONE rollup line
    assert "gpu-a100:seed" in rollups[0]
    with pytest.raises(registry.UnknownDeviceError):
        registry.load_models(["gpu-a100", "mystery-chip"], d)


# ---------------------------------------------------------------------------
# Heterogeneous elastic descriptor
# ---------------------------------------------------------------------------


def test_elastic_int_signature_is_one_pool_case():
    cfg = ARCHS[_ARCH]
    a = elastic.replan(cfg, SHAPES["train_4k"], 16)
    b = elastic.replan(cfg, SHAPES["train_4k"], [(None, 16)])
    assert [(o.shape, o.predicted_step_s, o.device) for o in a] == \
        [(o.shape, o.predicted_step_s, o.device) for o in b]
    assert all(o.device is None for o in a)


def test_elastic_heterogeneous_descriptor_merges_pools():
    cfg = ARCHS[_ARCH]
    desc = [("gpu-a100", 8), ("tpu-v5e", 8)]
    opts = elastic.replan(cfg, SHAPES["train_4k"], desc)
    assert {o.device for o in opts} == {"gpu-a100", "tpu-v5e"}
    secs = [o.predicted_step_s for o in opts]
    assert secs == sorted(secs)                 # one merged ranking
    # per-pool options match the pool scored alone
    solo = elastic.replan(cfg, SHAPES["train_4k"], [("gpu-a100", 8)])
    merged = [o for o in opts if o.device == "gpu-a100"]
    assert [(o.shape, o.predicted_step_s) for o in solo] == \
        [(o.shape, o.predicted_step_s) for o in merged]


def test_elastic_on_failure_pool_descriptor():
    cfg = ARCHS[_ARCH]
    # int path unchanged: 256 - 3 lost -> best power-of-two mesh over 128
    opt = elastic.on_failure(cfg, SHAPES["train_4k"], 256, lost=3)
    assert int(np.prod(list(opt.shape.values()))) == 128
    assert opt.device is None
    # descriptor path: the named pool rounds down, the other keeps its
    # count, and a dead pool drops out entirely
    opt = elastic.on_failure(cfg, SHAPES["train_4k"],
                             [("gpu-a100", 8), ("tpu-v5e", 8)], lost=3,
                             pool="gpu-a100")
    assert opt.device in ("gpu-a100", "tpu-v5e")
    opt = elastic.on_failure(cfg, SHAPES["train_4k"],
                             [("gpu-a100", 2), ("tpu-v5e", 8)], lost=2,
                             pool="gpu-a100")
    assert opt.device == "tpu-v5e"              # a100 pool died


# ---------------------------------------------------------------------------
# Allocator
# ---------------------------------------------------------------------------


def test_allocator_deterministic_and_priority_ordered():
    m = demo_manifest()
    a1 = FleetAllocator(m).allocate()
    a2 = FleetAllocator(demo_manifest()).allocate()
    assert a1.to_json_dict() == a2.to_json_dict()
    assert set(a1.placements) == {"train-hi", "serve", "train-lo"}
    assert a1.paused == {}
    # no pool overcommitted
    used = {}
    for p in a1.placements.values():
        used[p.pool] = used.get(p.pool, 0) + p.devices
    for pool in m.pools:
        assert used.get(pool.name, 0) <= pool.count
    # device-count bounds respected
    for name, p in a1.placements.items():
        job = next(j for j in m.jobs if j.name == name)
        assert job.min_devices <= p.devices <= job.max_devices


def test_allocator_pauses_unplaceable_job():
    m = Manifest(
        pools=[PoolSpec("a", "gpu-a100", 4)],
        jobs=[JobSpec(name="big", arch=_ARCH,
                      workload=WorkloadSpec(phase="train", global_batch=8,
                                            seq_len=128, name="big"),
                      priority=9, min_devices=8, max_devices=8),
              JobSpec(name="ok", arch=_ARCH,
                      workload=WorkloadSpec(phase="train", global_batch=8,
                                            seq_len=128, name="ok"),
                      priority=1, min_devices=1, max_devices=4)])
    a = FleetAllocator(m).allocate()
    assert a.paused == {"big": "capacity"}
    assert a.placements["ok"].devices == 4


def test_manifest_json_roundtrip(tmp_path):
    m = demo_manifest()
    path = str(tmp_path / "manifest.json")
    with open(path, "w") as f:
        json.dump(m.to_json_dict(), f)
    m2 = load_manifest(path)
    assert m2.to_json_dict() == m.to_json_dict()
    with pytest.raises(ValueError):
        Manifest(pools=[PoolSpec("a", "gpu-a100", 2),
                        PoolSpec("a", "tpu-v5e", 2)], jobs=[])


# ---------------------------------------------------------------------------
# Fleet churn determinism
# ---------------------------------------------------------------------------


def _run_fleet(manifest, plan_spec, seed, steps=12):
    allocator = FleetAllocator(manifest)
    fplan = FaultPlan.parse(plan_spec, seed=seed) if plan_spec \
        else FaultPlan(seed=seed)
    sup = FleetSupervisor(allocator, injector=FaultInjector(fplan),
                          runner_factory=SimJobRunner.factory())
    sup.run(steps)
    return sup


def test_placement_history_byte_identical():
    spec = "pool_shrink@3:pool=a100,k=2;pool_grow@8:pool=a100,k=2"
    s1 = _run_fleet(demo_manifest(), spec, seed=7)
    s2 = _run_fleet(demo_manifest(), spec, seed=7)
    assert s1.history_json() == s2.history_json()
    assert s1.history_json().encode() == s2.history_json().encode()


def test_empty_fleet_plan_identical_to_bare_allocator():
    bare = FleetAllocator(demo_manifest()).allocate()
    sup = _run_fleet(demo_manifest(), None, seed=7)
    assert sup.assignment.to_json_dict() == bare.to_json_dict()
    assert sup.actions == {}
    assert len(sup.placement_history) == 2      # allocate + final only
    # every sim runner ticked every step under its original placement
    for name, p in bare.placements.items():
        hist = sup.runners[name].history
        assert len(hist) == 12
        assert all(h["pool"] == p.pool and h["devices"] == p.devices
                   for h in hist)


# ---------------------------------------------------------------------------
# Degradation ladder
# ---------------------------------------------------------------------------


def test_ladder_shrink_replans_and_migrates_without_losing_jobs():
    sup = _run_fleet(demo_manifest(), "pool_shrink@5:pool=a100,k=2",
                     seed=7)
    # nobody lost: all three jobs still active, none paused
    assert len(sup.assignment.placements) == 3
    assert sup.assignment.paused == {}
    assert sup.actions.get("migrate", 0) >= 1
    # the churned pool fits its shrunken capacity
    assert sup.used("a100") <= sup.capacity["a100"] == 6


def test_ladder_pause_then_resume_on_grow():
    jobs = [JobSpec(name=n, arch=_ARCH,
                    workload=WorkloadSpec(phase="train", global_batch=8,
                                          seq_len=128, name=n),
                    priority=pri, min_devices=4, max_devices=4)
            for n, pri in (("hi", 10), ("lo", 1))]
    m = Manifest(pools=[PoolSpec("a", "gpu-a100", 8)], jobs=jobs)
    allocator = FleetAllocator(m)
    fplan = FaultPlan.parse("pool_shrink@2:pool=a,k=4;"
                            "pool_grow@6:pool=a,k=4", seed=0)
    sup = FleetSupervisor(allocator, injector=FaultInjector(fplan),
                          runner_factory=SimJobRunner.factory())
    sup.run(10)
    # shrink to 4: hi keeps 4, lo has nowhere to go -> paused with a
    # retry-after stamp; grow restores capacity -> lo resumes
    assert sup.actions.get("pause") == 1
    assert sup.actions.get("resume") == 1
    assert set(sup.assignment.placements) == {"hi", "lo"}
    events = [e["event"] for e in sup.placement_history]
    assert "pool_shrink:a" in events and "pool_grow:a" in events


def test_ladder_shrinks_lower_priority_to_make_room():
    wl4 = lambda n: WorkloadSpec(phase="train", global_batch=8,
                                 seq_len=128, name=n)
    m = Manifest(
        pools=[PoolSpec("a", "gpu-a100", 8), PoolSpec("b", "tpu-v5e", 4)],
        jobs=[JobSpec(name="hi", arch=_ARCH, workload=wl4("hi"),
                      priority=10, min_devices=4, max_devices=4),
              JobSpec(name="mid", arch=_ARCH, workload=wl4("mid"),
                      priority=8, min_devices=2, max_devices=4),
              JobSpec(name="lo", arch=_ARCH, workload=wl4("lo"),
                      priority=1, min_devices=2, max_devices=4)])
    allocator = FleetAllocator(m)
    a = allocator.allocate()
    assert a.placements["hi"].pool == "a"
    assert a.placements["mid"].pool == "a"
    assert a.placements["lo"].pool == "b"
    sup = FleetSupervisor(allocator, assignment=a,
                          injector=FaultInjector(
                              FaultPlan.parse("pool_shrink@2:pool=a,k=4",
                                              seed=0)),
                          runner_factory=SimJobRunner.factory())
    sup.run(6)
    # mid displaced from a; b full -> lo shrinks 4->2 to make room
    assert sup.actions.get("shrink", 0) >= 1
    assert sup.actions.get("migrate", 0) >= 1
    assert sup.assignment.placements["mid"].pool == "b"
    assert sup.assignment.placements["lo"].devices == 2
    assert sup.assignment.paused == {}


def test_rebalance_hysteresis_blocks_thrash():
    job = JobSpec(name="j", arch=_ARCH,
                  workload=WorkloadSpec(phase="train", global_batch=8,
                                        seq_len=128, name="j"),
                  priority=5, min_devices=2, max_devices=4)
    # two pools of the SAME device type: a grow offers zero predicted
    # win, so hysteresis must block any voluntary move
    m = Manifest(pools=[PoolSpec("a", "gpu-a100", 4),
                        PoolSpec("b", "gpu-a100", 0)], jobs=[job])
    allocator = FleetAllocator(m)
    sup = FleetSupervisor(allocator,
                          injector=FaultInjector(FaultPlan.parse(
                              "pool_grow@2:pool=b,k=4", seed=0)),
                          runner_factory=SimJobRunner.factory())
    sup.run(6)
    assert sup.actions.get("rebalance", 0) == 0
    assert sup.assignment.placements["j"].pool == "a"


def test_rebalance_fires_above_hysteresis_once_per_cooldown():
    job = JobSpec(name="j", arch=_ARCH,
                  workload=WorkloadSpec(phase="train", global_batch=8,
                                        seq_len=128, name="j"),
                  priority=5, min_devices=2, max_devices=4)
    # v5e -> h100 is far beyond the 15% hysteresis: ONE rebalance fires;
    # the second grow lands inside the cooldown window and must not move
    # the job again
    m = Manifest(pools=[PoolSpec("slow", "tpu-v5e", 4),
                        PoolSpec("fast", "gpu-h100", 0)], jobs=[job])
    allocator = FleetAllocator(m)
    sup = FleetSupervisor(allocator,
                          injector=FaultInjector(FaultPlan.parse(
                              "pool_grow@2:pool=fast,k=4;"
                              "pool_grow@3:pool=fast,k=4", seed=0)),
                          runner_factory=SimJobRunner.factory(),
                          cooldown_steps=3)
    sup.run(6)
    assert sup.actions.get("rebalance", 0) == 1
    assert sup.assignment.placements["j"].pool == "fast"


# ---------------------------------------------------------------------------
# Migration resume ≡ fault-free (real reduced trainers)
# ---------------------------------------------------------------------------

_TOTAL = 14


def _trainer_cfgs(ckpt_dir):
    cfg = ARCHS[_ARCH].reduced()
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4,
                    seed=5)
    tc = TrainerConfig(ckpt_dir=ckpt_dir, ckpt_every=5,
                       total_steps=_TOTAL, seed=0, log_every=1000,
                       save_on_exit=False)
    return cfg, dc, tc


def test_migration_resume_matches_fault_free_history(tmp_path):
    # fault-free reference
    ref_ck = str(tmp_path / "ref-ckpt")
    cfg, dc, tc = _trainer_cfgs(ref_ck)
    reference = Trainer(cfg, dc, tc).train(_TOTAL)

    # a 1-job fleet on two pools; shrink the job's pool to zero at step 9
    # -> forced migration mid-interval (last checkpoint: step 5)
    job = JobSpec(name="j", arch=_ARCH,
                  workload=WorkloadSpec(phase="train", global_batch=4,
                                        seq_len=64, name="j"),
                  priority=5, min_devices=2, max_devices=2)
    m = Manifest(pools=[PoolSpec("a100", "gpu-a100", 2),
                        PoolSpec("v5e", "tpu-v5e", 2)], jobs=[job])
    allocator = FleetAllocator(m)
    assignment = allocator.allocate()
    home = assignment.placements["j"].pool

    ck = str(tmp_path / "fleet-ckpt")
    fcfg, fdc, ftc = _trainer_cfgs(ck)

    def trainer_factory(job_spec, placement):
        return Trainer(fcfg, fdc, ftc)

    fplan = FaultPlan.parse(f"pool_shrink@9:pool={home},k=2", seed=7)
    sup = FleetSupervisor(
        allocator, assignment=assignment,
        injector=FaultInjector(fplan),
        runner_factory=TrainerJobRunner.factory(trainer_factory,
                                                target=_TOTAL))
    sup.run(_TOTAL)

    assert sup.actions.get("migrate") == 1
    other = {"a100": "v5e", "v5e": "a100"}[home]
    assert sup.assignment.placements["j"].pool == other
    runner = sup.runners["j"]
    assert runner.done and int(runner.trainer.step) >= _TOTAL

    hist = runner.history
    assert [h["step"] for h in hist] == \
        [h["step"] for h in reference]
    for h, r in zip(hist, reference):
        np.testing.assert_allclose(h["loss"], r["loss"], rtol=1e-5)
        np.testing.assert_allclose(h["grad_norm"], r["grad_norm"],
                                   rtol=1e-4)


# ---------------------------------------------------------------------------
# CLI dispatch
# ---------------------------------------------------------------------------


def test_launch_fleet_cli_smoke(tmp_path, capsys):
    from repro.launch.__main__ import main as launch_main
    hist = str(tmp_path / "hist.json")
    launch_main(["fleet", "--steps", "8",
                 "--fault-plan", "pool_shrink@2:pool=a100,k=2",
                 "--chaos-seed", "7", "--history-json", hist])
    out = capsys.readouterr().out
    assert "[fleet]" in out
    assert "replanned" in out
    assert "migrated" in out
    assert "run complete" in out
    entries = json.loads(open(hist).read())
    assert [e["event"] for e in entries] == \
        ["allocate", "pool_shrink:a100", "final"]


def test_launch_dispatch_rejects_unknown():
    from repro.launch.__main__ import main as launch_main
    with pytest.raises(SystemExit):
        launch_main(["frobnicate"])
