"""Property extraction tests (paper §3): the automatic jaxpr walk must
produce exactly the counts a human would derive by hand, the symbolic
per-arch counts must agree with the automatic extraction, and the HLO
rollup must be loop-aware."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import archcount, extract, hloparse
from repro.core import properties as props
from repro.core.symcount import (CeilDiv, Const, Max, Min, Piecewise, Var,
                                 as_expr)


# ---------------------------------------------------------------------------
# stride classes (paper §2.1 amortized stride fraction)
# ---------------------------------------------------------------------------


def test_stride_class_quantization():
    assert props.stride_class(0, 1.0) == "s0"
    assert props.stride_class(1, 1.0) == "s1"
    assert props.stride_class(2, 0.5) == "s2_1/2"
    assert props.stride_class(2, 1.0) == "s2_2/2"
    assert props.stride_class(3, 1 / 3) == "s3_1/3"
    assert props.stride_class(3, 1.0) == "s3_3/3"
    assert props.stride_class(4, 0.75) == "s4_3/4"
    assert props.stride_class(7, 1.0) == "s>4_4/>4"
    assert props.stride_class(9, 0.1) == "s>4_1/>4"


@given(st.integers(2, 64), st.floats(0.01, 1.0))
@settings(max_examples=200, deadline=None)
def test_stride_class_total(stride, util):
    cls = props.stride_class(stride, util)
    assert cls.startswith("s")
    num = cls.split("_")[1].split("/")[0]
    assert 1 <= int(num) <= 4


# ---------------------------------------------------------------------------
# jaxpr extraction vs hand counts
# ---------------------------------------------------------------------------


def test_extract_vector_add():
    n = 1024
    a = jnp.ones((n,), jnp.float32)
    pv = extract.extract_jaxpr(lambda a, b: a + b, a, a)
    assert pv[props.flop_key(32, "add")] == n
    assert pv[props.mem_key("load", 32, "s1")] == 2 * n
    assert pv[props.mem_key("store", 32, "s1")] == n
    assert pv[props.minls_key(32)] == n  # min(2n loads, n stores)
    assert pv[props.CONST1] == 1.0


def test_extract_matmul_mxu():
    a = jnp.ones((64, 32), jnp.float32)
    b = jnp.ones((32, 16), jnp.float32)
    pv = extract.extract_jaxpr(lambda a, b: a @ b, a, b)
    assert pv[props.mxu_key(32)] == 2 * 64 * 32 * 16


def test_extract_small_k_dot_is_vpu():
    """Contractions below MXU_MIN_K are charged as vector flops."""
    a = jnp.ones((64, 3), jnp.float32)
    b = jnp.ones((3, 16), jnp.float32)
    pv = extract.extract_jaxpr(lambda a, b: a @ b, a, b)
    assert props.mxu_key(32) not in pv
    assert pv[props.flop_key(32, "mul")] == 64 * 3 * 16


def test_extract_strided_slice_phases():
    """x[0::2] alone is a 1/2-utilization stride-2 access; adding x[1::2]
    fills the footprint -> 2/2 (paper Alg. 2 union-of-footprints)."""
    n = 1024
    x = jnp.ones((n,), jnp.float32)

    pv_half = extract.extract_jaxpr(
        lambda x: jax.lax.slice(x, (0,), (n,), (2,)) * 1.0, x)
    assert pv_half[props.mem_key("load", 32, "s2_1/2")] == n // 2

    def both(x):
        return (jax.lax.slice(x, (0,), (n - 1,), (2,))
                + jax.lax.slice(x, (1,), (n,), (2,)))
    pv_full = extract.extract_jaxpr(both, x)
    assert pv_full[props.mem_key("load", 32, "s2_2/2")] == 2 * (n // 2)


def test_extract_uniform_broadcast_is_stride0():
    """An explicit lane-independent broadcast is a 'uniform access'
    (paper §2.1 stride 0); a scalar operand read once is a single load."""
    x = jnp.ones((128,), jnp.float32)
    v = jnp.ones((1,), jnp.float32)
    pv = extract.extract_jaxpr(
        lambda x, v: x + jnp.broadcast_to(v, (128,)), x, v)
    assert pv[props.mem_key("load", 32, "s0")] == 128


def test_extract_transpose_is_gather():
    x = jnp.ones((64, 64), jnp.float32)
    pv = extract.extract_jaxpr(lambda x: x.T + 0.0, x)
    assert pv[props.mem_key("load", 32, "gather")] == 64 * 64


def test_extract_scan_multiplies_by_trip_count():
    x = jnp.ones((128,), jnp.float32)
    w = jnp.ones((5, 128), jnp.float32)

    def f(x, w):
        def body(c, wi):
            return c * wi, None
        c, _ = jax.lax.scan(body, x, w)
        return c
    pv = extract.extract_jaxpr(f, x, w)
    assert pv[props.flop_key(32, "mul")] == 5 * 128


def test_extract_flop_kinds():
    x = jnp.ones((100,), jnp.float32)
    pv = extract.extract_jaxpr(
        lambda x: jnp.exp(x) / (x + 1.0) * jax.lax.rsqrt(x), x)
    assert pv[props.flop_key(32, "exp")] == 100
    assert pv[props.flop_key(32, "div")] == 100
    assert pv[props.flop_key(32, "add")] == 100
    assert pv[props.flop_key(32, "special")] == 100
    assert pv[props.flop_key(32, "mul")] == 100


def test_extract_integer_ops_not_counted():
    x = jnp.ones((100,), jnp.int32)
    pv = extract.extract_jaxpr(lambda x: x + x, x)
    assert props.flop_key(32, "add") not in pv


def test_extract_bf16_bucketed_separately():
    x = jnp.ones((64,), jnp.bfloat16)
    pv = extract.extract_jaxpr(lambda x: x * x, x)
    assert pv[props.flop_key(16, "mul")] == 64
    assert pv[props.mem_key("load", 16, "s1")] == 2 * 64  # x read twice


# ---------------------------------------------------------------------------
# symcount (the piecewise-quasi-polynomial analog)
# ---------------------------------------------------------------------------


@given(st.integers(1, 10 ** 6), st.integers(1, 10 ** 4))
@settings(max_examples=100, deadline=None)
def test_symcount_eval(b, s):
    B, S = Var("B"), Var("S")
    e = (B * S * 3 + CeilDiv(B, Const(8)) + Min(S, Const(4096))
         + Max(B - 1, Const(0)))
    expect = (b * s * 3 + -(-b // 8) + min(s, 4096) + max(b - 1, 0))
    assert e.eval({"B": b, "S": s}) == expect


def test_symcount_piecewise():
    B = Var("B")
    e = Piecewise([(B - 4, Const(100))], B * 2)
    assert e.eval({"B": 8}) == 100   # guard 8-4 > 0
    assert e.eval({"B": 2}) == 4


def test_symcount_free_vars():
    B, S = Var("B"), Var("S")
    assert (B * S + 1).free_vars() == {"B", "S"}


# ---------------------------------------------------------------------------
# archcount vs automatic extraction (reduced configs)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["smollm-360m", "mixtral-8x7b",
                                  "mamba2-370m"])
def test_archcount_mxu_matches_jaxpr_extraction(arch):
    """Closed-form MXU flops ≈ automatic jaxpr extraction on the same
    reduced model (within 25%: the closed form folds small terms)."""
    from repro.configs.registry import ARCHS
    from repro.models import transformer
    cfg = ARCHS[arch].reduced()
    B, S = 2, 64
    params, _ = transformer.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((B, S), jnp.int32)}

    pv = extract.extract_jaxpr(
        lambda p, b: transformer.forward(p, cfg, b)[0], params, batch)
    auto = pv.get(props.mxu_key(16), 0.0) + pv.get(props.mxu_key(32), 0.0)

    sc = archcount.forward_counts(cfg)
    sym = sc[props.mxu_key(16)].eval({"B": B, "S": S})
    assert auto > 0 and sym > 0
    assert abs(auto - sym) / max(auto, sym) < 0.25, (arch, auto, sym)


def test_archcount_train_flops_scale():
    from repro.configs.registry import ARCHS
    cfg = ARCHS["glm4-9b"]
    from repro.core.workload import WorkloadSpec
    sc = archcount.counts_for(cfg, WorkloadSpec(phase="train"))
    mf = sc.concrete_model_flops({"B": 256, "S": 4096})
    # 6·N·D with N≈9.4B, D≈1.05M tokens
    assert 0.8 < mf / (6 * cfg.n_params() * 256 * 4096) < 1.05


# ---------------------------------------------------------------------------
# HLO rollup (loop-aware)
# ---------------------------------------------------------------------------


def test_hloparse_rollup_counts_loop_trips():
    def f(x, w):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, w)
        return h
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    c = hloparse.rollup(compiled.as_text())
    expect = 7 * 2 * 8 * 64 * 64
    assert 1.0 <= c.flops / expect < 1.25
    # XLA's own analysis counts the body once — the discrepancy this
    # rollup exists to fix
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    assert ca.get("flops", 0) < 0.5 * c.flops


def test_hloparse_scanned_params_stream_once():
    """A scanned parameter stack consumed via dynamic-slice must count at
    ~its own size (once per step total), not trips × full size."""
    L, n = 16, 256
    def f(x, w):
        def body(h, wi):
            return h @ wi, None
        h, _ = jax.lax.scan(body, x, w)
        return h
    x = jax.ShapeDtypeStruct((8, n), jnp.float32)
    w = jax.ShapeDtypeStruct((L, n, n), jnp.float32)
    c = hloparse.rollup(jax.jit(f).lower(x, w).compile().as_text())
    w_bytes = L * n * n * 4
    assert c.bytes < 4 * w_bytes, (c.bytes, w_bytes)


def test_hloparse_type_bytes():
    assert hloparse.type_bytes("f32[8,64]{1,0}") == 8 * 64 * 4
    assert hloparse.type_bytes("bf16[4,4]") == 32
    assert hloparse.type_bytes("(f32[8], s32[2])") == 40
    assert hloparse.type_bytes("pred[16]") == 16
