"""Calibration subsystem tests: schema-versioned model (de)serialization,
registry lookup/error paths, the batched plan-scoring hot path, and a
tiny-scale end-to-end calibrate -> register -> load -> predict loop."""
from __future__ import annotations

import json
import os

import numpy as np
import pytest

from repro.calibration import (ANALYTIC_SEEDS, UnknownDeviceError, calibrate,
                               list_models, load_model, resolve_model,
                               save_model, seeds)
from repro.core import predictor
from repro.core.model import (SCHEMA_VERSION, LinearCostModel,
                              ModelSchemaError)


@pytest.fixture(autouse=True)
def _isolated_registry(tmp_path, monkeypatch):
    """Pin the default registry to an empty tmp dir so fitted models a
    developer registered in ./experiments/registry can't shadow the
    analytic seeds these tests compare against."""
    monkeypatch.setenv("REPRO_MODEL_REGISTRY", str(tmp_path / "ambient-reg"))


def _awkward_model() -> LinearCostModel:
    # weights chosen so decimal shortening would be observable
    w = np.array([1.0 / 3.0 * 1e-9, np.pi * 1e-12, -7.3e-11, 2.0 ** -40])
    return LinearCostModel(keys=["a", "b", "c", "d"], weights=w,
                           device="rt-test", meta={"note": "round-trip"})


# ---------------------------------------------------------------------------
# serialization round-trip
# ---------------------------------------------------------------------------


def test_roundtrip_predictions_bitwise_identical(tmp_path):
    m = _awkward_model()
    p = str(tmp_path / "m.json")
    m.save(p)
    m2 = LinearCostModel.load(p)
    assert m2.keys == m.keys and m2.device == m.device and m2.meta == m.meta
    assert np.array_equal(m2.weights, m.weights)  # bitwise, not approx
    pvs = [{"a": 3.0, "b": 1e6, "c": 7.0, "d": 2.0},
           {"a": 1.0}, {"b": 123.456, "d": 1e-3}]
    for pv in pvs:
        assert m2.predict(pv) == m.predict(pv)
    assert np.array_equal(m2.predict_many(pvs), m.predict_many(pvs))


def test_serialized_file_carries_schema_version(tmp_path):
    p = str(tmp_path / "m.json")
    _awkward_model().save(p)
    with open(p) as f:
        d = json.load(f)
    assert d["schema"] == SCHEMA_VERSION
    assert d["kind"] == "linear_cost_model"


def test_legacy_v0_file_still_loads(tmp_path):
    # the pre-registry format: no schema/kind envelope
    p = str(tmp_path / "legacy.json")
    with open(p, "w") as f:
        json.dump({"device": "old", "keys": ["x"], "weights": [1e-9],
                   "meta": {}}, f)
    m = LinearCostModel.load(p)
    assert m.device == "old" and m.predict({"x": 2.0}) == 2e-9


def test_future_schema_rejected(tmp_path):
    p = str(tmp_path / "future.json")
    with open(p, "w") as f:
        json.dump({"schema": SCHEMA_VERSION + 1, "kind": "linear_cost_model",
                   "keys": ["x"], "weights": [1.0]}, f)
    with pytest.raises(ModelSchemaError):
        LinearCostModel.load(p)


def test_mismatched_lengths_rejected():
    with pytest.raises(ModelSchemaError):
        LinearCostModel.from_json_dict(
            {"schema": 1, "kind": "linear_cost_model",
             "keys": ["x", "y"], "weights": [1.0]})


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_save_then_load(tmp_path):
    m = _awkward_model()
    path = save_model(m, str(tmp_path))
    assert os.path.exists(path)
    m2 = load_model("rt-test", str(tmp_path))
    assert np.array_equal(m2.weights, m.weights)
    assert list_models(str(tmp_path))["rt-test"] == "fitted"


def test_registry_unknown_device_error_lists_available(tmp_path):
    with pytest.raises(UnknownDeviceError) as ei:
        load_model("no-such-device", str(tmp_path))
    msg = str(ei.value)
    assert "no-such-device" in msg and "tpu-v5e" in msg
    assert isinstance(ei.value, KeyError)


def test_registry_analytic_seeds_cover_cross_vendor(tmp_path):
    names = set(list_models(str(tmp_path)))
    assert {"tpu-v5e", "gpu-a100", "gpu-h100", "gpu-mi300x"} <= names
    vendors = {load_model(n, str(tmp_path)).meta.get("vendor")
               for n in ("gpu-a100", "gpu-mi300x")}
    assert vendors == {"nvidia", "amd"}


def test_registry_v5e_seed_matches_predictor_seed(tmp_path):
    reg = load_model("tpu-v5e", str(tmp_path))
    ref = predictor.tpu_v5e_weights()
    assert reg.keys == ref.keys
    assert np.array_equal(reg.weights, ref.weights)


def test_registry_fitted_model_shadows_analytic_seed(tmp_path):
    custom = LinearCostModel(keys=["const1"], weights=np.array([1.0]),
                             device="gpu-a100")
    save_model(custom, str(tmp_path))
    assert load_model("gpu-a100", str(tmp_path)).keys == ["const1"]
    assert list_models(str(tmp_path))["gpu-a100"] == "fitted"


def test_analytic_seeds_price_full_taxonomy():
    from repro.core import properties as props
    for name, build in ANALYTIC_SEEDS.items():
        m = build()
        have = set(m.keys)
        assert props.CONST1 in have and props.BARRIER in have, name
        assert props.mxu_key(16) in have, name
        assert props.mem_key("load", 32, "s1") in have, name
        assert props.coll_key("all_reduce") in have, name


def test_resolve_model_forms(tmp_path):
    m = _awkward_model()
    assert resolve_model(m) is m
    by_name = resolve_model("gpu-h100", registry_dir=str(tmp_path))
    assert by_name.device == "gpu-h100"
    default = resolve_model(None, registry_dir=str(tmp_path))
    assert default.device == predictor.tpu_v5e_weights().device
    with pytest.raises(TypeError):
        resolve_model(42)


# ---------------------------------------------------------------------------
# batched plan-scoring hot path
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def plan_search_cell():
    from repro.configs.base import SHAPES
    from repro.configs.registry import ARCHS
    from repro.launch.autoshard import candidate_plans
    cfg = ARCHS["glm4-9b"]
    shape = SHAPES["train_4k"]
    plans = candidate_plans(cfg, shape)
    return cfg, shape, plans, {"data": 16, "model": 16}


def test_predict_plans_matches_per_plan_loop(plan_search_cell):
    cfg, shape, plans, mesh = plan_search_cell
    batched = predictor.predict_plans(cfg, shape, plans, mesh)
    assert batched.shape == (len(plans),)
    loop = [predictor.predict_step(cfg, shape, p, mesh).seconds
            for p in plans]
    np.testing.assert_allclose(batched, loop, rtol=1e-9)


def test_rank_plans_is_sorted_and_complete(plan_search_cell):
    cfg, shape, plans, mesh = plan_search_cell
    ranked = predictor.rank_plans(cfg, shape, plans, mesh)
    assert len(ranked) == len(plans)
    secs = [s for s, _ in ranked]
    assert secs == sorted(secs)


def test_predict_plans_accepts_registry_name(plan_search_cell):
    cfg, shape, plans, mesh = plan_search_cell
    by_name = predictor.predict_plans(cfg, shape, plans[:8], mesh, "gpu-a100")
    by_model = predictor.predict_plans(cfg, shape, plans[:8], mesh,
                                       ANALYTIC_SEEDS["gpu-a100"]())
    np.testing.assert_array_equal(by_name, by_model)


def test_predict_plans_empty():
    from repro.configs.base import SHAPES
    from repro.configs.registry import ARCHS
    out = predictor.predict_plans(ARCHS["glm4-9b"], SHAPES["train_4k"], [],
                                  {"data": 2})
    assert out.shape == (0,)


def test_straggler_monitor_from_model(plan_search_cell):
    from repro.runtime.straggler import StragglerMonitor
    cfg, shape, plans, mesh = plan_search_cell
    mon = StragglerMonitor.from_model(cfg, shape, plans[0], mesh,
                                      n_hosts=4, model="tpu-v5e", k=3.0)
    expect = predictor.predict_step(cfg, shape, plans[0], mesh).seconds
    assert mon.predicted_step_s == pytest.approx(expect)
    assert mon.k == 3.0 and mon.n_hosts == 4


def test_elastic_replan_accepts_registry_name(plan_search_cell):
    from repro.distributed import elastic
    cfg, shape, _, _ = plan_search_cell
    opts = elastic.replan(cfg, shape, 64, weights="gpu-h100")
    assert opts and opts[0].predicted_step_s <= opts[-1].predicted_step_s


# ---------------------------------------------------------------------------
# end-to-end: calibrate -> registry -> load -> identical predictions
# ---------------------------------------------------------------------------


def test_calibrate_tiny_end_to_end(tmp_path):
    res = calibrate("cpu-test", scale="tiny", runs=5, drop=1,
                    classes=("stride1_global",), registry_dir=str(tmp_path),
                    verbose=False)
    assert res.registry_path and os.path.exists(res.registry_path)
    assert res.model.meta["source"] == "calibrated"
    assert res.report["n"] == len(res.labels) > 0

    loaded = load_model("cpu-test", str(tmp_path))
    assert np.array_equal(loaded.weights, res.model.weights)
    pv = {k: float(i + 1) for i, k in enumerate(res.model.keys)}
    assert loaded.predict(pv) == res.model.predict(pv)


def test_calibrate_rejects_unknown_class(tmp_path):
    with pytest.raises(ValueError, match="unknown kernel classes"):
        calibrate("x", scale="tiny", classes=("not_a_class",),
                  registry_dir=str(tmp_path), verbose=False)
