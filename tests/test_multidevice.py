"""Multi-device tests (8 virtual CPU devices, run in subprocesses so the
main pytest process keeps the single real device — see the dry-run brief).

Covers: compressed DP all-reduce (wire-format correctness + collective-byte
reduction in HLO), manual-DP train-step equivalence, sharded lowering of a
small arch on a (2, 4) mesh, and elastic-resume across mesh shapes.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run8(body: str, timeout: int = 420) -> str:
    """Run ``body`` in a python subprocess with 8 virtual devices."""
    script = ("import os\n"
              "os.environ['XLA_FLAGS'] = "
              "'--xla_force_host_platform_device_count=8'\n"
              + textwrap.dedent(body))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    p = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


def test_psum_compressed_matches_fp32_psum():
    run8("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.distributed import compression as comp

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((8,), ('data',))
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 4096))

    def body(xs):
        exact = jax.lax.psum(xs, 'data')
        approx = comp.psum_compressed(xs, 'data')
        return exact, approx

    f = shard_map(body, mesh=mesh, in_specs=(P('data'),),
                  out_specs=(P(), P()), check_rep=False)
    exact, approx = f(x.reshape(8, 1, 4096))
    rel = float(jnp.max(jnp.abs(exact - approx))
                / jnp.max(jnp.abs(exact)))
    assert rel < 0.05, rel

    # wire bytes: compressed int8 must move ~4x less than fp32
    from repro.core import hloparse
    txt = jax.jit(f).lower(x.reshape(8, 1, 4096)).compile().as_text()
    coll = hloparse.collective_summary(txt)
    total = sum(coll.values())
    assert total > 0
    print('collective bytes:', coll)
    """)


def test_manual_dp_train_step_compression_converges_like_fp32():
    run8("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.registry import ARCHS
    from repro.runtime import steps
    from repro.optim import optimizers as opt
    from repro.models import transformer

    cfg = ARCHS['smollm-360m'].reduced()
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((8,), ('data',))
    optimizer = opt.get_optimizer('adamw')
    params, _ = transformer.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 16, 32
    batch = {
        'tokens': jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                     cfg.vocab_size, jnp.int32),
        'labels': jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                     cfg.vocab_size, jnp.int32),
    }
    losses = {}
    for compression in (None, 'int8_ef'):
        st = steps.TrainState(params, optimizer.init(params),
                              jnp.zeros((), jnp.int32))
        fn, init_ef = steps.make_manual_dp_train_step(
            cfg, optimizer, mesh, compression=compression)
        fn = jax.jit(fn)
        ef = init_ef(params)
        ls = []
        for i in range(4):
            st, ef, m = fn(st, ef, batch)
            ls.append(float(m['loss']))
        losses[compression] = ls
    print('fp32 :', losses[None])
    print('int8 :', losses['int8_ef'])
    # same trajectory within quantization noise; both decreasing
    for a, b in zip(losses[None], losses['int8_ef']):
        assert abs(a - b) / a < 0.05, (a, b)
    assert losses['int8_ef'][-1] < losses['int8_ef'][0]
    """)


def test_sharded_train_lowering_small_mesh():
    run8("""
    import jax
    from repro.configs.base import SHAPES
    from repro.configs.registry import ARCHS
    from repro.distributed.plan import plan_for
    from repro.distributed.sharding import use_sharding
    from repro.launch.specs import step_and_specs
    from repro.core import extract as cx

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 4), ('data', 'model'))
    cfg, shape = ARCHS['smollm-360m'], SHAPES['train_4k']
    plan = plan_for(cfg, shape, tp_size=4)
    with mesh, use_sharding(mesh, plan):
        fn, specs, sh, osh = step_and_specs(cfg, shape, mesh, plan)
        compiled = jax.jit(fn, in_shardings=sh, out_shardings=osh).lower(*specs).compile()
    c = cx.extract_compiled(compiled)
    assert c.flops > 0 and c.collective_bytes, c
    print('ok', c.flops, c.collective_bytes)
    """)


def test_elastic_mesh_switch_resumes_from_checkpoint(tmp_path):
    run8(f"""
    import jax, jax.numpy as jnp, numpy as np
    from repro.checkpoint import store
    from repro.configs.registry import ARCHS
    from repro.models import transformer
    from repro.optim import optimizers as opt
    from repro.runtime import steps

    cfg = ARCHS['smollm-360m'].reduced()
    optimizer = opt.get_optimizer('adamw')
    params, _ = transformer.init_params(cfg, jax.random.PRNGKey(0))
    st = steps.TrainState(params, optimizer.init(params),
                          jnp.zeros((), jnp.int32))
    B, S = 16, 32
    batch = {{
        'tokens': jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                     cfg.vocab_size, jnp.int32),
        'labels': jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                     cfg.vocab_size, jnp.int32),
    }}
    # train 2 steps on an 8-device DP mesh, checkpoint
    from repro.launch.mesh import make_mesh
    mesh8 = make_mesh((8,), ('data',))
    fn8, init_ef = steps.make_manual_dp_train_step(cfg, optimizer, mesh8)
    ef = init_ef(params)
    for _ in range(2):
        st, ef, m = jax.jit(fn8)(st, ef, batch)
    store.save(r'{tmp_path}', int(st.step), st)

    # 'failure': restart on a 4-device mesh from the checkpoint
    mesh4 = make_mesh((4,), ('data',), devices=jax.devices()[:4])
    st2, _ = store.restore(r'{tmp_path}', st)
    assert int(st2.step) == 2
    fn4, init_ef4 = steps.make_manual_dp_train_step(cfg, optimizer, mesh4)
    st3, _, m = jax.jit(fn4)(st2, init_ef4(st2.params), batch)
    assert int(st3.step) == 3 and np.isfinite(float(m['loss']))
    print('elastic resume ok', float(m['loss']))
    """)


def test_moe_expert_parallel_lowering():
    """EP shards the expert dim when it divides the axis (8 experts on an
    8-wide model axis) — the plan must lower/compile with cross-device
    dispatch traffic.  (GSPMD may choose all-gather-based dispatch for the
    dense GShard formulation rather than all-to-all; both are accepted —
    the collective KIND is the partitioner's choice, the sharding is
    ours.)"""
    run8("""
    import jax, dataclasses
    from repro.configs.base import SHAPES
    from repro.configs.registry import ARCHS
    from repro.distributed.plan import plan_for
    from repro.distributed.sharding import use_sharding
    from repro.launch.specs import step_and_specs
    from repro.core import extract as cx

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 8), ('data', 'model'))
    cfg, shape = ARCHS['mixtral-8x7b'], SHAPES['prefill_32k']
    shape = dataclasses.replace(shape, global_batch=8)  # CPU-sized lowering
    plan = plan_for(cfg, shape, tp_size=8).with_(moe_mode='ep')
    with mesh, use_sharding(mesh, plan):
        fn, specs, sh, osh = step_and_specs(cfg, shape, mesh, plan)
        compiled = jax.jit(fn, in_shardings=sh,
                           out_shardings=osh).lower(*specs).compile()
    c = cx.extract_compiled(compiled)
    assert sum(c.collective_bytes.values()) > 0, c.collective_bytes
    assert c.flops > 0
    print('EP collectives:', c.collective_bytes)
    """)
