"""Chaos-path coverage (ISSUE 9): deterministic fault injection,
supervised recovery, and the hardened state paths.

The load-bearing guarantees pinned here:

  * seeded fault schedules are reproducible bit-for-bit;
  * device loss mid-run → elastic replan → checkpoint-restore resume,
    within a bounded step count, with exact global-batch semantics
    (per-step history equals the fault-free reference);
  * an armed-but-EMPTY fault plan runs byte-identical to an
    unsupervised run — zero recovery events, equal histories;
  * corrupt registry / checkpoint / compile-cache files recover
    silently (quarantine + fallback), never surfacing as exceptions;
  * the streaming calibrator quarantines poisoned samples.
"""
import json
import os
import shutil

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.calibration import registry, seeds
from repro.calibration.telemetry import TelemetrySink
from repro.checkpoint import store
from repro.configs.registry import ARCHS
from repro.core import exprops
from repro.core.fit import RLSState
from repro.core.model import FutureSchemaError, LinearCostModel
from repro.core.workload import WorkloadSpec
from repro.data.pipeline import DataConfig
from repro.obs import metrics as obs_metrics
from repro.runtime.faults import (DeviceLossError, Fault, FaultInjector,
                                  FaultPlan, corrupt_checkpoint,
                                  corrupt_file)
from repro.runtime.supervisor import (BackoffPolicy, ServingPolicy,
                                      ServingSupervisor, Supervisor,
                                      Watchdog, WatchdogTimeout)
from repro.runtime.trainer import Trainer, TrainerConfig


# ---------------------------------------------------------------------------
# FaultPlan: determinism, serialization, grammar
# ---------------------------------------------------------------------------


def test_random_plan_reproducible_bit_for_bit():
    p1 = FaultPlan.random(seed=7, n_steps=100)
    p2 = FaultPlan.random(seed=7, n_steps=100)
    assert p1 == p2
    assert p1.to_json_dict() == p2.to_json_dict()
    assert FaultPlan.random(seed=8, n_steps=100) != p1


def test_plan_json_roundtrip(tmp_path):
    p = FaultPlan.random(seed=3, n_steps=50,
                         kinds=("slowdown", "timing_spike",
                                "telemetry_nan", "device_loss"))
    path = str(tmp_path / "plan.json")
    p.save(path)
    assert FaultPlan.load(path) == p
    # parse() accepts a path to a JSON plan too (the CLI contract)
    assert FaultPlan.parse(path) == p


def test_plan_parse_grammar():
    p = FaultPlan.parse(
        "corrupt_registry@7;device_loss@12:count=2;"
        "slowdown@3:factor=8.0,duration=4", seed=5)
    kinds = [f.kind for f in p.faults]
    # canonical ordering: by step, then kind rank
    assert kinds == ["slowdown", "corrupt_registry", "device_loss"]
    loss = p.faults[2]
    assert loss.step == 12 and loss.count == 2
    slow = p.faults[0]
    assert slow.factor == 8.0 and slow.duration == 4
    assert p.seed == 5 and bool(p)
    assert not FaultPlan()


def test_plan_rejects_garbage():
    with pytest.raises(ValueError):
        Fault(kind="explode", step=1)
    with pytest.raises(ValueError):
        Fault(kind="slowdown", step=-1)
    with pytest.raises(ValueError):
        FaultPlan.parse("slowdown")          # missing @step
    with pytest.raises(ValueError):
        Fault(kind="corrupt_registry", step=0, mode="wat")
    with pytest.raises(ValueError):
        Fault(kind="timing_spike", step=1, pool="a100")   # pool= is
        # reserved for fleet-scoped kinds (and pool-tagged device_loss)


def test_plan_parse_pool_grammar():
    p = FaultPlan.parse("pool_shrink@5:pool=a100,k=2;pool_grow@9:pool=v5e",
                        seed=5)
    shrink, grow = p.faults
    assert (shrink.kind, shrink.pool, shrink.count) == \
        ("pool_shrink", "a100", 2)
    assert grow.pool == "v5e" and grow.fleet_scoped
    assert FaultPlan.from_json_dict(p.to_json_dict()) == p


def test_backoff_sequence_deterministic_and_bounded():
    b = BackoffPolicy(base_s=0.05, factor=2.0, max_s=1.0, jitter=0.5,
                      seed=11)
    s1 = b.sequence(8)
    s2 = BackoffPolicy(base_s=0.05, factor=2.0, max_s=1.0, jitter=0.5,
                       seed=11).sequence(8)
    assert s1 == s2
    assert all(0.0 <= d <= 1.0 * 1.5 for d in s1)
    assert BackoffPolicy(seed=12).sequence(8) != s1
    # sequence() is a pure probe: the live generator is not advanced
    assert b.delay(0) == BackoffPolicy(seed=11).delay(0)


# ---------------------------------------------------------------------------
# FaultInjector hooks
# ---------------------------------------------------------------------------


def test_empty_plan_hooks_are_identity():
    inj = FaultInjector(FaultPlan())
    assert not inj.armed()
    inj.step_begin(0)
    inj.decode_begin(3)
    assert inj.perturb_step_time(5, 0.25) == 0.25
    assert inj.perturb_decode_time(5, 0.25) == 0.25
    assert inj.perturb_telemetry(5, 0.25) == 0.25
    assert inj.injected == [] and inj.counts() == {}


def test_timing_faults_are_pure_functions_of_step():
    plan = FaultPlan(faults=(
        Fault("slowdown", 5, factor=4.0, duration=2),
        Fault("timing_spike", 9, factor=16.0)))
    inj = FaultInjector(plan)
    expect = {4: 1.0, 5: 4.0, 6: 4.0, 7: 1.0, 9: 16.0}
    for s, f in expect.items():
        assert inj.perturb_step_time(s, 1.0) == f
    # idempotent by step: a post-recovery replay sees the same values
    for s, f in expect.items():
        assert inj.perturb_step_time(s, 1.0) == f
    assert inj.counts() == {"slowdown": 2, "timing_spike": 1}


def test_device_loss_is_one_shot():
    inj = FaultInjector(FaultPlan(faults=(Fault("device_loss", 2,
                                                count=3),)))
    with pytest.raises(DeviceLossError) as ei:
        inj.step_begin(2)
    assert ei.value.count == 3 and ei.value.step == 2
    inj.step_begin(2)           # replay after resume: does not re-fire
    assert inj.counts() == {"device_loss": 1}


def test_telemetry_poison_at_step():
    inj = FaultInjector(FaultPlan(faults=(
        Fault("telemetry_nan", 4, value=float("inf")),)))
    assert inj.perturb_telemetry(3, 0.5) == 0.5
    assert inj.perturb_telemetry(4, 0.5) == float("inf")
    assert inj.perturb_telemetry(5, 0.5) == 0.5


def test_corrupt_file_modes(tmp_path):
    p = str(tmp_path / "f.json")
    with open(p, "w") as f:
        f.write(json.dumps({"k": list(range(100))}))
    size = os.path.getsize(p)
    assert corrupt_file(p, mode="truncate")
    assert os.path.getsize(p) == size // 2
    assert corrupt_file(p, np.random.default_rng(0), mode="garbage")
    with pytest.raises(ValueError):
        json.load(open(p))
    assert not corrupt_file(str(tmp_path / "missing"), mode="truncate")


# ---------------------------------------------------------------------------
# Hardened registry
# ---------------------------------------------------------------------------


def _chaos_model(name="chaos"):
    m = seeds.ANALYTIC_SEEDS["tpu-v5e"]()
    return LinearCostModel(keys=list(m.keys), weights=m.weights.copy(),
                           device=name, meta={})


def test_registry_falls_back_to_previous_revision(tmp_path):
    d = str(tmp_path)
    m = _chaos_model()
    registry.register_revision(m, d, name="chaos")
    registry.register_revision(m, d, name="chaos")
    path = registry._model_path(d, "chaos")
    before = obs_metrics.REGISTRY.counter(
        "repro_registry_fallbacks_total").value(device="chaos")
    corrupt_file(path, mode="truncate")
    got = registry.load_model("chaos", d)        # must NOT raise
    assert got.meta.get("revision") == 1
    assert os.path.exists(path + ".corrupt")     # quarantined
    assert not os.path.exists(path)
    after = obs_metrics.REGISTRY.counter(
        "repro_registry_fallbacks_total").value(device="chaos")
    assert after == before + 1


def test_registry_corrupt_file_falls_back_to_analytic_seed(tmp_path):
    d = str(tmp_path)
    os.makedirs(d, exist_ok=True)
    with open(registry._model_path(d, "tpu-v5e"), "w") as f:
        f.write("{not json")
    got = registry.load_model("tpu-v5e", d)
    assert got.meta.get("source") == "datasheet-seed"


def test_registry_corrupt_without_fallback_raises_unknown(tmp_path):
    d = str(tmp_path)
    with open(registry._model_path(d, "mystery"), "w") as f:
        f.write("{nope")
    with pytest.raises(registry.UnknownDeviceError):
        registry.load_model("mystery", d)


def test_registry_future_schema_still_raises(tmp_path):
    # a FUTURE schema is a version problem, not corruption: falling back
    # would mask the need to upgrade (the CLI depends on the rc=1 path)
    d = str(tmp_path)
    fut = _chaos_model().to_json_dict()
    fut["schema"] = 99
    os.makedirs(d, exist_ok=True)
    with open(registry._model_path(d, "future"), "w") as f:
        json.dump(fut, f)
    with pytest.raises(FutureSchemaError):
        registry.load_model("future", d)


def test_registry_backups_pruned_and_hidden(tmp_path):
    d = str(tmp_path)
    m = _chaos_model()
    for _ in range(6):
        registry.register_revision(m, d, name="chaos")
    backups = registry._revision_backups(d, "chaos")
    assert len(backups) == registry.KEEP_REVISION_BACKUPS
    listing = registry.list_models(d)
    assert "chaos" in listing
    assert not any(".rev" in name for name in listing)


# ---------------------------------------------------------------------------
# Hardened checkpoint store
# ---------------------------------------------------------------------------


def _tree():
    return {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": np.ones(4, np.float32)}


def test_restore_latest_valid_skips_corrupt_newest(tmp_path):
    ck = str(tmp_path / "ck")
    tree = _tree()
    store.save(ck, 5, tree)
    store.save(ck, 10, tree)
    corrupt_file(os.path.join(ck, "step_00000010", "leaf_00000.npy"),
                 np.random.default_rng(0), mode="garbage")
    out = store.restore_latest_valid(ck, tree)   # must NOT raise
    assert out is not None and out[2] == 5
    np.testing.assert_array_equal(out[0]["a"], tree["a"])
    # the bad checkpoint is quarantined out of latest_step's view
    assert os.path.isdir(os.path.join(ck, "quarantine", "step_00000010"))
    assert store.latest_step(ck) == 5


def test_restore_latest_valid_truncated_manifest(tmp_path):
    ck = str(tmp_path / "ck")
    tree = _tree()
    store.save(ck, 3, tree)
    corrupt_file(os.path.join(ck, "step_00000003", "manifest.json"),
                 mode="truncate")
    assert store.restore_latest_valid(ck, tree) is None
    assert store.restore_latest_valid(str(tmp_path / "none"), tree) is None


def test_restore_error_still_catchable_as_assertion(tmp_path):
    # CheckpointError subclasses AssertionError: pre-hardening callers
    # (and tests) catching the old bare asserts keep working
    ck = str(tmp_path / "ck")
    tree = _tree()
    store.save(ck, 7, tree)
    corrupt_file(os.path.join(ck, "step_00000007", "leaf_00000.npy"),
                 np.random.default_rng(1), mode="garbage")
    with pytest.raises(AssertionError, match="corrupt"):
        store.restore(ck, tree, 7)
    assert issubclass(store.CheckpointError, AssertionError)


def test_corrupt_checkpoint_helper_targets_newest(tmp_path):
    ck = str(tmp_path / "ck")
    store.save(ck, 2, _tree())
    store.save(ck, 4, _tree())
    target = corrupt_checkpoint(ck, mode="truncate")
    assert target is not None and "step_00000004" in target
    assert corrupt_checkpoint(str(tmp_path / "empty")) is None


# ---------------------------------------------------------------------------
# Calibration quarantine (RLS + telemetry sink)
# ---------------------------------------------------------------------------


def test_rls_quarantines_poisoned_samples():
    r = RLSState(["a", "b"])
    assert r.observe({"a": 1.0, "b": 2.0}, float("nan")) is False
    assert r.observe({"a": 1.0, "b": 2.0}, 0.0) is False
    assert r.observe({"a": 1.0, "b": 2.0}, -1.0) is False
    assert r.observe({"a": float("inf"), "b": 2.0}, 1.0) is False
    assert r.n_quarantined == 4 and r.n_samples == 0
    assert r.observe({"a": 1.0, "b": 2.0}, 0.5) is True
    assert r.n_samples == 1
    # strict batch path unchanged
    with pytest.raises(ValueError):
        r.row({"a": 1.0}, -1.0)


def test_telemetry_sink_rejects_nonfinite():
    sink = TelemetrySink(capacity=8)
    assert sink.record({"a": 1.0}, float("inf")) is None
    assert sink.record({"a": 1.0}, float("nan")) is None
    assert sink.record({"a": float("nan")}, 1.0) is None
    assert sink.n_recorded == 0 and sink.n_dropped == 3
    assert sink.record({"a": 1.0}, 0.5) == 0


# ---------------------------------------------------------------------------
# Compile cache: corrupt entries are misses (and get quarantined)
# ---------------------------------------------------------------------------


def test_compile_cache_corrupt_entry_rebuilds(tmp_path, monkeypatch):
    from repro.core.symcount import Var
    monkeypatch.setenv("REPRO_COMPILE_CACHE", str(tmp_path))
    calls = []

    def builder():
        calls.append(1)
        return {"p": Var("x") * 3 + 1}

    key = exprops.program_key("chaos-test-program", "v1")
    exprops.load_or_build(key, builder)
    path = os.path.join(exprops.compile_cache_dir(), f"{key}.json")
    assert os.path.exists(path)
    corrupt_file(path, mode="truncate")
    errors_before = exprops.DISK_STATS["errors"]
    prog = exprops.load_or_build(key, builder)   # must NOT raise
    assert len(calls) == 2                       # treated as a miss
    assert exprops.DISK_STATS["errors"] == errors_before + 1
    model = LinearCostModel.from_dict({"p": 2.0})
    env = {"x": np.arange(1, 3, dtype=np.int64)}
    got = exprops.score_cells(prog, env, 2, model)
    np.testing.assert_allclose(got, 2.0 * (np.arange(1, 3) * 3 + 1))
    # the rebuilt entry is valid again and the corrupt one quarantined
    assert os.path.exists(path)
    assert os.path.exists(path + ".corrupt")
    exprops.load_or_build(key, builder)
    assert len(calls) == 2


# ---------------------------------------------------------------------------
# Watchdog ladder
# ---------------------------------------------------------------------------


def test_watchdog_ladder_and_rescale():
    w = Watchdog(k=3.0, warmup=2)
    for _ in range(5):
        action, _ = w.observe(0.1)
        assert action is None
    assert w.observe(1.0)[0] == "report"
    assert w.observe(1.0)[0] == "rescale"
    assert w.observe(1.0)[0] == "replan"
    assert w.observe(1.0)[0] == "replan"     # stays on the top rung
    w.reset()
    assert w.breaches == 0 and w.n == 0
    k0 = w.k
    assert w.rescale() == k0 * 2.0
    assert Watchdog(k=60.0, max_k=64.0).rescale() == 64.0   # bounded


def test_watchdog_warmup_never_breaches():
    w = Watchdog(k=2.0, warmup=2)
    # the jit-compile first step is enormous; warmup must swallow it
    assert w.observe(60.0)[0] is None
    assert w.observe(0.1)[0] is None


# ---------------------------------------------------------------------------
# Supervised trainer e2e
# ---------------------------------------------------------------------------

_ARCH = "smollm-360m"
_TOTAL = 14


def _trainer_cfgs(ckpt_dir):
    cfg = ARCHS[_ARCH].reduced()
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4,
                    seed=5)
    tc = TrainerConfig(ckpt_dir=ckpt_dir, ckpt_every=5,
                       total_steps=_TOTAL, seed=0, log_every=1000)
    return cfg, dc, tc


@pytest.fixture(scope="module")
def reference_history(tmp_path_factory):
    """The fault-free unsupervised run every chaos run must reproduce."""
    ck = str(tmp_path_factory.mktemp("ref-ckpt"))
    cfg, dc, tc = _trainer_cfgs(ck)
    return Trainer(cfg, dc, tc).train(_TOTAL)


def _fast_backoff():
    return BackoffPolicy(base_s=0.0, factor=2.0, max_s=0.0, jitter=0.0,
                         seed=2)


def test_device_loss_replan_resume_exact(tmp_path, reference_history):
    ck = str(tmp_path / "chaos-ckpt")
    cfg, dc, tc = _trainer_cfgs(ck)
    inj = FaultInjector(FaultPlan(faults=(Fault("device_loss", 9),),
                                  seed=1), ckpt_dir=ck)
    wl = WorkloadSpec(phase="train", global_batch=4, seq_len=64,
                      name="chaos")
    sup = Supervisor(lambda mesh: Trainer(cfg, dc, tc, injector=inj),
                     _TOTAL, cfg=ARCHS[_ARCH], workload=wl, n_devices=8,
                     injector=inj, backoff=_fast_backoff(),
                     sleep=lambda s: None)
    hist = sup.run()

    assert len(sup.recoveries) == 1
    rec = sup.recoveries[0]
    assert rec.cause == "device_loss" and rec.action == "replan"
    assert rec.mttr_s > 0 and sup.mttr_s() == rec.mttr_s
    # power-of-two survivor fallback: 8 - 1 lost -> best mesh over 4
    assert sup.n_devices == 7
    assert sup.mesh is not None
    assert int(np.prod(list(sup.mesh.shape.values()))) == 4
    # bounded recovery: at most one checkpoint interval of replay
    assert sup.steps_run <= _TOTAL + tc.ckpt_every

    # exact global-batch semantics: per-step history matches the
    # fault-free reference (replays collapsed last-write-wins)
    assert [h["step"] for h in hist] == \
        [h["step"] for h in reference_history]
    for h, r in zip(hist, reference_history):
        np.testing.assert_allclose(h["loss"], r["loss"], rtol=1e-5)
        np.testing.assert_allclose(h["grad_norm"], r["grad_norm"],
                                   rtol=1e-4)


def test_empty_plan_supervised_run_is_identical(tmp_path,
                                                reference_history):
    ck = str(tmp_path / "clean-ckpt")
    cfg, dc, tc = _trainer_cfgs(ck)
    inj = FaultInjector(FaultPlan(), ckpt_dir=ck)
    sup = Supervisor(lambda mesh: Trainer(cfg, dc, tc, injector=inj),
                     _TOTAL, injector=inj, sleep=lambda s: None)
    hist = sup.run()
    assert sup.recoveries == [] and sup.steps_run == _TOTAL
    assert inj.injected == []
    # byte-identical step outputs: exact equality, not approx
    assert [(h["step"], h["loss"], h["grad_norm"], h["lr"])
            for h in hist] == \
        [(h["step"], h["loss"], h["grad_norm"], h["lr"])
         for h in reference_history]


def test_corrupt_checkpoint_resume_is_silent(tmp_path, reference_history):
    # corrupt the newest checkpoint mid-run AND lose a device right
    # after: the rebuild must fall back to the older checkpoint without
    # any exception surfacing
    ck = str(tmp_path / "ckpt-chaos")
    cfg, dc, tc = _trainer_cfgs(ck)
    plan = FaultPlan(faults=(Fault("corrupt_checkpoint", 12,
                                   mode="garbage"),
                             Fault("device_loss", 12)), seed=4)
    inj = FaultInjector(plan, ckpt_dir=ck)
    sup = Supervisor(lambda mesh: Trainer(cfg, dc, tc, injector=inj),
                     _TOTAL, injector=inj, backoff=_fast_backoff(),
                     sleep=lambda s: None)
    hist = sup.run()
    assert len(sup.recoveries) == 1
    # step-10 checkpoint was corrupted, so resume fell back to step 5
    assert os.path.isdir(os.path.join(ck, "quarantine", "step_00000010"))
    for h, r in zip(hist, reference_history):
        np.testing.assert_allclose(h["loss"], r["loss"], rtol=1e-5)


def test_recovery_budget_bounds_runaway(tmp_path):
    ck = str(tmp_path / "budget-ckpt")
    cfg, dc, tc = _trainer_cfgs(ck)

    class AlwaysLoses:
        """Injector stub whose every segment dies at its first step."""
        def step_begin(self, step):
            raise DeviceLossError(1, step)

    sup = Supervisor(
        lambda mesh: Trainer(cfg, dc, tc, injector=AlwaysLoses()),
        _TOTAL, backoff=_fast_backoff(), max_recoveries=2,
        sleep=lambda s: None)
    with pytest.raises(RuntimeError, match="recovery budget"):
        sup.run()
    assert len(sup.recoveries) == 2


# ---------------------------------------------------------------------------
# Serving degradation e2e
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def serve_setup():
    from repro.models import transformer
    cfg = ARCHS[_ARCH].reduced()
    params, _ = transformer.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _submit(server, cfg, n, max_new=8):
    from repro.runtime.server import Request
    rng = np.random.default_rng(0)
    for rid in range(n):
        prompt = rng.integers(2, cfg.vocab_size, size=6).astype(np.int32)
        server.submit(Request(rid=rid, prompt=prompt, max_new=max_new))


def test_serving_evicts_sheds_and_completes(serve_setup):
    from repro.runtime.server import DecodeServer
    cfg, params = serve_setup
    # a long, enormous slowdown window: consecutive watchdog breaches
    # must evict, throttle, and still finish every non-shed request
    plan = FaultPlan(faults=(Fault("slowdown", 3, factor=1e5,
                                   duration=40),))
    inj = FaultInjector(plan)
    srv = DecodeServer(cfg, params, slots=2, max_len=128, seed=0,
                       injector=inj)
    _submit(srv, cfg, 6)
    sup = ServingSupervisor(srv, ServingPolicy(watchdog_k=4.0,
                                               max_queue=3),
                            injector=inj)
    done = sup.run(max_iters=500)
    assert sup.evictions >= 1
    assert len(sup.shed) >= 1
    for r in sup.shed:
        assert r.shed and r.retry_after_s == 1.0
    # every completed request got its full token budget — including any
    # that were evicted and re-admitted mid-stream
    assert all(len(r.out) == 8 and not r.shed for r in done)
    assert len(done) + len(sup.shed) == 6


def test_serving_clean_run_no_degradation(serve_setup):
    from repro.runtime.server import DecodeServer
    cfg, params = serve_setup
    srv = DecodeServer(cfg, params, slots=2, max_len=128, seed=0,
                       injector=FaultInjector(FaultPlan()))
    _submit(srv, cfg, 4)
    sup = ServingSupervisor(srv, ServingPolicy(watchdog_k=50.0))
    done = sup.run(max_iters=500)
    assert len(done) == 4 and sup.evictions == 0 and sup.shed == []


def test_evicted_request_resumes_from_prefix(serve_setup):
    from repro.runtime.server import DecodeServer, Request
    cfg, params = serve_setup
    srv = DecodeServer(cfg, params, slots=1, max_len=128, seed=0)
    req = Request(rid=0, prompt=np.asarray([5, 6, 7], np.int32),
                  max_new=6)
    srv.submit(req)
    srv._refill()
    srv.step()
    srv.step()
    produced = list(req.out)
    assert len(produced) == 2
    evicted = srv.evict_slot(0)
    assert evicted is req and req.evictions == 1
    assert srv.queue[0] is req and srv.active[0] is None
    srv._refill()                      # re-admit: prefix is replayed
    assert srv.remaining[0] == 4       # owes only the missing tokens
    while not req.done:
        srv.step()
    assert req.out[:2] == produced and len(req.out) <= 6


def test_simulate_serving_seeded_noise_deterministic(serve_setup):
    from repro.runtime.server import simulate_serving
    cfg, _ = serve_setup
    kw = dict(slots=2, policy="model")
    a = simulate_serving(cfg, [8, 16, 4, 12], seed=3, noise=0.2, **kw)
    b = simulate_serving(cfg, [8, 16, 4, 12], seed=3, noise=0.2, **kw)
    c = simulate_serving(cfg, [8, 16, 4, 12], seed=4, noise=0.2, **kw)
    assert a == b
    assert a["makespan_s"] != c["makespan_s"]
    # default (noise=0) stays the exact predicted-time replay
    d1 = simulate_serving(cfg, [8, 16, 4, 12], **kw)
    d2 = simulate_serving(cfg, [8, 16, 4, 12], seed=9, **kw)
    assert d1 == d2 and d1["n_done"] == 4
