"""WorkloadSpec tests: the one workload currency across predictor,
planspace, autotuner, trainer and server.

Four pillars:
  * golden pins — ``WorkloadSpec(phase="train")`` predictions are
    bit-identical (rtol 1e-12) to the pre-refactor outputs captured in
    ``tests/golden/workload_train.json`` for every registry arch;
  * phase physics — decode compute follows tokens-not-sequence, cache
    reads scale linearly in context (``CT``), speculative length (``SL``)
    multiplies throughput, prefill writes the KV cache;
  * deprecation — bare ``kind=`` strings still work but warn;
  * the payoff — model-guided admission beats FIFO under the model's own
    physics (``runtime/server.py``), and phase-tagged telemetry keeps
    refit windows pure.
"""
from __future__ import annotations

import json
import os
import warnings

import numpy as np
import pytest

from repro.configs.base import SHAPES, ShapeConfig
from repro.configs.registry import ARCHS
from repro.core import archcount, planspace, predictor
from repro.core import properties as props
from repro.core import workload as wl
from repro.core.workload import WorkloadSpec
from repro.launch.autoshard import candidate_plans
from repro.distributed.plan import plan_for

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "workload_train.json")
MESH = {"data": 16, "model": 16}


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


# ---------------------------------------------------------------------------
# spec basics
# ---------------------------------------------------------------------------


def test_spec_phase_validation_and_kind_alias():
    s = WorkloadSpec(phase="decode", global_batch=8, seq_len=512)
    assert s.kind == "decode" and s.tokens == 8 * 512
    with pytest.raises(ValueError, match="unknown phase"):
        WorkloadSpec(phase="serve")
    with pytest.raises(TypeError):
        wl.as_spec(42)


def test_structure_flags_only_when_refined():
    assert WorkloadSpec(phase="decode").structure() == ("decode",)
    assert WorkloadSpec(phase="train", spec_len=3).structure() == ("train",)
    s = WorkloadSpec(phase="decode", cache_tokens=0.0, active_slots=0,
                     spec_len=2, moe_imbalance=1.5)
    assert s.structure() == ("decode", "ct", "as", "sl", "mi")
    # the unrefined structure keys the PRE-spec disk cache entries
    assert predictor._structure_key(wl.TRAIN_4K) == "train"
    assert predictor._structure_key(s) == ("decode", "ct", "as", "sl", "mi")


def test_env_defaults_fill_neutral_values():
    cfg = ARCHS["glm4-9b"]
    s = WorkloadSpec(phase="decode", global_batch=4, seq_len=1024)
    e = s.env(cfg)
    ctx = min(1024, cfg.sliding_window) if cfg.sliding_window else 1024
    assert e["AS"] == 4 and e["CT"] == 4 * ctx
    assert e["SL"] == 1 and e["MI"] == 1.0
    assert WorkloadSpec(phase="train", global_batch=2).env() == \
        {"B": 2, "S": 1, "M": 1}


def test_as_spec_shapeconfig_is_silent_string_warns():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        s = wl.as_spec(SHAPES["prefill_32k"])
    assert s.phase == "prefill" and s.name == "prefill_32k"
    with pytest.warns(DeprecationWarning, match="kind='decode' strings"):
        assert wl.as_spec("decode").phase == "decode"


# ---------------------------------------------------------------------------
# golden pins: spec-routed train predictions are bit-identical
# ---------------------------------------------------------------------------


def test_golden_predict_step_bit_identical(golden):
    for arch, g in golden.items():
        cfg = ARCHS[arch]
        plan = plan_for(cfg, wl.TRAIN_4K)
        pred = predictor.predict_step(cfg, wl.TRAIN_4K, plan, MESH)
        np.testing.assert_allclose(pred.seconds, g["predict_step_seconds"],
                                   rtol=1e-12, err_msg=arch)
        for k, v in g["predict_step_terms"].items():
            np.testing.assert_allclose(pred.terms[k], v, rtol=1e-12,
                                       err_msg=f"{arch}:{k}")


def test_golden_predict_plans_bit_identical(golden):
    for arch, g in golden.items():
        cfg = ARCHS[arch]
        plans = candidate_plans(cfg, wl.TRAIN_4K)[:24]
        assert len(plans) == g["n_plans"]
        secs = predictor.predict_plans(cfg, wl.TRAIN_4K, plans, MESH)
        np.testing.assert_allclose(secs, g["predict_plans"], rtol=1e-12,
                                   err_msg=arch)


def test_golden_planspace_scores_bit_identical(golden):
    meshes = planspace.mesh_factorizations(64)
    for arch, g in golden.items():
        cfg = ARCHS[arch]
        plans = candidate_plans(cfg, wl.TRAIN_4K)[:8]
        space = planspace.PlanSpace.from_product(cfg, wl.TRAIN_4K, plans,
                                                 meshes)
        np.testing.assert_allclose(space.scores(None),
                                   g["planspace_scores_64dev"],
                                   rtol=1e-12, err_msg=arch)


def test_spec_equals_shape_and_legacy_string_all_phases():
    cfg = ARCHS["glm4-9b"]
    for shape_name in ("train_4k", "prefill_32k", "decode_32k"):
        shape = SHAPES[shape_name]
        plan = plan_for(cfg, shape)
        via_shape = predictor.predict_step(cfg, shape, plan, MESH).seconds
        via_spec = predictor.predict_step(cfg, wl.from_shape(shape), plan,
                                          MESH).seconds
        assert via_spec == via_shape
        env = {"B": shape.global_batch, "S": shape.seq_len, "M": 1}
        spec_cv = predictor.step_vector_fn(cfg, wl.from_shape(shape))
        with pytest.warns(DeprecationWarning):
            str_cv = predictor.step_vector_fn(cfg, shape.kind)
        a, b = spec_cv(env), str_cv(env)
        assert set(a) == set(b)
        for k in a:
            np.testing.assert_allclose(float(a[k]), float(b[k]), rtol=0,
                                       err_msg=f"{shape_name}:{k}")


# ---------------------------------------------------------------------------
# decode / prefill physics
# ---------------------------------------------------------------------------


def _mxu_key(cfg):
    return props.mxu_key(16 if "16" in cfg.compute_dtype else 32)


def test_decode_compute_counts_tokens_not_sequence():
    """At fixed context load (CT pinned) decode mxu work is per-token: it
    must not grow with the allocated cache capacity S."""
    cfg = ARCHS["llama3.2-3b"]
    spec = WorkloadSpec(phase="decode", global_batch=8, seq_len=1024,
                        cache_tokens=8 * 1024.0)
    cv = predictor.step_vector_fn(cfg, spec)
    k = _mxu_key(cfg)
    base = {"B": 8, "M": 1, "CT": 8 * 1024.0}
    a = float(cv({**base, "S": 1024})[k])
    b = float(cv({**base, "S": 65536})[k])
    assert a == b > 0


def test_decode_cache_read_bytes_linear_in_context():
    cfg = ARCHS["llama3.2-3b"]
    spec = WorkloadSpec(phase="decode", global_batch=8, seq_len=4096,
                        cache_tokens=1.0)
    cv = predictor.step_vector_fn(cfg, spec)
    lk = props.mem_key("load", 16, "s1")
    env = {"B": 8, "S": 4096, "M": 1}
    l1 = float(cv({**env, "CT": 8 * 1024.0})[lk])
    l2 = float(cv({**env, "CT": 16 * 1024.0})[lk])
    l3 = float(cv({**env, "CT": 24 * 1024.0})[lk])
    assert l2 - l1 == pytest.approx(l3 - l2, rel=1e-12)
    assert l2 > l1   # more context = more cache bytes streamed


def test_decode_speculative_length_multiplies_compute():
    cfg = ARCHS["llama3.2-3b"]
    base = WorkloadSpec(phase="decode", global_batch=8, seq_len=1024,
                        cache_tokens=8 * 1024.0)
    spec = base.with_(spec_len=2)
    k = _mxu_key(cfg)
    env = {"B": 8, "S": 1024, "M": 1, "CT": 8 * 1024.0}
    m1 = float(predictor.step_vector_fn(cfg, base)(env)[k])
    m2 = float(predictor.step_vector_fn(cfg, spec)({**env, "SL": 2})[k])
    assert m2 == pytest.approx(2 * m1, rel=1e-12)


def test_decode_default_spec_matches_neutral_refinements():
    """A fully-refined program evaluated at the neutral point (every slot
    occupied, full context, SL=1, MI=1) equals the default program."""
    cfg = ARCHS["glm4-9b"]
    shape = SHAPES["decode_32k"]
    spec0 = wl.from_shape(shape)
    spec1 = spec0.with_(active_slots=0, cache_tokens=0.0, spec_len=2,
                        moe_imbalance=2.0)
    env = spec0.env(cfg)
    env["M"] = 1
    cv0 = predictor.step_vector_fn(cfg, spec0)
    cv1 = predictor.step_vector_fn(cfg, spec1)
    a = cv0(env)
    b = cv1({**env, "SL": 1, "MI": 1.0})
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_allclose(float(a[k]), float(b[k]), rtol=1e-9,
                                   err_msg=k)


def test_prefill_writes_kv_cache():
    cfg = ARCHS["llama3.2-3b"]      # dense: cache = KV rows exactly
    env = {"B": 4, "S": 2048, "M": 1}
    from repro.core.symcount import as_expr
    sk = props.mem_key("store", 16, "s1")
    pf = as_expr(archcount.prefill_counts(cfg).pv[sk]).eval(env)
    fwd = as_expr(archcount.forward_counts(cfg)[sk]).eval(env)
    kv_rows = 4 * 2048 * 2 * cfg.n_kv_heads * cfg.head_dim_ * cfg.n_layers
    assert pf - fwd == pytest.approx(kv_rows, rel=1e-12)


def test_moe_imbalance_scales_decode_expert_compute_only():
    cfg = ARCHS["mixtral-8x7b"]
    base = WorkloadSpec(phase="decode", global_batch=8, seq_len=1024)
    hot = base.with_(moe_imbalance=2.0)
    k = _mxu_key(cfg)
    env = base.env(cfg)
    env["M"] = 1
    m1 = float(predictor.step_vector_fn(cfg, base)(env)[k])
    m2 = float(predictor.step_vector_fn(cfg, hot)({**env, "MI": 2.0})[k])
    assert m1 < m2 < 2 * m1   # experts scale, attention/head do not
    # train formulas never carry MI (GShard capacity padding)
    t = WorkloadSpec(phase="train", moe_imbalance=2.0)
    assert t.structure() == ("train",)


# ---------------------------------------------------------------------------
# the payoff: model-scored admission beats FIFO
# ---------------------------------------------------------------------------


def test_model_admission_beats_fifo_on_mixed_prompts():
    from repro.runtime.server import AdmissionScorer, simulate_serving
    cfg = ARCHS["glm4-9b"]
    scorer = AdmissionScorer(cfg, slots=4, max_len=4096)
    lens = [2048, 1024] + [16] * 8        # adversarial arrival for FIFO
    m = simulate_serving(cfg, lens, 32, slots=4, max_len=4096,
                         policy="model", scorer=scorer)
    f = simulate_serving(cfg, lens, 32, slots=4, max_len=4096,
                         policy="fifo", scorer=scorer)
    assert m["n_done"] == f["n_done"] == len(lens)
    assert m["mean_latency_s"] < f["mean_latency_s"]
    # model policy defers the long prompts; FIFO admits them first
    assert f["order"][:2] == [0, 1] and m["order"][-2:] == [1, 0]


def test_admission_scorer_sweeps_occupancy_as_arrays():
    from repro.runtime.server import AdmissionScorer
    cfg = ARCHS["glm4-9b"]
    sc = AdmissionScorer(cfg, slots=8, max_len=2048)
    secs = sc.decode_step_seconds(np.arange(1, 9),
                                  np.arange(1, 9) * 512.0)
    assert secs.shape == (8,)
    assert np.all(np.diff(secs) > 0)      # more occupancy = slower step
    pf = sc.prefill_seconds([64, 512, 2048])
    assert pf[0] < pf[1] < pf[2]


def test_admission_print_line_and_slo_defer(capsys):
    import jax
    from repro.models import transformer
    from repro.runtime.server import DecodeServer, Request
    cfg = ARCHS["glm4-9b"].reduced()
    params, _ = transformer.init_params(cfg, jax.random.PRNGKey(0))
    srv = DecodeServer(cfg, params, slots=2, max_len=64, seed=0,
                       admission="model")
    rng = np.random.default_rng(0)
    for rid, plen in enumerate([12, 3]):
        srv.submit(Request(rid=rid, prompt=rng.integers(
            2, cfg.vocab_size, plen).astype(np.int32), max_new=2))
    done = srv.run()
    assert len(done) == 2
    out = capsys.readouterr().out
    # the short prompt admits first, each line carries the model scores
    lines = [l for l in out.splitlines() if l.startswith("[admit]")]
    assert len(lines) == 2 and "policy=model" in lines[0]
    assert "rid=1" in lines[0] and "rid=0" in lines[1]
    # an impossible decode SLO defers admission while slots are busy
    srv2 = DecodeServer(cfg, params, slots=2, max_len=64, seed=0,
                        admission="model", slo_decode_s=0.0)
    srv2.submit(Request(rid=0, prompt=np.asarray([3, 4], np.int32),
                        max_new=2))
    srv2.submit(Request(rid=1, prompt=np.asarray([5, 6], np.int32),
                        max_new=2))
    srv2._refill()
    assert srv2._n_active() == 1 and len(srv2.queue) == 1


# ---------------------------------------------------------------------------
# phase-tagged telemetry
# ---------------------------------------------------------------------------


def test_pv_fingerprint_phase_sensitive():
    from repro.calibration.telemetry import pv_fingerprint
    pv = {"mxu:16": 1.0}
    assert pv_fingerprint(pv) == pv_fingerprint(pv)
    assert pv_fingerprint(pv, "train") != pv_fingerprint(pv, "decode")
    assert pv_fingerprint(pv, "train") != pv_fingerprint(pv)


def test_sink_phase_filter_and_schema1_migration():
    from repro.calibration.telemetry import TelemetrySink, pv_fingerprint
    sink = TelemetrySink()
    sink.record({"x": 1.0}, 0.1, phase="train")
    sink.record({"x": 1.0}, 0.2, phase="decode")
    sink.record({"x": 1.0}, 0.3)           # default phase is train
    assert [s.seconds for s in sink.samples(phase="train")] == [0.1, 0.3]
    assert [s.seconds for s in sink.samples(phase="decode")] == [0.2]
    assert sink.stats()["n_unique_pvs"] == 2    # phase keys the pv table
    back = TelemetrySink.from_json_dict(sink.to_json_dict())
    assert [s.phase for s in back.samples()] == ["train", "decode", "train"]
    # schema-1 rows (no phase column) load as phase="train"
    fp = pv_fingerprint({"x": 1.0})
    legacy = {"schema": 1, "kind": "telemetry", "capacity": 8,
              "n_recorded": 1, "n_dropped": 0, "pvs": {fp: {"x": 1.0}},
              "samples": [[0, fp, 0.5, 7, "train"]]}
    mig = TelemetrySink.from_json_dict(legacy)
    s, = mig.samples()
    assert s.phase == "train" and s.seconds == 0.5 and s.step == 7


def test_phase_scoped_calibrator_ignores_other_phases():
    from repro.calibration.online import OnlineCalibrator
    cal = OnlineCalibrator(None, device="t", phase="train", warmup=0)
    pv = {"mxu:16": 1e12, "const1": 1.0}
    for i in range(8):
        cal.observe(pv, 0.01, step=i, phase="train")
    n = cal.rls.n_samples
    cal.observe(pv, 5.0, step=9, phase="decode")   # wild outlier, off-phase
    assert cal.rls.n_samples == n                  # never reached the fit
    assert cal.drift.evidence == 0.0
    assert len(cal.sink.samples(phase="decode")) == 1   # but was buffered


def test_refit_window_filters_by_event_phase():
    from repro.calibration.online import OnlineCalibrator
    cal = OnlineCalibrator(None, device="t", warmup=2, min_refit_samples=2,
                           drift=None)
    cal.drift.slack, cal.drift.threshold = 0.05, 1.0
    pv_t = {"mxu:16": 1e12, "const1": 1.0}
    pv_d = {"load:16:s1": 1e9, "const1": 1.0}
    for i in range(6):
        cal.observe(pv_t, 0.01, step=i, phase="train")
        cal.observe(pv_d, 0.002, step=i, phase="decode")
    # drive a slowdown in the TRAIN stream only
    ev = None
    for i in range(6, 40):
        ev = ev or cal.observe(pv_t, 0.05, step=i, phase="train")
        cal.observe(pv_d, 0.002, step=i, phase="decode")
    assert ev is not None and ev.phase == "train"
    # the refit window must have been pure train rows
    pvs, _ = cal.sink.window(since_seq=ev.onset_seq, phase="train")
    assert all("mxu:16" in p for p in pvs)
    assert cal.refits >= 1
    assert cal.model.meta["refit_onset_seq"] >= 0


# ---------------------------------------------------------------------------
# launch-layer plumbing
# ---------------------------------------------------------------------------


def test_phase_cell_matches_legacy_wrappers():
    import jax
    from jax.sharding import Mesh
    from repro.launch import specs
    cfg = ARCHS["llama3.2-3b"].reduced()
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    plan = plan_for(cfg, SHAPES["train_4k"])
    for wrapper, phase, n_args in ((specs.train_cell, "train", 2),
                                   (specs.prefill_cell, "prefill", 2),
                                   (specs.decode_cell, "decode", 4)):
        fn, arg_specs, in_sh, out_sh = wrapper(
            cfg, SHAPES["train_4k"], mesh, plan)
        assert callable(fn) and len(arg_specs) == n_args
        spec = wl.as_spec(SHAPES["train_4k"]).with_(phase=phase)
        fn2, arg_specs2, in_sh2, _ = specs.phase_cell(cfg, spec, mesh, plan)
        assert jax.tree.structure(arg_specs) == jax.tree.structure(arg_specs2)
        assert jax.tree.structure(in_sh) == jax.tree.structure(in_sh2)


def test_make_step_dispatches_on_phase():
    from repro.runtime import steps
    cfg = ARCHS["llama3.2-3b"].reduced()
    assert steps.make_step(cfg, wl.TRAIN_4K).__name__ == "train_step"
    assert steps.make_step(cfg, wl.PREFILL_32K).__name__ == "prefill_step"
    assert steps.make_step(cfg, wl.DECODE_32K).__name__ == "serve_step"
    with pytest.warns(DeprecationWarning):
        assert steps.make_step(cfg, "decode").__name__ == "serve_step"


def test_elastic_replan_accepts_spec():
    from repro.distributed import elastic
    cfg = ARCHS["glm4-9b"]
    a = elastic.replan(cfg, SHAPES["train_4k"], 16)
    b = elastic.replan(cfg, wl.TRAIN_4K, 16)
    assert [o.predicted_step_s for o in a] == \
        [o.predicted_step_s for o in b]
    assert a and a[0].shape == b[0].shape


def test_autotune_workload_kernel_shapes_decode_occupancy():
    from repro.kernels import autotune
    cfg = ARCHS["llama3.2-3b"]
    full = WorkloadSpec(phase="decode", global_batch=16, seq_len=1024)
    half = full.with_(active_slots=8)
    sh_full = autotune.workload_kernel_shapes(cfg, full)
    sh_half = autotune.workload_kernel_shapes(cfg, half)
    assert "flash_attention" not in sh_full      # decode streams the cache
    assert sh_full["matmul"]["M"] == 2 * sh_half["matmul"]["M"]
