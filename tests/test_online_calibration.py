"""Online calibration & drift watch — the telemetry-driven test harness.

Covers the streaming subsystem end to end: RLS ≡ batch ``fit_relative``
(the exactness property), telemetry ring-buffer semantics, CUSUM drift
detection bounds (including the no-false-positive property), the full
drift-injection scenario (detect → refit → registry revision bump → cache
invalidation → fused ≡ loop coherence), the learned residual head, the
calibration CLI round-trip, and the inf-safe fit diagnostics.
"""
from __future__ import annotations

import json
import math
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(__file__))
from _hypothesis_compat import given, settings, st  # noqa: E402

from repro.calibration import registry
from repro.calibration.online import DriftMonitor, OnlineCalibrator
from repro.calibration.registry import register_revision
from repro.calibration.telemetry import (TelemetrySink, pv_fingerprint)
from repro.core import exprops, fit, predictor
from repro.core.model import (SCHEMA_VERSION, LinearCostModel, geomean)


@pytest.fixture(autouse=True)
def _isolated_registry(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_MODEL_REGISTRY", str(tmp_path / "ambient-reg"))


def _geo_rel_err(model, pvs, times):
    errs = fit.safe_relative_errors(model.predict_many(list(pvs)), times)
    finite = errs[np.isfinite(errs)]
    return geomean(finite) if len(finite) else float("inf")


# ---------------------------------------------------------------------------
# RLS ≡ batch fit_relative
# ---------------------------------------------------------------------------


def _synthetic_stream(rng, n, keys, w_true, noise=0.1):
    pvs, times = [], []
    for _ in range(n):
        pv = {k: float(v) for k, v in zip(keys, rng.uniform(0.1, 10.0,
                                                            len(keys)))}
        t = float(sum(w * pv[k] for w, k in zip(w_true, keys)))
        t *= float(np.exp(noise * rng.standard_normal()))
        pvs.append(pv)
        times.append(t)
    return pvs, times


def test_rls_forgetting_one_equals_batch_fit_seeded():
    rng = np.random.default_rng(7)
    keys = ["a", "b", "c", "d"]
    pvs, times = _synthetic_stream(rng, 64, keys,
                                   np.array([0.5, 2.0, 1.0, 3.0]))
    batch = fit.fit_relative(pvs, times, keys=keys)
    rls = fit.RLSState.init(keys, lam=1.0, delta=1e12)
    rls.observe_many(pvs, times)
    np.testing.assert_allclose(rls.w, batch.weights, rtol=1e-7, atol=1e-10)
    # and the materialized model predicts identically to its weights
    m = rls.model(device="rls-test")
    for pv in pvs[:5]:
        assert m.predict(pv) == pytest.approx(rls.predict(pv), rel=1e-12)
    assert m.meta["n_samples"] == 64 and m.meta["forgetting"] == 1.0


@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=8, max_value=40),
       st.integers(min_value=2, max_value=5))
@settings(max_examples=20, deadline=None)
def test_rls_forgetting_one_equals_batch_fit_property(seed, n, k):
    rng = np.random.default_rng(seed)
    keys = [f"p{i}" for i in range(k)]
    w_true = rng.uniform(0.5, 3.0, size=k)
    pvs, times = _synthetic_stream(rng, n, keys, w_true)
    batch = fit.fit_relative(pvs, times, keys=keys)
    rls = fit.RLSState.init(keys, lam=1.0, delta=1e12)
    rls.observe_many(pvs, times)
    np.testing.assert_allclose(rls.w, batch.weights, rtol=1e-7, atol=1e-10)


def test_rls_warm_start_anchors_unobserved_directions():
    # a rank-1 stream (one pv repeated) must leave the unexercised weights
    # at the prior instead of collapsing them to zero
    prior = LinearCostModel(keys=["x", "y"], weights=np.array([2.0, 5.0]),
                            device="warm")
    rls = fit.RLSState.from_model(prior, lam=1.0, delta=1e12)
    for _ in range(10):
        rls.observe({"x": 4.0}, 8.0)          # consistent with w_x = 2.0
    np.testing.assert_allclose(rls.w, [2.0, 5.0], rtol=1e-6)


def test_rls_forgetting_tracks_drift_better_than_batch(make_drift_stream):
    s = make_drift_stream(n_pre=150, n_post=150, shift=1.5, noise=0.02,
                          seed=3)
    flat = fit.RLSState.init(s.keys, lam=1.0)
    windowed = fit.RLSState.init(s.keys, lam=0.97)
    flat.observe_many(s.pvs, s.times)
    windowed.observe_many(s.pvs, s.times)
    post = slice(s.shift_index, None)
    err = lambda r: np.mean([abs(r.predict(pv) - t) / t for pv, t in
                             zip(s.pvs[post], s.times[post])])
    assert err(windowed) < err(flat)


def test_rls_validates_inputs():
    with pytest.raises(ValueError, match="forgetting"):
        fit.RLSState.init(["a"], lam=0.0)
    rls = fit.RLSState.init(["a"])
    # the strict row constructor still raises...
    with pytest.raises(ValueError, match="non-positive"):
        rls.row({"a": 1.0}, 0.0)
    # ...but the streaming path QUARANTINES a poisoned sample instead of
    # letting one clock glitch kill a live calibrator (tests/test_faults.py
    # covers the full quarantine contract)
    assert rls.observe({"a": 1.0}, 0.0) is False
    assert rls.n_quarantined == 1 and rls.n_samples == 0


def test_refit_strictly_reduces_windowed_error_on_drift(make_drift_stream):
    s = make_drift_stream(n_pre=100, n_post=60, shift=1.6, noise=0.03,
                          seed=11)
    pre = fit.fit_relative(s.pvs[:s.shift_index], s.times[:s.shift_index],
                           keys=s.keys)
    post_pvs = s.pvs[s.shift_index:]
    post_times = s.times[s.shift_index:]
    refit = fit.RLSState.from_model(pre, lam=1.0)
    refit.observe_many(post_pvs, post_times)
    old_err = _geo_rel_err(pre, post_pvs, post_times)
    new_err = _geo_rel_err(refit.model(), post_pvs, post_times)
    assert new_err < old_err          # strictly better on the drifted window
    assert old_err > 0.3              # the 1.6× drift really was visible
    assert new_err < 0.05


# ---------------------------------------------------------------------------
# telemetry sink
# ---------------------------------------------------------------------------


def test_pv_fingerprint_ignores_zero_entries():
    assert pv_fingerprint({"a": 1.0, "b": 0.0}) == pv_fingerprint({"a": 1.0})
    assert pv_fingerprint({"a": 1.0}) != pv_fingerprint({"a": 2.0})


def test_sink_dedups_vectors_and_evicts_with_gc():
    sink = TelemetrySink(capacity=4)
    pv_a, pv_b = {"x": 1.0}, {"x": 2.0}
    for i in range(3):
        sink.record(pv_a, 0.1, step=i, tag="train")
    assert sink.stats()["n_unique_pvs"] == 1
    for i in range(4):                 # evicts all pv_a samples
        sink.record(pv_b, 0.2, step=i)
    st_ = sink.stats()
    assert len(sink) == 4 and st_["n_recorded"] == 7
    assert st_["n_unique_pvs"] == 1    # pv_a garbage-collected
    with pytest.raises(KeyError):
        sink.pv(pv_fingerprint(pv_a))


def test_sink_drops_non_positive_timings():
    sink = TelemetrySink()
    assert sink.record({"x": 1.0}, 0.0) is None
    assert sink.record({"x": 1.0}, -1.0) is None
    assert sink.record({"x": 1.0}, 1e-9) == 0
    assert sink.stats()["n_dropped"] == 2


def test_sink_windows_filter_by_seq_and_tag():
    sink = TelemetrySink()
    for i in range(6):
        sink.record({"x": float(i + 1)}, float(i + 1),
                    tag="train" if i % 2 == 0 else "decode")
    pvs, times = sink.window(since_seq=3)
    assert times == [4.0, 5.0, 6.0]
    pvs, times = sink.window(tag="decode")
    assert times == [2.0, 4.0, 6.0]
    pvs, times = sink.window(n=2)
    assert times == [5.0, 6.0] and pvs[-1] == {"x": 6.0}


def test_sink_json_roundtrip(tmp_path):
    sink = TelemetrySink(capacity=8)
    for i in range(5):
        sink.record({"mxu:16": float(i + 1), "const1": 1.0},
                    0.01 * (i + 1), step=i, tag="train")
    sink.record({"x": 1.0}, -1.0)      # counted drop
    path = str(tmp_path / "telemetry.json")
    sink.save(path)
    back = TelemetrySink.load(path)
    assert back.stats() == sink.stats()
    assert back.window() == sink.window()
    assert [s.seq for s in back.samples()] == [s.seq for s in sink.samples()]
    with open(path) as f:
        d = json.load(f)
    assert d["kind"] == "telemetry" and d["schema"] == 2
    with pytest.raises(ValueError, match="not a telemetry record"):
        TelemetrySink.from_json_dict({"kind": "nope"})


# ---------------------------------------------------------------------------
# drift detection
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shift", [1.2, 1.5, 2.0])
def test_drift_monitor_flags_within_bounded_samples(shift):
    mon = DriftMonitor(slack=0.1, threshold=3.0)
    resid = shift - 1.0
    bound = math.ceil(mon.threshold / (resid - mon.slack)) + 2
    ev = None
    for i in range(bound):
        ev = mon.observe(i, resid, step=i)
        if ev is not None:
            break
    assert ev is not None, f"{shift}x drift not flagged within {bound}"
    assert ev.direction == "slow" and ev.onset_seq == 0


def test_drift_monitor_onset_is_change_point_estimate():
    mon = DriftMonitor(slack=0.1, threshold=2.0)
    ev = None
    for i in range(200):
        ev = mon.observe(i, 0.0 if i < 50 else 0.6, step=i)
        if ev is not None:
            break
    assert ev is not None and ev.onset_seq == 50 and ev.step == ev.seq
    assert mon.evidence == 0.0         # state reset after the event


def test_drift_monitor_detects_speedups_too():
    mon = DriftMonitor(slack=0.1, threshold=2.0)
    ev = None
    for i in range(100):
        ev = mon.observe(i, -0.4)
        if ev is not None:
            break
    assert ev is not None and ev.direction == "fast"


def test_drift_monitor_quiet_under_pure_noise():
    rng = np.random.default_rng(0)
    mon = DriftMonitor()               # default slack 0.15
    for i in range(2000):
        assert mon.observe(i, float(0.05 * rng.standard_normal())) is None
    assert mon.status == "ok" and not mon.events


def test_calibrator_no_false_positive_under_noise(make_drift_stream):
    s = make_drift_stream(n_pre=400, n_post=0, shift=1.0, noise=0.05,
                          seed=21)
    truth = LinearCostModel(keys=s.keys, weights=s.weights, device="truth")
    cal = OnlineCalibrator(truth, device="noise-dev")
    for i, (pv, t) in enumerate(zip(s.pvs, s.times)):
        assert cal.observe(pv, t, step=i) is None
    assert cal.refits == 0 and cal.drift.status == "ok" and not cal.events


# ---------------------------------------------------------------------------
# end-to-end drift injection: detect -> refit -> registry -> caches coherent
# ---------------------------------------------------------------------------


def test_drift_injection_end_to_end(tmp_path, make_drift_stream):
    s = make_drift_stream(n_pre=120, n_post=80, shift=1.5, noise=0.02,
                          seed=5)
    truth = LinearCostModel(keys=s.keys, weights=s.weights, device="truth",
                            meta={"source": "synthetic"})
    cache = exprops.BasisCache(maxsize=256)
    cal = OnlineCalibrator(truth, device="drift-dev",
                           registry_dir=str(tmp_path), auto_register=True,
                           caches=[cache])
    events = []
    for i, (pv, t) in enumerate(zip(s.pvs, s.times)):
        ev = cal.observe(pv, t, step=i)
        if ev is not None:
            events.append(ev)

    # detected once, within a bounded window after the injected shift
    assert len(events) == 1 == len(cal.events) == cal.refits
    ev = events[0]
    assert ev.direction == "slow"
    assert s.shift_index <= ev.seq <= s.shift_index + 60
    # the CUSUM's change-point estimate lands on the injected shift
    assert abs(ev.onset_seq - s.shift_index) <= 3

    # registry revision bumped exactly once; the refit model round-trips
    assert cal.revision == 1
    loaded = registry.load_model("drift-dev", str(tmp_path))
    assert loaded.meta["revision"] == 1
    assert loaded.meta["refit_epoch"] == 1
    np.testing.assert_array_equal(loaded.weights, cal.model.weights)

    # refit swapped in a NEW model object (fold caches key on identity)
    # and its predictions track the 1.5x-shifted regime
    assert cal.model is not truth
    np.testing.assert_allclose(
        cal.model.predict_many(s.pvs[s.shift_index:]),
        np.asarray(s.times[s.shift_index:]), rtol=0.1)

    # stale basis-cache entries were invalidated
    assert cache.invalidations == 1

    # post-refit windowed error within 1.25x of the pre-drift error
    pre_err = _geo_rel_err(truth, s.pvs[:s.shift_index],
                           s.times[:s.shift_index])
    post_err = _geo_rel_err(cal.model, s.pvs[s.shift_index:],
                            s.times[s.shift_index:])
    assert post_err <= 1.25 * pre_err

    # observability: the report line carries the whole story
    line = cal.report_line()
    assert "drift=ok" in line and "refits=1" in line and "revision=1" in line
    assert f"samples={len(s.pvs)}" in line
    report = cal.final_report()
    assert "drift event:" in report and "direction=slow" in report


def test_refit_model_scores_fused_equals_loop(tmp_path, make_drift_stream):
    """All prediction paths stay coherent after a refit: the batched engine
    (through the cache the calibrator cleared) matches the per-plan oracle
    under the refit model."""
    from repro.configs.base import SHAPES
    from repro.configs.registry import ARCHS
    from repro.distributed.plan import plan_for
    s = make_drift_stream(n_pre=60, n_post=60, shift=1.5, noise=0.0, seed=9)
    truth = LinearCostModel(keys=s.keys, weights=s.weights, device="truth")
    cache = exprops.BasisCache(maxsize=256)
    cfg, shape = ARCHS["glm4-9b"], SHAPES["train_4k"]
    mesh = {"data": 16, "model": 16}
    base = plan_for(cfg, shape)
    plans = [base.with_(microbatches=m, fsdp=f)
             for m in (1, 4) for f in (True, False)]
    # warm the cache with the OLD model so stale columns exist to invalidate
    predictor.predict_plans(cfg, shape, plans, mesh, truth, cache=cache)

    cal = OnlineCalibrator(truth, device="fused-dev",
                           registry_dir=str(tmp_path), caches=[cache])
    for i, (pv, t) in enumerate(zip(s.pvs, s.times)):
        cal.observe(pv, t, step=i)
    assert cal.refits == 1 and cache.invalidations == 1

    fused = predictor.predict_plans(cfg, shape, plans, mesh, cal.model,
                                    cache=cache)
    loop = predictor.predict_plans_loop(cfg, shape, plans, mesh, cal.model)
    np.testing.assert_allclose(fused, loop, rtol=1e-9)


def test_register_revision_bumps_monotonically(tmp_path):
    m = LinearCostModel(keys=["const1"], weights=np.array([1.0]),
                        device="rev-dev")
    path1, r1 = register_revision(m, str(tmp_path))
    path2, r2 = register_revision(m, str(tmp_path))
    assert (r1, r2) == (1, 2) and path1 == path2
    assert registry.load_model("rev-dev", str(tmp_path)).meta["revision"] == 2


# ---------------------------------------------------------------------------
# learned residual head
# ---------------------------------------------------------------------------


def test_fit_residual_learns_systematic_correction():
    rng = np.random.default_rng(13)
    keys = ["a", "b"]
    base = LinearCostModel(keys=keys, weights=np.array([1.0, 2.0]),
                           device="res")
    pvs, times = [], []
    for _ in range(80):
        pv = {k: float(v) for k, v in zip(keys, rng.uniform(1.0, 50.0, 2))}
        # true time = base prediction x a feature-dependent factor the
        # linear basis cannot express
        factor = 1.0 + 0.3 * np.tanh(np.log1p(pv["a"]) - 2.5)
        pvs.append(pv)
        times.append(base.predict(pv) * factor)
    head = fit.fit_residual(pvs, times, base, ridge=1e-3)
    assert head is not None
    raw = fit.safe_relative_errors(base.predict_many(pvs), times)
    corr = fit.safe_relative_errors(
        [head.predict(base, pv) for pv in pvs], times)
    assert geomean(corr) < 0.5 * geomean(raw)
    # serialization round-trip
    back = fit.ResidualHead.from_json_dict(head.to_json_dict())
    for pv in pvs[:5]:
        assert back.predict(base, pv) == head.predict(base, pv)
    with pytest.raises(ValueError, match="not a residual_head"):
        fit.ResidualHead.from_json_dict({"kind": "nope"})


def test_fit_residual_degenerate_returns_none():
    m = LinearCostModel(keys=["a"], weights=np.array([1.0]), device="x")
    assert fit.fit_residual([{"a": 1.0}], [1.0], m) is None
    # rows with non-positive predictions/times are unusable
    neg = LinearCostModel(keys=["a"], weights=np.array([-1.0]), device="x")
    assert fit.fit_residual([{"a": 1.0}] * 4, [1.0] * 4, neg) is None


def test_residual_head_correction_is_clipped():
    head = fit.ResidualHead(keys=["a"], mean=np.zeros(1), scale=np.ones(1),
                            beta=np.array([100.0, 0.0]), clip=2.0)
    assert head.correction({"a": 1e9}) == pytest.approx(np.exp(2.0))
    assert head.correction({}) >= np.exp(-2.0)


def test_predict_step_applies_residual_head():
    from repro.configs.base import SHAPES
    from repro.configs.registry import ARCHS
    from repro.distributed.plan import plan_for
    cfg, shape = ARCHS["glm4-9b"], SHAPES["train_4k"]
    plan = plan_for(cfg, shape)
    mesh = {"data": 16, "model": 16}
    # bias-only head: exact x1.1 correction regardless of features
    head = fit.ResidualHead(keys=["const1"], mean=np.zeros(1),
                            scale=np.ones(1),
                            beta=np.array([0.0, np.log(1.1)]))
    base = predictor.predict_step(cfg, shape, plan, mesh)
    corr = predictor.predict_step(cfg, shape, plan, mesh, residual=head)
    assert corr.seconds == pytest.approx(1.1 * base.seconds, rel=1e-9)
    assert corr.terms["residual"] == pytest.approx(0.1 * base.seconds,
                                                  rel=1e-9)
    assert "residual" not in base.terms


def test_calibrator_fits_residual_head_on_refit(tmp_path, make_drift_stream):
    s = make_drift_stream(n_pre=60, n_post=60, shift=1.5, noise=0.02, seed=2)
    truth = LinearCostModel(keys=s.keys, weights=s.weights, device="truth")
    cal = OnlineCalibrator(truth, device="res-dev",
                           registry_dir=str(tmp_path), residual=True)
    for i, (pv, t) in enumerate(zip(s.pvs, s.times)):
        cal.observe(pv, t, step=i)
    assert cal.refits == 1 and cal.residual_head is not None
    assert "residual head:" in cal.final_report()


# ---------------------------------------------------------------------------
# calibration CLI round-trip regression
# ---------------------------------------------------------------------------


def test_cli_measure_fit_register_load_roundtrip(tmp_path, capsys):
    from repro.calibration.__main__ import main
    reg = str(tmp_path / "cli-reg")
    rc = main(["--device", "cli-dev", "--scale", "tiny", "--runs", "3",
               "--drop", "1", "--classes", "stride1_global", "--out", reg])
    assert rc == 0
    m1 = registry.load_model("cli-dev", reg)
    assert m1.meta["source"] == "calibrated"
    # register -> load -> re-register -> load is bit-exact (no decimal decay)
    reg2 = str(tmp_path / "cli-reg-2")
    registry.save_model(m1, reg2)
    m2 = registry.load_model("cli-dev", reg2)
    np.testing.assert_array_equal(m1.weights, m2.weights)
    assert m1.keys == m2.keys
    # --show renders the registered model
    assert main(["--show", "cli-dev", "--out", reg]) == 0
    assert "cli-dev" in capsys.readouterr().out


def test_cli_show_unknown_device_is_clean_error(tmp_path, capsys):
    from repro.calibration.__main__ import main
    rc = main(["--show", "no-such-dev", "--out", str(tmp_path)])
    assert rc == 1
    err = capsys.readouterr().err
    assert "cannot load model 'no-such-dev'" in err
    assert "tpu-v5e" in err            # lists what IS available


def test_cli_show_rejects_future_schema(tmp_path, capsys):
    from repro.calibration.__main__ import main
    with open(tmp_path / "future-dev.json", "w") as f:
        json.dump({"schema": SCHEMA_VERSION + 1,
                   "kind": "linear_cost_model",
                   "keys": ["x"], "weights": [1.0]}, f)
    rc = main(["--show", "future-dev", "--out", str(tmp_path)])
    assert rc == 1
    assert "cannot load model 'future-dev'" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# inf-safe fit diagnostics (previously ZeroDivisionError / LinAlgError)
# ---------------------------------------------------------------------------


def test_fit_report_zero_timing_rows_are_inf_not_crash():
    m = LinearCostModel(keys=["a"], weights=np.array([2.0]), device="x")
    pvs = [{"a": 1.0}, {"a": 2.0}, {"a": 3.0}]
    times = [2.0, 0.0, 6.0]            # would previously divide by zero
    rep = fit.fit_report(m, pvs, times)
    assert rep["n"] == 3 and rep["n_finite"] == 2
    assert rep["rows"][1]["rel_err"] == float("inf")
    assert rep["geomean_rel_err"] <= 2e-12  # the finite rows are exact
    assert np.isfinite(rep["max_rel_err"])


def test_fit_report_all_zero_timings():
    m = LinearCostModel(keys=["a"], weights=np.array([1.0]), device="x")
    rep = fit.fit_report(m, [{"a": 1.0}], [0.0])
    assert rep["n_finite"] == 0
    assert rep["geomean_rel_err"] == float("inf")
    assert rep["max_rel_err"] == float("inf")


def test_condition_report_drops_zero_timing_rows():
    pvs = [{"a": 1.0, "b": 2.0}, {"a": 3.0, "b": 1.0}, {"a": 2.0, "b": 2.0}]
    rep = fit.condition_report(pvs, [1.0, 0.0, 2.0])
    assert rep["n_rows"] == 2 and rep["n_dropped"] == 1
    assert np.isfinite(rep["cond"])
    all_zero = fit.condition_report(pvs, [0.0, 0.0, 0.0])
    assert all_zero["n_rows"] == 0 and all_zero["rank"] == 0
    assert all_zero["cond"] == float("inf") and all_zero["n_dropped"] == 3


def test_safe_relative_errors_basic():
    errs = fit.safe_relative_errors([1.0, 2.0, 3.0], [2.0, 0.0, 3.0])
    assert errs[0] == 0.5 and errs[1] == float("inf") and errs[2] == 0.0


# ---------------------------------------------------------------------------
# runtime integration: trainer + decode server feed the sink
# ---------------------------------------------------------------------------


def test_trainer_feeds_calibrator(tmp_path, capsys):
    from repro.configs.registry import ARCHS
    from repro.data.pipeline import DataConfig
    from repro.runtime.trainer import Trainer, TrainerConfig
    cfg = ARCHS["smollm-360m"].reduced()
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4,
                    seed=5)
    tc = TrainerConfig(log_every=2, total_steps=6, online_calibrate=True,
                       calib_registry=str(tmp_path))
    t = Trainer(cfg, dc, tc)
    assert t.calibrator is not None
    t.train(6)
    assert t.calibrator.sink.stats()["n_recorded"] == 6
    assert t.calibrator.sink.samples(tag="train")
    assert t.calibrator.rls.n_samples == 6
    out = capsys.readouterr().out
    assert "[calib] samples=" in out and "drift=" in out


def test_decode_server_feeds_calibrator(tmp_path):
    import jax
    from repro.configs.registry import ARCHS
    from repro.models import transformer
    from repro.runtime.server import DecodeServer, Request
    cfg = ARCHS["smollm-360m"].reduced()
    params, _ = transformer.init_params(cfg, jax.random.PRNGKey(0))
    cal = OnlineCalibrator(None, device="decode-dev",
                           registry_dir=str(tmp_path))
    srv = DecodeServer(cfg, params, slots=2, max_len=64, seed=0,
                       calibrator=cal)
    rng = np.random.default_rng(0)
    srv.submit(Request(rid=0, prompt=rng.integers(2, 200, 4).astype(np.int32),
                       max_new=4))
    done = srv.run()
    assert len(done) == 1
    assert cal.sink.stats()["n_recorded"] >= 4
    assert all(sm.tag == "decode" for sm in cal.sink.samples())
