"""Observability layer — tracer, metrics registry, basis-term attribution.

Covers the three pillars of ``repro.obs``: nested-span tracing with the
predicted-duration overlay (Chrome-trace schema, fake-clock determinism,
the disabled-tracer no-op contract), the metrics registry (counter /
gauge / histogram semantics, Prometheus exposition golden, JSON dump,
get-or-create registration), and basis-term attribution
(``score_explain`` ≡ the fused ``PlanSpace.scores`` GEMV at rtol 1e-9
across every registered arch; residual attribution recovering an
injected single-term perturbation).  Plus the crash-safe telemetry save
regression (a failed save must never truncate the previous artifact).
"""
from __future__ import annotations

import json
import os

import numpy as np
import pytest

from repro.configs.base import SHAPES, shape_applicable
from repro.configs.registry import ARCHS
from repro.core import exprops, planspace, predictor
from repro.core import properties as props
from repro.core import workload as wl
from repro.core.workload import WorkloadSpec
from repro.distributed.plan import plan_for
from repro.obs import metrics as obs_metrics
from repro.obs import report as obs_report
from repro.obs import trace as obs_trace
from repro.obs.explain import (attribute_residual, attribute_residual_pv,
                               explain_program, score_explain)


class FakeClock:
    """Deterministic monotone clock: each call advances by ``tick``."""

    def __init__(self, tick: float = 1.0):
        self.t = 0.0
        self.tick = tick
        self.calls = 0

    def __call__(self) -> float:
        self.calls += 1
        v = self.t
        self.t += self.tick
        return v


# ---------------------------------------------------------------------------
# Tracer: span nesting, timing monotonicity, predicted overlay
# ---------------------------------------------------------------------------


def test_span_nesting_and_monotonic_timing():
    clk = FakeClock()
    tr = obs_trace.Tracer(clock=clk)          # epoch consumes tick 0
    with tr.span("outer", predicted_s=3.0) as outer:   # start t=1
        with tr.span("inner") as inner:                # start t=2
            pass                                       # finish t=3
        outer.set(tokens=7)                            # finish t=4
    assert len(tr.spans) == 2
    # completion order: child lands before parent
    sp_inner, sp_outer = tr.spans
    assert sp_inner.name == "inner" and sp_outer.name == "outer"
    assert sp_outer.depth == 0 and sp_inner.depth == 1
    # fake clock: outer spans [1, 4), inner [2, 3) — strictly contained
    assert sp_outer.t_start_s == 1.0 and sp_outer.duration_s == 3.0
    assert sp_inner.t_start_s == 2.0 and sp_inner.duration_s == 1.0
    assert sp_inner.t_start_s >= sp_outer.t_start_s
    assert (sp_inner.t_start_s + sp_inner.duration_s
            <= sp_outer.t_start_s + sp_outer.duration_s)
    assert sp_outer.args["tokens"] == 7
    assert sp_outer.predicted_s == 3.0
    assert sp_outer.gap_s == pytest.approx(0.0)
    assert sp_inner.gap_s is None            # no prediction on the child
    assert inner.duration_s == 1.0           # live handle sees the result


def test_span_predicted_can_arrive_late():
    tr = obs_trace.Tracer(clock=FakeClock())
    with tr.span("decode") as sp:
        sp.set(predicted_s=0.25, rid=3)
    assert tr.spans[0].predicted_s == 0.25
    assert tr.spans[0].args == {"rid": 3}


def test_summary_and_report_lines():
    tr = obs_trace.Tracer(clock=FakeClock())
    for _ in range(3):
        with tr.span("step", predicted_s=1.0):
            pass
    summ = tr.summary()["step"]
    assert summ["count"] == 3
    assert summ["measured_s"] == pytest.approx(3.0)
    assert summ["predicted_s"] == pytest.approx(3.0)
    assert summ["gap_s"] == pytest.approx(0.0)
    (line,) = tr.report_lines()
    assert line.startswith("step: n=3 measured=3000.00ms "
                           "predicted=3000.00ms")
    assert "ratio=1.00x" in line


# ---------------------------------------------------------------------------
# Chrome-trace export schema
# ---------------------------------------------------------------------------


def test_chrome_trace_schema_and_overlay():
    tr = obs_trace.Tracer(clock=FakeClock(), process_name="unit")
    with tr.span("outer", predicted_s=2.5):
        with tr.span("inner"):
            pass
    tr.instant("drift_event", direction="up")
    d = tr.to_chrome_trace()
    assert set(d) == {"traceEvents", "displayTimeUnit", "otherData"}
    ev = d["traceEvents"]

    meta = [e for e in ev if e["ph"] == "M"]
    names = {(e["name"], e["tid"]): e["args"]["name"] for e in meta}
    assert names[("process_name", obs_trace.MEASURED_TID)] == "unit"
    assert names[("thread_name", obs_trace.MEASURED_TID)] == "measured"
    assert names[("thread_name", obs_trace.PREDICTED_TID)] == "predicted"

    xs = [e for e in ev if e["ph"] == "X"]
    measured = [e for e in xs if e["tid"] == obs_trace.MEASURED_TID]
    predicted = [e for e in xs if e["tid"] == obs_trace.PREDICTED_TID]
    # export re-sorts by start time: parent precedes child
    assert [e["name"] for e in measured] == ["outer", "inner"]
    # ts/dur are microseconds (fake clock: outer [1s, 4s))
    assert measured[0]["ts"] == pytest.approx(1e6)
    assert measured[0]["dur"] == pytest.approx(3e6)
    # the predicted overlay: sibling event, same ts, dur = predicted
    (ov,) = predicted
    assert ov["name"] == "outer (predicted)"
    assert ov["ts"] == measured[0]["ts"]
    assert ov["dur"] == pytest.approx(2.5e6)
    assert ov["args"]["gap_s"] == pytest.approx(3.0 - 2.5)

    (inst,) = [e for e in ev if e["ph"] == "i"]
    assert inst["name"] == "drift_event"
    assert inst["args"] == {"direction": "up"}


def test_trace_save_round_trip(tmp_path):
    tr = obs_trace.Tracer(clock=FakeClock())
    with tr.span("s", predicted_s=1.0):
        pass
    path = tmp_path / "sub" / "trace.json"   # save creates parents
    tr.save(str(path))
    d = json.loads(path.read_text())
    assert any(e.get("tid") == obs_trace.PREDICTED_TID
               for e in d["traceEvents"] if e["ph"] == "X")
    assert not [p for p in os.listdir(path.parent)
                if p.endswith(".tmp")], "tmp files must not leak"


# ---------------------------------------------------------------------------
# Disabled tracer: a true no-op
# ---------------------------------------------------------------------------


def test_disabled_tracer_is_noop():
    clk = FakeClock()
    tr = obs_trace.Tracer(enabled=False, clock=clk)
    epoch_calls = clk.calls                  # __init__ reads the epoch once
    s1 = tr.span("a", predicted_s=1.0, x=1)
    s2 = tr.span("b")
    assert s1 is s2, "disabled span() must hand out ONE shared null object"
    with s1 as sp:
        sp.set(predicted_s=2.0, y=3)         # must not raise
    tr.instant("marker")
    assert clk.calls == epoch_calls, "disabled path must never read the clock"
    assert tr.spans == [] and tr.instants == []
    assert tr.report_lines() == []


def test_module_tracer_default_disabled_and_swap():
    assert obs_trace.get_tracer().enabled is False
    t = obs_trace.Tracer(clock=FakeClock(), process_name="t")
    prev = obs_trace.set_tracer(t)
    try:
        assert obs_trace.get_tracer() is t
    finally:
        obs_trace.set_tracer(prev)
    assert obs_trace.get_tracer() is prev


def test_planspace_emits_one_span_per_sweep():
    cfg = ARCHS["smollm-360m"]
    spec = wl.from_shape(SHAPES["train_4k"])
    plan = plan_for(cfg, SHAPES["train_4k"])
    space = planspace.PlanSpace.from_product(
        cfg, spec, [plan], [{"data": 16, "model": 16}])
    t = obs_trace.Tracer(process_name="test")
    prev = obs_trace.set_tracer(t)
    try:
        space.scores()
    finally:
        obs_trace.set_tracer(prev)
    assert [s.name for s in t.spans] == ["planspace.scores"]
    assert t.spans[0].args["cells"] == 1


# ---------------------------------------------------------------------------
# score_explain ≡ fused GEMV (rtol 1e-9, every registered arch)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_score_explain_matches_fused_scores(arch):
    cfg = ARCHS[arch]
    shape = SHAPES["train_4k"]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        pytest.skip(why)
    spec = wl.from_shape(shape)
    plan = plan_for(cfg, shape)
    mesh = {"data": 16, "model": 16}
    model = predictor.resolve_model(None)

    space = planspace.PlanSpace.from_product(cfg, spec, [plan], [mesh])
    fused = float(space.scores(model)[0])

    exp = score_explain(cfg, spec, plan, mesh, model=model)
    assert exp.total_seconds == pytest.approx(fused, rel=1e-9)
    # the decomposition is exact: rows sum to the total
    assert sum(r.seconds for r in exp.rows) == pytest.approx(
        exp.total_seconds, rel=1e-12)
    assert sum(r.share for r in exp.rows) == pytest.approx(1.0, rel=1e-9)
    # grouped views re-sum to the same total
    assert sum(exp.by_group().values()) == pytest.approx(fused, rel=1e-9)
    assert sum(exp.by_source().values()) == pytest.approx(fused, rel=1e-9)
    assert sum(exp.by_property().values()) == pytest.approx(fused, rel=1e-9)
    assert set(exp.by_group()) <= set(props.CATEGORIES)
    assert set(exp.by_source()) <= {"step", "collective", "launch"}
    assert exp.report()          # renders without raising


def test_score_explain_entry_points_agree():
    cfg = ARCHS["glm4-9b"]
    shape = SHAPES["train_4k"]
    plan = plan_for(cfg, shape)
    mesh = {"data": 16, "model": 16}
    via_predictor = predictor.score_explain(cfg, shape, plan, mesh)
    direct = score_explain(cfg, wl.from_shape(shape), plan, mesh)
    assert via_predictor.total_seconds == pytest.approx(
        direct.total_seconds, rel=1e-12)
    # and the fused-vs-explained check holds for the decode phase too
    dshape = SHAPES["decode_32k"]
    dplan = plan_for(cfg, dshape)
    dspec = wl.from_shape(dshape)
    dspace = planspace.PlanSpace.from_product(cfg, dspec, [dplan], [mesh])
    dexp = score_explain(cfg, dspec, dplan, mesh)
    assert dexp.total_seconds == pytest.approx(
        float(dspace.scores()[0]), rel=1e-9)
    assert dexp.phase == "decode"


def test_basis_program_explain_method():
    cfg = ARCHS["smollm-360m"]
    spec = wl.from_shape(SHAPES["train_4k"])
    model = predictor.resolve_model(None)
    prog = predictor.step_program(cfg, spec, "none")
    env = spec.env(cfg)
    env["M"] = 1
    rows = prog.explain(env, model)
    assert rows == explain_program(prog, env, model)
    total = sum(sec for _, sec, _, _ in rows)
    assert total == pytest.approx(float(prog.score(env, model)), rel=1e-9)


# ---------------------------------------------------------------------------
# Residual attribution: recover an injected perturbation
# ---------------------------------------------------------------------------


def _varied_envs(cfg, n=16):
    # batch/seq values deliberately OFF the ceil granularities (128-token
    # tiles, 16k chunks): on-grid windows make every ceil term an exact
    # multiple of B*S and the basis columns collinear — no single-term
    # perturbation is identifiable from such a window
    batches = (3, 5, 7, 9)
    seqs = (260, 388, 516, 644, 772, 900)
    envs = []
    for i in range(n):
        spec = WorkloadSpec(phase="train", global_batch=batches[i % 4],
                            seq_len=seqs[i % 6])
        env = spec.env(cfg)
        env["M"] = 1
        envs.append(env)
    return envs


def test_attribute_residual_recovers_injected_term_error():
    cfg = ARCHS["smollm-360m"]
    model = predictor.resolve_model(None)
    prog = predictor.step_program(cfg, wl.from_shape(SHAPES["train_4k"]),
                                  "none")
    envs = _varied_envs(cfg)
    # pick a live term whose value VARIES across the window (identifiable)
    per_env = [dict(((t, s) for t, s, _, _ in explain_program(
        prog, e, model))) for e in envs]
    terms = [t for t in per_env[0] if t != "1"]
    B = np.asarray([[d[t] for t in terms] for d in per_env])

    def unexplained(j):
        # seconds² of column j the OTHER columns cannot reproduce: the
        # attribution can only pin a perturbation on a term whose window
        # signature is not a linear mix of the rest of the basis
        y = B[:, j]
        X = np.delete(B, j, axis=1)
        coef = np.linalg.lstsq(X, y, rcond=None)[0]
        return float(((y - X @ coef) ** 2).sum())

    j_target = max(range(len(terms)), key=unexplained)
    target = terms[j_target]
    assert unexplained(j_target) > 0, "window must isolate the target"
    eps_true = 0.2
    measured = [sum(d.values()) + eps_true * d[target] for d in per_env]

    att = attribute_residual(prog, model, envs, measured)
    assert att.n_samples == len(envs)
    assert att.shares()[target] > 0.9, att.shares()
    i = att.columns.index(target)
    assert att.epsilon[i] == pytest.approx(eps_true, abs=0.02)
    # the attributed miss reconstructs the mean residual
    assert float(np.sum(att.miss_seconds)) == pytest.approx(
        att.residual_s, rel=1e-2)
    assert att.line().startswith("residual=")


def test_attribute_residual_pv_property_basis():
    rng = np.random.default_rng(3)
    model = predictor.resolve_model(None)
    priced = [k for k, w in zip(model.keys, model.weights) if w][:4]
    assert len(priced) >= 2
    pvs = [{k: float(rng.uniform(1e6, 1e9)) for k in priced}
           for _ in range(16)]
    target = priced[0]
    w = dict(zip(model.keys, model.weights))
    measured = [model.predict(pv) + 0.3 * w[target] * pv[target]
                for pv in pvs]
    att = attribute_residual_pv(model, pvs, measured)
    assert att.shares()[target] > 0.9
    i = att.columns.index(target)
    assert att.epsilon[i] == pytest.approx(0.3, rel=1e-2)
    assert att.group_shares()[props.category(target)] > 0.9


def test_attribute_residual_zero_residual_attributes_nothing():
    cfg = ARCHS["smollm-360m"]
    model = predictor.resolve_model(None)
    prog = predictor.step_program(cfg, wl.from_shape(SHAPES["train_4k"]),
                                  "none")
    envs = _varied_envs(cfg, n=6)
    measured = [sum(s for _, s, _, _ in explain_program(prog, e, model))
                for e in envs]
    att = attribute_residual(prog, model, envs, measured)
    assert att.residual_s == pytest.approx(0.0, abs=1e-12)
    assert float(np.abs(att.miss_seconds).sum()) == pytest.approx(
        0.0, abs=1e-9)


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


def test_counter_semantics():
    reg = obs_metrics.MetricsRegistry()
    c = reg.counter("events_total", "help text")
    c.inc()
    c.inc(2.5)
    c.inc(1, phase="decode")
    assert c.value() == 3.5
    assert c.value(phase="decode") == 1.0
    assert c.value(phase="absent") == 0.0
    with pytest.raises(ValueError):
        c.inc(-1)
    assert reg.counter("events_total") is c     # get-or-create


def test_gauge_semantics():
    g = obs_metrics.MetricsRegistry().gauge("occupancy")
    g.set(7)
    g.inc(2)
    g.dec(4)
    assert g.value() == 5.0
    g.set(1.5, ring="a")
    assert g.value(ring="a") == 1.5


def test_histogram_semantics():
    reg = obs_metrics.MetricsRegistry()
    h = reg.histogram("lat_seconds", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 5.0, 50.0):
        h.observe(v)
    assert h.value() == 4.0                     # count
    assert h.sum() == pytest.approx(55.55)
    d = h.to_json_dict()
    (s,) = d["samples"]
    assert s["bucket_counts"] == [1.0, 2.0, 3.0]   # cumulative
    assert s["count"] == 4.0
    text = h.render()
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="+Inf"} 4' in text
    assert "lat_seconds_count 4" in text


def test_registry_type_clash_raises():
    reg = obs_metrics.MetricsRegistry()
    reg.counter("x_total")
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x_total")


def test_metric_name_validation():
    with pytest.raises(ValueError):
        obs_metrics.Counter("bad name")


def test_render_prometheus_golden():
    reg = obs_metrics.MetricsRegistry()
    c = reg.counter("repro_events_total", "events by kind")
    c.inc(3, kind="a")
    c.inc(1.5, kind="b")
    g = reg.gauge("repro_height")
    g.set(2.25)
    reg.counter("repro_untouched_total")
    assert reg.render() == (
        "# HELP repro_events_total events by kind\n"
        "# TYPE repro_events_total counter\n"
        'repro_events_total{kind="a"} 3\n'
        'repro_events_total{kind="b"} 1.5\n'
        "# TYPE repro_height gauge\n"
        "repro_height 2.25\n"
        "# TYPE repro_untouched_total counter\n"
        "repro_untouched_total 0\n"
    )


def test_registry_json_dump_and_reset(tmp_path):
    reg = obs_metrics.MetricsRegistry()
    reg.counter("c_total").inc(2, k="v")
    reg.histogram("h_seconds", buckets=(1.0,)).observe(0.5)
    path = tmp_path / "m" / "metrics.json"
    reg.save_json(str(path))
    d = json.loads(path.read_text())
    assert d["kind"] == "metrics" and d["schema"] == 1
    by_name = {m["name"]: m for m in d["metrics"]}
    assert by_name["c_total"]["samples"] == [
        {"labels": {"k": "v"}, "value": 2.0}]
    assert by_name["h_seconds"]["buckets"] == [1.0]
    reg.reset()
    assert reg.counter("c_total").value(k="v") == 0.0
    assert "c_total" in reg                   # registration survives reset


def test_process_registry_has_framework_families():
    # producers register at import time: the process-wide registry must
    # already know the cache / telemetry / report families
    text = obs_metrics.REGISTRY.render()
    for name in ("repro_basis_cache_hits_total",
                 "repro_compile_cache_events_total",
                 "repro_telemetry_samples_total",
                 "repro_report_lines_total",
                 "repro_lru_evictions_total"):
        assert name in text, name


def test_basis_cache_counters_flow_to_registry():
    cfg = ARCHS["smollm-360m"]
    spec = wl.from_shape(SHAPES["train_4k"])
    plan = plan_for(cfg, SHAPES["train_4k"])
    space = planspace.PlanSpace.from_product(
        cfg, spec, [plan], [{"data": 16, "model": 16}])
    hits = obs_metrics.REGISTRY.counter("repro_basis_cache_hits_total")
    misses = obs_metrics.REGISTRY.counter("repro_basis_cache_misses_total")
    h0, m0 = hits.value(), misses.value()
    cache = exprops.BasisCache()
    space.scores(cache=cache)                 # cold: misses
    space.scores(cache=cache)                 # warm: hits
    assert misses.value() > m0
    assert hits.value() > h0


# ---------------------------------------------------------------------------
# Structured report lines
# ---------------------------------------------------------------------------


def test_report_emit_format_and_counting():
    got = []
    line = obs_report.emit("admit", {"rid": 3, "score": 1.25,
                                     "pred": "0.006ms"},
                           text="policy=model", printer=got.append)
    assert line == "[admit] rid=3 score=1.25 pred=0.006ms policy=model"
    assert got == [line]
    before = obs_metrics.REGISTRY.counter(
        "repro_report_lines_total").value(tag="quiet")
    assert obs_report.emit("quiet", printer=None) == "[quiet]"
    after = obs_metrics.REGISTRY.counter(
        "repro_report_lines_total").value(tag="quiet")
    assert after == before + 1


# ---------------------------------------------------------------------------
# Crash-safe telemetry save (regression: truncated artifact)
# ---------------------------------------------------------------------------


def test_telemetry_save_failure_keeps_previous_artifact(tmp_path,
                                                        monkeypatch):
    from repro.calibration import telemetry
    sink = telemetry.TelemetrySink(capacity=8)
    for i in range(3):
        sink.record({"flops": 1e9 + i}, 0.01 * (i + 1), step=i)
    path = tmp_path / "telemetry.json"
    sink.save(str(path))
    good = path.read_text()

    # a crash mid-serialization: json.dump writes half a document and dies
    def exploding_dump(obj, f, **kw):
        f.write('{"kind": "telemetry", "samples": [[0,')
        raise OSError("disk full")

    monkeypatch.setattr(telemetry.json, "dump", exploding_dump)
    sink.record({"flops": 5e9}, 0.5)
    with pytest.raises(OSError, match="disk full"):
        sink.save(str(path))
    monkeypatch.undo()

    # the artifact is byte-identical to the last good save — not truncated
    assert path.read_text() == good
    loaded = telemetry.TelemetrySink.load(str(path))
    assert len(loaded) == 3
    assert not [p for p in os.listdir(tmp_path) if p.endswith(".tmp")], \
        "failed save must clean up its temp file"


def test_metrics_save_json_failure_keeps_previous_artifact(tmp_path,
                                                           monkeypatch):
    reg = obs_metrics.MetricsRegistry()
    reg.counter("c_total").inc()
    path = tmp_path / "metrics.json"
    reg.save_json(str(path))
    good = path.read_text()

    def exploding_dump(obj, f, **kw):
        f.write('{"kind": "met')
        raise OSError("disk full")

    monkeypatch.setattr(obs_metrics.json, "dump", exploding_dump)
    with pytest.raises(OSError):
        reg.save_json(str(path))
    monkeypatch.undo()
    assert path.read_text() == good
    assert not [p for p in os.listdir(tmp_path) if p.endswith(".tmp")]
