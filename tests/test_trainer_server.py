"""Trainer + server integration tests (reduced configs, CPU)."""
from __future__ import annotations

import numpy as np

from repro.configs.registry import ARCHS
from repro.data.pipeline import DataConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def _mk(tmp_path, save_on_exit=True, total=30):
    cfg = ARCHS["smollm-360m"].reduced()
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4,
                    seed=5)
    tc = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=5, log_every=1000,
                       total_steps=total, save_on_exit=save_on_exit)
    return Trainer(cfg, dc, tc)


def test_trainer_loss_finite_and_checkpoints(tmp_path):
    t = _mk(tmp_path)
    hist = t.train(8)
    assert len(hist) == 8
    assert all(np.isfinite(m["loss"]) for m in hist)
    from repro.checkpoint import store
    assert store.latest_step(str(tmp_path)) == 8  # save_on_exit


def test_trainer_resume_is_exact(tmp_path):
    t1 = _mk(tmp_path, save_on_exit=False)
    t1.train(9)  # ckpts at 5; runs to 9
    ref = [m["loss"] for m in t1.history]
    del t1
    t2 = _mk(tmp_path, save_on_exit=False)
    assert t2.step == 5
    t2.train(4)  # replay 5..8
    np.testing.assert_allclose(ref[5:9],
                               [m["loss"] for m in t2.history], rtol=1e-5)


def test_server_completes_requests():
    import jax
    from repro.models import transformer
    from repro.runtime.server import DecodeServer, Request
    cfg = ARCHS["smollm-360m"].reduced()
    params, _ = transformer.init_params(cfg, jax.random.PRNGKey(0))
    srv = DecodeServer(cfg, params, slots=2, max_len=64, seed=0)
    rng = np.random.default_rng(0)
    for rid in range(5):
        srv.submit(Request(rid=rid,
                           prompt=rng.integers(2, 200, 6).astype(np.int32),
                           max_new=5))
    done = srv.run()
    assert len(done) == 5
    assert all(1 <= len(r.out) <= 5 for r in done)
