"""Optional-``hypothesis`` shim so the tier-1 suite collects on a bare
environment (numpy + jax + pytest only).

``from _hypothesis_compat import given, settings, st`` behaves exactly like
the real hypothesis imports when the package is installed; otherwise the
decorators turn each property-based test into a single skipped test and the
strategy expressions evaluate to inert placeholders.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _StubStrategies:
        """Any ``st.<name>(...)`` call returns an inert placeholder."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StubStrategies()

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed — property-based cases "
                       "skipped")(fn)
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn
