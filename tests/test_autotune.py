"""Kernel-level predictor & model-guided autotuner (tentpole tests).

Covers: (a) ``Expr.compile`` ≡ ``Expr.eval`` on randomized trees/envs,
(b) the tuner's ranked-best configuration against exhaustive interpreted
scoring, (c) ``block_sizes="auto"`` kernels against the pure-jnp oracles,
plus the compiled-sweep speedup bar and the step-composition invariants.
"""
from __future__ import annotations

import random
import time

import numpy as np
import pytest

from repro.core import kernelmodel
from repro.core import properties as props
from repro.core.workload import WorkloadSpec
from repro.core.symcount import (
    CeilDiv, Const, Expr, FloorDiv, Max, Min, Piecewise, Var, as_expr,
    compile_vector, evaluate_vector,
)
from repro.kernels import autotune


# ---------------------------------------------------------------------------
# (a) compiled ≡ interpreted on randomized expression trees
# ---------------------------------------------------------------------------

_VARS = ("x", "y", "z")


def _rand_expr(rng: random.Random, depth: int = 0) -> Expr:
    if depth > 4 or rng.random() < 0.25:
        if rng.random() < 0.5:
            return Const(rng.randint(1, 9))
        return Var(rng.choice(_VARS))
    op = rng.choice(["add", "sub", "mul", "fdiv", "cdiv", "max", "min",
                     "pow", "div", "pw"])
    a = _rand_expr(rng, depth + 1)
    b = _rand_expr(rng, depth + 1)
    if op == "add":
        return a + b
    if op == "sub":
        return a - b
    if op == "mul":
        return a * b
    if op == "fdiv":
        return FloorDiv(a, as_expr(rng.randint(1, 7)))
    if op == "cdiv":
        return CeilDiv(a, as_expr(rng.randint(1, 7)))
    if op == "max":
        return Max(a, b)
    if op == "min":
        return Min(a, b)
    if op == "pow":
        return a ** rng.choice([1, 2, 3])
    if op == "div":
        return a / as_expr(rng.randint(1, 7))
    return Piecewise([(a - 3, b)], a + b)


def test_compiled_matches_eval_randomized():
    rng = random.Random(1234)
    for _ in range(200):
        e = _rand_expr(rng)
        env = {v: rng.randint(1, 64) for v in _VARS}
        expected = e.eval(env)
        got = e.compile()(env)
        np.testing.assert_allclose(float(got), float(expected), rtol=1e-12)


def test_compiled_vectorized_matches_pointwise_eval():
    rng = random.Random(99)
    e = _rand_expr(rng)
    while not e.free_vars():
        e = _rand_expr(rng)
    n = 257
    envs = {v: np.asarray([rng.randint(1, 64) for _ in range(n)])
            for v in _VARS}
    arr = e.compile()(envs)
    pts = [e.eval({v: int(envs[v][i]) for v in _VARS}) for i in range(n)]
    np.testing.assert_allclose(np.asarray(arr, dtype=np.float64), pts,
                               rtol=1e-12)


def test_compile_vector_passthrough_constants():
    pv = {"a": Var("x") * 2, "b": 7.0}
    out = compile_vector(pv)({"x": 5})
    assert float(out["a"]) == 10.0 and out["b"] == 7.0


# ---------------------------------------------------------------------------
# (b) tuner vs exhaustive interpreted scoring
# ---------------------------------------------------------------------------

SHAPES = {
    "matmul": {"M": 1024, "N": 512, "K": 2048, "bits": 16},
    "flash_attention": {"B": 2, "H": 8, "KVH": 2, "Sq": 2048, "Skv": 2048,
                        "dh": 64, "causal": True, "window": None,
                        "bits": 16},
    "ssd_scan": {"Bz": 2, "H": 8, "L": 2048, "P": 64, "N": 128, "bits": 16},
    "transpose": {"M": 2048, "N": 1024, "bits": 32},
}


@pytest.mark.parametrize("kernel", sorted(SHAPES))
def test_compiled_scoring_matches_interpreted(kernel):
    shape = SHAPES[kernel]
    cands = autotune.candidate_configs(kernel, shape)
    fast = autotune.score_configs(kernel, shape, cands)
    slow = autotune.score_configs_interpreted(kernel, shape, cands)
    np.testing.assert_allclose(fast, slow, rtol=1e-12)


@pytest.mark.parametrize("kernel", sorted(SHAPES))
def test_best_block_sizes_in_top3_of_exhaustive(kernel):
    """Acceptance: the tuner's pick is within the top-3 of an exhaustive
    per-point interpreted sweep (model.predict over Expr.eval'd vectors)."""
    shape = SHAPES[kernel]
    best = autotune.best_block_sizes(kernel, shape)
    cands = autotune.candidate_configs(kernel, shape)
    secs = autotune.score_configs_interpreted(kernel, shape, cands)
    top3 = {tuple(sorted(cands[i].items()))
            for i in np.argsort(secs, kind="stable")[:3]}
    assert tuple(sorted(best.items())) in top3


def test_best_block_sizes_accepts_registry_name_and_model():
    from repro.calibration.seeds import ANALYTIC_SEEDS
    shape = SHAPES["matmul"]
    by_name = autotune.best_block_sizes("matmul", shape, model="gpu-a100")
    by_model = autotune.best_block_sizes("matmul", shape,
                                         model=ANALYTIC_SEEDS["gpu-a100"]())
    assert by_name == by_model


def test_candidates_respect_vmem_budget():
    shape = SHAPES["matmul"]
    km = kernelmodel.get("matmul")
    budget = kernelmodel.VMEM_BYTES * kernelmodel.VMEM_BUDGET
    for c in autotune.candidate_configs("matmul", shape):
        assert km.vmem_bytes(shape, c) <= budget


def test_compiled_sweep_speedup_over_interpreted():
    """Acceptance: ≥10× on a ≥64-point grid (best-of-3, warm compile)."""
    shape = SHAPES["matmul"]
    cands = autotune.candidate_configs("matmul", shape)
    assert len(cands) >= 64, len(cands)
    autotune.score_configs("matmul", shape, cands)  # warm codegen memo

    def best_of(fn, n=3):
        ts = []
        for _ in range(n):
            t0 = time.perf_counter()
            fn()
            ts.append(time.perf_counter() - t0)
        return min(ts)

    t_fast = best_of(lambda: autotune.score_configs("matmul", shape, cands))
    t_slow = best_of(lambda: autotune.score_configs_interpreted(
        "matmul", shape, cands))
    assert t_slow >= 10.0 * t_fast, (t_slow, t_fast)


# ---------------------------------------------------------------------------
# (c) block_sizes="auto" kernels vs the reference oracles (interpret mode)
# ---------------------------------------------------------------------------


def test_auto_matmul_matches_ref():
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.normal(k1, (256, 512), jnp.float32)
    b = jax.random.normal(k2, (512, 384), jnp.float32)
    o = ops.matmul(a, b, block_sizes="auto", interpret=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref.matmul(a, b)),
                               atol=1e-3, rtol=1e-5)


def test_auto_flash_attention_matches_ref():
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (2, 4, 256, 64), jnp.float32)
    k = jax.random.normal(ks[1], (2, 2, 256, 64), jnp.float32)
    v = jax.random.normal(ks[2], (2, 2, 256, 64), jnp.float32)
    o = ops.flash_attention(q, k, v, causal=True, block_sizes="auto",
                            interpret=True)
    r = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               atol=3e-5, rtol=3e-5)


def test_auto_ssd_scan_matches_ref():
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    Bz, H, G, L, P, N = 1, 2, 1, 256, 16, 16
    x = jax.random.normal(ks[0], (Bz, H, L, P), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bz, H, L), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.3)
    B = jax.random.normal(ks[3], (Bz, G, L, N), jnp.float32) * 0.3
    C = jax.random.normal(ks[4], (Bz, G, L, N), jnp.float32) * 0.3
    y, h = ops.ssd_scan(x, dt, A, B, C, block_sizes="auto", interpret=True)
    yr, hr = ref.ssd(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               atol=5e-4, rtol=5e-4)


def test_auto_transpose_matches_ref():
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    x = jax.random.normal(jax.random.PRNGKey(5), (512, 256), jnp.float32)
    o = ops.transpose(x, block_sizes="auto", interpret=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(x.T))


# ---------------------------------------------------------------------------
# step composition — the predictor's per-kernel compute term
# ---------------------------------------------------------------------------


def test_step_kernel_vectors_track_archcount_mxu():
    """The kernel-composed mxu total must agree with archcount's step count
    in the leading term (block rounding only adds low-order overshoot)."""
    from repro.configs.registry import ARCHS
    from repro.core import archcount
    from repro.core.symcount import add_vectors
    env = {"B": 8, "S": 4096, "M": 1}
    for arch in ("glm4-9b", "mamba2-370m", "mixtral-8x7b", "zamba2-2.7b"):
        cfg = ARCHS[arch]
        bits = 16 if "16" in cfg.compute_dtype else 32
        total = add_vectors(
            *kernelmodel.step_kernel_vectors(
                cfg, WorkloadSpec(phase="prefill")).values())
        kern = evaluate_vector(total, env)[props.mxu_key(bits)]
        step = archcount.forward_counts(cfg)[props.mxu_key(bits)].eval(env)
        assert kern == pytest.approx(step, rel=0.05), (arch, kern, step)


def test_predict_step_uses_kernel_local_traffic():
    """Kernel-granularity compute terms add VMEM (local:) traffic to the
    step breakdown — absent from the old whole-step counts."""
    from repro.configs.base import SHAPES as SHAPES_CFG
    from repro.configs.registry import ARCHS
    from repro.core import predictor
    from repro.distributed.plan import Plan
    cfg = ARCHS["glm4-9b"]
    pred = predictor.predict_step(cfg, SHAPES_CFG["train_4k"],
                                  Plan(dp_axes=("data",)),
                                  {"data": 8, "model": 8})
    bits = 16 if "16" in cfg.compute_dtype else 32
    assert props.local_key(bits) in pred.breakdown
    assert pred.seconds > 0 and np.isfinite(pred.seconds)
