"""Suite-wide fixtures.

The fused-program disk cache (``core.exprops``) defaults to
``~/.cache/repro/exprops``; tests must neither litter the user's real
cache nor read stale programs from it (which would couple test outcomes
to machine state), so the whole session is pointed at a throwaway
directory.  Individual tests that probe the cache behavior override the
variable themselves via ``monkeypatch``.

``make_drift_stream`` is the fault-injection helper for the online
calibration suite: synthetic timing streams from a known ground-truth
linear model with a hardware-drift step (a multiplicative slowdown)
injected mid-stream.
"""
import os
from types import SimpleNamespace

import numpy as np
import pytest


@pytest.fixture(scope="session", autouse=True)
def _isolated_compile_cache(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("exprops-cache"))
    old = os.environ.get("REPRO_COMPILE_CACHE")
    os.environ["REPRO_COMPILE_CACHE"] = d
    yield
    if old is None:
        os.environ.pop("REPRO_COMPILE_CACHE", None)
    else:
        os.environ["REPRO_COMPILE_CACHE"] = old


#: real taxonomy keys + ground-truth seconds/event weights (v5e-seed scale)
#: used by the drift streams, so refit models are directly usable by the
#: prediction paths (plan_property_vector emits keys from this family)
DRIFT_KEYS = ["mxu:16", "load:32:s1", "store:32:s1", "flop:32:add",
              "coll:all_reduce", "const1"]
DRIFT_WEIGHTS = np.array([2.5e-15, 9.0e-12, 9.5e-12, 1.6e-13,
                          1.2e-11, 5.0e-6])


@pytest.fixture
def make_drift_stream():
    """Factory for synthetic timing streams with an injected drift step.

    Returns (pvs, times, ...) where ``times[j] = <w_true, p_j>`` for
    ``j < n_pre`` and ``shift × <w_true, p_j>`` after — the "device got
    1.5× slower mid-run" scenario — with optional multiplicative
    lognormal-ish noise.  Property vectors vary randomly per sample (full
    column rank), so batch/RLS fits are identifiable.
    """
    def _make(n_pre=120, n_post=80, shift=1.5, noise=0.0, seed=0,
              keys=None, weights=None):
        keys = list(keys) if keys is not None else list(DRIFT_KEYS)
        w = (np.asarray(weights, dtype=np.float64) if weights is not None
             else DRIFT_WEIGHTS[:len(keys)].copy())
        rng = np.random.default_rng(seed)
        pvs, times = [], []
        for j in range(n_pre + n_post):
            counts = rng.uniform(0.5, 2.0, size=len(keys)) * 1e9
            pv = {k: float(c) for k, c in zip(keys, counts)}
            if "const1" in pv:
                pv["const1"] = 1.0
            t = float(sum(w[i] * pv[k] for i, k in enumerate(keys)))
            if j >= n_pre:
                t *= shift
            if noise:
                t *= float(np.exp(noise * rng.standard_normal()))
            pvs.append(pv)
            times.append(t)
        return SimpleNamespace(pvs=pvs, times=times, keys=keys,
                               weights=w, shift_index=n_pre, shift=shift)
    return _make
