"""Suite-wide fixtures.

The fused-program disk cache (``core.exprops``) defaults to
``~/.cache/repro/exprops``; tests must neither litter the user's real
cache nor read stale programs from it (which would couple test outcomes
to machine state), so the whole session is pointed at a throwaway
directory.  Individual tests that probe the cache behavior override the
variable themselves via ``monkeypatch``.
"""
import os

import pytest


@pytest.fixture(scope="session", autouse=True)
def _isolated_compile_cache(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("exprops-cache"))
    old = os.environ.get("REPRO_COMPILE_CACHE")
    os.environ["REPRO_COMPILE_CACHE"] = d
    yield
    if old is None:
        os.environ.pop("REPRO_COMPILE_CACHE", None)
    else:
        os.environ["REPRO_COMPILE_CACHE"] = old
