"""Array-batched search-space engine (core/planspace.py) tests.

Pins the three equivalences the engine's speed claims rest on:

  * compiled array-env evaluation of arbitrary ``symcount.Expr`` trees
    matches interpreted ``Expr.eval`` pointwise (seeded random trees, plus
    the hypothesis-driven version when hypothesis is installed);
  * ``PlanSpace.scores`` matches the per-plan interpreted loop
    (``predictor.predict_plans_loop``) over (plan × mesh) products;
  * the symbolic per-topology-class collectives and the vectorized HBM
    feasibility match their scalar references branch for branch.

Plus the satellites: bounded LRU caches, deterministic rank tie-breaks,
mesh-factorization sweeps in autoshard, batched elastic replan.
"""
from __future__ import annotations

import random

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS
from repro.core import archcount, planspace, predictor
from repro.core.lru import LRUCache
from repro.core.symcount import (
    Add, CeilDiv, Const, Expr, FloorDiv, Max, Min, Mul, Piecewise, Pow,
    Var, evaluate_vector,
)
from repro.distributed.plan import Plan
from repro.launch.autoshard import candidate_plans

# ---------------------------------------------------------------------------
# compiled vs interpreted Expr evaluation (property-based)
# ---------------------------------------------------------------------------

_VARS = ("x", "y", "z")


def random_expr(rng: random.Random, depth: int) -> Expr:
    """A random symcount tree.  Divisor operands stay positive atoms so
    eval never divides by zero; magnitudes stay small enough that int
    arithmetic is exact in both Python and int64 numpy."""
    if depth <= 0 or rng.random() < 0.25:
        r = rng.random()
        if r < 0.45:
            return Var(rng.choice(_VARS))
        if r < 0.75:
            return Const(rng.randint(1, 6))
        return Const(round(rng.uniform(0.25, 3.0), 3))
    op = rng.randrange(8)
    a = random_expr(rng, depth - 1)
    b = random_expr(rng, depth - 1)
    if op == 0:
        return Add(a, b)
    if op == 1:
        return Mul(a, b)
    if op == 2:
        return a - b
    if op == 3:
        return FloorDiv(a, Const(rng.randint(1, 5)))
    if op == 4:
        return CeilDiv(a, Const(rng.randint(1, 5)))
    if op == 5:
        return Max(a, b) if rng.random() < 0.5 else Min(a, b)
    if op == 6:
        return Piecewise([(a, b)], random_expr(rng, depth - 1))
    return Pow(a, rng.choice((0, 1, 2)))


def _check_compiled_matches_eval(seed: int) -> None:
    rng = random.Random(seed)
    e = random_expr(rng, depth=3)
    envs = [{v: rng.randint(1, 24) for v in _VARS} for _ in range(32)]
    pointwise = np.asarray([float(e.eval(env)) for env in envs])
    arr_env = {v: np.asarray([env[v] for env in envs], dtype=np.int64)
               for v in _VARS}
    compiled = np.broadcast_to(
        np.asarray(e.compile()(arr_env), dtype=np.float64), (len(envs),))
    np.testing.assert_allclose(compiled, pointwise, rtol=1e-12, atol=0)


@pytest.mark.parametrize("seed", range(40))
def test_compiled_expr_matches_eval_random_trees(seed):
    _check_compiled_matches_eval(seed)


@given(st.integers(min_value=0, max_value=2 ** 32 - 1))
@settings(max_examples=200, deadline=None)
def test_compiled_expr_matches_eval_hypothesis(seed):
    _check_compiled_matches_eval(seed)


# ---------------------------------------------------------------------------
# batched-vs-loop golden tests
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def sweep_cell():
    cfg = ARCHS["smollm-360m"]
    shape = SHAPES["train_4k"]
    plans = candidate_plans(cfg, shape)
    meshes = planspace.mesh_factorizations(64)
    return cfg, shape, plans, meshes


def test_planspace_scores_match_interpreted_loop(sweep_cell):
    cfg, shape, plans, meshes = sweep_cell
    space = planspace.PlanSpace.from_product(cfg, shape, plans, meshes)
    assert len(space) == len(plans) * len(meshes)
    batched = space.scores(None)
    loop = np.concatenate([
        predictor.predict_plans_loop(cfg, shape, plans, m) for m in meshes])
    # from_product is plan-major, the loop above mesh-major
    np.testing.assert_allclose(
        batched.reshape(len(plans), len(meshes)),
        loop.reshape(len(meshes), len(plans)).T, rtol=1e-9)


def test_predict_plans_routes_through_engine(sweep_cell):
    cfg, shape, plans, _ = sweep_cell
    mesh = {"data": 8, "model": 8}
    np.testing.assert_allclose(
        predictor.predict_plans(cfg, shape, plans, mesh),
        predictor.predict_plans_loop(cfg, shape, plans, mesh), rtol=1e-9)


def test_from_cells_matches_from_product(sweep_cell):
    cfg, shape, plans, meshes = sweep_cell
    prod_space = planspace.PlanSpace.from_product(cfg, shape, plans[:6],
                                                  meshes)
    cells = [(p, m) for p in plans[:6] for m in meshes]
    cell_space = planspace.PlanSpace.from_cells(cfg, shape, cells)
    np.testing.assert_array_equal(prod_space.dp, cell_space.dp)
    np.testing.assert_array_equal(prod_space.tp, cell_space.tp)
    np.testing.assert_array_equal(prod_space.n_dev, cell_space.n_dev)
    # product spaces score through the rank-1 profile fast path, cell
    # spaces through the generic unique-row path: same math, so only
    # rounding-order noise apart
    np.testing.assert_allclose(prod_space.scores(None),
                               cell_space.scores(None), rtol=1e-12)


def test_subset_preserves_cells(sweep_cell):
    cfg, shape, plans, meshes = sweep_cell
    space = planspace.PlanSpace.from_product(cfg, shape, plans, meshes)
    secs = space.scores(None)
    mask = np.zeros(len(space), dtype=bool)
    mask[::7] = True
    sub = space.subset(mask)
    assert len(sub) == int(mask.sum())
    # subsetting drops the product structure, so the subset rescores
    # through the generic path: rounding-order noise only
    np.testing.assert_allclose(sub.scores(None), secs[mask], rtol=1e-12)
    # the precomputed evaluation groups are remapped, not recomputed
    assert sub.remat_groups is not None and sub.topo_groups is not None
    assert sum(len(g) for g in sub.remat_groups.values()) == len(sub)


def test_empty_candidate_set(sweep_cell):
    cfg, shape, _, _ = sweep_cell
    space = planspace.PlanSpace.from_cells(cfg, shape, [])
    assert len(space) == 0
    assert space.scores(None).shape == (0,)
    assert space.feasible_mask().shape == (0,)
    assert planspace.peak_bytes(cfg, shape, [], []).shape == (0,)
    assert space.rank(None) == []
    assert predictor.predict_plans(cfg, shape, [], {"data": 2}).shape == (0,)


def test_subset_with_reordering_indices(sweep_cell):
    cfg, shape, plans, meshes = sweep_cell
    space = planspace.PlanSpace.from_product(cfg, shape, plans, meshes)
    secs = space.scores(None)
    order = np.argsort(space.peak_bytes(), kind="stable")[:37][::-1]
    sub = space.subset(order)
    np.testing.assert_allclose(sub.scores(None), secs[order], rtol=1e-12)
    assert [id(p) for p in sub.plans] == [id(space.plans[i]) for i in order]


# ---------------------------------------------------------------------------
# symbolic collectives vs the scalar reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["glm4-9b", "mixtral-8x7b"])
@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_collective_symbolic_matches_scalar(arch, kind):
    cfg = ARCHS[arch]
    env = {"B": 64, "S": 2048}
    for fsdp in (True, False):
        for compression in (None, "int8_ef"):
            for moe_mode in (("tp", "ep") if cfg.moe else ("tp",)):
                for dp, tp in ((1, 1), (1, 16), (16, 1), (4, 8)):
                    for mb in (1, 4):
                        plan = Plan(dp_axes=("data",), fsdp=fsdp,
                                    microbatches=mb, moe_mode=moe_mode,
                                    compression=compression)
                        mesh = {"data": dp, "model": tp}
                        ref = evaluate_vector(
                            archcount.collective_counts(cfg, kind, plan,
                                                        mesh), env)
                        sym = evaluate_vector(
                            archcount.collective_counts_symbolic(
                                cfg, kind,
                                archcount.collective_topology(plan)),
                            {**env, "M": mb, "DP": dp, "TP": tp})
                        for k, v in ref.items():
                            assert sym[k] == pytest.approx(v, rel=1e-12), \
                                (k, fsdp, compression, moe_mode, dp, tp)
                        for k, v in sym.items():  # extra keys must be gated off
                            if k not in ref:
                                assert v == 0.0, (k, dp, tp)


# ---------------------------------------------------------------------------
# vectorized HBM feasibility vs the scalar formula
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch,shname", [
    ("glm4-9b", "train_4k"), ("glm4-9b", "prefill_32k"),
    ("mixtral-8x7b", "decode_32k"), ("mamba2-370m", "decode_32k"),
    ("zamba2-2.7b", "train_4k")])
def test_peak_bytes_batched_matches_scalar(arch, shname):
    cfg, shape = ARCHS[arch], SHAPES[shname]
    plans = candidate_plans(cfg, shape)
    meshes = planspace.mesh_factorizations(256)
    space = planspace.PlanSpace.from_product(cfg, shape, plans, meshes)
    batched = space.peak_bytes()
    assert batched.shape == (len(space),)
    rng = random.Random(0)
    for i in rng.sample(range(len(space)), 25):
        scalar = predictor.estimate_peak_bytes(
            cfg, shape, space.plans[i], space.mesh_shapes[i])
        assert batched[i] == scalar, i
    mask = space.feasible_mask()
    assert mask.dtype == bool and mask.shape == (len(space),)


# ---------------------------------------------------------------------------
# mesh factorizations
# ---------------------------------------------------------------------------


def test_mesh_factorizations_cover_all_splits():
    meshes = planspace.mesh_factorizations(64)
    assert all(m["data"] * m["model"] == 64 for m in meshes)
    assert len(meshes) == len({(m["data"], m["model"]) for m in meshes}) == 7
    assert {m["data"] for m in meshes} == {1, 2, 4, 8, 16, 32, 64}
    # non-power-of-two counts factor too
    assert all(m["data"] * m["model"] == 48
               for m in planspace.mesh_factorizations(48))
    with pytest.raises(ValueError):
        planspace.mesh_factorizations(8, axes=("a", "b", "c"))


def test_elastic_factorizations_alias():
    from repro.distributed import elastic
    assert elastic._factorizations(36) == planspace.factor_pairs(36)


# ---------------------------------------------------------------------------
# autoshard mesh sweep + co-tuning
# ---------------------------------------------------------------------------


def test_autoshard_search_default_mesh_unchanged():
    from repro.launch import autoshard
    ranked = autoshard.search("smollm-360m", "train_4k", top_k=3)
    assert all(mesh == {"data": 16, "model": 16} for _, _, mesh in ranked)
    secs = [s for s, _, _ in ranked]
    assert secs == sorted(secs)


def test_autoshard_search_mesh_sweep():
    from repro.launch import autoshard
    ranked = autoshard.search("smollm-360m", "train_4k", n_devices=64,
                              top_k=8)
    assert ranked
    assert all(mesh["data"] * mesh["model"] == 64 for _, _, mesh in ranked)
    # training keeps exact batch semantics: dp divides the global batch
    assert all(SHAPES["train_4k"].global_batch % mesh["data"] == 0
               for _, _, mesh in ranked)
    secs = [s for s, _, _ in ranked]
    assert secs == sorted(secs)
    # the sweep can only improve on (or match) the fixed default mesh
    fixed = autoshard.search("smollm-360m", "train_4k",
                             meshes=[{"data": 8, "model": 8}], top_k=1)
    assert ranked[0][0] <= fixed[0][0] + 1e-12


def test_autoshard_multi_pod_rejects_device_sweep():
    from repro.launch import autoshard
    with pytest.raises(ValueError, match="multi_pod"):
        autoshard.search("smollm-360m", "train_4k", multi_pod=True,
                         n_devices=64)


def test_autoshard_tune_kernels_quadruples():
    from repro.launch import autoshard
    ranked = autoshard.search("smollm-360m", "train_4k", top_k=2,
                              tune_kernels=True)
    for entry in ranked:
        assert len(entry) == 4
        blocks = entry[3]
        assert "matmul" in blocks and "flash_attention" in blocks
        assert set(blocks["matmul"]) == {"block_m", "block_n", "block_k"}
        assert all(isinstance(v, int) for b in blocks.values()
                   for v in b.values())


# ---------------------------------------------------------------------------
# batched elastic replan / straggler threshold
# ---------------------------------------------------------------------------


def test_elastic_replan_matches_predict_step():
    from repro.distributed import elastic
    cfg, shape = ARCHS["smollm-360m"], SHAPES["train_4k"]
    opts = elastic.replan(cfg, shape, 64)
    assert opts
    for o in opts:
        ref = predictor.predict_step(cfg, shape, o.plan, o.shape).seconds
        assert o.predicted_step_s == pytest.approx(ref, rel=1e-9)
    secs = [o.predicted_step_s for o in opts]
    assert secs == sorted(secs)


# ---------------------------------------------------------------------------
# deterministic tie-breaks + bounded caches
# ---------------------------------------------------------------------------


def test_rank_plans_tie_break_is_enumeration_order_free():
    from repro.core.model import LinearCostModel
    cfg, shape = ARCHS["smollm-360m"], SHAPES["train_4k"]
    plans = candidate_plans(cfg, shape)
    # a model that scores every plan identically: only const1 is priced
    flat = LinearCostModel(keys=["const1"], weights=np.array([1.0]),
                           device="flat")
    mesh = {"data": 8, "model": 8}
    a = predictor.rank_plans(cfg, shape, plans, mesh, flat)
    shuffled = list(plans)
    random.Random(3).shuffle(shuffled)
    b = predictor.rank_plans(cfg, shape, shuffled, mesh, flat)
    assert [p for _, p in a] == [p for _, p in b]


def test_lru_cache_bounds_and_recency():
    c = LRUCache(maxsize=3)
    for i in range(3):
        c[i] = i * 10
    assert c.get(0) == 0          # refresh 0
    c[3] = 30                     # evicts 1 (LRU), not 0
    assert 0 in c and 3 in c and 1 not in c and len(c) == 3
    c[0] = 99                     # overwrite refreshes too
    assert c.get(0) == 99
    with pytest.raises(ValueError):
        LRUCache(0)


def test_step_pv_cache_is_bounded_lru():
    assert isinstance(predictor._STEP_PV_CACHE, LRUCache)
    assert predictor._STEP_PV_CACHE.maxsize <= 128
    assert isinstance(planspace._COLL_CV_CACHE, LRUCache)
