"""Fused basis-matrix lowering (core/exprops.py) tests.

Pins the equivalences the fused engine's speed claims rest on:

  * ``exprops.simplify`` preserves ``Expr.eval`` semantics EXACTLY on
    integer trees (seeded random trees over every node type, plus the
    hypothesis-driven version when installed) and to rounding noise on
    float trees;
  * a ``BasisProgram``'s property columns / GEMV scores match the
    per-property interpreted evaluation;
  * fused ``PlanSpace.scores`` ≡ the PR 3 column engine ≡ the per-plan
    interpreted loop (rtol ≤ 1e-9);
  * streamed-chunk top-k ≡ the full ``rank`` prefix (with and without HBM
    pruning), and ``rank``'s lexsort ordering ≡ the Python tuple-key sort;
  * incremental (``BasisCache``) rescores ≡ cold rescores, and a
    device-count delta reuses ≥ half of the basis columns;
  * the persistent compile cache: a second build with the same key skips
    the builder and the loaded program scores identically.

Plus the satellites: cached ``Expr`` repr/hash (no re-walk on repeat
probes), warm/cold disk-cache reporting.
"""
from __future__ import annotations

import json
import random

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS
from repro.core import exprops, planspace, predictor
from repro.core.model import LinearCostModel
from repro.core.symcount import (
    Add, CeilDiv, Const, Expr, FloorDiv, Max, Min, Mul, Piecewise, Pow,
    Var, compile_vector, evaluate_vector,
)
from repro.launch.autoshard import candidate_plans

_VARS = ("x", "y", "z")


# ---------------------------------------------------------------------------
# simplify ≡ eval (property-based)
# ---------------------------------------------------------------------------


def random_int_expr(rng: random.Random, depth: int) -> Expr:
    """Random trees over every node type with INTEGER constants only, so
    Python's arbitrary-precision arithmetic makes ``simplify`` exact."""
    if depth <= 0 or rng.random() < 0.25:
        if rng.random() < 0.55:
            return Var(rng.choice(_VARS))
        return Const(rng.randint(-4, 6))
    op = rng.randrange(9)
    a = random_int_expr(rng, depth - 1)
    b = random_int_expr(rng, depth - 1)
    if op == 0:
        return Add(a, b)
    if op == 1:
        return Mul(a, b)
    if op == 2:
        return a - b
    if op == 3:
        return FloorDiv(a, Const(rng.randint(1, 5)))
    if op == 4:
        return CeilDiv(a, Const(rng.randint(1, 5)))
    if op == 5:
        return Max(a, b) if rng.random() < 0.5 else Min(a, b)
    if op == 6:
        return Piecewise([(a, b)], random_int_expr(rng, depth - 1))
    if op == 7:
        return Piecewise([(Const(rng.randint(-1, 1)), a)], b)
    return Pow(a, rng.choice((0, 1, 2)))


def _check_simplify_matches_eval(seed: int) -> None:
    rng = random.Random(seed)
    e = random_int_expr(rng, depth=4)
    s = exprops.simplify(e)
    for _ in range(8):
        env = {v: rng.randint(-5, 12) for v in _VARS}
        assert s.eval(env) == e.eval(env), (e, s, env)


@pytest.mark.parametrize("seed", range(60))
def test_simplify_matches_eval_random_trees(seed):
    _check_simplify_matches_eval(seed)


@given(st.integers(min_value=0, max_value=2 ** 32 - 1))
@settings(max_examples=200, deadline=None)
def test_simplify_matches_eval_hypothesis(seed):
    _check_simplify_matches_eval(seed)


@pytest.mark.parametrize("seed", range(20))
def test_simplify_float_trees_close(seed):
    """Float constants may reassociate under canonicalization — pinned to
    rounding noise, mirroring the engine's 1e-9 score equivalence bar."""
    rng = random.Random(seed)

    def rand_float_expr(depth):
        if depth <= 0 or rng.random() < 0.3:
            return Var(rng.choice(_VARS)) if rng.random() < 0.5 \
                else Const(round(rng.uniform(-2.0, 3.0), 3))
        a, b = rand_float_expr(depth - 1), rand_float_expr(depth - 1)
        return rng.choice((Add(a, b), Mul(a, b), a - b, Max(a, b),
                           Min(a, b)))

    e = rand_float_expr(4)
    s = exprops.simplify(e)
    for _ in range(8):
        env = {v: rng.randint(1, 9) for v in _VARS}
        assert s.eval(env) == pytest.approx(e.eval(env), rel=1e-12, abs=1e-9)


def test_simplify_canonical_rewrites():
    x, y = Var("x"), Var("y")
    # constant folding + like-term collection
    assert repr(exprops.simplify((x + 0) * 1 + x + 2 * x + Const(3)
                                 + Const(4))) == "(4*x + 7)"
    # zero annihilation and Pow identities
    assert repr(exprops.simplify(Mul(Const(0), x) + Pow(x, 1))) == "x"
    assert repr(exprops.simplify(Pow(x, 0))) == "1"
    # constant distributes over a sum so shared addends stay visible
    assert repr(exprops.simplify(2 * (x + y))) == "(2*x + 2*y)"
    # Max flattening, dedup, constant pre-fold
    m = exprops.simplify(Max(Max(x, Const(2)), x, Const(5)))
    assert repr(m) == "max(5, x)"
    # Piecewise: else-chain hoisting + constant-guard resolution
    pw = Piecewise([(x - 1, y)], Piecewise([(Const(2), Const(7))],
                                           Const(9)))
    s = exprops.simplify(pw)
    assert isinstance(s, Piecewise) and len(s.branches) == 1
    assert repr(s.otherwise) == "7"     # const guard 2>0 always fires
    # dead constant guard drops its branch entirely
    assert repr(exprops.simplify(Piecewise([(Const(0), x)], y))) == "y"
    # a branch whose value equals the fallthrough is dropped
    assert repr(exprops.simplify(Piecewise([(x, y)], y))) == "y"


# ---------------------------------------------------------------------------
# BasisProgram ≡ interpreted property evaluation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(25))
def test_program_columns_match_interpreted(seed):
    rng = random.Random(seed)
    pv = {f"p{i}": random_int_expr(rng, 3) for i in range(5)}
    pv["p_const"] = 3.5
    prog = exprops.build_program(pv)
    n = 16
    env = {v: np.asarray([rng.randint(1, 24) for _ in range(n)],
                         dtype=np.int64) for v in _VARS}
    cols = prog.property_columns(env, n)
    for k, v in pv.items():
        ref = [float(v.eval({vn: int(env[vn][i]) for vn in _VARS}))
               if isinstance(v, Expr) else float(v) for i in range(n)]
        np.testing.assert_allclose(cols[k], ref, rtol=1e-9, atol=1e-9,
                                   err_msg=k)
    # GEMV score ≡ weighted interpreted sum, cached and uncached
    model = LinearCostModel.from_dict(
        {k: rng.uniform(0.5, 2.0) for k in pv})
    ref = np.zeros(n)
    for k, w in zip(model.keys, model.weights):
        ref += w * np.asarray(cols[k])
    np.testing.assert_allclose(
        exprops.score_cells(prog, env, n, model), ref, rtol=1e-9)
    cache = exprops.BasisCache()
    np.testing.assert_allclose(
        exprops.score_cells(prog, env, n, model, cache), ref, rtol=1e-9)
    np.testing.assert_allclose(                      # warm pass
        exprops.score_cells(prog, env, n, model, cache), ref, rtol=1e-9)
    assert cache.hits > 0
    # basis matrix: B @ Cᵀ + const reproduces every property column
    B = prog.matrix(env, n)
    assert B.shape == (n, prog.n_terms)
    P = B @ prog.coeff.T + prog.const
    for j, k in enumerate(prog.keys):
        np.testing.assert_allclose(P[:, j], cols[k], rtol=1e-12)


def test_program_json_roundtrip_scores_identically():
    rng = random.Random(7)
    pv = {f"p{i}": random_int_expr(rng, 3) for i in range(4)}
    prog = exprops.build_program(pv)
    clone = exprops.BasisProgram.from_json_dict(
        json.loads(json.dumps(prog.to_json_dict())))
    model = LinearCostModel.from_dict({k: 1.25 for k in pv})
    n = 8
    env = {v: np.arange(1, n + 1, dtype=np.int64) for v in _VARS}
    np.testing.assert_array_equal(
        exprops.score_cells(prog, env, n, model),
        exprops.score_cells(clone, env, n, model))
    # the cached per-term path works on a loaded program too (term lambdas
    # rebuild from their serialized sources)
    np.testing.assert_allclose(
        exprops.score_cells(clone, env, n, model, exprops.BasisCache()),
        exprops.score_cells(prog, env, n, model), rtol=1e-12)


def test_program_stale_format_rejected():
    d = exprops.build_program({"p": Var("x")}).to_json_dict()
    d["format"] = -1
    with pytest.raises(ValueError):
        exprops.BasisProgram.from_json_dict(d)


# ---------------------------------------------------------------------------
# fused ≡ columns ≡ interpreted loop goldens
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def sweep_cell():
    cfg = ARCHS["smollm-360m"]
    shape = SHAPES["train_4k"]
    plans = candidate_plans(cfg, shape)
    meshes = planspace.mesh_factorizations(64) \
        + planspace.mesh_factorizations(48)
    return cfg, shape, plans, meshes


def test_fused_scores_match_columns_and_loop(sweep_cell):
    cfg, shape, plans, meshes = sweep_cell
    space = planspace.PlanSpace.from_product(cfg, shape, plans, meshes)
    fused = space.scores(None)
    cols = space.scores_columns(None)
    np.testing.assert_allclose(fused, cols, rtol=1e-9)
    loop = np.concatenate([
        predictor.predict_plans_loop(cfg, shape, plans, m) for m in meshes])
    np.testing.assert_allclose(
        fused.reshape(len(plans), len(meshes)),
        loop.reshape(len(meshes), len(plans)).T, rtol=1e-9)


def test_fused_step_program_matches_compiled_vector(sweep_cell):
    cfg, shape, plans, _ = sweep_cell
    from repro.core.workload import WorkloadSpec
    prog = predictor.step_program(cfg, WorkloadSpec(phase="train"), "full")
    cv = predictor.step_vector_fn(cfg, WorkloadSpec(phase="train"), "full")
    env = {"B": shape.global_batch, "S": shape.seq_len,
           "M": np.asarray([1, 2, 4, 8], dtype=np.int64)}
    model = predictor.resolve_model(None)
    ref = np.zeros(4)
    w = dict(zip(model.keys, model.weights))
    for k, v in cv(env).items():
        if w.get(k):
            ref += w[k] * np.broadcast_to(
                np.asarray(v, dtype=np.float64), (4,))
    np.testing.assert_allclose(
        exprops.score_cells(prog, env, 4, model), ref, rtol=1e-9)


# ---------------------------------------------------------------------------
# rank: lexsort ordering + argpartition top-k ≡ the tuple-key reference
# ---------------------------------------------------------------------------


def _reference_rank(space, model):
    secs = space.scores(model)
    order = sorted(range(len(space)),
                   key=lambda i: (secs[i],
                                  planspace.plan_sort_key(space.plans[i]),
                                  planspace.mesh_sort_key(
                                      space.mesh_shapes[i])))
    return [(float(secs[i]), space.plans[i], space.mesh_shapes[i])
            for i in order]


@pytest.mark.parametrize("model_kind", ["seed", "flat"])
def test_rank_lexsort_matches_tuple_sort(sweep_cell, model_kind):
    cfg, shape, plans, meshes = sweep_cell
    space = planspace.PlanSpace.from_product(cfg, shape, plans[:40], meshes)
    # "flat" scores every cell identically, exercising pure tie-breaks
    model = None if model_kind == "seed" else LinearCostModel(
        keys=["const1"], weights=np.array([1.0]), device="flat")
    assert space.rank(model) == _reference_rank(space, model)


def test_rank_topk_is_full_prefix(sweep_cell):
    cfg, shape, plans, meshes = sweep_cell
    space = planspace.PlanSpace.from_product(cfg, shape, plans, meshes)
    full = space.rank(None)
    for k in (0, 1, 5, 23, len(space), len(space) + 7):
        assert space.rank(None, top_k=k) == full[:k]


# ---------------------------------------------------------------------------
# streaming: chunked top-k ≡ full rank prefix, bounded pools
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chunk", [37, 300, 10 ** 7])
def test_stream_topk_matches_rank_prefix(sweep_cell, chunk):
    cfg, shape, plans, meshes = sweep_cell
    space = planspace.PlanSpace.from_product(cfg, shape, plans, meshes)
    full = space.rank(None)
    for k in (1, 7, 19):
        stats = {}
        got = planspace.stream_topk(cfg, shape, plans, meshes, None, k=k,
                                    chunk_cells=chunk, stats=stats)
        assert got == full[:k]
        assert stats["cells"] == len(space)
        assert stats["max_chunk_cells"] <= max(chunk, len(meshes))
        assert stats["pool_high_water"] <= k + chunk + len(meshes)


def test_stream_topk_hbm_pruning(sweep_cell):
    cfg, shape, plans, meshes = sweep_cell
    space = planspace.PlanSpace.from_product(cfg, shape, plans, meshes)
    budget = float(np.median(space.peak_bytes()))  # force real pruning
    secs = space.scores(None)
    mask = space.feasible_mask(budget)
    order = planspace._rank_order(
        secs, space.plans, space.mesh_shapes)
    expected = [(float(secs[i]), space.plans[i], space.mesh_shapes[i])
                for i in order if mask[i]][:8]
    stats = {}
    got = planspace.stream_topk(cfg, shape, plans, meshes, None, k=8,
                                chunk_cells=256, hbm_budget=budget,
                                stats=stats)
    assert got == expected
    assert stats["pruned_cells"] == int((~mask).sum())


def test_stream_topk_pool_stays_bounded_under_total_ties(sweep_cell):
    """A model blind to the mesh scores every cell identically; tie
    closure alone would retain the whole space.  The pool must stay
    bounded AND the result must still be the exact rank prefix."""
    cfg, shape, plans, meshes = sweep_cell
    flat = LinearCostModel(keys=["const1"], weights=np.array([1.0]),
                           device="flat")
    space = planspace.PlanSpace.from_product(cfg, shape, plans, meshes)
    full = space.rank(flat)
    k = 5
    stats = {}
    got = planspace.stream_topk(cfg, shape, plans, meshes, flat, k=k,
                                chunk_cells=64, stats=stats)
    assert got == full[:k]
    assert stats["pool_high_water"] <= k + 512 + 64 + len(meshes)
    assert stats["pool_high_water"] < len(space) // 2


def test_stream_topk_empty_and_degenerate(sweep_cell):
    cfg, shape, plans, meshes = sweep_cell
    assert planspace.stream_topk(cfg, shape, [], meshes, None, k=3) == []
    assert planspace.stream_topk(cfg, shape, plans, [], None, k=3) == []
    assert planspace.stream_topk(cfg, shape, plans, meshes, None, k=0) == []
    # a budget nothing satisfies yields an empty result, not a crash
    assert planspace.stream_topk(cfg, shape, plans[:4], meshes, None, k=3,
                                 hbm_budget=1.0) == []


# ---------------------------------------------------------------------------
# incremental rescoring (BasisCache)
# ---------------------------------------------------------------------------


def test_incremental_rescore_matches_cold_after_device_delta(sweep_cell):
    cfg, shape, plans, _ = sweep_cell
    model = predictor.resolve_model(None)
    cache = exprops.BasisCache()
    for n_dev in (64, 63):  # second space: a single device-count delta
        meshes = planspace.mesh_factorizations(n_dev)
        cells = [(p, m) for p in plans[:10] for m in meshes]
        space = planspace.PlanSpace.from_cells(cfg, shape, cells)
        cold = space.scores(model)
        warm = space.scores(model, cache=cache)
        np.testing.assert_allclose(warm, cold, rtol=1e-12)
    # the delta only touches DP/TP-keyed columns: ≥ half came from cache
    assert cache.hits >= cache.misses > 0


def test_elastic_replan_reuses_basis_columns(sweep_cell):
    from repro.distributed import elastic
    cfg, shape, _, _ = sweep_cell
    model = predictor.resolve_model(None)
    elastic.replan(cfg, shape, 64, model)
    h0, m0 = elastic._BASIS_CACHE.hits, elastic._BASIS_CACHE.misses
    opts = elastic.replan(cfg, shape, 63, model)
    h1, m1 = elastic._BASIS_CACHE.hits, elastic._BASIS_CACHE.misses
    assert (h1 - h0) >= (m1 - m0), "device delta must reuse >= half"
    # incremental scores stay pinned to the interpreted predictor
    for o in opts:
        ref = predictor.predict_step(cfg, shape, o.plan, o.shape).seconds
        assert o.predicted_step_s == pytest.approx(ref, rel=1e-9)


def test_straggler_monitor_scores_through_cache(sweep_cell):
    from repro.runtime.straggler import StragglerMonitor, _BASIS_CACHE
    cfg, shape, plans, _ = sweep_cell
    mesh = {"data": 8, "model": 8}
    mon = StragglerMonitor.from_model(cfg, shape, plans[0], mesh, n_hosts=4)
    ref = predictor.predict_plans(cfg, shape, [plans[0]], mesh)
    assert mon.predicted_step_s == pytest.approx(float(ref[0]), rel=1e-9)
    probes = _BASIS_CACHE.hits + _BASIS_CACHE.misses
    assert probes > 0


# ---------------------------------------------------------------------------
# persistent on-disk compile cache
# ---------------------------------------------------------------------------


def test_disk_cache_warm_second_build(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_COMPILE_CACHE", str(tmp_path))
    calls = []

    def builder():
        calls.append(1)
        return {"p": Var("x") * 3 + 1, "q": CeilDiv(Var("x"), Const(4))}

    key = exprops.program_key("test-program", "v1")
    p1 = exprops.load_or_build(key, builder)
    p2 = exprops.load_or_build(key, builder)
    assert len(calls) == 1, "second build must come from disk"
    model = LinearCostModel.from_dict({"p": 2.0, "q": 0.5})
    env = {"x": np.arange(1, 9, dtype=np.int64)}
    np.testing.assert_array_equal(exprops.score_cells(p1, env, 8, model),
                                  exprops.score_cells(p2, env, 8, model))
    # a different key is a different program
    other = exprops.program_key("test-program", "v2")
    exprops.load_or_build(other, builder)
    assert len(calls) == 2


def test_disk_cache_disabled_and_report(monkeypatch):
    monkeypatch.setenv("REPRO_COMPILE_CACHE", "off")
    assert exprops.compile_cache_dir() is None
    assert exprops.disk_cache_report() == "compile cache: disabled"
    monkeypatch.setenv("REPRO_COMPILE_CACHE", "/tmp/somewhere")
    assert exprops.compile_cache_dir() == "/tmp/somewhere"
    assert exprops.disk_cache_report().startswith("compile cache:")


def test_program_key_changes_with_inputs():
    k1 = exprops.program_key("step", "cfg-a", "train", "full")
    k2 = exprops.program_key("step", "cfg-a", "train", "dots")
    k3 = exprops.program_key("step", "cfg-b", "train", "full")
    assert len({k1, k2, k3}) == 3


# ---------------------------------------------------------------------------
# satellite: cached Expr repr/hash (no tree re-walks on repeat probes)
# ---------------------------------------------------------------------------


def _deep_tree(depth: int) -> Expr:
    e = Var("x")
    f = Var("y")
    for i in range(depth):
        e = Add(Mul(e, Const(2)), f) if i % 2 else Mul(Add(e, f), Const(3))
    return e


def test_expr_hash_does_not_rewalk(monkeypatch):
    e1 = _deep_tree(60)
    e2 = _deep_tree(60)
    h1, h2 = hash(e1), hash(e2)      # populate the repr/hash caches
    assert h1 == h2 and e1 == e2
    calls = {"n": 0}
    orig = Add._render

    def counting(self):
        calls["n"] += 1
        return orig(self)

    monkeypatch.setattr(Add, "_render", counting)
    assert hash(e1) == h1
    assert repr(e2) and e1 == e2     # equality probes reuse cached reprs
    assert calls["n"] == 0, "hash/eq after first use must not re-serialize"


def test_expr_hash_eq_still_structural():
    a = Add(Var("x"), Const(1))
    b = Add(Var("x"), Const(1))
    c = Add(Var("x"), Const(2))
    assert a == b and hash(a) == hash(b)
    assert a != c
    assert len({a, b, c}) == 2
