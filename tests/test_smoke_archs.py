"""Per-architecture smoke tests (deliverable f).

Each assigned architecture is instantiated at a REDUCED same-family config
and runs one forward + one train-grad step + one decode step on CPU,
asserting output shapes and absence of NaNs.  Full configs are exercised
only via the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.models import transformer

ARCH_IDS = sorted(ARCHS)


def make_batch(cfg, B=2, S=32, key=None):
    key = key or jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    tok_shape = (B, S, cfg.n_input_codebooks) if cfg.n_input_codebooks > 1 else (B, S)
    batch = {
        "tokens": jax.random.randint(k1, tok_shape, 0, cfg.vocab_size, jnp.int32),
        "labels": jax.random.randint(k2, tok_shape, 0, cfg.vocab_size, jnp.int32),
    }
    if cfg.vision_tokens:
        batch["vision_embeds"] = jnp.ones(
            (B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16) * 0.01
        mask = jnp.ones((B, S), jnp.float32)
        batch["loss_mask"] = mask.at[:, :cfg.vision_tokens].set(0.0)
    return batch


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = ARCHS[request.param].reduced()
    params, axes = transformer.init_params(cfg, jax.random.PRNGKey(1))
    return cfg, params, axes


def test_forward_shapes_no_nan(arch_setup):
    cfg, params, _ = arch_setup
    batch = make_batch(cfg)
    logits, aux = jax.jit(
        lambda p, b: transformer.forward(p, cfg, b))(params, batch)
    B, S = batch["tokens"].shape[:2]
    if cfg.n_output_heads > 1:
        assert logits.shape == (B, S, cfg.n_output_heads, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))
    assert np.isfinite(float(aux))


def test_train_grad_step(arch_setup):
    cfg, params, _ = arch_setup
    batch = make_batch(cfg)

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(
            transformer.loss_fn, has_aux=True)(p, cfg, b)
        return loss, grads

    loss, grads = step(params, batch)
    assert np.isfinite(float(loss)), cfg.name
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0.0


def test_decode_step(arch_setup):
    cfg, params, _ = arch_setup
    B, S = 2, 16
    state = transformer.init_decode_state(cfg, B, S)
    state["pos"] = jnp.asarray(S - 1, jnp.int32)
    tok_shape = (B, 1, cfg.n_input_codebooks) if cfg.n_input_codebooks > 1 else (B, 1)
    tokens = jnp.zeros(tok_shape, jnp.int32)
    logits, new_state = jax.jit(
        lambda p, s, t: transformer.decode_step(p, cfg, s, t))(
            params, state, tokens)
    if cfg.n_output_heads > 1:
        assert logits.shape == (B, 1, cfg.n_output_heads, cfg.vocab_size)
    else:
        assert logits.shape == (B, 1, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))
    assert int(new_state["pos"]) == S


def test_param_count_matches_closed_form(arch_setup):
    """The symbolic n_params() (used by the cost model) must match the real
    parameter tree — on the reduced config, exactly."""
    cfg, params, _ = arch_setup
    actual = transformer.param_count(params)
    predicted = cfg.n_params()
    assert actual == predicted, (cfg.name, actual, predicted)
