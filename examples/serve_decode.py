"""Batched serving example: continuous-batching decode server on a reduced
GLM-4-family model, with cost-model-predicted per-token latency.

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import numpy as np

from repro.configs.base import SHAPES
from repro.configs.registry import get_arch
from repro.core import predictor
from repro.distributed.plan import plan_for
from repro.models import transformer
from repro.runtime.server import DecodeServer, Request


def main():
    cfg = get_arch("glm4-9b").reduced()
    params, _ = transformer.init_params(cfg, jax.random.PRNGKey(0))
    server = DecodeServer(cfg, params, slots=4, max_len=128, seed=0)

    # cost-model prediction for the FULL arch on the production mesh —
    # what this decode step would cost on 256 chips
    full = get_arch("glm4-9b")
    shape = SHAPES["decode_32k"]
    plan = plan_for(full, shape)
    pred = predictor.predict_step(full, shape, plan,
                                  {"data": 16, "model": 16})
    print(f"[serve] full glm4-9b decode_32k on 16x16 v5e: predicted "
          f"{pred.seconds*1e3:.2f} ms/token/batch "
          f"(dominant: {max(pred.terms, key=pred.terms.get)})")

    rng = np.random.default_rng(0)
    for rid in range(10):
        plen = int(rng.integers(4, 12))
        server.submit(Request(
            rid=rid,
            prompt=rng.integers(2, cfg.vocab_size, plen).astype(np.int32),
            max_new=16))

    t0 = time.perf_counter()
    done = server.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    print(f"[serve] reduced model on CPU: {len(done)} requests, "
          f"{toks} tokens in {dt:.2f}s ({toks/max(dt,1e-9):.1f} tok/s)")
    assert len(done) == 10 and all(len(r.out) >= 1 for r in done)
    for r in done[:3]:
        print(f"  req {r.rid}: {len(r.prompt)}-token prompt -> "
              f"{len(r.out)} new tokens")


if __name__ == "__main__":
    main()
