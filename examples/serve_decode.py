"""Batched serving example: continuous-batching decode server on a reduced
GLM-4-family model, with cost-model-predicted per-token latency and
model-informed admission (``admission="model"``): each refill decision is
scored through the fused decode/prefill basis programs and prints an
``[admit] … policy=model`` line (CI's decode-server smoke greps for it).

    PYTHONPATH=src python examples/serve_decode.py
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import SHAPES
from repro.configs.registry import get_arch
from repro.core import predictor
from repro.core.workload import WorkloadSpec
from repro.distributed.plan import plan_for
from repro.models import transformer
from repro.runtime.server import DecodeServer, Request, simulate_serving


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace-json", default=None,
                    help="write a Chrome-trace/Perfetto JSON of the serve "
                         "run (prefill/decode spans + predicted overlay)")
    ap.add_argument("--metrics-json", default=None,
                    help="dump the metrics registry as JSON at exit")
    args = ap.parse_args()

    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as obs_trace
    if args.trace_json:
        obs_trace.enable(process_name="serve_decode")

    cfg = get_arch("glm4-9b").reduced()
    params, _ = transformer.init_params(cfg, jax.random.PRNGKey(0))
    server = DecodeServer(cfg, params, slots=4, max_len=128, seed=0,
                          admission="model")

    # cost-model prediction for the FULL arch on the production mesh —
    # what this decode step would cost on 256 chips
    full = get_arch("glm4-9b")
    shape = SHAPES["decode_32k"]
    plan = plan_for(full, shape)
    pred = predictor.predict_step(full, shape, plan,
                                  {"data": 16, "model": 16})
    print(f"[serve] full glm4-9b decode_32k on 16x16 v5e: predicted "
          f"{pred.seconds*1e3:.2f} ms/token/batch "
          f"(dominant: {max(pred.terms, key=pred.terms.get)})")

    # occupancy-refined spec: the same fused program rescored at half-full
    # slots / half context — the refinement the admission scorer sweeps
    half = WorkloadSpec(phase="decode", global_batch=shape.global_batch,
                        seq_len=shape.seq_len,
                        active_slots=shape.global_batch // 2,
                        cache_tokens=shape.global_batch * shape.seq_len / 2)
    pred_half = predictor.predict_step(full, half, plan,
                                       {"data": 16, "model": 16})
    print(f"[serve] same cell at 50% slot occupancy / context: "
          f"{pred_half.seconds*1e3:.2f} ms/token/batch")

    # mixed prompt lengths, LONG ones first — the adversarial arrival order
    # for FIFO admission; the model policy reorders by predicted cost
    rng = np.random.default_rng(0)
    plens = [24, 20, 4, 5, 4, 6, 5, 4, 6, 5]
    for rid, plen in enumerate(plens):
        server.submit(Request(
            rid=rid,
            prompt=rng.integers(2, cfg.vocab_size, plen).astype(np.int32),
            max_new=16))

    t0 = time.perf_counter()
    done = server.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    print(f"[serve] reduced model on CPU: {len(done)} requests, "
          f"{toks} tokens in {dt:.2f}s ({toks/max(dt,1e-9):.1f} tok/s)")
    assert len(done) == 10 and all(len(r.out) >= 1 for r in done)
    for r in done[:3]:
        print(f"  req {r.rid}: {len(r.prompt)}-token prompt -> "
              f"{len(r.out)} new tokens")

    # the policies compared under the model's own physics (full arch)
    sim_m = simulate_serving(full, [2048, 1024] + [16] * 8, 32,
                             slots=4, max_len=4096, policy="model")
    sim_f = simulate_serving(full, [2048, 1024] + [16] * 8, 32,
                             slots=4, max_len=4096, policy="fifo")
    print(f"[serve] simulated mean latency (model admission): "
          f"{sim_m['mean_latency_s']*1e3:.2f} ms vs fifo "
          f"{sim_f['mean_latency_s']*1e3:.2f} ms "
          f"({sim_f['mean_latency_s']/max(sim_m['mean_latency_s'],1e-12):.2f}x)")

    tracer = obs_trace.get_tracer()
    if args.trace_json:
        for line in tracer.report_lines():
            print(f"[trace] {line}")
        tracer.save(args.trace_json)
        print(f"[example] trace written to {args.trace_json}")
    if args.metrics_json:
        obs_metrics.REGISTRY.save_json(args.metrics_json)
        print(f"[example] metrics written to {args.metrics_json}")


if __name__ == "__main__":
    main()
