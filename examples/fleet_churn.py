"""Fleet churn walkthrough: allocate the demo manifest across two
device pools, shrink a pool mid-run (watch the degradation ladder warm-
replan one job and migrate another), then grow it back and watch the
hysteresis-damped resume/rebalance path (docs/FLEET.md).

    PYTHONPATH=src python examples/fleet_churn.py
"""
from repro.launch.fleet import FleetAllocator, demo_manifest
from repro.runtime.faults import FaultInjector, FaultPlan
from repro.runtime.fleet_supervisor import FleetSupervisor, SimJobRunner


def show(tag, assignment):
    print(f"\n== {tag} ==")
    for name, p in sorted(assignment.placements.items()):
        mesh = "x".join(f"{k}={v}" for k, v in p.mesh)
        print(f"  {name:9s} -> {p.pool}:{p.devices} ({p.device}) "
              f"mesh {mesh} pred {p.predicted_step_s * 1e3:.2f} ms "
              f"({p.tokens_per_s:,.0f} tok/s)")
    for name, why in sorted(assignment.paused.items()):
        print(f"  {name:9s} -> PAUSED ({why})")


def main():
    manifest = demo_manifest()

    # phase 1: model-guided allocation over the heterogeneous pools
    allocator = FleetAllocator(manifest)
    assignment = allocator.allocate()
    show("initial allocation", assignment)
    stats = allocator.cache_stats()
    print(f"  basis cache after allocate: {stats['hits']} hits / "
          f"{stats['misses']} misses")

    # phase 2: seeded churn — shrink a100 by 2 at step 5 (ladder:
    # warm replan -> migrate), grow it back at step 10 (hysteresis-
    # damped resume/rebalance)
    plan = FaultPlan.parse(
        "pool_shrink@5:pool=a100,k=2;pool_grow@10:pool=a100,k=2", seed=7)
    sup = FleetSupervisor(allocator, assignment=assignment,
                          injector=FaultInjector(plan),
                          runner_factory=SimJobRunner.factory())
    sup.run(14)
    show("after churn", sup.assignment)
    print(f"  ladder actions: {sup.actions}")

    # phase 3: the placement history is the audit trail — same manifest
    # + same FaultPlan seed reproduces it byte-for-byte
    events = [e["event"] for e in sup.placement_history]
    print(f"  history events: {events}")


if __name__ == "__main__":
    main()
