"""Cost-model-guided Pallas block-size autotuning, end to end.

Demonstrates the kernel-level prediction granularity (paper §6.2: use the
fitted model to "select the optimal set of kernel configurations"):

  1. enumerate the valid block-size grid for a kernel + shape;
  2. score EVERY candidate through a registry model with ONE compiled
     vectorized sweep (``Expr.compile``) — and show the speedup over
     per-point interpreted ``Expr.eval``;
  3. compare the model-chosen tiling across devices (the cross-GPU claim:
     same property vectors, different fitted weights, different winners
     possible);
  4. run a kernel with ``block_sizes="auto"`` and check it against the
     reference implementation.

    PYTHONPATH=src python examples/kernel_autotune.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import autotune, ops, ref


def sweep(kernel: str, shape: dict, models=("tpu-v5e", "gpu-a100",
                                            "gpu-h100")) -> None:
    cands = autotune.candidate_configs(kernel, shape)
    print(f"\n=== {kernel} {shape} — {len(cands)} candidates ===")

    # compiled vs interpreted scoring (identical results, one is a sweep);
    # warm once so the one-time Expr.compile codegen isn't in the timing
    autotune.score_configs(kernel, shape, cands)
    t0 = time.perf_counter()
    compiled = autotune.score_configs(kernel, shape, cands)
    t_c = time.perf_counter() - t0
    t0 = time.perf_counter()
    interp = autotune.score_configs_interpreted(kernel, shape, cands)
    t_i = time.perf_counter() - t0
    np.testing.assert_allclose(compiled, interp, rtol=1e-12)
    print(f"scoring: compiled {t_c*1e3:.2f} ms vs interpreted "
          f"{t_i*1e3:.2f} ms  ({t_i/t_c:.0f}x)")

    for device in models:
        ranked = autotune.rank_block_sizes(kernel, shape, device)
        best_s, best = ranked[0]
        worst_s, _ = ranked[-1]
        print(f"{device:>10s}: best {best}  "
              f"{best_s*1e6:8.1f} µs  (worst {worst_s*1e6:8.1f} µs, "
              f"{worst_s/best_s:.1f}x slower)")


def auto_kernel_check() -> None:
    print("\n=== block_sizes='auto' correctness (interpret mode) ===")
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.normal(k1, (256, 512), jnp.float32)
    b = jax.random.normal(k2, (512, 384), jnp.float32)
    o = ops.matmul(a, b, block_sizes="auto", interpret=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref.matmul(a, b)),
                               atol=1e-3, rtol=1e-5)
    print("auto-tuned matmul matches reference:",
          autotune.best_block_sizes(
              "matmul", {"M": 256, "K": 512, "N": 384, "bits": 32}))


def main() -> None:
    sweep("matmul", {"M": 4096, "N": 4096, "K": 4096, "bits": 16})
    sweep("flash_attention", {"B": 8, "H": 32, "KVH": 8, "Sq": 8192,
                              "Skv": 8192, "dh": 128, "causal": True,
                              "window": None, "bits": 16})
    sweep("ssd_scan", {"Bz": 8, "H": 64, "L": 8192, "P": 64, "N": 128,
                       "bits": 16})
    sweep("transpose", {"M": 8192, "N": 8192, "bits": 32})
    auto_kernel_check()


if __name__ == "__main__":
    main()
