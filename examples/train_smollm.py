"""End-to-end training driver: a ~100M-parameter SmolLM-family model
trained for a few hundred steps on the synthetic corpus, with async
checkpointing, kill-and-resume, and cost-model step-time prediction.

    PYTHONPATH=src python examples/train_smollm.py [--steps 300]

The config is a width/depth-reduced SmolLM (still the same family:
GQA + RoPE + SwiGLU + tied embeddings); on a TPU slice the same driver
trains the full config via --full.
"""
import argparse
import dataclasses
import os
import shutil

from repro.configs.registry import get_arch
from repro.data.pipeline import DataConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def hundred_m_config():
    """~100M-param SmolLM-family config that trains in CPU minutes."""
    base = get_arch("smollm-360m")
    return dataclasses.replace(
        base, name="smollm-100m", n_layers=6, d_model=512, n_heads=8,
        n_kv_heads=4, head_dim=64, d_ff=1536, vocab_size=49152,
        remat_policy="none",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_smollm_ckpt")
    ap.add_argument("--resume", action="store_true",
                    help="keep existing checkpoints (kill-and-resume demo)")
    ap.add_argument("--full", action="store_true",
                    help="train the full 360M config (TPU-scale)")
    ap.add_argument("--online-calibrate", action="store_true",
                    help="stream step timings into the online calibrator "
                         "(RLS refit + drift watch)")
    ap.add_argument("--telemetry-json", default=None,
                    help="write the telemetry ring buffer to this JSON "
                         "file at exit (requires --online-calibrate)")
    ap.add_argument("--trace-json", default=None,
                    help="write a Chrome-trace/Perfetto JSON of the run "
                         "(measured step spans + predicted overlay)")
    ap.add_argument("--metrics-json", default=None,
                    help="dump the metrics registry as JSON at exit")
    args = ap.parse_args()

    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as obs_trace
    if args.trace_json:
        obs_trace.enable(process_name="train_smollm")

    cfg = get_arch("smollm-360m") if args.full else hundred_m_config()
    print(f"[example] {cfg.name}: {cfg.n_params()/1e6:.1f}M params")

    if not args.resume and os.path.isdir(args.ckpt):
        shutil.rmtree(args.ckpt)

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch, seed=11)
    tc = TrainerConfig(ckpt_dir=args.ckpt, ckpt_every=100, log_every=20,
                       lr=1e-3, warmup=30, total_steps=args.steps,
                       online_calibrate=args.online_calibrate,
                       calib_device=f"{cfg.name}-online")
    trainer = Trainer(cfg, dc, tc)
    start = trainer.step
    hist = trainer.train(args.steps - start)

    first, last = hist[0], hist[-1]
    print(f"\n[example] steps {first['step']}..{last['step']}: "
          f"loss {first['loss']:.4f} -> {last['loss']:.4f}")
    assert last["loss"] < first["loss"], "loss must decrease"
    print(f"[example] checkpoints in {args.ckpt}: resume with --resume")

    if trainer.calibrator is not None:
        print("[calib] refit report:")
        print(trainer.calibrator.final_report())
        if args.telemetry_json:
            trainer.calibrator.sink.save(args.telemetry_json)
            print(f"[calib] telemetry saved to {args.telemetry_json} "
                  f"({len(trainer.calibrator.sink)} samples buffered)")

    tracer = obs_trace.get_tracer()
    if args.trace_json:
        for line in tracer.report_lines():
            print(f"[trace] {line}")
        tracer.save(args.trace_json)
        print(f"[example] trace written to {args.trace_json}")
    if args.metrics_json:
        obs_metrics.REGISTRY.save_json(args.metrics_json)
        print(f"[example] metrics written to {args.metrics_json}")


if __name__ == "__main__":
    main()
