"""Cost-model-driven plan search + heterogeneous load balancing —
the paper's §6.1/§6.2 applications, realized.

    PYTHONPATH=src python examples/autoshard_search.py

1. For three representative (arch × shape) cells, sweep the Plan space and
   rank by the analytic v5e model: thousands of predictions in seconds (the
   paper's 'rapid evaluation' claim at framework scale).
2. Schedule a mixed workload queue across two heterogeneous pools using
   predicted step times (load balancing).
3. Simulate a 5-node failure and re-plan (elastic).
"""
import time

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS
from repro.core import predictor
from repro.distributed import elastic
from repro.launch import autoshard


def main():
    # 1 — plan search ------------------------------------------------------
    for arch, shape in (("glm4-9b", "train_4k"),
                        ("mixtral-8x22b", "train_4k"),
                        ("llama3-405b", "prefill_32k")):
        t0 = time.perf_counter()
        plans = autoshard.candidate_plans(ARCHS[arch], SHAPES[shape])
        ranked = autoshard.search(arch, shape, top_k=3)
        dt = time.perf_counter() - t0
        print(f"\n{arch} × {shape}: ranked {len(plans)} plans "
              f"in {dt*1e3:.0f} ms")
        for t, p, mesh in ranked:
            print(f"  {t*1e3:9.2f} ms/step  fsdp={p.fsdp} "
                  f"mb={p.microbatches} remat={p.remat_policy} "
                  f"comp={p.compression}")

    # 1b — mesh-factorization sweep (the batched engine makes it cheap) ----
    t0 = time.perf_counter()
    swept = autoshard.search("glm4-9b", "train_4k", n_devices=1024,
                             top_k=3)
    dt = time.perf_counter() - t0
    print(f"\nglm4-9b × train_4k over every 1024-chip mesh "
          f"factorization ({dt*1e3:.0f} ms):")
    for t, p, mesh in swept:
        print(f"  {t*1e3:9.2f} ms/step  mesh={mesh} fsdp={p.fsdp} "
              f"mb={p.microbatches} remat={p.remat_policy}")

    # 2 — load balancing across heterogeneous pools ------------------------
    print("\nload balancing a mixed queue over pod-A (16×16) and "
          "pod-B (8×8):")
    pools = {"pod-A": {"data": 16, "model": 16},
             "pod-B": {"data": 8, "model": 8}}
    queue = [("smollm-360m", "train_4k"), ("glm4-9b", "prefill_32k"),
             ("mixtral-8x7b", "decode_32k"), ("mamba2-370m", "train_4k")]
    loads = {k: 0.0 for k in pools}
    for arch, shape in queue:
        cfg, shp = ARCHS[arch], SHAPES[shape]
        best, best_pool = None, None
        for pool, mesh in pools.items():
            from repro.distributed.plan import plan_for
            p = plan_for(cfg, shp, tp_size=mesh["model"])
            t = predictor.predict_step(cfg, shp, p, mesh).seconds
            finish = loads[pool] + t
            if best is None or finish < best:
                best, best_pool = finish, pool
        loads[best_pool] = best
        print(f"  {arch:>14} × {shape:<12} -> {best_pool} "
              f"(finishes at {best*1e3:.1f} ms)")

    # 3 — elastic re-plan after failure ------------------------------------
    print("\nelastic: glm4-9b train, 256 chips, 5 fail:")
    opt = elastic.on_failure(ARCHS["glm4-9b"], SHAPES["train_4k"],
                             prev_devices=256, lost=5)
    print(f"  new mesh {opt.shape}, predicted step "
          f"{opt.predicted_step_s*1e3:.1f} ms")


if __name__ == "__main__":
    main()
