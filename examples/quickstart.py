"""Quickstart: fit the paper's linear cost model on THIS machine and
predict a held-out kernel — the whole pipeline in one minute.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import extract, fit, measure, mkernels


def main():
    # 1. measurement library (tiny ladder for the quickstart) ------------
    cases = mkernels.measurement_cases("tiny")
    print(f"measuring {len(cases)} kernels (paper §4.1 library, tiny scale)…")
    pvs, times = [], []
    for c in cases:
        pvs.append(c.properties())          # automatic extraction (§3)
        times.append(measure.time_kernel(c.jitted(), runs=10, drop=2).min_s)

    # 2. black-box fit (§4.3) --------------------------------------------
    model = fit.fit_relative(pvs, times, device="quickstart-cpu", ridge=1e-4)
    rep = fit.fit_report(model, pvs, times)
    print(f"fit geomean rel err on the library: {rep['geomean_rel_err']:.2%}")

    # 3. predict a kernel the fit never saw -------------------------------
    n = 384
    key = jax.random.PRNGKey(0)
    a = jax.random.uniform(key, (n, n))

    def my_kernel(a):                       # fused polynomial + matmul
        b = a @ a
        return b * a + jnp.exp(-a)

    pv = extract.extract_jaxpr(my_kernel, a)   # symbolic -> concrete counts
    predicted = model.predict(pv)              # <alpha, p> inner product
    jitted = jax.jit(my_kernel)
    actual = measure.time_kernel(lambda: jitted(a), runs=10, drop=2).min_s
    print(f"\nheld-out kernel ({n}x{n} matmul+pointwise):")
    print(f"  predicted {predicted*1e3:7.3f} ms")
    print(f"  actual    {actual*1e3:7.3f} ms")
    print(f"  rel err   {abs(predicted-actual)/actual:7.2%}")

    # 4. what the time is made of (Table-2-style attribution) ------------
    print("\ncost attribution:")
    for k, v in list(model.breakdown(pv).items())[:5]:
        print(f"  {k:<20} {v*1e6:8.1f} µs")


if __name__ == "__main__":
    main()
