"""Fault-tolerance walkthrough: train, 'crash', resume exactly; then a
straggler appears and is mitigated; finally the whole loop runs under
the Supervisor with an injected device loss (docs/ROBUSTNESS.md).

    PYTHONPATH=src python examples/fault_tolerance.py
"""
import dataclasses
import os
import shutil

import numpy as np

from repro.configs.registry import get_arch
from repro.core.workload import WorkloadSpec
from repro.data.pipeline import DataConfig
from repro.runtime.faults import Fault, FaultInjector, FaultPlan
from repro.runtime.straggler import StragglerMonitor
from repro.runtime.supervisor import BackoffPolicy, Supervisor
from repro.runtime.trainer import Trainer, TrainerConfig

CKPT = "/tmp/repro_ft_ckpt"
CHAOS_CKPT = "/tmp/repro_ft_chaos_ckpt"


def tiny_cfg():
    return get_arch("smollm-360m").reduced()


def main():
    if os.path.isdir(CKPT):
        shutil.rmtree(CKPT)
    cfg = tiny_cfg()
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4,
                    seed=3)
    tc = TrainerConfig(ckpt_dir=CKPT, ckpt_every=10, log_every=1000,
                       total_steps=40, save_on_exit=False)

    # phase 1: train 25 steps, checkpoints at 10/20, then 'crash'
    t1 = Trainer(cfg, dc, tc)
    t1.train(25)
    losses_1 = [m["loss"] for m in t1.history]
    print(f"[ft] phase 1 trained to step {t1.step} (ckpt at 20), 'crash'")
    del t1

    # phase 2: a fresh process resumes from the last durable checkpoint
    t2 = Trainer(cfg, dc, tc)
    assert t2.step == 20, t2.step
    t2.train(5)  # replays steps 20..24 — same data, same rng
    losses_2 = [m["loss"] for m in t2.history]
    # determinism: the replayed steps reproduce the original losses
    np.testing.assert_allclose(losses_1[20:25], losses_2, rtol=1e-5)
    print(f"[ft] resumed at 20, replayed to {t2.step}: losses match "
          f"the pre-crash run exactly")

    # phase 3: straggler mitigation
    mon = StragglerMonitor(n_hosts=8, predicted_step_s=0.10, k=2.0,
                           ewma=0.0, policy="rescale")
    times = [0.1] * 8
    times[3] = 0.9  # host 3 degrades
    events = mon.observe(step=t2.step, host_times_s=times)
    print(f"[ft] straggler events: "
          f"{[(e.host, round(e.observed_s, 2), e.action) for e in events]}")
    print(f"[ft] skip-and-rescale weight: {mon.rescale_weight():.3f} "
          f"(gradient rescaled over 7 healthy hosts)")
    assert events and events[0].host == 3

    # phase 4: the same crash-and-resume loop, but unattended — the
    # Supervisor detects the (injected) device loss, replans the mesh
    # over the 7 survivors, restores the last valid checkpoint, and
    # replays to completion with the same losses as phases 1+2
    if os.path.isdir(CHAOS_CKPT):
        shutil.rmtree(CHAOS_CKPT)
    tc_chaos = dataclasses.replace(tc, ckpt_dir=CHAOS_CKPT)
    injector = FaultInjector(
        FaultPlan(faults=(Fault("device_loss", 13),), seed=1),
        ckpt_dir=CHAOS_CKPT)
    workload = WorkloadSpec(phase="train", global_batch=dc.global_batch,
                            seq_len=dc.seq_len, name="ft_chaos")
    sup = Supervisor(
        lambda mesh: Trainer(cfg, dc, tc_chaos, injector=injector),
        25, cfg=get_arch("smollm-360m"), workload=workload,
        n_devices=8, injector=injector,
        backoff=BackoffPolicy(base_s=0.0, max_s=0.0, jitter=0.0, seed=1))
    hist = sup.run()
    sup.report()
    assert len(sup.recoveries) == 1 and sup.n_devices == 7
    np.testing.assert_allclose(losses_1, [m["loss"] for m in hist],
                               rtol=1e-5)
    print(f"[ft] supervised chaos run: device lost at step 13, "
          f"recovered in {sup.mttr_s()*1e3:.0f}ms, losses still match")


if __name__ == "__main__":
    main()
