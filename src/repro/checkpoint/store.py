"""Sharded, atomic, resumable checkpointing (numpy-backed, orbax-free).

Layout:
    <dir>/step_<N>/
        manifest.json        # tree structure, shapes, dtypes, crc32s, step
        leaf_00000.npy …     # one file per pytree leaf (host-local shard)

Guarantees:
  * **Atomicity** — writes land in ``step_<N>.tmp`` and are ``os.rename``d
    only after the manifest (written last) is fsynced: a crash mid-write
    never yields a directory that ``latest_step`` will pick up.
  * **Integrity** — each leaf carries a crc32 in the manifest; restore
    verifies before handing the tree to the trainer.
  * **Async** — ``AsyncCheckpointer`` snapshots to host memory synchronously
    (cheap) and writes on a background thread, overlapping I/O with the next
    training steps; ``wait()`` joins before the next save or at exit.
  * **Multi-host** — each host writes only the leaves it owns (addressable
    shards); ``process_index`` namespacing keeps paths disjoint.  On this
    single-process runtime that reduces to one full copy.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.obs import metrics as _obs_metrics
from repro.obs import report as _obs_report

_CKPT_FALLBACKS = _obs_metrics.REGISTRY.counter(
    "repro_checkpoint_fallbacks_total",
    "invalid checkpoints quarantined by restore_latest_valid while "
    "falling back to an older step")

_STEP_DIR = re.compile(r"step_(\d+)$")


class CheckpointError(AssertionError):
    """A checkpoint failed integrity verification (truncated manifest,
    tree/shape/dtype mismatch, crc failure).  Subclasses AssertionError
    so pre-hardening callers catching the old bare asserts keep working;
    new callers should prefer ``restore_latest_valid``, which quarantines
    and falls back instead of raising."""


def _step_dirs(ckpt_dir: str) -> List[int]:
    """Steps with a complete-looking directory (manifest present),
    ascending.  Non-step entries (``quarantine/``, ``*.tmp``) are
    ignored rather than crashing the parse."""
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for d in os.listdir(ckpt_dir):
        m = _STEP_DIR.fullmatch(d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(int(m.group(1)))
    return sorted(steps)


def _leaves_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out.append((key, leaf))
    return out


def save(ckpt_dir: str, step: int, tree: Any,
         extra_meta: Optional[Dict[str, Any]] = None) -> str:
    """Synchronous atomic save.  Returns the final directory path."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    manifest: Dict[str, Any] = {"step": int(step), "leaves": [],
                                "meta": extra_meta or {}}
    for i, (key, leaf) in enumerate(_leaves_with_paths(tree)):
        arr = np.asarray(jax.device_get(leaf))
        fn = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fn), arr, allow_pickle=False)
        manifest["leaves"].append({
            "key": key, "file": fn, "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
        })
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _step_dirs(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, template: Any, step: Optional[int] = None) -> Any:
    """Restore into the structure of ``template`` (leaf order must match —
    verified leaf-by-leaf against the manifest keys/shapes/dtypes/crc32)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise CheckpointError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, ValueError) as exc:
        raise CheckpointError(
            f"corrupt manifest in step {step}: {exc}") from exc

    tpl = _leaves_with_paths(template)
    if len(tpl) != len(manifest.get("leaves", [])):
        raise CheckpointError(
            f"corrupt checkpoint step {step}: {len(tpl)} template leaves "
            f"but {len(manifest.get('leaves', []))} in manifest")
    leaves = []
    for (key, tleaf), m in zip(tpl, manifest["leaves"]):
        if key != m["key"]:
            raise CheckpointError(f"tree mismatch: {key} != {m['key']}")
        try:
            arr = np.load(os.path.join(d, m["file"]), allow_pickle=False)
        except (OSError, ValueError) as exc:
            raise CheckpointError(
                f"corrupt leaf {key} in step {step}: {exc}") from exc
        if str(arr.dtype) != m["dtype"]:
            # ml_dtypes (bfloat16, fp8) round-trip through .npy as raw
            # void records; view them back to the manifest dtype
            try:
                arr = arr.view(np.dtype(m["dtype"]))
            except (TypeError, ValueError) as exc:
                raise CheckpointError(
                    f"corrupt leaf {key} in step {step}: dtype "
                    f"{arr.dtype} != {m['dtype']}") from exc
        if list(arr.shape) != m["shape"] or str(arr.dtype) != m["dtype"]:
            raise CheckpointError(
                f"corrupt leaf {key} in step {step}: shape/dtype "
                f"{arr.shape}/{arr.dtype} != {m['shape']}/{m['dtype']}")
        crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
        if crc != m["crc32"]:
            raise CheckpointError(f"corrupt leaf {key} in step {step}")
        leaves.append(arr)
    struct = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(struct, leaves), manifest


def quarantine(ckpt_dir: str, step: int) -> Optional[str]:
    """Move an invalid checkpoint into ``<ckpt_dir>/quarantine/`` so
    ``latest_step`` stops offering it (best-effort; returns the new path,
    replacing any earlier quarantined copy of the same step)."""
    src = os.path.join(ckpt_dir, f"step_{step:08d}")
    qdir = os.path.join(ckpt_dir, "quarantine")
    dst = os.path.join(qdir, f"step_{step:08d}")
    try:
        os.makedirs(qdir, exist_ok=True)
        if os.path.exists(dst):
            shutil.rmtree(dst, ignore_errors=True)
        os.rename(src, dst)
        return dst
    except OSError:
        shutil.rmtree(src, ignore_errors=True)  # still unblock the parse
        return None


def restore_latest_valid(ckpt_dir: str, template: Any
                         ) -> Optional[Tuple[Any, Dict[str, Any], int]]:
    """Restore the newest checkpoint that passes verification.

    Invalid checkpoints (truncated manifest, crc/shape mismatch — e.g. a
    write interrupted by the very preemption being recovered from) are
    quarantined under ``<ckpt_dir>/quarantine/`` and the next-older step
    is tried, so a corrupt newest checkpoint costs one interval of
    replay, never the run.  Returns ``(tree, manifest, step)`` or None
    when no valid checkpoint exists."""
    for step in reversed(_step_dirs(ckpt_dir)):
        try:
            tree, manifest = restore(ckpt_dir, template, step)
            return tree, manifest, step
        except CheckpointError as exc:
            qpath = quarantine(ckpt_dir, step)
            _CKPT_FALLBACKS.inc()
            _obs_report.emit("ckpt", {
                "step": step, "action": "quarantine",
                "to": qpath or "<removed>"},
                text=f"invalid checkpoint skipped: {exc}")
    return None


def prune(ckpt_dir: str, keep: int = 3) -> None:
    """Keep the newest ``keep`` checkpoints (and remove stale .tmp dirs)."""
    if not os.path.isdir(ckpt_dir):
        return
    for d in os.listdir(ckpt_dir):
        if d.endswith(".tmp"):
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
    steps = sorted(s for s in (
        int(m.group(1)) for m in (
            _STEP_DIR.fullmatch(d) for d in os.listdir(ckpt_dir)) if m))
    for s in steps[:-keep] if keep else steps:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write on a background thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any,
             extra_meta: Optional[Dict[str, Any]] = None) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            try:
                save(self.ckpt_dir, step, host_tree, extra_meta)
                prune(self.ckpt_dir, self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
