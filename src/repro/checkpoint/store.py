"""Sharded, atomic, resumable checkpointing (numpy-backed, orbax-free).

Layout:
    <dir>/step_<N>/
        manifest.json        # tree structure, shapes, dtypes, crc32s, step
        leaf_00000.npy …     # one file per pytree leaf (host-local shard)

Guarantees:
  * **Atomicity** — writes land in ``step_<N>.tmp`` and are ``os.rename``d
    only after the manifest (written last) is fsynced: a crash mid-write
    never yields a directory that ``latest_step`` will pick up.
  * **Integrity** — each leaf carries a crc32 in the manifest; restore
    verifies before handing the tree to the trainer.
  * **Async** — ``AsyncCheckpointer`` snapshots to host memory synchronously
    (cheap) and writes on a background thread, overlapping I/O with the next
    training steps; ``wait()`` joins before the next save or at exit.
  * **Multi-host** — each host writes only the leaves it owns (addressable
    shards); ``process_index`` namespacing keeps paths disjoint.  On this
    single-process runtime that reduces to one full copy.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _leaves_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out.append((key, leaf))
    return out


def save(ckpt_dir: str, step: int, tree: Any,
         extra_meta: Optional[Dict[str, Any]] = None) -> str:
    """Synchronous atomic save.  Returns the final directory path."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    manifest: Dict[str, Any] = {"step": int(step), "leaves": [],
                                "meta": extra_meta or {}}
    for i, (key, leaf) in enumerate(_leaves_with_paths(tree)):
        arr = np.asarray(jax.device_get(leaf))
        fn = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fn), arr, allow_pickle=False)
        manifest["leaves"].append({
            "key": key, "file": fn, "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
        })
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp") and \
                os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(int(d[len("step_"):]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, template: Any, step: Optional[int] = None) -> Any:
    """Restore into the structure of ``template`` (leaf order must match —
    verified leaf-by-leaf against the manifest keys/shapes/dtypes/crc32)."""
    if step is None:
        step = latest_step(ckpt_dir)
        assert step is not None, f"no checkpoint under {ckpt_dir}"
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    tpl = _leaves_with_paths(template)
    assert len(tpl) == len(manifest["leaves"]), \
        (len(tpl), len(manifest["leaves"]))
    leaves = []
    for (key, tleaf), m in zip(tpl, manifest["leaves"]):
        assert key == m["key"], f"tree mismatch: {key} != {m['key']}"
        arr = np.load(os.path.join(d, m["file"]), allow_pickle=False)
        if str(arr.dtype) != m["dtype"]:
            # ml_dtypes (bfloat16, fp8) round-trip through .npy as raw
            # void records; view them back to the manifest dtype
            arr = arr.view(np.dtype(m["dtype"]))
        assert list(arr.shape) == m["shape"] and str(arr.dtype) == m["dtype"]
        crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
        assert crc == m["crc32"], f"corrupt leaf {key} in step {step}"
        leaves.append(arr)
    struct = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(struct, leaves), manifest


def prune(ckpt_dir: str, keep: int = 3) -> None:
    """Keep the newest ``keep`` checkpoints (and remove stale .tmp dirs)."""
    if not os.path.isdir(ckpt_dir):
        return
    for d in os.listdir(ckpt_dir):
        if d.endswith(".tmp"):
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
    steps = sorted(s for s in (
        int(d[len("step_"):]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")))
    for s in steps[:-keep] if keep else steps:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write on a background thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any,
             extra_meta: Optional[Dict[str, Any]] = None) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            try:
                save(self.ckpt_dir, step, host_tree, extra_meta)
                prune(self.ckpt_dir, self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
