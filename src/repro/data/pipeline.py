"""Deterministic, seekable, sharded data pipeline.

Design goals (1000-node scale):
  * **Stateless addressing** — batch ``(step, dp_rank)`` is a pure function
    of ``(seed, step, dp_rank)``; no iterator state to snapshot.  Resume
    after preemption = restart at the checkpointed step.  Elastic re-shard =
    recompute rank strides; no data is lost or duplicated within a step.
  * **Deterministic synthetic corpus** — a seeded doc generator with a
    Zipf-ish length distribution and an order-1 Markov token chain, so a
    ~100M-param model shows a real (falling) loss curve without external
    data.  Swapping in a real tokenized corpus only replaces ``_doc``.
  * **Packing** — documents are packed into fixed ``seq_len`` rows with EOS
    separators and a loss mask; labels are next-token shifted.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

EOS = 0
BOS = 1
_VOCAB_RESERVED = 2


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    mean_doc_len: int = 256
    n_codebooks: int = 1  # MusicGen: parallel codebook streams


class SyntheticCorpus:
    """Deterministic infinite corpus: doc ``i`` is a pure function of
    ``(seed, i)``."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def _doc(self, idx: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.Generator(np.random.PCG64(
            (cfg.seed * 0x9E3779B1 + idx) & 0xFFFFFFFF))
        # Zipf-ish doc length in [16, 4·mean]
        ln = int(np.clip(rng.pareto(1.5) * cfg.mean_doc_len * 0.5 + 16,
                         16, 4 * cfg.mean_doc_len))
        V = cfg.vocab_size - _VOCAB_RESERVED
        # order-1 Markov chain: next ≈ affine hash of current, + noise.
        # gives the model learnable structure (bigram statistics).
        a = int(rng.integers(1, 257)) * 2 + 1
        b = int(rng.integers(0, V))
        toks = np.empty(ln, np.int64)
        t = int(rng.integers(0, V))
        noise = rng.integers(0, V, size=ln)
        pick = rng.random(ln) < 0.15
        for j in range(ln):
            t = (a * t + b) % V
            if pick[j]:
                t = int(noise[j])
            toks[j] = t + _VOCAB_RESERVED
        return toks


class PackedLoader:
    """Packs corpus docs into (batch, seq_len) rows, sharded by dp rank.

    ``batch(step, rank, n_ranks)`` is deterministic and independent of call
    order — the pipeline 'state' is just the integer ``step``.
    """

    def __init__(self, cfg: DataConfig, corpus: Optional[SyntheticCorpus] = None):
        self.cfg = cfg
        self.corpus = corpus or SyntheticCorpus(cfg)

    def _row(self, row_idx: int) -> Dict[str, np.ndarray]:
        """One packed row; doc ids derive from the row index."""
        cfg = self.cfg
        S = cfg.seq_len
        toks = np.full(S + 1, EOS, np.int64)
        mask = np.zeros(S + 1, np.float32)
        pos = 0
        doc = row_idx * 1_000_003  # disjoint doc-id streams per row
        while pos < S + 1:
            d = self.corpus._doc(doc)
            doc += 1
            take = min(len(d), S + 1 - pos - 1)
            if take <= 0:
                break
            toks[pos] = BOS
            toks[pos + 1: pos + 1 + take] = d[:take]
            mask[pos: pos + 1 + take] = 1.0
            pos += take + 2  # BOS + doc + EOS separator
        return {"tokens": toks[:S], "labels": toks[1:],
                "loss_mask": mask[1:]}

    def batch(self, step: int, rank: int = 0, n_ranks: int = 1
              ) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        assert cfg.global_batch % n_ranks == 0
        per = cfg.global_batch // n_ranks
        base = step * cfg.global_batch + rank * per
        rows = [self._row(base + i) for i in range(per)]
        out = {k: np.stack([r[k] for r in rows]) for k in rows[0]}
        out["tokens"] = out["tokens"].astype(np.int32)
        out["labels"] = out["labels"].astype(np.int32)
        if cfg.n_codebooks > 1:  # replicate the chain per codebook stream
            for k in ("tokens", "labels"):
                out[k] = np.stack([
                    (out[k] + c * 17) % cfg.vocab_size
                    for c in range(cfg.n_codebooks)], axis=-1).astype(np.int32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
