"""Mamba2-370M [arXiv:2405.21060] — attention-free SSD (state-space duality)."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1),
    tie_embeddings=True,
)
