"""Architecture + shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; the four assigned
input-shape points are ``ShapeConfig``s.  ``registry.py`` maps ``--arch`` ids
to configs.  Reduced (smoke) variants are derived with ``cfg.reduced()``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    # Token capacity factor for dense (GShard-style) dispatch.
    capacity_factor: float = 1.25
    # router jitter / aux loss weight
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) mixer configuration."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64  # P
    n_groups: int = 1
    chunk: int = 128  # SSD chunk length


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style hybrid: shared attention block applied every k SSM layers."""

    attn_every: int = 6  # apply the (single, shared) attention block after
    # every `attn_every`-th SSM layer


# ---------------------------------------------------------------------------
# Main architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int  # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # --- positional encoding ---
    rope_theta: float = 10000.0
    m_rope: bool = False  # Qwen2-VL multi-dimensional RoPE
    mrope_sections: Tuple[int, ...] = (16, 24, 24)  # splits of head_dim//2
    # --- attention variants ---
    sliding_window: Optional[int] = None  # SWA (Mixtral): window size
    use_qkv_bias: bool = False  # Qwen2 uses qkv bias
    # --- mixers ---
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # --- heads / embeddings ---
    tie_embeddings: bool = False
    n_output_heads: int = 1  # MusicGen: 4 codebook heads
    n_input_codebooks: int = 1  # MusicGen: sum of 4 codebook embeddings
    # --- modality frontend stubs ---
    vision_tokens: int = 0  # Qwen2-VL: leading positions carry patch embeds
    embed_inputs: bool = False  # True -> input_specs supplies (B,S,d) embeds
    # --- numerics ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    # --- training-memory knobs (per-arch defaults, overridable by plan) ---
    optimizer: str = "adamw"  # adamw | adafactor
    remat_policy: str = "full"  # none | dots | full (full = save block
    # boundaries only; required for the large-arch dry-runs to fit HBM)

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        assert self.n_heads > 0
        return self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the long_500k decode cell?"""
        return (
            self.ssm is not None
            or self.hybrid is not None
            or self.sliding_window is not None
        )

    @property
    def d_inner(self) -> int:
        assert self.ssm is not None
        return self.ssm.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        assert self.ssm is not None
        return self.d_inner // self.ssm.head_dim

    def n_params(self) -> int:
        """Closed-form parameter count (embedding + blocks + head)."""
        d, ff, V, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        total = V * d * self.n_input_codebooks  # embeddings
        if not self.tie_embeddings:
            total += V * d * self.n_output_heads
        hd = self.head_dim_ if self.n_heads else 0

        def attn_params() -> int:
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            b = (self.n_heads + 2 * self.n_kv_heads) * hd if self.use_qkv_bias else 0
            return q + kv + o + b

        def ffn_params(dff: int) -> int:
            return 3 * d * dff  # SwiGLU

        def ssm_params() -> int:
            s = self.ssm
            din = self.d_inner
            nh = self.ssm_heads
            conv_dim = din + 2 * s.n_groups * s.d_state
            in_proj = d * (2 * din + 2 * s.n_groups * s.d_state + nh)
            conv = (s.d_conv + 1) * conv_dim  # weight + bias
            out_proj = din * d
            extra = 3 * nh + din  # A_log, D, dt_bias, gated-norm weight
            return in_proj + conv + out_proj + extra

        per_layer = 0
        if self.family == "ssm":
            per_layer = ssm_params() + d  # + norm
            total += L * per_layer
        elif self.family == "hybrid":
            total += L * (ssm_params() + d)
            # one shared attention+MLP block
            total += attn_params() + ffn_params(self.d_ff) + 2 * d
        else:
            per_layer = attn_params() + 2 * d  # two norms
            if self.moe is not None:
                per_layer += d * self.moe.n_experts  # router
                per_layer += self.moe.n_experts * ffn_params(self.d_ff)
            else:
                per_layer += ffn_params(self.d_ff)
            total += L * per_layer
        total += d  # final norm
        return total

    def n_active_params(self) -> int:
        """Active (per-token) parameter count — differs for MoE."""
        if self.moe is None:
            return self.n_params()
        dense_like = dataclasses.replace(self, moe=None)
        base = dense_like.n_params()
        # dense counted 1 FFN / layer; MoE activates top_k + router
        per_layer_extra = (self.moe.top_k - 1) * 3 * self.d_model * self.d_ff
        per_layer_extra += self.d_model * self.moe.n_experts
        return base + self.n_layers * per_layer_extra

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_heads else 0,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            head_dim=16 if self.n_heads else 0,
            vision_tokens=min(self.vision_tokens, 4),
        )
        if self.m_rope:
            kw["mrope_sections"] = (2, 3, 3)  # scaled to head_dim 16
        if self.moe is not None:
            kw["moe"] = MoEConfig(n_experts=4, top_k=2)
        if self.ssm is not None:
            kw["ssm"] = SSMConfig(
                d_state=16, head_dim=16, expand=2, n_groups=1, chunk=16,
                d_conv=self.ssm.d_conv,
            )
        if self.hybrid is not None:
            kw["hybrid"] = HybridConfig(attn_every=1)
            kw["n_kv_heads"] = 4
        if self.sliding_window is not None:
            kw["sliding_window"] = 16
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic attention (see DESIGN.md §4)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "SKIP(full-attention): 524k dense-attn KV cache infeasible"
    return True, ""
