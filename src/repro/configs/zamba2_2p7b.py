"""Zamba2-2.7B [arXiv:2411.15242] — Mamba2 blocks + shared attention block.

54 SSD layers; a single shared (attention + MLP) block is applied after every
6th SSD layer (9 applications, one parameter set).
"""
from repro.configs.base import ArchConfig, HybridConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,  # shared block is MHA
    d_ff=10240,
    vocab_size=32000,
    head_dim=80,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, n_groups=1),
    hybrid=HybridConfig(attn_every=6),
)
