"""Mixtral-8x7B [arXiv:2401.04088] — MoE 8 experts top-2, GQA kv=8, SWA."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    rope_theta=1000000.0,
    sliding_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2),
)
