"""Llama-3.2-3B [arXiv:2407.21783 family] — small llama3, GQA kv=8."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500000.0,
)
