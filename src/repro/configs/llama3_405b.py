"""Llama-3-405B [arXiv:2407.21783] — GQA kv=8, 128k vocab.

Trains with Adafactor + full remat: fp32 Adam m/v would need ~22 GB/chip on a
256-chip v5e pod (16 GB HBM) — see DESIGN.md §2 and EXPERIMENTS.md §Dry-run.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500000.0,
    optimizer="adafactor",
    remat_policy="full",
)
