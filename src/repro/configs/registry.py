"""``--arch`` id → ArchConfig registry."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ArchConfig
from repro.configs import (
    glm4_9b,
    smollm_360m,
    llama3_2_3b,
    llama3_405b,
    zamba2_2p7b,
    qwen2_vl_7b,
    musicgen_medium,
    mamba2_370m,
    mixtral_8x22b,
    mixtral_8x7b,
)

_MODULES = (
    glm4_9b,
    smollm_360m,
    llama3_2_3b,
    llama3_405b,
    zamba2_2p7b,
    qwen2_vl_7b,
    musicgen_medium,
    mamba2_370m,
    mixtral_8x22b,
    mixtral_8x7b,
)

ARCHS: Dict[str, ArchConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]
