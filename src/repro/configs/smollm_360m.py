"""SmolLM-360M [hf:HuggingFaceTB/SmolLM-135M family] — small llama-arch, GQA kv=5."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    head_dim=64,
    rope_theta=10000.0,
    tie_embeddings=True,
)
