"""Qwen2-VL-7B [arXiv:2409.12191] — VLM backbone, M-RoPE, GQA kv=4.

Backbone only: the vision frontend is a stub; ``input_specs()`` supplies
precomputed patch embeddings occupying the first ``vision_tokens`` positions.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    head_dim=128,
    rope_theta=1000000.0,
    m_rope=True,
    mrope_sections=(16, 24, 24),
    use_qkv_bias=True,
    vision_tokens=256,
)
