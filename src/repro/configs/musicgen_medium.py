"""MusicGen-medium [arXiv:2306.05284] — decoder-only over EnCodec tokens.

Backbone only: the EnCodec frontend is a stub; ``input_specs()`` supplies
4-codebook token ids (summed codebook embeddings on input, 4 parallel
lm-heads with the delay pattern on output).  Text cross-attention conditioning
is out of backbone scope (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    head_dim=64,
    n_output_heads=4,
    n_input_codebooks=4,
)
