"""Structured report lines — ONE formatter for the ``[tag] key=value``
surface.

The trainer's ``[calib]`` lines, the decode server's ``[admit]`` lines,
and the autoshard CLI's compile-cache line each grew their own formatting
(and their own test greps).  This module is the single source for that
surface: every human-readable status line flows through ``emit``, which

  * formats the canonical ``[tag] key=value key=value …`` layout
    (``format_line``), so every line is machine-greppable the same way;
  * counts the emission in the metrics registry
    (``repro_report_lines_total{tag=…}``), so a run's report volume is
    itself observable;
  * prints through an injectable printer (tests pass a capture list, the
    disabled path passes ``printer=None`` to format-and-count only).

Zero dependencies; imports only the sibling ``metrics`` module.
"""
from __future__ import annotations

from typing import Callable, Mapping, Optional

from repro.obs import metrics

__all__ = ["format_fields", "format_line", "emit"]

_LINES = metrics.REGISTRY.counter(
    "repro_report_lines_total",
    "structured [tag] report lines emitted, by tag")


def _fmt_value(v: object) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def format_fields(fields: Mapping[str, object]) -> str:
    """``key=value`` pairs, insertion-ordered, space-separated."""
    return " ".join(f"{k}={_fmt_value(v)}" for k, v in fields.items())


def format_line(tag: str, fields: Optional[Mapping[str, object]] = None,
                text: str = "") -> str:
    """The canonical line: ``[tag] key=value … free text``."""
    parts = [f"[{tag}]"]
    if fields:
        parts.append(format_fields(fields))
    if text:
        parts.append(text)
    return " ".join(parts)


def emit(tag: str, fields: Optional[Mapping[str, object]] = None,
         text: str = "",
         printer: Optional[Callable[[str], None]] = print) -> str:
    """Format, count, and (optionally) print one report line; returns it."""
    line = format_line(tag, fields, text)
    _LINES.inc(1, tag=tag)
    if printer is not None:
        printer(line)
    return line
