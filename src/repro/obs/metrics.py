"""Metrics registry — one home for the framework's scattered counters.

Before this module, operational counters lived wherever they were
incremented: ``BasisCache.hits`` on the cache object, the disk
compile-cache tallies in a module dict, telemetry ring occupancy inside
the sink, the CUSUM statistic inside the drift monitor, admission
decisions as ad-hoc print lines.  ``MetricsRegistry`` unifies them behind
the standard ``Counter`` / ``Gauge`` / ``Histogram`` trio with Prometheus
text exposition (``render()``) and a JSON dump (``--metrics-json`` /
``save_json``), so a trainer, server, or autoshard run can export ONE
machine-readable snapshot of everything the process counted.

Zero dependencies (stdlib only) and zero imports from the rest of
``repro`` — any module may import this one at module level without
cycles.  Producers push into the process-wide default ``REGISTRY``;
multi-registry use (tests, isolated benchmarks) constructs private
``MetricsRegistry`` instances.

Design points:

  * metrics are *families*: ``counter("x").inc()`` is the unlabeled fast
    path, ``counter("x").inc(1, phase="decode")`` creates one child per
    label set — Prometheus semantics without a client-library dep;
  * ``get-or-create`` registration: calling ``registry.counter(name)``
    twice returns the same object (so producer modules need no import
    ordering), but re-registering a name as a different *type* raises;
  * rendering is pull-based and cheap; nothing in the registry runs
    timers or threads.  Hot paths pay one float add per event.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
from collections import OrderedDict
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
    "get_registry",
]

LabelSet = Tuple[Tuple[str, str], ...]
_NO_LABELS: LabelSet = ()


def _labelset(labels: Mapping[str, object]) -> LabelSet:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt(v: float) -> str:
    """Prometheus-style number: integers without a trailing ``.0``."""
    if v != v:                       # NaN
        return "NaN"
    if v in (float("inf"), float("-inf")):
        return "+Inf" if v > 0 else "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


class _Metric:
    """Shared family machinery: one value slot per label set."""

    kind = "untyped"
    __slots__ = ("name", "help", "_children", "_lock")

    def __init__(self, name: str, help: str = ""):
        if not name or not all(c.isalnum() or c in "_:" for c in name):
            raise ValueError(f"invalid metric name: {name!r}")
        self.name = name
        self.help = help
        self._children: "OrderedDict[LabelSet, float]" = OrderedDict()
        self._lock = threading.Lock()

    def _bump(self, ls: LabelSet, amount: float, absolute: bool) -> None:
        with self._lock:
            if absolute:
                self._children[ls] = float(amount)
            else:
                self._children[ls] = self._children.get(ls, 0.0) \
                    + float(amount)

    def value(self, **labels) -> float:
        return self._children.get(_labelset(labels), 0.0)

    def items(self) -> List[Tuple[LabelSet, float]]:
        return list(self._children.items())

    def _zero(self) -> None:
        with self._lock:
            self._children.clear()

    # -- exposition --------------------------------------------------------
    def _sample_lines(self) -> List[str]:
        out = []
        for ls, v in self._children.items():
            lbl = "{" + ",".join(f'{k}="{val}"' for k, val in ls) + "}" \
                if ls else ""
            out.append(f"{self.name}{lbl} {_fmt(v)}")
        if not out:                 # registered but never touched: expose 0
            out.append(f"{self.name} 0")
        return out

    def render(self) -> str:
        head = []
        if self.help:
            head.append(f"# HELP {self.name} {self.help}")
        head.append(f"# TYPE {self.name} {self.kind}")
        return "\n".join(head + self._sample_lines())

    def to_json_dict(self) -> Dict[str, object]:
        return {
            "name": self.name, "type": self.kind, "help": self.help,
            "samples": [{"labels": dict(ls), "value": v}
                        for ls, v in self._children.items()],
        }


class Counter(_Metric):
    """Monotone event count.  ``inc`` only; negative increments raise."""

    kind = "counter"
    __slots__ = ()

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"({amount})")
        self._bump(_labelset(labels), amount, absolute=False)


class Gauge(_Metric):
    """A value that goes up and down (occupancy, CUSUM height, RSS…)."""

    kind = "gauge"
    __slots__ = ()

    def set(self, value: float, **labels) -> None:
        self._bump(_labelset(labels), value, absolute=True)

    def inc(self, amount: float = 1.0, **labels) -> None:
        self._bump(_labelset(labels), amount, absolute=False)

    def dec(self, amount: float = 1.0, **labels) -> None:
        self._bump(_labelset(labels), -amount, absolute=False)


#: powers-of-ten ladder spanning µs-scale GEMV scores to multi-second steps
DEFAULT_BUCKETS = (1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0, 60.0)


class Histogram(_Metric):
    """Cumulative-bucket histogram (Prometheus layout: ``_bucket{le=}``,
    ``_sum``, ``_count``).  Buckets are fixed at construction."""

    kind = "histogram"
    __slots__ = ("buckets",)

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        super().__init__(name, help)
        bs = sorted(float(b) for b in buckets)
        if not bs or any(b != b for b in bs):
            raise ValueError(f"bad histogram buckets: {buckets!r}")
        self.buckets = tuple(bs)

    def observe(self, value: float, **labels) -> None:
        ls = _labelset(labels)
        v = float(value)
        with self._lock:
            st = self._children.get(ls)
            if st is None:
                st = self._children[ls] = \
                    [0.0] * (len(self.buckets) + 2)  # buckets + count + sum
            for i, b in enumerate(self.buckets):
                if v <= b:
                    st[i] += 1
            st[-2] += 1
            st[-1] += v

    def value(self, **labels) -> float:
        """The observation COUNT for the label set (family contract)."""
        st = self._children.get(_labelset(labels))
        return st[-2] if st else 0.0

    def sum(self, **labels) -> float:
        st = self._children.get(_labelset(labels))
        return st[-1] if st else 0.0

    def _sample_lines(self) -> List[str]:
        out = []
        children = self._children.items() or [(_NO_LABELS,
                                               [0.0] * (len(self.buckets)
                                                        + 2))]
        for ls, st in children:
            base = ",".join(f'{k}="{v}"' for k, v in ls)
            for i, b in enumerate(self.buckets):
                lbl = f'{{{base}{"," if base else ""}le="{_fmt(b)}"}}'
                out.append(f"{self.name}_bucket{lbl} {_fmt(st[i])}")
            lbl = f'{{{base}{"," if base else ""}le="+Inf"}}'
            out.append(f"{self.name}_bucket{lbl} {_fmt(st[-2])}")
            tail = f"{{{base}}}" if base else ""
            out.append(f"{self.name}_sum{tail} {_fmt(st[-1])}")
            out.append(f"{self.name}_count{tail} {_fmt(st[-2])}")
        return out

    def to_json_dict(self) -> Dict[str, object]:
        return {
            "name": self.name, "type": self.kind, "help": self.help,
            "buckets": list(self.buckets),
            "samples": [{"labels": dict(ls),
                         "bucket_counts": st[:-2],
                         "count": st[-2], "sum": st[-1]}
                        for ls, st in self._children.items()],
        }


class MetricsRegistry:
    """Ordered collection of metric families with get-or-create access."""

    def __init__(self):
        self._metrics: "OrderedDict[str, _Metric]" = OrderedDict()
        self._lock = threading.Lock()

    # -- registration ------------------------------------------------------
    def _get_or_create(self, cls, name: str, help: str, **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if type(m) is not cls:
                    raise ValueError(
                        f"metric {name!r} already registered as {m.kind}")
                return m
            m = cls(name, help, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_create(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __len__(self) -> int:
        return len(self._metrics)

    # -- exposition --------------------------------------------------------
    def render(self) -> str:
        """Prometheus text exposition of every registered family."""
        return "\n".join(m.render() for m in self._metrics.values()) \
            + ("\n" if self._metrics else "")

    def to_json_dict(self) -> Dict[str, object]:
        return {"kind": "metrics", "schema": 1,
                "metrics": [m.to_json_dict()
                            for m in self._metrics.values()]}

    def save_json(self, path: str) -> None:
        """Atomic JSON dump (temp file + ``os.replace``), mirroring the
        telemetry sink's crash-safe save."""
        d = os.path.dirname(path) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self.to_json_dict(), f, indent=1)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    # -- lifecycle ---------------------------------------------------------
    def reset(self) -> None:
        """Zero every family's samples, keeping registrations (tests)."""
        for m in self._metrics.values():
            m._zero()


#: the process-wide default registry every producer pushes into
REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return REGISTRY
