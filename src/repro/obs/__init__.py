"""Unified observability layer: tracing, attribution, metrics.

Three pillars, one package:

* ``repro.obs.trace`` — nested spans with a *predicted* overlay and
  Chrome-trace/Perfetto export (``--trace-json``);
* ``repro.obs.explain`` — basis-term attribution: ``score_explain``
  opens the fused GEMV into per-term/per-category addends, and
  ``attribute_residual`` projects measured-vs-predicted error back onto
  the basis;
* ``repro.obs.metrics`` — ``Counter``/``Gauge``/``Histogram`` registry
  with Prometheus text exposition and a JSON dump (``--metrics-json``),
  the single home for cache, calibration, and admission counters.

Plus ``repro.obs.report``, the one formatter behind every
``[tag] key=value`` status line.

Import discipline: ``trace``/``metrics``/``report`` import nothing from
the rest of ``repro`` (core modules import them freely); ``explain``
imports ``repro.core`` and is therefore exposed *lazily* here so that
``core`` modules importing ``repro.obs.metrics`` never trigger a cycle.
"""
from __future__ import annotations

from repro.obs import metrics, report, trace
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               REGISTRY, get_registry)
from repro.obs.report import emit, format_line
from repro.obs.trace import (NULL_TRACER, Span, Tracer, enable, get_tracer,
                             set_tracer)

__all__ = [
    "metrics", "report", "trace", "explain",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
    "get_registry", "emit", "format_line",
    "NULL_TRACER", "Span", "Tracer", "enable", "get_tracer", "set_tracer",
    "score_explain", "attribute_residual", "attribute_residual_pv",
    "Explanation", "TermContribution", "ResidualAttribution",
]

_EXPLAIN_NAMES = {
    "explain", "score_explain", "attribute_residual",
    "attribute_residual_pv", "Explanation", "TermContribution",
    "ResidualAttribution", "explain_program",
}


def __getattr__(name: str):
    if name in _EXPLAIN_NAMES:
        import importlib
        _explain = importlib.import_module("repro.obs.explain")
        if name == "explain":
            return _explain
        return getattr(_explain, name)
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")
