"""Nested-span tracer with a predicted-duration overlay.

The framework both *predicts* durations (fused basis-program GEMV,
``core/exprops.py``) and *measures* them (``time.perf_counter`` loops in
the trainer and decode server).  This tracer is where the two meet: any
span may carry the model's ``predicted_s`` for the work it wraps, and the
Chrome-trace export renders predicted time as a sibling track aligned
under the measured span — load the JSON in Perfetto (or
``chrome://tracing``) and the measured-vs-predicted gap is *visible* per
step, per admission decision, per refit.

Usage::

    tracer = Tracer()
    with tracer.span("decode_step", predicted_s=pred, step=i) as sp:
        ...                       # timed region
        sp.set(tokens=n)          # annotate late
    tracer.save("trace.json")     # Perfetto-loadable

Spans nest via a per-thread stack; completed spans record (name, start,
duration, depth, predicted seconds, free-form args).  A **disabled**
tracer is a true no-op: ``span()`` returns one shared null context
manager, no clock is read, nothing allocates — the near-zero-overhead
path production code keeps on by default (``benchmarks/fused_bench.py``
holds it to ≤2% on the fused scoring hot path).

The module-level tracer (``get_tracer`` / ``set_tracer``) is what library
code consults; it defaults to a disabled instance, and CLI entry points
swap in an enabled one under ``--trace-json``.

Zero dependencies; imports nothing from the rest of ``repro``.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
import time
from typing import Callable, Dict, List, Optional

__all__ = [
    "Span", "Tracer", "NULL_TRACER", "get_tracer", "set_tracer", "enable",
]

#: Chrome-trace thread ids: measured spans nest on MEASURED_TID, each
#: predicted overlay is a sibling "X" event on PREDICTED_TID.
MEASURED_TID = 0
PREDICTED_TID = 1


class Span:
    """One finished (or in-flight) span."""

    __slots__ = ("name", "t_start_s", "duration_s", "predicted_s", "depth",
                 "args")

    def __init__(self, name: str, t_start_s: float, depth: int,
                 predicted_s: Optional[float], args: Dict[str, object]):
        self.name = name
        self.t_start_s = t_start_s      # seconds since the tracer's epoch
        self.duration_s: Optional[float] = None
        self.predicted_s = predicted_s
        self.depth = depth
        self.args = args

    @property
    def gap_s(self) -> Optional[float]:
        """measured − predicted seconds (None until both exist)."""
        if self.duration_s is None or self.predicted_s is None:
            return None
        return self.duration_s - self.predicted_s

    def __repr__(self) -> str:
        dur = f"{self.duration_s:.6f}s" if self.duration_s is not None \
            else "open"
        pred = f" pred={self.predicted_s:.6f}s" \
            if self.predicted_s is not None else ""
        return f"Span({self.name!r} @{self.t_start_s:.6f} {dur}{pred})"


class _NullSpan:
    """The shared no-op context manager a disabled tracer hands out."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **kw) -> None:
        pass

    predicted_s = None
    duration_s = None


_NULL_SPAN = _NullSpan()


class _LiveSpan:
    """Context manager recording one span into its tracer."""

    __slots__ = ("_tracer", "span")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self.span = span

    def __enter__(self) -> "_LiveSpan":
        return self

    def __exit__(self, *exc) -> bool:
        self._tracer._finish(self.span)
        return False

    def set(self, predicted_s: Optional[float] = None, **kw) -> None:
        """Annotate the span mid-flight (args merge; ``predicted_s`` may
        arrive late, e.g. once the admission scorer has run)."""
        if predicted_s is not None:
            self.span.predicted_s = float(predicted_s)
        self.span.args.update(kw)

    @property
    def predicted_s(self):
        return self.span.predicted_s

    @property
    def duration_s(self):
        return self.span.duration_s


class Tracer:
    """Monotonic-clock span recorder with Chrome-trace export.

    ``clock`` is injectable (tests pin a fake clock for deterministic
    goldens); it must be monotone non-decreasing.  Span *starts* are
    ordered per thread by construction; the recorded list holds spans in
    COMPLETION order (children before parents), so exports re-sort by
    start time.
    """

    def __init__(self, enabled: bool = True,
                 clock: Callable[[], float] = time.perf_counter,
                 process_name: str = "repro"):
        self.enabled = enabled
        self._clock = clock
        self._epoch = clock()
        self._tls = threading.local()
        self._lock = threading.Lock()
        self.spans: List[Span] = []        # completed spans
        self.instants: List[Span] = []     # zero-duration marker events
        self.process_name = process_name
        self.dropped = 0                   # spans opened while disabled

    # -- recording ---------------------------------------------------------
    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def span(self, name: str, *, predicted_s: Optional[float] = None,
             **args):
        """Open a nested span; use as a context manager.  On a disabled
        tracer this returns the shared null span — no clock read, no
        allocation."""
        if not self.enabled:
            return _NULL_SPAN
        st = self._stack()
        sp = Span(name, self._clock() - self._epoch, len(st),
                  None if predicted_s is None else float(predicted_s),
                  dict(args))
        st.append(sp)
        return _LiveSpan(self, sp)

    def _finish(self, sp: Span) -> None:
        st = self._stack()
        # exits are LIFO under the context-manager protocol; tolerate a
        # foreign pop (misuse) by searching from the top
        if st and st[-1] is sp:
            st.pop()
        elif sp in st:
            st.remove(sp)
        sp.duration_s = (self._clock() - self._epoch) - sp.t_start_s
        with self._lock:
            self.spans.append(sp)

    def instant(self, name: str, **args) -> None:
        """Record a zero-duration marker (admission decisions, drift
        events…)."""
        if not self.enabled:
            return
        sp = Span(name, self._clock() - self._epoch, len(self._stack()),
                  None, dict(args))
        sp.duration_s = 0.0
        with self._lock:
            self.instants.append(sp)

    # -- summaries ---------------------------------------------------------
    def summary(self) -> Dict[str, Dict[str, float]]:
        """Per-name aggregate: count, measured seconds, predicted seconds,
        and the total gap — the text-mode view of the overlay."""
        out: Dict[str, Dict[str, float]] = {}
        with self._lock:
            spans = list(self.spans)
        for sp in spans:
            agg = out.setdefault(sp.name, {
                "count": 0, "measured_s": 0.0, "predicted_s": 0.0,
                "predicted_count": 0, "gap_s": 0.0})
            agg["count"] += 1
            agg["measured_s"] += sp.duration_s or 0.0
            if sp.predicted_s is not None:
                agg["predicted_count"] += 1
                agg["predicted_s"] += sp.predicted_s
                agg["gap_s"] += (sp.duration_s or 0.0) - sp.predicted_s
        return out

    def report_lines(self) -> List[str]:
        """Human-readable measured-vs-predicted rollup, widest gap first."""
        rows = sorted(self.summary().items(),
                      key=lambda kv: -abs(kv[1]["gap_s"]))
        out = []
        for name, a in rows:
            line = (f"{name}: n={int(a['count'])} "
                    f"measured={a['measured_s']*1e3:.2f}ms")
            if a["predicted_count"]:
                ratio = a["measured_s"] / a["predicted_s"] \
                    if a["predicted_s"] > 0 else float("inf")
                line += (f" predicted={a['predicted_s']*1e3:.2f}ms "
                         f"gap={a['gap_s']*1e3:+.2f}ms "
                         f"ratio={ratio:.2f}x")
            out.append(line)
        return out

    # -- Chrome-trace / Perfetto export ------------------------------------
    def to_chrome_trace(self) -> Dict[str, object]:
        """The trace as a Chrome ``traceEvents`` dict (Perfetto-loadable).

        Measured spans are complete events (``ph="X"``) on the
        ``measured`` track, nested by containment; every span carrying
        ``predicted_s`` additionally emits a sibling complete event on the
        ``predicted`` track at the same start timestamp, whose duration is
        the *predicted* seconds — the two tracks line up so the gap is the
        visible overhang.  Instants are ``ph="i"`` marks."""
        pid = 0
        ev: List[Dict[str, object]] = [
            {"ph": "M", "pid": pid, "tid": MEASURED_TID,
             "name": "process_name", "args": {"name": self.process_name}},
            {"ph": "M", "pid": pid, "tid": MEASURED_TID,
             "name": "thread_name", "args": {"name": "measured"}},
            {"ph": "M", "pid": pid, "tid": PREDICTED_TID,
             "name": "thread_name", "args": {"name": "predicted"}},
        ]
        with self._lock:
            spans = sorted(self.spans, key=lambda s: (s.t_start_s, -s.depth))
            instants = list(self.instants)
        for sp in spans:
            ts = sp.t_start_s * 1e6
            dur = (sp.duration_s or 0.0) * 1e6
            args = dict(sp.args)
            if sp.predicted_s is not None:
                args["predicted_s"] = sp.predicted_s
                args["gap_s"] = sp.gap_s
            ev.append({"name": sp.name, "ph": "X", "pid": pid,
                       "tid": MEASURED_TID, "ts": ts, "dur": dur,
                       "args": args})
            if sp.predicted_s is not None:
                ev.append({"name": f"{sp.name} (predicted)", "ph": "X",
                           "pid": pid, "tid": PREDICTED_TID, "ts": ts,
                           "dur": sp.predicted_s * 1e6,
                           "args": {"measured_s": sp.duration_s,
                                    "predicted_s": sp.predicted_s,
                                    "gap_s": sp.gap_s}})
        for sp in instants:
            ev.append({"name": sp.name, "ph": "i", "pid": pid,
                       "tid": MEASURED_TID, "ts": sp.t_start_s * 1e6,
                       "s": "t", "args": dict(sp.args)})
        return {"traceEvents": ev, "displayTimeUnit": "ms",
                "otherData": {"producer": "repro.obs.trace"}}

    def save(self, path: str) -> None:
        """Atomic write of the Chrome-trace JSON."""
        d = os.path.dirname(path) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self.to_chrome_trace(), f)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def clear(self) -> None:
        with self._lock:
            self.spans.clear()
            self.instants.clear()


#: the always-disabled tracer library code sees by default
NULL_TRACER = Tracer(enabled=False)

_ACTIVE: Tracer = NULL_TRACER


def get_tracer() -> Tracer:
    """The process-wide tracer (disabled unless an entry point enabled
    one).  Library code writes ``with get_tracer().span(...)`` and pays
    one attribute check when tracing is off."""
    return _ACTIVE


def set_tracer(tracer: Optional[Tracer]) -> Tracer:
    """Install ``tracer`` as the process-wide tracer (None restores the
    disabled default); returns the previous one so callers can restore."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = tracer if tracer is not None else NULL_TRACER
    return prev


def enable(process_name: str = "repro") -> Tracer:
    """Install and return a fresh enabled tracer (the ``--trace-json``
    entry-point hook)."""
    t = Tracer(process_name=process_name)
    set_tracer(t)
    return t
