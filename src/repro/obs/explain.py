"""Basis-term attribution — decompose predictions, project residuals.

The fused engine (``core/exprops.py``) scores a cell as one GEMV
``B @ w̃ + c``; this module keeps that sum OPEN: ``score_explain``
returns every addend — per basis term, grouped per property and per cost
category (compute / memory / collective / other), per program source
(step vs. collective vs. launch constant) — so "the model predicts
41.3 ms" becomes "38.1 ms of HBM streaming across 3 terms, 2.9 ms of
all-reduce bytes, 0.3 ms launch overhead".  The decomposition is exact:
the rows sum to the fused ``PlanSpace.scores`` cell at rtol 1e-9 (an
acceptance bar, pinned in ``tests/test_obs.py`` across every registered
arch).

``attribute_residual`` runs the same decomposition *backwards*: given
measured-vs-predicted errors over a sample window, it solves a ridge
least-squares for per-term multiplicative miscalibrations ε (measured ≈
predicted + Σ εᵢ·sᵢ where sᵢ is term i's predicted seconds), so a drift
report can say "HBM-traffic terms account for 78% of the miss" instead
of just flagging drift.  With envs that vary across the window the
projection identifies an injected single-term perturbation (tested);
with identical rows it degrades gracefully to the minimum-norm
projection (shares ∝ contribution²).

This module imports ``repro.core`` lazily where needed, so ``core``
modules may import ``repro.obs`` (trace/metrics/report) without cycles.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "TermContribution", "Explanation", "explain_program", "score_explain",
    "ResidualAttribution", "attribute_residual", "attribute_residual_pv",
]


# ---------------------------------------------------------------------------
# Forward: open up a prediction into its basis-term addends
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TermContribution:
    term: str                 # canonical repr of the basis term ("1" = const)
    seconds: float            # this term's predicted seconds for the cell
    share: float              # seconds / total (signed)
    group: str                # compute | memory | collective | other
    source: str               # "step" | "collective" | "launch"
    properties: Tuple[str, ...]  # property keys the term feeds


@dataclass
class Explanation:
    """A fully decomposed prediction for one cell."""

    total_seconds: float
    rows: List[TermContribution]          # sorted by |seconds| descending
    phase: str = ""
    meta: Dict[str, object] = field(default_factory=dict)

    def by_group(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for r in self.rows:
            out[r.group] = out.get(r.group, 0.0) + r.seconds
        return out

    def by_source(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for r in self.rows:
            out[r.source] = out.get(r.source, 0.0) + r.seconds
        return out

    def by_property(self) -> Dict[str, float]:
        """Per-property seconds (the ``LinearCostModel.breakdown`` analog,
        reconstructed from the term decomposition).  Stored by
        ``score_explain``; empty for hand-built explanations."""
        return dict(self.meta.get("property_seconds", {}))

    def top(self, n: int = 5) -> List[TermContribution]:
        return self.rows[:n]

    def report(self, n: int = 10) -> str:
        """Human-readable table, biggest contributor first."""
        lines = [f"predicted {self.total_seconds*1e3:.3f} ms"
                 + (f" ({self.phase})" if self.phase else "")]
        for g, s in sorted(self.by_group().items(), key=lambda kv: -kv[1]):
            pct = 100.0 * s / self.total_seconds if self.total_seconds \
                else 0.0
            lines.append(f"  {g:<10} {s*1e3:10.4f} ms  {pct:5.1f}%")
        lines.append(f"  top {min(n, len(self.rows))} terms:")
        for r in self.rows[:n]:
            term = r.term if len(r.term) <= 46 else r.term[:43] + "..."
            lines.append(f"    {r.seconds*1e3:10.4f} ms {r.share*100:5.1f}%"
                         f" [{r.group}/{r.source}] {term}")
        return "\n".join(lines)


def _term_groups(program, model) -> Tuple[np.ndarray, List[str],
                                          List[Tuple[str, ...]]]:
    """(w̃ per term, category per term, fed property keys per term).

    A term's category is decided by where its weighted seconds flow: the
    property row with the largest |α_k · coeff[k, i]| wins (terms shared
    across properties are rare after dedup, and the dominant row is what
    a reader wants named)."""
    from repro.core import properties as props
    w = {k: float(v) for k, v in zip(model.keys, model.weights)}
    alpha = np.asarray([w.get(k, 0.0) for k in program.keys])
    contrib = program.coeff * alpha[:, None]        # (n_props, n_terms)
    w_terms = contrib.sum(axis=0)
    groups: List[str] = []
    fed: List[Tuple[str, ...]] = []
    for i in range(program.coeff.shape[1]):
        rows = np.nonzero(program.coeff[:, i])[0]
        fed.append(tuple(program.keys[int(r)] for r in rows))
        pri = np.nonzero(contrib[:, i])[0]
        if len(pri) == 0:
            pri = rows
        if len(pri) == 0:
            groups.append("other")
        else:
            dom = pri[np.argmax(np.abs(contrib[pri, i]))] \
                if len(pri) > 1 else pri[0]
            groups.append(props.category(program.keys[int(dom)]))
    return w_terms, groups, fed


def explain_program(program, env: Mapping[str, object], model, *,
                    scale: float = 1.0, source: str = "step"
                    ) -> List[Tuple[str, float, str, Tuple[str, ...]]]:
    """Per-term (term repr, seconds, group, fed properties) for one
    program at one environment, including the folded constant (term
    ``"1"``).  ``scale`` applies the caller's work division (``1/n_dev``
    for step terms).  The seconds sum EXACTLY to
    ``scale · (program.score(env, model))`` — same folded weights, same
    generated term functions."""
    from repro.core import properties as props
    w_terms, groups, fed = _term_groups(program, model)
    out: List[Tuple[str, float, str, Tuple[str, ...]]] = []
    if np.any(w_terms):
        vals = program(env)
        for i in np.nonzero(w_terms)[0]:
            i = int(i)
            sec = float(w_terms[i]) * float(np.asarray(vals[i], np.float64))
            out.append((program.term_reprs[i], sec * scale, groups[i],
                        fed[i]))
    # the folded constant: Σ_k α_k · const_k
    w = {k: float(v) for k, v in zip(model.keys, model.weights)}
    alpha = np.asarray([w.get(k, 0.0) for k in program.keys])
    c = float(program.const @ alpha)
    if c:
        rows = np.nonzero(program.const * alpha)[0]
        dom = rows[np.argmax(np.abs((program.const * alpha)[rows]))]
        out.append(("1", c * scale, props.category(program.keys[int(dom)]),
                    tuple(program.keys[int(r)] for r in rows)))
    return out


def score_explain(cfg, workload, plan, mesh_shape: Mapping[str, int],
                  model=None) -> Explanation:
    """Decompose one (cfg × workload × plan × mesh) cell's predicted step
    seconds into basis-term contributions.

    The composition mirrors ``planspace.PlanSpace.scores`` exactly —
    fused step program scaled by the SPMD work division, fused collective
    program at the cell's (DP, TP), the model's per-dispatch constant as
    a ``launch`` row — so the rows sum to the fused GEMV score at rtol
    1e-9 (tested across all registered archs).
    """
    from repro.core import archcount, planspace, predictor
    from repro.core import properties as props
    from repro.core import workload as wl
    model = predictor.resolve_model(model)
    spec = wl.as_spec(workload)
    mesh = dict(mesh_shape)
    n_dev = 1
    for v in mesh.values():
        n_dev *= int(v)
    n_dev = max(n_dev, 1)
    dp = 1
    for ax in plan.dp_axes:
        dp *= mesh.get(ax, 1)
    tp = mesh.get(plan.tp_axis, 1) if plan.tp_axis else 1

    env = spec.env(cfg)
    env["M"] = plan.microbatches

    rows: List[TermContribution] = []
    raw: List[Tuple[str, float, str, Tuple[str, ...], str]] = []

    step_prog = predictor.step_program(cfg, spec, plan.remat_policy)
    for term, sec, group, keys in explain_program(
            step_prog, env, model, scale=1.0 / n_dev, source="step"):
        raw.append((term, sec, group, keys, "step"))

    topo = archcount.collective_topology(plan)
    coll_prog = planspace._collective_program(cfg, spec.phase, topo)
    cenv = {**env, "DP": dp, "TP": tp}
    for term, sec, group, keys in explain_program(
            coll_prog, cenv, model, source="collective"):
        raw.append((term, sec, group, keys, "collective"))

    w1 = 0.0
    for k, w in zip(model.keys, model.weights):
        if k == props.CONST1:
            w1 = float(w)
    if w1:
        raw.append(("1", w1, "other", (props.CONST1,), "launch"))

    total = sum(sec for _, sec, _, _, _ in raw)
    for term, sec, group, keys, source in raw:
        rows.append(TermContribution(
            term=term, seconds=sec,
            share=sec / total if total else 0.0,
            group=group, source=source, properties=keys))
    rows.sort(key=lambda r: (-abs(r.seconds), r.source, r.term))

    # the per-property view (breakdown analog) rides in meta
    prop_secs: Dict[str, float] = {}
    for prog, e, scale in ((step_prog, env, 1.0 / n_dev),
                           (coll_prog, cenv, 1.0)):
        P = prog.matrix(e, 1) @ prog.coeff.T + prog.const
        w = {k: float(v) for k, v in zip(model.keys, model.weights)}
        for j, k in enumerate(prog.keys):
            s = w.get(k, 0.0) * float(P[0, j]) * scale
            if s:
                prop_secs[k] = prop_secs.get(k, 0.0) + s
    if w1:
        prop_secs[props.CONST1] = prop_secs.get(props.CONST1, 0.0) + w1

    return Explanation(
        total_seconds=total, rows=rows, phase=spec.phase,
        meta={"device": model.device, "n_dev": n_dev, "dp": dp, "tp": tp,
              "microbatches": plan.microbatches,
              "remat_policy": plan.remat_policy,
              "property_seconds": prop_secs})


# ---------------------------------------------------------------------------
# Backward: project measured-vs-predicted error onto the basis
# ---------------------------------------------------------------------------


@dataclass
class ResidualAttribution:
    """Per-column attribution of a measured-vs-predicted miss.

    ``columns[i]``'s estimated contribution to the (mean) residual is
    ``miss_seconds[i]``; ``epsilon[i]`` is the implied multiplicative
    miscalibration of that column's weight (``measured ≈ predicted +
    Σ εᵢ·sᵢ``)."""

    columns: List[str]
    groups: List[str]
    epsilon: np.ndarray          # per-column multiplicative error estimate
    miss_seconds: np.ndarray     # per-column mean seconds of the residual
    residual_s: float            # mean residual over the window
    n_samples: int

    def shares(self) -> Dict[str, float]:
        """Per-column fraction of the total |attributed| miss."""
        tot = float(np.abs(self.miss_seconds).sum())
        if tot <= 0:
            return {c: 0.0 for c in self.columns}
        return {c: float(abs(s)) / tot
                for c, s in zip(self.columns, self.miss_seconds)}

    def group_shares(self) -> Dict[str, float]:
        """Category → fraction of the |attributed| miss (the "HBM-traffic
        terms account for 78% of the miss" number)."""
        tot = float(np.abs(self.miss_seconds).sum())
        out: Dict[str, float] = {}
        for g, s in zip(self.groups, self.miss_seconds):
            out[g] = out.get(g, 0.0) + abs(float(s))
        if tot > 0:
            out = {g: v / tot for g, v in out.items()}
        return dict(sorted(out.items(), key=lambda kv: -kv[1]))

    def line(self) -> str:
        """One report fragment: ``memory=78% compute=15% …`` plus the mean
        residual."""
        parts = [f"{g}={v*100:.0f}%" for g, v in self.group_shares().items()
                 if v >= 0.005]
        return (f"residual={self.residual_s*1e3:+.3f}ms "
                + " ".join(parts or ["unattributed"]))


def _solve_attribution(B: np.ndarray, r: np.ndarray, columns: List[str],
                       groups: List[str], ridge: float
                       ) -> ResidualAttribution:
    """Ridge least squares ``ε = argmin ‖Bε − r‖² + λ‖ε‖²`` with λ scaled
    to the column energy (scale-free).  B's columns are per-sample
    CONTRIBUTION SECONDS, so ε is dimensionless (a relative weight error)
    and ``B @ ε`` is seconds."""
    n, k = B.shape
    G = B.T @ B
    lam = ridge * (np.trace(G) / k if k else 1.0)
    eps = np.linalg.solve(G + lam * np.eye(k), B.T @ r) if k \
        else np.zeros(0)
    miss = eps * B.mean(axis=0) if k else np.zeros(0)
    return ResidualAttribution(
        columns=columns, groups=groups, epsilon=eps, miss_seconds=miss,
        residual_s=float(r.mean()) if n else 0.0, n_samples=n)


def attribute_residual(program, model, envs: Sequence[Mapping[str, object]],
                       measured_s: Sequence[float], *, scale: float = 1.0,
                       ridge: float = 1e-6) -> ResidualAttribution:
    """Project measured-vs-predicted errors onto the TERM basis of one
    fused program.

    ``envs``/``measured_s`` are a sample window (one env per measured
    wall time; ``scale`` is the caller's work division, as in
    ``explain_program``).  When the envs vary, an error injected on a
    single term's weight is recovered on that term; identical envs give
    the minimum-norm projection (shares ∝ contribution²).
    """
    w_terms, groups_all, _ = _term_groups(program, model)
    live = [int(i) for i in np.nonzero(w_terms)[0]]
    n = len(measured_s)
    B = np.zeros((n, len(live)), dtype=np.float64)
    r = np.zeros(n, dtype=np.float64)
    w = {k: float(v) for k, v in zip(model.keys, model.weights)}
    alpha = np.asarray([w.get(k, 0.0) for k in program.keys])
    c = float(program.const @ alpha) * scale
    for j, env in enumerate(envs):
        vals = program(env)
        pred = c
        for col, i in enumerate(live):
            s = float(w_terms[i]) * float(np.asarray(vals[i], np.float64)) \
                * scale
            B[j, col] = s
            pred += s
        r[j] = float(measured_s[j]) - pred
    return _solve_attribution(
        B, r, [program.term_reprs[i] for i in live],
        [groups_all[i] for i in live], ridge)


def attribute_residual_pv(model, pvs: Sequence[Mapping[str, float]],
                          measured_s: Sequence[float], *,
                          ridge: float = 1e-6) -> ResidualAttribution:
    """Project measured-vs-predicted errors onto the PROPERTY basis.

    This is the telemetry-side frontend: the online calibrator buffers
    (property vector, seconds) samples, so the attribution columns are
    the model's priced properties (``α_k · p_k`` seconds per sample) —
    coarser than the term basis but available wherever a
    ``TelemetrySink`` window is."""
    from repro.core import properties as props
    keys = [k for k, w in zip(model.keys, model.weights)
            if w and any(pv.get(k) for pv in pvs)]
    n = len(measured_s)
    w = {k: float(v) for k, v in zip(model.keys, model.weights)}
    B = np.zeros((n, len(keys)), dtype=np.float64)
    r = np.zeros(n, dtype=np.float64)
    for j, pv in enumerate(pvs):
        for col, k in enumerate(keys):
            B[j, col] = w[k] * float(pv.get(k, 0.0))
        r[j] = float(measured_s[j]) - model.predict(pv)
    return _solve_attribution(B, r, keys,
                              [props.category(k) for k in keys], ridge)
