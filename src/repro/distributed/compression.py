"""Int8 error-feedback gradient compression for the DP all-reduce.

The wire format is per-chunk int8 + fp32 scale (≈4× fewer collective bytes
than fp32, 2× vs bf16).  Error feedback (Seide et al. / 1-bit Adam lineage)
accumulates the quantization residual locally and re-adds it before the
next step's compression, so the *long-run* gradient is unbiased and
convergence matches uncompressed SGD/Adam to first order.

Two layers:

  * pure quantizer (``quantize``/``dequantize``/``ef_compress``) — unit
    tested, usable anywhere;
  * ``compressed_psum`` — a shard_map collective: quantized
    reduce-scatter (all_to_all + local sum) followed by a quantized
    all_gather.  Per-device wire bytes ≈ 2·(n−1)/n·(size/4) vs
    2·(n−1)/n·size uncompressed — the 4× shows up directly in the dry-run
    HLO (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

CHUNK = 1024  # quantization granularity (one fp32 scale per CHUNK values)


# ---------------------------------------------------------------------------
# Quantizer
# ---------------------------------------------------------------------------


def _pad_to(x: jnp.ndarray, mult: int) -> Tuple[jnp.ndarray, int]:
    n = x.size
    pad = (-n) % mult
    return jnp.pad(x.reshape(-1), (0, pad)), n


def quantize(x: jnp.ndarray, chunk: int = CHUNK
             ) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
    """fp array -> (int8 codes, fp32 per-chunk scales, original size)."""
    flat, n = _pad_to(x.astype(jnp.float32), chunk)
    c = flat.reshape(-1, chunk)
    scale = jnp.max(jnp.abs(c), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    codes = jnp.clip(jnp.round(c / scale), -127, 127).astype(jnp.int8)
    return codes, scale[:, 0], n


def dequantize(codes: jnp.ndarray, scales: jnp.ndarray, n: int,
               shape, dtype=jnp.float32) -> jnp.ndarray:
    vals = codes.astype(jnp.float32) * scales[:, None]
    return vals.reshape(-1)[:n].reshape(shape).astype(dtype)


def ef_compress(x: jnp.ndarray, residual: jnp.ndarray, chunk: int = CHUNK):
    """Error-feedback compress: returns (codes, scales, new_residual)."""
    y = x.astype(jnp.float32) + residual
    codes, scales, n = quantize(y, chunk)
    deq = dequantize(codes, scales, n, x.shape)
    return codes, scales, y.reshape(x.shape) - deq


# ---------------------------------------------------------------------------
# Compressed all-reduce (shard_map collective)
# ---------------------------------------------------------------------------


def psum_compressed(x: jnp.ndarray, axis: str, chunk: int = CHUNK
                    ) -> jnp.ndarray:
    """``jax.lax.psum`` with int8 wire format — call INSIDE a shard_map body.

    Algorithm (ring-equivalent):
      1. split the local value into n destination shards, quantize, and
         ``all_to_all`` (the reduce-scatter wire move, int8);
      2. dequantize + sum the n received contributions (my reduced shard);
      3. re-quantize, ``all_gather`` (int8), dequantize.
    """
    # jax.lax.axis_size only exists in newer JAX; psum(1) is the portable form
    n = jax.lax.axis_size(axis) if hasattr(jax.lax, "axis_size") \
        else jax.lax.psum(1, axis)
    flat, size = _pad_to(x.astype(jnp.float32), n * chunk)
    shards = flat.reshape(n, -1)  # row i -> destined for rank i

    codes, scales, _ = quantize(shards.reshape(-1), chunk)
    codes = codes.reshape(n, -1)
    scales = scales.reshape(n, -1)
    # all_to_all: exchange shard rows (the reduce-scatter wire move)
    codes_x = jax.lax.all_to_all(codes, axis, 0, 0)
    scales_x = jax.lax.all_to_all(scales, axis, 0, 0)
    # local dequant-sum of the n received contributions for my shard
    part = jnp.sum(codes_x.astype(jnp.float32)
                   * jnp.repeat(scales_x, chunk, axis=-1), axis=0)

    # quantize my reduced shard, all_gather to complete the all-reduce
    c2, s2, _ = quantize(part, chunk)          # (k, chunk) int8, (k,) f32
    c_all = jax.lax.all_gather(c2, axis)       # (n, k, chunk) on the wire
    s_all = jax.lax.all_gather(s2, axis)       # (n, k)
    full = (c_all.astype(jnp.float32) * s_all[..., None]).reshape(-1)
    return full[:size].reshape(x.shape).astype(x.dtype)


def psum_tree_compressed(tree: Any, axis: str, chunk: int = CHUNK) -> Any:
    return jax.tree.map(
        functools.partial(psum_compressed, axis=axis, chunk=chunk), tree)
