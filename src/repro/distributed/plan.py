"""Parallelism plan: how logical axes map onto the physical mesh.

A ``Plan`` is the unit the cost-model-driven autosharding search ranks
(see ``repro.core.predictor`` / ``launch/autoshard.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class Plan:
    # mesh axis names used for each role (must exist in the physical mesh)
    dp_axes: Tuple[str, ...] = ("pod", "data")  # batch / FSDP axes
    tp_axis: Optional[str] = "model"            # tensor-parallel axis
    # features
    fsdp: bool = True                 # shard params over dp_axes too (ZeRO-3)
    sequence_parallel: bool = True    # shard residual-stream seq dim over tp
    moe_mode: str = "tp"              # "tp" | "ep" (expert-parallel)
    microbatches: int = 1             # gradient-accumulation chunks
    remat_policy: Optional[str] = None  # override arch default
    compression: Optional[str] = None   # None | "int8_ef" for DP grad all-reduce
    # decode-specific
    cache_seq_axes: Tuple[str, ...] = ()  # mesh axes sharding the KV-cache
    # sequence dim (context-parallel decode; scores psum over these axes)

    def param_rules(self) -> Dict[str, object]:
        """Logical param axis -> mesh axes."""
        fsdp_ax = self.dp_axes if self.fsdp else ()
        return {
            "embed": fsdp_ax,          # FSDP shards the embed dim of weights
            "ff": self.tp_axis,
            "heads": self.tp_axis,
            "kv_heads": self.tp_axis,  # applied only when divisible
            "vocab": self.tp_axis,
            "layers": None,
            "codebook": None,
            "head_idx": None,
            "expert": self.tp_axis if self.moe_mode == "ep" else None,
            "ssm_inner": self.tp_axis,
            "ssm_state": None,
            "ssm_heads": self.tp_axis,
            "conv": None,
            "head_dim": None,
        }

    def act_rules(self) -> Dict[str, object]:
        """Logical activation axis -> mesh axes."""
        return {
            "act_batch": self.dp_axes,
            "act_seq": self.tp_axis if self.sequence_parallel else None,
            "act_seq_dp": self.cache_seq_axes or None,
            "act_embed": None,
            "act_heads": self.tp_axis,
            "act_kv_heads": self.tp_axis,
            "act_ff": self.tp_axis,
            "act_vocab": self.tp_axis,
            "act_expert": self.tp_axis if self.moe_mode == "ep" else None,
            "act_cp": self.tp_axis,   # context-parallel q-slice dim
            "act_ssm_heads": self.tp_axis,
            "act_ssm_inner": self.tp_axis,
            "act_layers": None,
        }

    def with_(self, **kw) -> "Plan":
        return replace(self, **kw)


# sensible defaults per shape kind
def default_plan(kind: str, multi_pod: bool) -> Plan:
    dp = ("pod", "data") if multi_pod else ("data",)
    if kind == "train":
        return Plan(dp_axes=dp)
    if kind == "prefill":
        return Plan(dp_axes=dp, fsdp=False, microbatches=1)
    # decode: batch over dp, weights TP; cache seq sharding for long contexts
    return Plan(dp_axes=dp, fsdp=False, sequence_parallel=False)


def plan_for(cfg, shape, *, multi_pod: bool = False,
             tp_size: int = 16, hbm_budget: float = 16e9) -> Plan:
    """Memory-aware default plan for an (arch × shape) cell.

    This is the *paper-faithful baseline* plan the dry-run lowers; the
    cost-model autosharding search (launch/autoshard.py) refines it.
    """
    dp = ("pod", "data") if multi_pod else ("data",)
    n_dev = (2 if multi_pod else 1) * 16 * tp_size
    bits = 16 if "16" in cfg.param_dtype else 32
    param_bytes = cfg.n_params() * (bits // 8)

    if shape.kind == "train":
        # microbatches so that remat boundary activations fit comfortably
        act = (2 * shape.global_batch * shape.seq_len * cfg.d_model
               * cfg.n_layers) / n_dev
        m = 1
        while m < shape.global_batch and act / m > 2e9:
            m *= 2
        # sequence-parallel norms pay a dW reduce penalty under GSPMD (the
        # token contraction crosses the seq-shard axis and lowers as a
        # replicated all-reduce): at 405B width the dW tensors dominate
        # that trade (measured 8× collective inflation; EXPERIMENTS.md
        # §Perf iter B), below it the activation savings win.
        sp = cfg.d_model < 12288
        return Plan(dp_axes=dp, fsdp=True, microbatches=m,
                    sequence_parallel=sp)

    fsdp = param_bytes / tp_size > hbm_budget / 2  # weight-distributed serving
    if shape.kind == "prefill":
        return Plan(dp_axes=dp, fsdp=fsdp, microbatches=1)

    # decode: shard the KV-cache sequence over the model axis when the
    # effective context is long (kv-head sharding alone underuses the axis)
    eff_ctx = min(shape.seq_len, cfg.sliding_window or shape.seq_len)
    cache_seq = ("model",) if (cfg.n_heads and eff_ctx >= 32768) else ()
    return Plan(dp_axes=dp, fsdp=fsdp, sequence_parallel=False,
                cache_seq_axes=cache_seq)
