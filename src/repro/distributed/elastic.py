"""Elastic re-planning: on node/pod loss, choose the best feasible
(mesh, plan) for the surviving devices and resume from the last checkpoint.

Uses the fitted/analytic linear cost model (core/predictor.py) to rank the
candidate meshes in microseconds — the paper's 'rapid evaluation' property
is what makes in-failure-path re-planning viable at all (a compile-and-
measure search would take minutes per candidate).  The ``weights`` argument
accepts a registry device name (``repro.calibration``) as well as an
in-memory ``LinearCostModel``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Tuple

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import exprops, planspace, predictor
from repro.core import workload as wl
from repro.distributed.plan import Plan, plan_for

#: incremental-rescore cache for the failure path: basis columns keyed by
#: (term, its own free-variable values), so a replan after a device-count
#: delta recomputes only the DP/TP-dependent columns — every (B, S, M)-
#: keyed column returns from cache and warm replans stay in microseconds.
_BASIS_CACHE = exprops.BasisCache(maxsize=8192)


@dataclass(frozen=True)
class MeshOption:
    shape: Dict[str, int]          # axis -> size
    plan: Plan
    predicted_step_s: float


def _factorizations(n: int) -> List[Tuple[int, int]]:
    """All ordered (data, model) splits of ``n`` — now shared with the
    autoshard mesh sweep via ``core.planspace.factor_pairs``."""
    return planspace.factor_pairs(n)


def replan(cfg: ArchConfig, shape: wl.WorkloadLike, n_devices: int,
           weights: predictor.ModelLike = None,
           max_candidates: int = 64) -> List[MeshOption]:
    """Rank feasible (data × model) meshes for ``n_devices`` survivors.

    Feasibility: the global batch must still divide the data axis (training
    keeps exact batch semantics across restarts) and the model dims must
    divide the model axis (checked softly — the sharding layer drops
    non-divisible axes, so these plans still *lower*, they just waste the
    axis; the predictor prices that in).

    Every surviving-mesh candidate is scored with ONE batched call through
    the fused search engine (``core.planspace`` → ``core.exprops``) — this
    runs on the failure path, so the sweep must stay in microseconds per
    candidate.  Scoring passes the module's ``exprops.BasisCache``: across
    successive replans only the basis columns a device-count/shape delta
    actually touches recompute (the incremental-rescore contract,
    docs/MODEL.md §2.7).
    """
    weights = predictor.resolve_model(weights)  # once, not per candidate
    spec = wl.as_spec(shape)    # any WorkloadLike; one currency from here
    cells: List[Tuple[Plan, Dict[str, int]]] = []
    for dp, tp in _factorizations(n_devices)[:max_candidates]:
        if spec.phase == "train" and spec.global_batch % dp != 0:
            continue
        plan = plan_for(cfg, spec, multi_pod=False, tp_size=tp)
        plan = dataclasses.replace(plan, dp_axes=("data",))
        cells.append((plan, {"data": dp, "model": tp}))
    if not cells:
        return []
    space = planspace.PlanSpace.from_cells(cfg, spec, cells)
    secs = space.scores(weights, cache=_BASIS_CACHE)
    opts = [MeshOption(mesh, plan, float(s))
            for (plan, mesh), s in zip(cells, secs)]
    opts.sort(key=lambda o: (o.predicted_step_s,
                             planspace.mesh_sort_key(o.shape)))
    return opts


def on_failure(cfg: ArchConfig, shape: wl.WorkloadLike, prev_devices: int,
               lost: int, weights: predictor.ModelLike = None
               ) -> MeshOption:
    """Failure handler: fall back to the best mesh over the largest
    'round' (power-of-two) survivor count — spares become hot standbys,
    matching how real pods drain around a failed host."""
    survivors = prev_devices - lost
    n = 1
    while n * 2 <= survivors:
        n *= 2
    options = replan(cfg, shape, n, weights)
    assert options, f"no feasible mesh for {n} devices"
    return options[0]
