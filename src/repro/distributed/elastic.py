"""Elastic re-planning: on node/pod loss, choose the best feasible
(mesh, plan) for the surviving devices and resume from the last checkpoint.

Uses the fitted/analytic linear cost model (core/predictor.py) to rank the
candidate meshes in microseconds — the paper's 'rapid evaluation' property
is what makes in-failure-path re-planning viable at all (a compile-and-
measure search would take minutes per candidate).  The ``weights`` argument
accepts a registry device name (``repro.calibration``) as well as an
in-memory ``LinearCostModel``.

``devices`` generalizes beyond a homogeneous count (ISSUE 10): any entry
point taking a device count also accepts a **heterogeneous pool
descriptor** — a list of ``(device_name, count)`` pairs — in which case
each pool's factorization space is priced through that pool's own registry
model (hardened load: corrupt file → revision backup → analytic seed) and
the ranked options carry the pool's device name.  A plain ``int`` remains
the 1-pool case with the caller-supplied ``weights``, byte-identical to the
pre-fleet behavior.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import exprops, planspace, predictor
from repro.core import workload as wl
from repro.distributed.plan import Plan, plan_for

#: incremental-rescore cache for the failure path: basis columns keyed by
#: (term, its own free-variable values), so a replan after a device-count
#: delta recomputes only the DP/TP-dependent columns — every (B, S, M)-
#: keyed column returns from cache and warm replans stay in microseconds.
_BASIS_CACHE = exprops.BasisCache(maxsize=8192)

#: the same incremental contract per named pool: each device type's
#: columns live in their own cache so a churny heterogeneous fleet warms
#: every pool independently (cleared together by ``clear_caches``).
_POOL_CACHES: Dict[str, exprops.BasisCache] = {}

#: a heterogeneous pool: ordered (registry device name, chip count) pairs.
PoolDescriptor = Sequence[Tuple[Optional[str], int]]
DevicesArg = Union[int, PoolDescriptor]


@dataclass(frozen=True)
class MeshOption:
    shape: Dict[str, int]          # axis -> size
    plan: Plan
    predicted_step_s: float
    #: pool device name this option was priced for (None: homogeneous
    #: 1-pool case scored with the caller's ``weights``)
    device: Optional[str] = None


def pool_cache(device: Optional[str] = None) -> exprops.BasisCache:
    """The incremental ``BasisCache`` for one pool (None: the classic
    homogeneous cache).  Exposed so the fleet benchmark can read the
    hits/misses telemetry behind the warm-replan acceptance bar."""
    if device is None:
        return _BASIS_CACHE
    cache = _POOL_CACHES.get(device)
    if cache is None:
        cache = _POOL_CACHES[device] = exprops.BasisCache(maxsize=8192)
    return cache


def as_pools(devices: DevicesArg) -> List[Tuple[Optional[str], int]]:
    """Normalize a devices argument: ``int`` → the anonymous 1-pool case,
    a descriptor passes through with counts coerced to ``int``."""
    if isinstance(devices, (int,)) or hasattr(devices, "__index__"):
        return [(None, int(devices))]
    out: List[Tuple[Optional[str], int]] = []
    for device, n in devices:
        out.append((None if device is None else str(device), int(n)))
    return out


def _pool_model(device: Optional[str], weights,
                registry_dir: Optional[str],
                models: Optional[Mapping[str, object]]):
    """The cost model pricing one pool: a named pool loads its own registry
    model (or takes it from ``models``, the fleet allocator's batch-loaded
    map); the anonymous pool keeps the caller's ``weights``."""
    if device is None:
        return predictor.resolve_model(weights)
    if models is not None and device in models:
        return models[device]
    from repro.calibration import registry
    return registry.load_model(device, registry_dir)


def _factorizations(n: int) -> List[Tuple[int, int]]:
    """All ordered (data, model) splits of ``n`` — now shared with the
    autoshard mesh sweep via ``core.planspace.factor_pairs``."""
    return planspace.factor_pairs(n)


def mesh_cells(cfg: ArchConfig, spec: wl.WorkloadSpec, n_devices: int,
               max_candidates: int = 64
               ) -> List[Tuple[Plan, Dict[str, int]]]:
    """The feasible (plan, mesh) cells for ``n_devices`` chips: every
    (data × model) factorization whose data way still divides the global
    batch (training keeps exact batch semantics across restarts), each
    with its memory-aware default plan.  Shared by ``replan`` and the
    fleet allocator's per-pool scoring."""
    cells: List[Tuple[Plan, Dict[str, int]]] = []
    for dp, tp in _factorizations(n_devices)[:max_candidates]:
        if spec.phase == "train" and spec.global_batch % dp != 0:
            continue
        plan = plan_for(cfg, spec, multi_pod=False, tp_size=tp)
        plan = dataclasses.replace(plan, dp_axes=("data",))
        cells.append((plan, {"data": dp, "model": tp}))
    return cells


def replan(cfg: ArchConfig, shape: wl.WorkloadLike, devices: DevicesArg,
           weights: predictor.ModelLike = None,
           max_candidates: int = 64, *,
           registry_dir: Optional[str] = None,
           models: Optional[Mapping[str, object]] = None,
           cache: Optional[exprops.BasisCache] = None) -> List[MeshOption]:
    """Rank feasible (data × model) meshes for the surviving devices.

    ``devices`` is a survivor count (the classic 1-pool case) or a
    heterogeneous pool descriptor ``[(device_name, count), ...]``; with a
    descriptor every pool's candidates are priced through that pool's own
    registry model and all options are merged into one ranking (seconds
    first, then the deterministic plan/mesh/device tie-breaks).

    Feasibility: the global batch must still divide the data axis (training
    keeps exact batch semantics across restarts) and the model dims must
    divide the model axis (checked softly — the sharding layer drops
    non-divisible axes, so these plans still *lower*, they just waste the
    axis; the predictor prices that in).

    Every surviving-mesh candidate is scored with ONE batched call through
    the fused search engine (``core.planspace`` → ``core.exprops``) — this
    runs on the failure path, so the sweep must stay in microseconds per
    candidate.  Scoring passes each pool's ``exprops.BasisCache`` (or the
    caller's ``cache`` override): across successive replans only the basis
    columns a device-count/shape delta actually touches recompute (the
    incremental-rescore contract, docs/MODEL.md §2.7).
    """
    spec = wl.as_spec(shape)    # any WorkloadLike; one currency from here
    opts: List[MeshOption] = []
    for device, n in as_pools(devices):
        model = _pool_model(device, weights, registry_dir, models)
        cells = mesh_cells(cfg, spec, n, max_candidates)
        if not cells:
            continue
        space = planspace.PlanSpace.from_cells(cfg, spec, cells)
        secs = space.scores(model,
                            cache=cache if cache is not None
                            else pool_cache(device))
        opts.extend(MeshOption(mesh, plan, float(s), device=device)
                    for (plan, mesh), s in zip(cells, secs))
    opts.sort(key=lambda o: (o.predicted_step_s,
                             planspace.mesh_sort_key(o.shape),
                             o.device or ""))
    return opts


def _pow2_floor(n: int) -> int:
    """Largest power of two ≤ n (0 for n ≤ 0) — the 'round' survivor
    count real pods drain to around a failed host."""
    if n <= 0:
        return 0
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def on_failure(cfg: ArchConfig, shape: wl.WorkloadLike,
               prev_devices: DevicesArg, lost: int,
               weights: predictor.ModelLike = None, *,
               pool: Optional[str] = None,
               registry_dir: Optional[str] = None,
               models: Optional[Mapping[str, object]] = None
               ) -> MeshOption:
    """Failure handler: fall back to the best mesh over the largest
    'round' (power-of-two) survivor count — spares become hot standbys,
    matching how real pods drain around a failed host.

    With a heterogeneous ``prev_devices`` descriptor the ``lost`` devices
    come out of the ``pool`` named by the fault (default: the first pool);
    that pool rounds down to a power of two, the others keep their counts,
    and the best option across all surviving pools wins — a dead pool
    (zero survivors) simply drops out of the descriptor."""
    pools = as_pools(prev_devices)
    if len(pools) == 1 and pools[0][0] is None and pool is None:
        survivors = pools[0][1] - lost
        options = replan(cfg, shape, _pow2_floor(survivors), weights,
                         registry_dir=registry_dir, models=models)
        assert options, f"no feasible mesh for {_pow2_floor(survivors)} " \
                        f"devices"
        return options[0]
    target = pool if pool is not None else pools[0][0]
    desc: List[Tuple[Optional[str], int]] = []
    for device, n in pools:
        if device == target:
            n = _pow2_floor(n - lost)
        if n > 0:
            desc.append((device, n))
    options = replan(cfg, shape, desc, weights,
                     registry_dir=registry_dir, models=models)
    assert options, f"no feasible mesh over surviving pools {desc}"
    return options[0]
