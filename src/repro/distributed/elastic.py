"""Elastic re-planning: on node/pod loss, choose the best feasible
(mesh, plan) for the surviving devices and resume from the last checkpoint.

Uses the fitted/analytic linear cost model (core/predictor.py) to rank the
candidate meshes in microseconds — the paper's 'rapid evaluation' property
is what makes in-failure-path re-planning viable at all (a compile-and-
measure search would take minutes per candidate).  The ``weights`` argument
accepts a registry device name (``repro.calibration``) as well as an
in-memory ``LinearCostModel``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Tuple

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import predictor
from repro.distributed.plan import Plan, plan_for


@dataclass(frozen=True)
class MeshOption:
    shape: Dict[str, int]          # axis -> size
    plan: Plan
    predicted_step_s: float


def _factorizations(n: int) -> List[Tuple[int, int]]:
    out = []
    d = 1
    while d * d <= n:
        if n % d == 0:
            out.append((d, n // d))
            if d != n // d:
                out.append((n // d, d))
        d += 1
    return sorted(set(out))


def replan(cfg: ArchConfig, shape: ShapeConfig, n_devices: int,
           weights: predictor.ModelLike = None,
           max_candidates: int = 64) -> List[MeshOption]:
    """Rank feasible (data × model) meshes for ``n_devices`` survivors.

    Feasibility: the global batch must still divide the data axis (training
    keeps exact batch semantics across restarts) and the model dims must
    divide the model axis (checked softly — the sharding layer drops
    non-divisible axes, so these plans still *lower*, they just waste the
    axis; the predictor prices that in).
    """
    weights = predictor.resolve_model(weights)  # once, not per candidate
    opts: List[MeshOption] = []
    for dp, tp in _factorizations(n_devices)[:max_candidates]:
        if shape.kind == "train" and shape.global_batch % dp != 0:
            continue
        mesh_shape = {"data": dp, "model": tp}
        plan = plan_for(cfg, shape, multi_pod=False, tp_size=tp)
        plan = dataclasses.replace(plan, dp_axes=("data",))
        pred = predictor.predict_step(cfg, shape, plan, mesh_shape, weights)
        opts.append(MeshOption(mesh_shape, plan, pred.seconds))
    opts.sort(key=lambda o: o.predicted_step_s)
    return opts


def on_failure(cfg: ArchConfig, shape: ShapeConfig, prev_devices: int,
               lost: int, weights: predictor.ModelLike = None
               ) -> MeshOption:
    """Failure handler: fall back to the best mesh over the largest
    'round' (power-of-two) survivor count — spares become hot standbys,
    matching how real pods drain around a failed host."""
    survivors = prev_devices - lost
    n = 1
    while n * 2 <= survivors:
        n *= 2
    options = replan(cfg, shape, n, weights)
    assert options, f"no feasible mesh for {n} devices"
    return options[0]
