"""Logical-axis sharding (MaxText-style), without a flax dependency.

Models annotate activations with *logical* axis names via ``logical()``;
parameters carry logical axes in a parallel ``axes`` tree.  A thread-local
``ShardingCtx`` (mesh + Plan rules) resolves names to ``PartitionSpec``s.
Outside any context, ``logical()`` is the identity — so smoke tests and
benchmarks run unsharded on one device.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.plan import Plan

_tls = threading.local()


class ShardingCtx:
    def __init__(self, mesh: Mesh, plan: Plan):
        self.mesh = mesh
        self.plan = plan
        self.axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    # ------------------------------------------------------------------
    def _resolve(self, rule_value, dim: int) -> Optional[Tuple[str, ...]]:
        """Mesh axes for one dim, dropping axes that don't divide it or
        don't exist in this mesh."""
        if rule_value is None:
            return None
        axes = (rule_value,) if isinstance(rule_value, str) else tuple(rule_value)
        out = []
        size = 1
        for ax in axes:
            if ax not in self.axis_sizes:
                continue
            s = self.axis_sizes[ax]
            if dim % (size * s) == 0:
                out.append(ax)
                size *= s
        return tuple(out) or None

    def spec(self, logical_axes: Sequence[Optional[str]], shape: Sequence[int],
             rules: dict) -> P:
        parts, used = [], set()
        for name, dim in zip(logical_axes, shape):
            r = self._resolve(rules.get(name), dim) if name else None
            # an axis may be used at most once per spec
            if r:
                r = tuple(ax for ax in r if ax not in used)
            if r:
                used.update(r)
                parts.append(r if len(r) > 1 else r[0])
            else:
                parts.append(None)
        return P(*parts)

    def param_spec(self, logical_axes, shape) -> P:
        return self.spec(logical_axes, shape, self.plan.param_rules())

    def act_spec(self, logical_axes, shape) -> P:
        return self.spec(logical_axes, shape, self.plan.act_rules())


def current() -> Optional[ShardingCtx]:
    return getattr(_tls, "ctx", None)


@contextmanager
def use_sharding(mesh: Mesh, plan: Plan):
    prev = getattr(_tls, "ctx", None)
    _tls.ctx = ShardingCtx(mesh, plan)
    try:
        yield _tls.ctx
    finally:
        _tls.ctx = prev


def logical(x: jax.Array, names: Sequence[Optional[str]]) -> jax.Array:
    """Annotate an activation with logical axis names (no-op w/o context)."""
    ctx = current()
    if ctx is None:
        return x
    spec = ctx.act_spec(names, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter sharding trees
# ---------------------------------------------------------------------------


def is_axes_leaf(x) -> bool:
    """A logical-axes leaf is a plain tuple of axis names (str | None) —
    NamedTuples (KVCache, SSMState, …) are containers, not leaves."""
    return (isinstance(x, tuple) and not hasattr(x, "_fields")
            and all(e is None or isinstance(e, str) for e in x))


def param_shardings(mesh: Mesh, plan: Plan, axes_tree, shapes_tree):
    """NamedSharding tree for a param pytree given its logical-axes tree."""
    ctx = ShardingCtx(mesh, plan)

    def one(axes, shp):
        shape = shp.shape if hasattr(shp, "shape") else shp
        return NamedSharding(mesh, ctx.param_spec(axes, shape))

    return jax.tree.map(one, axes_tree, shapes_tree, is_leaf=is_axes_leaf)


def tree_bytes(shapes_tree) -> int:
    leaves = jax.tree.leaves(shapes_tree)
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize for l in leaves)


def context_parallel_factor(n_heads: int, seq_len: int,
                            min_slice: int = 1024) -> int:
    """How many ways to split the q-sequence for attention (context
    parallelism).  Used when the head dim cannot occupy the model axis
    (n_heads % tp != 0): slicing the q range over the same axis recovers
    the tp-fold division of attention compute (k/v stay replicated; the
    causal diagonal makes slices unequal work — see DESIGN.md §Perf)."""
    ctx = current()
    if ctx is None or ctx.plan.tp_axis is None:
        return 1
    tp = ctx.axis_sizes.get(ctx.plan.tp_axis, 1)
    if tp <= 1 or n_heads % tp == 0:
        return 1  # head sharding already uses the axis fully
    if seq_len % (tp * min_slice) != 0:
        return 1
    return tp


def constrain_like_params(tree, axes_tree):
    """Pin a param-shaped tree (e.g. the gradient accumulator) to the param
    sharding rules.  No-op outside a sharding context.  Without this, GSPMD
    materializes REPLICATED f32 dW partials inside the grad-accumulation
    loop (all-reduce + slice) instead of reduce-scattering into the sharded
    accumulator — 8–12 GB/layer on the 405B lowering."""
    ctx = current()
    if ctx is None:
        return tree

    def one(axes, x):
        spec = ctx.param_spec(axes, x.shape)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(ctx.mesh, spec))

    return jax.tree.map(one, axes_tree, tree, is_leaf=is_axes_leaf)
