"""Serving launcher: batched decode with continuous slot refill.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --reduced --requests 8 --max-new 24
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import ARCHS, get_arch
from repro.models import transformer
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs_trace
from repro.runtime.server import DecodeServer, Request


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--admission", default="fifo",
                    choices=("fifo", "model"),
                    help="slot-refill policy: arrival order or "
                         "shortest-predicted-job-first")
    ap.add_argument("--trace-json", default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto JSON of the serve "
                         "run (prefill/decode spans + predicted overlay)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="dump the metrics registry as JSON on exit")
    # --- supervised degradation / chaos (runtime/supervisor.py) ---
    ap.add_argument("--supervise", action="store_true",
                    help="run under ServingSupervisor: decode watchdog, "
                         "slot eviction, admission throttling, load "
                         "shedding with retry-after")
    ap.add_argument("--fault-plan", default=None, metavar="SPEC|PATH",
                    help="deterministic fault schedule (iteration-indexed"
                         "); implies --supervise")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--slo-decode-s", type=float, default=None,
                    help="decode-iteration latency SLO (admission defers "
                         "when the model predicts a breach)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="shed queued requests beyond this depth "
                         "(stamped with retry-after)")
    args = ap.parse_args()

    if args.trace_json:
        _obs_trace.enable(process_name="serve")

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params, _ = transformer.init_params(cfg, jax.random.PRNGKey(args.seed))
    supervised = args.supervise or args.fault_plan
    injector = None
    if supervised:
        from repro.runtime.faults import FaultInjector, FaultPlan
        fplan = FaultPlan.parse(args.fault_plan, seed=args.chaos_seed) \
            if args.fault_plan else FaultPlan(seed=args.chaos_seed)
        injector = FaultInjector(fplan)
        if fplan:
            print(f"[serve] fault plan armed: {fplan.describe()}")
    server = DecodeServer(cfg, params, slots=args.slots,
                          max_len=args.max_len, seed=args.seed,
                          admission=args.admission,
                          slo_decode_s=args.slo_decode_s,
                          injector=injector)

    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        plen = int(rng.integers(4, 17))
        prompt = rng.integers(2, cfg.vocab_size, size=plen).astype(np.int32)
        server.submit(Request(rid=rid, prompt=prompt, max_new=args.max_new))

    t0 = time.perf_counter()
    if supervised:
        from repro.runtime.supervisor import ServingPolicy, ServingSupervisor
        sup = ServingSupervisor(
            server, ServingPolicy(max_queue=args.max_queue),
            injector=injector)
        done = sup.run()
        sup.report()
    else:
        done = server.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, slots={args.slots})")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt[:6]={r.prompt[:6].tolist()} "
              f"out[:8]={r.out[:8]}")

    tracer = _obs_trace.get_tracer()
    if args.trace_json:
        for line in tracer.report_lines():
            print(f"[trace] {line}")
        tracer.save(args.trace_json)
        print(f"[serve] trace written to {args.trace_json}")
    if args.metrics_json:
        _obs_metrics.REGISTRY.save_json(args.metrics_json)
        print(f"[serve] metrics written to {args.metrics_json}")


if __name__ == "__main__":
    main()
