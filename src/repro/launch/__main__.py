"""Subcommand dispatcher: ``python -m repro.launch <cmd> …``.

    python -m repro.launch fleet --manifest demo --steps 12
    python -m repro.launch train --arch smollm-360m --reduced …

Each subcommand is the ``main()`` of the matching ``repro.launch``
module; the per-module entry points (``python -m repro.launch.train``)
keep working unchanged.
"""
from __future__ import annotations

import sys

_COMMANDS = ("fleet", "train", "serve", "autoshard", "dryrun")


def main(argv=None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] in ("-h", "--help"):
        print(__doc__.strip())
        print(f"\ncommands: {', '.join(_COMMANDS)}")
        raise SystemExit(0 if argv else 2)
    cmd, rest = argv[0], argv[1:]
    if cmd not in _COMMANDS:
        print(f"unknown command {cmd!r}; expected one of "
              f"{', '.join(_COMMANDS)}", file=sys.stderr)
        raise SystemExit(2)
    if cmd == "fleet":
        # the only main() taking argv directly — the others parse sys.argv
        from repro.launch.fleet import main as run
        run(rest)
        return
    import importlib
    mod = importlib.import_module(f"repro.launch.{cmd}")
    sys.argv = [f"repro.launch.{cmd}"] + rest
    mod.main()


if __name__ == "__main__":
    main()
