"""Model-guided multi-job fleet allocator (ISSUE 10 tentpole).

    PYTHONPATH=src python -m repro.launch fleet --manifest demo \
        --steps 12 --fault-plan 'pool_shrink@5:pool=a100,k=2' --chaos-seed 7

The paper's one-model-per-device-type premise is exactly what a
heterogeneous fleet needs: a manifest of concurrent train/serve jobs is
placed across device *pools* (tpu-v5e / a100 / h100 / mi300x) by pricing
every (job × pool × device-count × plan × mesh) cell through that pool's
own registry model (``calibration.registry.load_models`` — the hardened
batch loader, so one corrupt model file degrades only its pool's
placements).  Scoring runs through the fused engine: each (job, pool)
scores ONE ``PlanSpace.from_cells`` batch spanning every power-of-two
device count the pool could grant, against a per-(job, pool)
``exprops.BasisCache`` — churn-time rescoring (``FleetSupervisor``'s
degradation ladder, ``runtime/fleet_supervisor.py``) therefore reuses the
allocation-time basis columns and stays warm-replan fast.  The optional
``wide_sweep`` path runs the same pricing through ``planspace.stream_topk``
for plan-space breadth far beyond the default mesh sweep, in bounded
memory.

Placement policy (deterministic — the byte-identical-history contract in
``tests/test_fleet.py`` pins it): jobs place in (priority desc, name)
order; each job takes the pool whose best cell maximizes (SLO met,
predicted tokens/s), tie-broken on pool name; a job no pool can fit is
*paused* with a capacity reason, never dropped.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.calibration import registry as _registry
from repro.configs.base import ArchConfig
from repro.configs.registry import ARCHS, get_arch
from repro.core import exprops, planspace
from repro.core import workload as wl
from repro.distributed import elastic
from repro.obs import metrics as _obs_metrics
from repro.obs import report as _obs_report
from repro.obs import trace as _obs_trace

#: demo pool sizing — also the CI chaos-smoke fixture (the workflow's
#: ``pool_shrink@5:pool=a100,k=2`` drives one kept-job warm replan and one
#: forced migration against exactly this manifest)
_DEMO = {
    "name": "demo",
    "pools": [
        {"name": "a100", "device": "gpu-a100", "count": 8},
        {"name": "v5e", "device": "tpu-v5e", "count": 8},
    ],
    "jobs": [
        {"name": "train-hi", "arch": "smollm-360m", "phase": "train",
         "global_batch": 8, "seq_len": 128, "priority": 10,
         "min_devices": 2, "max_devices": 4},
        {"name": "serve", "arch": "smollm-360m", "phase": "decode",
         "global_batch": 4, "seq_len": 256, "priority": 8,
         "min_devices": 4, "max_devices": 4},
        {"name": "train-lo", "arch": "smollm-360m", "phase": "train",
         "global_batch": 4, "seq_len": 128, "priority": 5,
         "min_devices": 1, "max_devices": 4},
    ],
}


@dataclass(frozen=True)
class PoolSpec:
    """One homogeneous device pool: ``device`` is the registry model name
    pricing it (``gpu-a100``, ``tpu-v5e``, …), ``count`` its chip count."""
    name: str
    device: str
    count: int

    def __post_init__(self):
        if self.count < 0:
            raise ValueError(f"pool {self.name!r}: count must be >= 0")


@dataclass(frozen=True)
class JobSpec:
    """One manifest job: a ``WorkloadSpec`` plus the placement contract —
    priority (higher preempts), device bounds, and an optional step-time
    SLO the allocator prefers (but does not require) to meet."""
    name: str
    arch: str
    workload: wl.WorkloadSpec
    priority: int = 0
    min_devices: int = 1
    max_devices: int = 64
    slo_step_s: Optional[float] = None

    def __post_init__(self):
        if self.min_devices < 1 or self.max_devices < self.min_devices:
            raise ValueError(
                f"job {self.name!r}: need 1 <= min_devices <= max_devices "
                f"(got {self.min_devices}..{self.max_devices})")

    def move_cost_bytes(self) -> float:
        """Checkpoint bytes a migration must hand off (params + opt state,
        ~3 fp32 copies) — the 'cheapest-to-move' ordering key of the
        degradation ladder's migrate rung."""
        return float(ARCHS[self.arch].n_params()) * 4.0 * 3.0


@dataclass(frozen=True)
class Placement:
    """One job's placement: the pool, the granted device count, and the
    model-ranked best (plan, mesh) on it with its predicted rate."""
    job: str
    pool: str
    device: str               # the pool's registry model name
    devices: int
    mesh: Tuple[Tuple[str, int], ...]     # sorted (axis, size) pairs
    predicted_step_s: float
    tokens_per_s: float
    slo_ok: bool = True
    plan: object = field(default=None, compare=False, repr=False)

    @property
    def mesh_dict(self) -> Dict[str, int]:
        return dict(self.mesh)

    def to_json_dict(self) -> Dict[str, object]:
        return {"job": self.job, "pool": self.pool, "device": self.device,
                "devices": self.devices, "mesh": dict(self.mesh),
                "predicted_step_s": self.predicted_step_s,
                "tokens_per_s": self.tokens_per_s, "slo_ok": self.slo_ok}


@dataclass
class FleetAssignment:
    """The allocator's output: active placements by job name, paused jobs
    (with reasons) and the per-pool free-device ledger."""
    placements: Dict[str, Placement] = field(default_factory=dict)
    paused: Dict[str, str] = field(default_factory=dict)
    free: Dict[str, int] = field(default_factory=dict)

    def to_json_dict(self) -> Dict[str, object]:
        return {
            "placements": {n: p.to_json_dict()
                           for n, p in sorted(self.placements.items())},
            "paused": dict(sorted(self.paused.items())),
            "free": dict(sorted(self.free.items())),
        }


# ---------------------------------------------------------------------------
# Manifest
# ---------------------------------------------------------------------------


@dataclass
class Manifest:
    pools: List[PoolSpec]
    jobs: List[JobSpec]
    name: str = "fleet"

    def __post_init__(self):
        names = [p.name for p in self.pools]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate pool names in manifest: {names}")
        jnames = [j.name for j in self.jobs]
        if len(set(jnames)) != len(jnames):
            raise ValueError(f"duplicate job names in manifest: {jnames}")

    @classmethod
    def from_json_dict(cls, d: Mapping) -> "Manifest":
        pools = [PoolSpec(name=p["name"], device=p["device"],
                          count=int(p["count"])) for p in d["pools"]]
        jobs = []
        for j in d["jobs"]:
            spec = wl.WorkloadSpec(
                phase=j.get("phase", "train"),
                global_batch=int(j.get("global_batch", 1)),
                seq_len=int(j.get("seq_len", 1)),
                microbatches=int(j.get("microbatches", 1)),
                name=j["name"])
            jobs.append(JobSpec(
                name=j["name"], arch=j["arch"], workload=spec,
                priority=int(j.get("priority", 0)),
                min_devices=int(j.get("min_devices", 1)),
                max_devices=int(j.get("max_devices", 64)),
                slo_step_s=j.get("slo_step_s")))
        return cls(pools=pools, jobs=jobs, name=d.get("name", "fleet"))

    def to_json_dict(self) -> Dict[str, object]:
        return {
            "name": self.name,
            "pools": [{"name": p.name, "device": p.device,
                       "count": p.count} for p in self.pools],
            "jobs": [{"name": j.name, "arch": j.arch,
                      "phase": j.workload.phase,
                      "global_batch": j.workload.global_batch,
                      "seq_len": j.workload.seq_len,
                      "microbatches": j.workload.microbatches,
                      "priority": j.priority,
                      "min_devices": j.min_devices,
                      "max_devices": j.max_devices,
                      "slo_step_s": j.slo_step_s} for j in self.jobs],
        }


def demo_manifest() -> Manifest:
    """The built-in 2-pool / 3-job manifest (``--manifest demo``)."""
    return Manifest.from_json_dict(_DEMO)


def load_manifest(path_or_demo: str) -> Manifest:
    if path_or_demo == "demo":
        return demo_manifest()
    with open(path_or_demo) as f:
        return Manifest.from_json_dict(json.load(f))


# ---------------------------------------------------------------------------
# The allocator
# ---------------------------------------------------------------------------


def _throughput(spec: wl.WorkloadSpec, step_s: float) -> float:
    """Predicted tokens/s of one step: processed tokens for train/prefill,
    emitted tokens (slots × speculative length) per decode iteration."""
    if step_s <= 0:
        return 0.0
    if spec.phase == "decode":
        return spec.global_batch * spec.spec_len / step_s
    return spec.tokens / step_s


class FleetAllocator:
    """Scores the (job × pool × device-count × plan × mesh) space through
    per-device-type registry models and emits deterministic placements.

    One instance owns: the batch-loaded model map (one hardened
    ``load_model`` per distinct pool device, one ``[registry]`` rollup
    line), and a ``BasisCache`` per (job, pool) pair — the warm state the
    ``FleetSupervisor`` replans against when the pool ledger churns.
    """

    def __init__(self, manifest: Manifest,
                 registry_dir: Optional[str] = None,
                 max_candidates: int = 64):
        self.manifest = manifest
        self.pools: Dict[str, PoolSpec] = {p.name: p for p in manifest.pools}
        self.jobs: Dict[str, JobSpec] = {j.name: j for j in manifest.jobs}
        self.registry_dir = registry_dir
        self.max_candidates = max_candidates
        self.models = _registry.load_models(
            [p.device for p in manifest.pools], registry_dir)
        self._caches: Dict[Tuple[str, str], exprops.BasisCache] = {}

    # -- warm state -------------------------------------------------------
    def cache(self, job: str, pool: str) -> exprops.BasisCache:
        key = (job, pool)
        c = self._caches.get(key)
        if c is None:
            c = self._caches[key] = exprops.BasisCache(maxsize=4096)
        return c

    def cache_stats(self) -> Dict[str, int]:
        hits = sum(c.hits for c in self._caches.values())
        misses = sum(c.misses for c in self._caches.values())
        return {"hits": hits, "misses": misses}

    # -- scoring ----------------------------------------------------------
    def candidate_counts(self, job: JobSpec, free: int) -> List[int]:
        """Power-of-two device counts the pool could grant ``job``,
        largest first — the count axis of the scored space."""
        n = elastic._pow2_floor(min(free, job.max_devices))
        out = []
        while n >= job.min_devices:
            out.append(n)
            n //= 2
        return out

    def score_job(self, job: JobSpec, pool: PoolSpec, free: int
                  ) -> Optional[Placement]:
        """The best cell of (count × plan × mesh) for ``job`` on ``pool``
        with ``free`` devices available — ONE fused ``PlanSpace`` batch
        spanning every candidate count, scored against this (job, pool)'s
        warm ``BasisCache``.  None when the pool can't meet
        ``min_devices`` or no mesh divides the batch."""
        counts = self.candidate_counts(job, free)
        if not counts:
            return None
        cfg = ARCHS[job.arch]
        cells: List[Tuple[object, Dict[str, int]]] = []
        for n in counts:
            cells.extend(elastic.mesh_cells(cfg, job.workload, n,
                                            self.max_candidates))
        if not cells:
            return None
        space = planspace.PlanSpace.from_cells(cfg, job.workload, cells)
        secs = space.scores(self.models[pool.device],
                            cache=self.cache(job.name, pool.name))
        best_i = min(
            range(len(cells)),
            key=lambda i: (secs[i],
                           planspace.mesh_sort_key(cells[i][1]),
                           planspace.plan_sort_key(cells[i][0])))
        plan, mesh = cells[best_i]
        step_s = float(secs[best_i])
        devices = 1
        for v in mesh.values():
            devices *= v
        return Placement(
            job=job.name, pool=pool.name, device=pool.device,
            devices=devices, mesh=tuple(sorted(mesh.items())),
            predicted_step_s=step_s,
            tokens_per_s=_throughput(job.workload, step_s),
            slo_ok=(job.slo_step_s is None or step_s <= job.slo_step_s),
            plan=plan)

    def place_job(self, job: JobSpec, free: Mapping[str, int],
                  exclude_pools: Sequence[str] = ()
                  ) -> Optional[Placement]:
        """The best placement for ``job`` across every non-excluded pool:
        maximize (SLO met, predicted tokens/s), tie-break on pool name.
        The supervisor's migrate rung calls this with the churned pool
        excluded."""
        best: Optional[Placement] = None
        best_key = None
        for pname in sorted(self.pools):
            if pname in exclude_pools:
                continue
            p = self.score_job(job, self.pools[pname],
                               int(free.get(pname, 0)))
            if p is None:
                continue
            key = (not p.slo_ok, -p.tokens_per_s, pname)
            if best_key is None or key < best_key:
                best, best_key = p, key
        return best

    def allocate(self, capacity: Optional[Mapping[str, int]] = None
                 ) -> FleetAssignment:
        """Place every manifest job, priority-descending.  ``capacity``
        overrides the manifest pool counts (the supervisor passes the
        churned ledger when it re-allocates)."""
        free = {p.name: int(capacity[p.name]) if capacity is not None
                else p.count for p in self.manifest.pools}
        out = FleetAssignment(free=free)
        order = sorted(self.jobs.values(),
                       key=lambda j: (-j.priority, j.name))
        for job in order:
            p = self.place_job(job, free)
            if p is None:
                out.paused[job.name] = "capacity"
                _obs_report.emit("fleet", {
                    "job": job.name, "action": "paused",
                    "reason": "capacity"},
                    text="no pool can grant min_devices")
                continue
            out.placements[job.name] = p
            free[p.pool] -= p.devices
        return out

    def wide_sweep(self, job_name: str, pool_name: str, n_devices: int,
                   k: int = 5, stats: Optional[dict] = None):
        """Top-``k`` of the FULL (plan-variant × mesh) product for one
        (job, pool) through ``planspace.stream_topk`` — the bounded-memory
        wide path for capacity studies far beyond the placement sweep.
        Returns (seconds, plan, mesh) triples."""
        from repro.launch.autoshard import candidate_meshes, candidate_plans
        job = self.jobs[job_name]
        pool = self.pools[pool_name]
        cfg = ARCHS[job.arch]
        plans = candidate_plans(cfg, job.workload)
        meshes = candidate_meshes(job.workload, n_devices=n_devices)
        return planspace.stream_topk(cfg, job.workload, plans, meshes,
                                     self.models[pool.device], k=k,
                                     stats=stats)


# ---------------------------------------------------------------------------
# CLI  (python -m repro.launch fleet …)
# ---------------------------------------------------------------------------


def _print_assignment(a: FleetAssignment) -> None:
    for name, p in sorted(a.placements.items()):
        _obs_report.emit("fleet", {
            "job": name, "pool": p.pool, "devices": p.devices,
            "mesh": "x".join(str(v) for _, v in p.mesh),
            "pred_ms": f"{p.predicted_step_s * 1e3:.3f}",
            "tok_s": f"{p.tokens_per_s:.0f}",
            "slo": "ok" if p.slo_ok else "MISS"},
            text="placed")
    for name, why in sorted(a.paused.items()):
        _obs_report.emit("fleet", {"job": name, "action": "paused",
                                   "reason": why}, text="not placed")


def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        prog="repro.launch fleet", description=__doc__)
    ap.add_argument("--manifest", default="demo", metavar="PATH|demo",
                    help="fleet manifest JSON (docs/FLEET.md schema), or "
                         "'demo' for the built-in 2-pool/3-job fixture")
    ap.add_argument("--steps", type=int, default=12,
                    help="supervised fleet steps to run (0: allocate only)")
    ap.add_argument("--fault-plan", default=None, metavar="SPEC|PATH",
                    help="deterministic churn schedule, e.g. "
                         "'pool_shrink@5:pool=a100,k=2'")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--registry", default=None, metavar="DIR",
                    help="model-registry directory override")
    ap.add_argument("--hysteresis", type=float, default=0.15,
                    help="min fractional step-time improvement before a "
                         "voluntary rebalance moves a job")
    ap.add_argument("--cooldown-steps", type=int, default=3,
                    help="steps between voluntary rebalances of one job")
    ap.add_argument("--retry-after-steps", type=int, default=5,
                    help="steps before a capacity-paused job retries")
    ap.add_argument("--history-json", default=None, metavar="PATH",
                    help="write the placement history JSON on exit")
    ap.add_argument("--trace-json", default=None, metavar="PATH")
    ap.add_argument("--metrics-json", default=None, metavar="PATH")
    args = ap.parse_args(argv)

    if args.trace_json:
        _obs_trace.enable(process_name="fleet")

    manifest = load_manifest(args.manifest)
    allocator = FleetAllocator(manifest, registry_dir=args.registry)
    t0 = time.perf_counter()
    assignment = allocator.allocate()
    _obs_report.emit("fleet", {
        "manifest": manifest.name, "jobs": len(manifest.jobs),
        "pools": len(manifest.pools),
        "allocate_ms": f"{(time.perf_counter() - t0) * 1e3:.2f}"},
        text="initial allocation")
    _print_assignment(assignment)

    if args.steps > 0:
        from repro.runtime.faults import FaultInjector, FaultPlan
        from repro.runtime.fleet_supervisor import (FleetSupervisor,
                                                    SimJobRunner)
        fplan = FaultPlan.parse(args.fault_plan, seed=args.chaos_seed) \
            if args.fault_plan else FaultPlan(seed=args.chaos_seed)
        if fplan:
            _obs_report.emit("fleet",
                             text=f"fault plan armed: {fplan.describe()}")
        injector = FaultInjector(fplan, registry_dir=args.registry)
        sup = FleetSupervisor(
            allocator, injector=injector,
            runner_factory=SimJobRunner.factory(),
            hysteresis=args.hysteresis,
            cooldown_steps=args.cooldown_steps,
            retry_after_steps=args.retry_after_steps,
            assignment=assignment)
        sup.run(args.steps)
        sup.report()
        if args.history_json:
            d = os.path.dirname(args.history_json)
            if d:
                os.makedirs(d, exist_ok=True)
            with open(args.history_json, "w") as f:
                f.write(sup.history_json())
            _obs_report.emit("fleet",
                             text=f"history written to {args.history_json}")

    tracer = _obs_trace.get_tracer()
    if args.trace_json:
        tracer.save(args.trace_json)
        _obs_report.emit("fleet",
                         text=f"trace written to {args.trace_json}")
    if args.metrics_json:
        _obs_metrics.REGISTRY.save_json(args.metrics_json)
        _obs_report.emit("fleet",
                         text=f"metrics written to {args.metrics_json}")


if __name__ == "__main__":
    main()
