"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 200 --batch 8 --seq 256 --reduced --ckpt /tmp/ck

On the CPU container this drives reduced configs end-to-end (the ~100M-scale
example); on a TPU slice the same entry point runs the full configs on the
production mesh (``--mesh single|multi``) with the plan from
``plan_for`` / ``autoshard``.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS, get_arch
from repro.data.pipeline import DataConfig
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs_trace
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.core import predictor
from repro.distributed.plan import plan_for


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced same-family config (CPU scale)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--online-calibrate", action="store_true",
                    help="stream per-step timings into the online "
                         "calibrator (RLS refit + drift watch)")
    ap.add_argument("--calib-device", default=None,
                    help="registry device name for online refits "
                         "(default: '<arch>-online')")
    ap.add_argument("--calib-auto-register", action="store_true",
                    help="write drift-refit models into the registry "
                         "(bumps the model file revision)")
    ap.add_argument("--trace-json", default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto JSON of the run "
                         "(measured step spans + predicted overlay)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="dump the metrics registry as JSON on exit")
    # --- supervised recovery / chaos (runtime/supervisor.py, faults.py) ---
    ap.add_argument("--supervise", action="store_true",
                    help="run under the Supervisor: watchdog deadlines, "
                         "backoff, elastic replan + checkpoint-resume on "
                         "device loss")
    ap.add_argument("--fault-plan", default=None, metavar="SPEC|PATH",
                    help="deterministic fault schedule — "
                         "'kind@step[:k=v,..];..' (e.g. "
                         "'corrupt_registry@7;device_loss@12') or a JSON "
                         "plan path; implies --supervise")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed for fault payloads and backoff jitter")
    ap.add_argument("--devices", type=int, default=1,
                    help="fleet size the supervisor replans over")
    ap.add_argument("--model", default=None, metavar="DEVICE",
                    help="cost-model device name pricing the replan "
                         "candidates (hardened registry lookup)")
    ap.add_argument("--registry", default=None, metavar="DIR",
                    help="model-registry directory override")
    ap.add_argument("--watchdog-k", type=float, default=6.0,
                    help="watchdog deadline = k x max(predicted, median)")
    ap.add_argument("--max-recoveries", type=int, default=8)
    args = ap.parse_args()

    if args.trace_json:
        _obs_trace.enable(process_name="train")

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch, seed=args.seed,
                    n_codebooks=cfg.n_input_codebooks)
    tc = TrainerConfig(ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every,
                       lr=args.lr, total_steps=args.steps, seed=args.seed,
                       online_calibrate=args.online_calibrate,
                       calib_device=args.calib_device,
                       calib_auto_register=args.calib_auto_register)

    # cost-model prediction for the straggler monitor threshold
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=args.seq,
                                global_batch=args.batch)
    plan = plan_for(ARCHS[args.arch], SHAPES["train_4k"])
    pred = predictor.predict_step(ARCHS[args.arch], shape, plan,
                                  {"data": 1, "model": 1})
    print(f"[train] {cfg.name}: {cfg.n_params()/1e6:.1f}M params, "
          f"predicted full-arch step {pred.seconds*1e3:.1f}ms on 1 chip")

    if args.supervise or args.fault_plan:
        from repro.core.workload import WorkloadSpec
        from repro.runtime.faults import FaultInjector, FaultPlan
        from repro.runtime.supervisor import BackoffPolicy, Supervisor

        fplan = FaultPlan.parse(args.fault_plan, seed=args.chaos_seed) \
            if args.fault_plan else FaultPlan(seed=args.chaos_seed)
        injector = FaultInjector(fplan, ckpt_dir=args.ckpt,
                                 registry_dir=args.registry,
                                 registry_device=args.model)
        if fplan:
            print(f"[train] fault plan armed: {fplan.describe()}")
        workload = WorkloadSpec(phase="train", global_batch=args.batch,
                                seq_len=args.seq, name="train_live")
        sup = Supervisor(
            lambda mesh: Trainer(cfg, dc, tc, injector=injector),
            args.steps, cfg=ARCHS[args.arch], workload=workload,
            n_devices=args.devices, model=args.model,
            registry_dir=args.registry, injector=injector,
            watchdog_k=args.watchdog_k,
            backoff=BackoffPolicy(seed=args.chaos_seed),
            max_recoveries=args.max_recoveries)
        hist = sup.run()
        sup.report()
        trainer = sup.trainer
    else:
        trainer = Trainer(cfg, dc, tc)
        hist = trainer.train(args.steps)
    print(f"[train] done: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")
    if trainer.calibrator is not None:
        print("[calib] refit report:")
        print(trainer.calibrator.final_report())

    tracer = _obs_trace.get_tracer()
    if args.trace_json:
        for line in tracer.report_lines():
            print(f"[trace] {line}")
        tracer.save(args.trace_json)
        print(f"[train] trace written to {args.trace_json}")
    if args.metrics_json:
        _obs_metrics.REGISTRY.save_json(args.metrics_json)
        print(f"[train] metrics written to {args.metrics_json}")


if __name__ == "__main__":
    main()
