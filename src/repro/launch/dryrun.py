import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run — deliverable (e).

For every (architecture × input shape) cell, ``lower().compile()`` the step
function on the production mesh (single-pod 16×16 and multi-pod 2×16×16),
print ``memory_analysis()`` / ``cost_analysis()``, and persist the records
(FLOPs, bytes, per-kind collective bytes, bytes-per-device) that feed
EXPERIMENTS.md §Dry-run and the §Roofline table.

The two ``os.environ`` lines above MUST run before any other import — jax
locks the device count on first init.  This module is the ONLY place the
512-device placeholder topology is created; tests and benchmarks see the
real single CPU device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b \
        --shape train_4k --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --out experiments/dryrun.json
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs.base import SHAPES, shape_applicable
from repro.configs.registry import ARCHS
from repro.core import extract as cx
from repro.distributed.plan import Plan, plan_for
from repro.distributed.sharding import use_sharding
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import step_and_specs


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             plan: Optional[Plan] = None, verbose: bool = True,
             keep_text: bool = False) -> Dict:
    """Lower + compile one cell; return its dry-run record."""
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec: Dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        rec["status"] = "skip"
        rec["why"] = why
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = plan or plan_for(cfg, shape, multi_pod=multi_pod)
    n_dev = mesh.devices.size

    t0 = time.time()
    with mesh, use_sharding(mesh, plan):
        step_fn, arg_specs, in_sh, out_sh = step_and_specs(
            cfg, shape, mesh, plan)
        lowered = jax.jit(step_fn, in_shardings=in_sh,
                          out_shardings=out_sh).lower(*arg_specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    costs = cx.extract_compiled(compiled)
    mem = compiled.memory_analysis()
    rec.update({
        "status": "ok",
        "plan": {
            "fsdp": plan.fsdp, "microbatches": plan.microbatches,
            "sequence_parallel": plan.sequence_parallel,
            "moe_mode": plan.moe_mode,
            "cache_seq_axes": list(plan.cache_seq_axes),
            "compression": plan.compression,
            "remat": plan.remat_policy or cfg.remat_policy,
        },
        "n_devices": int(n_dev),
        "flops_per_device": costs.flops,
        "bytes_per_device": costs.bytes_accessed,
        "collective_bytes_per_device": costs.collective_bytes,
        "peak_bytes_per_device": costs.peak_bytes_per_device,
        # raw XLA cost_analysis (counts loop bodies once; for comparison)
        "xla_flops_per_device": costs.xla_flops,
        "xla_bytes_per_device": costs.xla_bytes,
        "memory_analysis": {
            k: int(getattr(mem, k, 0)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "alias_size_in_bytes",
             "generated_code_size_in_bytes")
        },
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    })
    if keep_text:
        rec["hlo_text"] = compiled.as_text()
    if verbose:
        ma = rec["memory_analysis"]
        print(f"[{rec['mesh']}] {arch} × {shape_name}: "
              f"flops/dev={costs.flops:.3e} bytes/dev={costs.bytes_accessed:.3e} "
              f"coll={ {k: f'{v:.2e}' for k, v in costs.collective_bytes.items()} } "
              f"args={ma['argument_size_in_bytes']/1e9:.2f}GB "
              f"temp={ma['temp_size_in_bytes']/1e9:.2f}GB "
              f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="architecture id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=("single", "multi", "both"))
    ap.add_argument("--out", default="experiments/dryrun.json")
    args = ap.parse_args()

    assert len(jax.devices()) == 512, (
        "dry-run requires the 512-device placeholder topology; do not "
        "import jax before this module sets XLA_FLAGS")

    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    records, failures = [], []
    for multi in meshes:
        for a in archs:
            for s in shapes:
                try:
                    rec = run_cell(a, s, multi_pod=multi)
                except Exception as e:  # a failure here is a bug in the system
                    traceback.print_exc()
                    rec = {"arch": a, "shape": s,
                           "mesh": "2x16x16" if multi else "16x16",
                           "status": "fail", "error": f"{type(e).__name__}: {e}"}
                    failures.append(rec)
                records.append(rec)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(records, f, indent=1)

    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skip" for r in records)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skip (documented), "
          f"{len(failures)} FAILED -> {args.out}")
    if failures:
        for r in failures:
            print(f"  FAIL {r['mesh']} {r['arch']} × {r['shape']}: {r['error']}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
