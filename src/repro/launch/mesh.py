"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """Version-portable ``jax.make_mesh``.

    ``jax.sharding.AxisType`` (and the ``axis_types=`` kwarg) only exist in
    newer JAX releases; on older ones (e.g. 0.4.37) a plain ``Mesh`` is the
    same thing — every axis defaults to Auto.  All mesh construction in the
    repo (and the multidevice tests' subprocess bodies) routes through here.
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                axis_shapes, axis_names,
                axis_types=(axis_type.Auto,) * len(axis_names), **kwargs)
        except TypeError:  # make_mesh predates the axis_types kwarg
            pass
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod: 16×16 = 256 chips; multi-pod: 2 pods = 512 chips.

    The ``pod`` axis extends data parallelism hierarchically (gradient
    all-reduce crosses pods once per step, optionally int8-compressed —
    see repro.distributed.compression).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for tests/examples on CPU."""
    return make_mesh((1, 1), ("data", "model"))
