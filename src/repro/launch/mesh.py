"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod: 16×16 = 256 chips; multi-pod: 2 pods = 512 chips.

    The ``pod`` axis extends data parallelism hierarchically (gradient
    all-reduce crosses pods once per step, optionally int8-compressed —
    see repro.distributed.compression).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Single-device mesh for tests/examples on CPU."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
