"""Cost-model-driven autosharding search — the paper's §6.2 future-work item
('select the optimal set of kernel configurations'), realized at the
distributed-plan level.

Enumerates candidate ``Plan``s for an (arch × shape × mesh) cell and scores
them ALL with one batched matrix–vector product (``predictor.predict_plans``
→ ``LinearCostModel.predict_many``) — the paper's 'small inner product'
evaluation speed is exactly what makes an exhaustive plan sweep cheap.
Optionally verifies the top-k candidates by actually lowering them (the
expensive ground truth the model replaces).

The cost model may be a registry device name (``--model cpu`` after running
``python -m repro.calibration --device cpu``), defaulting to the analytic
TPU-v5e seed.

    PYTHONPATH=src python -m repro.launch.autoshard --arch glm4-9b \
        --shape train_4k --model tpu-v5e
"""
from __future__ import annotations

import argparse
import dataclasses
import itertools
from typing import List, Tuple

from repro.configs.base import SHAPES, ShapeConfig, shape_applicable
from repro.configs.registry import ARCHS
from repro.core import predictor
from repro.distributed.plan import Plan, plan_for


def candidate_plans(cfg, shape: ShapeConfig, multi_pod: bool = False
                    ) -> List[Plan]:
    """The search space: fsdp × sequence-parallel × microbatches × remat ×
    compression × (EP for MoE) × cache-seq sharding (decode)."""
    dp = ("pod", "data") if multi_pod else ("data",)
    base = plan_for(cfg, shape, multi_pod=multi_pod)
    out = []
    if shape.kind == "train":
        for fsdp, sp, m, remat, compress in itertools.product(
                (True, False), (True, False), (1, 2, 4, 8, 16),
                ("full", "dots", "none"), (None, "int8_ef")):
            if m > shape.global_batch:
                continue
            out.append(base.with_(dp_axes=dp, fsdp=fsdp,
                                  sequence_parallel=sp, microbatches=m,
                                  remat_policy=remat, compression=compress))
    elif shape.kind == "prefill":
        for fsdp, sp in itertools.product((True, False), (True, False)):
            out.append(base.with_(dp_axes=dp, fsdp=fsdp,
                                  sequence_parallel=sp))
    else:  # decode
        for fsdp, cache_seq in itertools.product(
                (True, False), ((), ("model",))):
            out.append(base.with_(dp_axes=dp, fsdp=fsdp,
                                  cache_seq_axes=cache_seq))
    if cfg.moe is not None:
        out += [p.with_(moe_mode="ep") for p in out]
    return out


def search(arch: str, shape_name: str, *, multi_pod: bool = False,
           model: predictor.ModelLike = None, top_k: int = 5
           ) -> List[Tuple[float, Plan]]:
    """Rank candidate plans under ``model`` (a ``LinearCostModel``, a
    registry device name, or None for the analytic v5e seed)."""
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(why)
    model = predictor.resolve_model(model)  # resolve once for the whole sweep
    mesh_shape = ({"pod": 2, "data": 16, "model": 16} if multi_pod
                  else {"data": 16, "model": 16})
    plans = candidate_plans(cfg, shape, multi_pod)
    fits = [p for p in plans
            if predictor.feasible(cfg, shape, p, mesh_shape)]
    if not fits:  # degrade gracefully: report least-infeasible
        fits = sorted(plans, key=lambda p: predictor.estimate_peak_bytes(
            cfg, shape, p, mesh_shape))[:max(top_k, 8)]
    ranked = predictor.rank_plans(cfg, shape, fits, mesh_shape, model)
    return ranked[:top_k]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--shape", default="train_4k", choices=sorted(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=5)
    ap.add_argument("--model", default=None,
                    help="cost-model registry device name (default: the "
                         "analytic tpu-v5e seed); see python -m "
                         "repro.calibration --list")
    args = ap.parse_args()

    ranked = search(args.arch, args.shape, multi_pod=args.multi_pod,
                    model=args.model, top_k=args.top)
    # None resolves to the built-in analytic seed, which an explicit
    # "--model tpu-v5e" does NOT (a fitted registry file would shadow it)
    model_label = args.model or "tpu-v5e analytic seed"
    print(f"top-{args.top} plans for {args.arch} × {args.shape} "
          f"({'2x16x16' if args.multi_pod else '16x16'}, "
          f"model={model_label}):")
    for t, p in ranked:
        print(f"  {t*1e3:9.2f} ms  fsdp={p.fsdp} sp={p.sequence_parallel} "
              f"mb={p.microbatches} remat={p.remat_policy} "
              f"moe={p.moe_mode} comp={p.compression} "
              f"cache_seq={p.cache_seq_axes}")


if __name__ == "__main__":
    main()
