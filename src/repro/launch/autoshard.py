"""Cost-model-driven autosharding search — the paper's §6.2 future-work item
('select the optimal set of kernel configurations'), realized at the
distributed-plan level.

Enumerates candidate ``Plan``s for an (arch × shape) cell — optionally
crossed with every mesh factorization of a device count — and scores the
WHOLE space through the array-batched search engine (``core.planspace``):
compiled property vectors over array environments, vectorized HBM
feasibility, one weighted sum for the scores.  The paper's 'small inner
product' evaluation speed is exactly what makes an exhaustive
(plan × mesh) sweep cheap; ``benchmarks/search_bench.py`` records the
batched engine's speedup over the per-plan interpreted loop.

The cost model may be a registry device name (``--model cpu`` after running
``python -m repro.calibration --device cpu``), defaulting to the analytic
TPU-v5e seed.

    PYTHONPATH=src python -m repro.launch.autoshard --arch glm4-9b \
        --shape train_4k --model tpu-v5e

    # sweep every mesh factorization of 1024 chips, co-tune kernel blocks
    PYTHONPATH=src python -m repro.launch.autoshard --arch glm4-9b \
        --shape train_4k --devices 1024 --tune-kernels
"""
from __future__ import annotations

import argparse
import itertools
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import SHAPES, ShapeConfig, shape_applicable
from repro.configs.registry import ARCHS
from repro.core import planspace, predictor
from repro.core import workload as wl
from repro.distributed.plan import Plan, plan_for
from repro.obs import metrics as _obs_metrics
from repro.obs import report as _obs_report
from repro.obs import trace as _obs_trace

#: a ranked search result: (predicted seconds, plan, mesh shape); with
#: ``tune_kernels`` a fourth element carries {kernel: block sizes}
Ranked = Tuple[float, Plan, Dict[str, int]]
RankedTuned = Tuple[float, Plan, Dict[str, int], Dict[str, Dict[str, int]]]


def candidate_plans(cfg, workload: wl.WorkloadLike, multi_pod: bool = False
                    ) -> List[Plan]:
    """The search space: fsdp × sequence-parallel × microbatches × remat ×
    compression × (EP for MoE) × cache-seq sharding (decode)."""
    shape = wl.as_spec(workload)
    dp = ("pod", "data") if multi_pod else ("data",)
    base = plan_for(cfg, shape, multi_pod=multi_pod)
    out = []
    if shape.kind == "train":
        for fsdp, sp, m, remat, compress in itertools.product(
                (True, False), (True, False), (1, 2, 4, 8, 16),
                ("full", "dots", "none"), (None, "int8_ef")):
            if m > shape.global_batch:
                continue
            out.append(base.with_(dp_axes=dp, fsdp=fsdp,
                                  sequence_parallel=sp, microbatches=m,
                                  remat_policy=remat, compression=compress))
    elif shape.kind == "prefill":
        for fsdp, sp in itertools.product((True, False), (True, False)):
            out.append(base.with_(dp_axes=dp, fsdp=fsdp,
                                  sequence_parallel=sp))
    else:  # decode
        for fsdp, cache_seq in itertools.product(
                (True, False), ((), ("model",))):
            out.append(base.with_(dp_axes=dp, fsdp=fsdp,
                                  cache_seq_axes=cache_seq))
    if cfg.moe is not None:
        out += [p.with_(moe_mode="ep") for p in out]
    return out


def candidate_meshes(workload: wl.WorkloadLike, *, multi_pod: bool = False,
                     n_devices: Optional[int] = None
                     ) -> List[Dict[str, int]]:
    """The mesh side of the space.  Default: the fixed 16×16 pod (2×16×16
    multi-pod).  With ``n_devices``: every (data × model) factorization,
    minus train meshes whose data axis doesn't divide the global batch
    (training keeps exact batch semantics)."""
    shape = wl.as_spec(workload)
    if n_devices is None:
        return [{"pod": 2, "data": 16, "model": 16} if multi_pod
                else {"data": 16, "model": 16}]
    if multi_pod:
        raise ValueError(
            "multi_pod cannot be combined with an n_devices sweep: the "
            "factorization space is 2-axis (data × model) and would "
            "silently leave the pod axis at 1; drop --multi-pod or pass "
            "explicit meshes")
    meshes = planspace.mesh_factorizations(n_devices)
    if shape.kind == "train":
        # never empties: {data: 1, model: n} always divides the batch
        meshes = [m for m in meshes
                  if shape.global_batch % m["data"] == 0]
    return meshes


def search(arch: str, shape_name: str, *, multi_pod: bool = False,
           model: predictor.ModelLike = None, top_k: int = 5,
           n_devices: Optional[int] = None,
           meshes: Optional[Sequence[Mapping[str, int]]] = None,
           tune_kernels: bool = False,
           stream_chunk_cells: Optional[int] = None
           ) -> "List[Ranked] | List[RankedTuned]":
    """Rank (plan × mesh) candidates under ``model`` (a ``LinearCostModel``,
    a registry device name, or None for the analytic v5e seed).

    Returns ``(seconds, plan, mesh)`` triples, best first.  By default the
    mesh side is the fixed 16×16 pod (unchanged picks vs. the pre-engine
    search); pass ``n_devices`` to sweep every mesh factorization, or
    ``meshes`` for an explicit list.  With ``tune_kernels`` each returned
    cell is additionally co-tuned at kernel granularity
    (``planspace.cotune_kernel_blocks``) and the triples become
    ``(seconds, plan, mesh, {kernel: blocks})`` quadruples.

    ``stream_chunk_cells`` switches to the streaming engine
    (``planspace.stream_topk``): the space scores in bounded-memory chunks
    with HBM-infeasible cells pruned from the running top-k pool — the
    way to sweep candidate spaces far past RAM (it does not degrade to
    least-infeasible when nothing fits; the fully-materialized path does).
    """
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(why)
    spec = wl.from_shape(shape)  # one workload currency from here down
    # keep the unresolved form for co-tuning: autotune's block-choice memo
    # keys on registry names / None, not on resolved model objects
    raw_model = model
    model = predictor.resolve_model(model)  # resolve once for the sweep
    if meshes is None:
        meshes = candidate_meshes(spec, multi_pod=multi_pod,
                                  n_devices=n_devices)
    plans = candidate_plans(cfg, spec, multi_pod)

    if stream_chunk_cells is not None:
        ranked = planspace.stream_topk(
            cfg, spec, plans, meshes, model, k=top_k,
            chunk_cells=stream_chunk_cells,
            hbm_budget=predictor.HBM_BYTES)
    else:
        space = planspace.PlanSpace.from_product(cfg, spec, plans, meshes)
        fits = space.feasible_mask()
        if fits.any():
            space = space.subset(fits)
        else:  # degrade gracefully: report least-infeasible
            order = np.argsort(space.peak_bytes(), kind="stable")
            space = space.subset(order[:max(top_k, 8)])
        ranked = space.rank(model, top_k=top_k)
    if tune_kernels:
        return [(s, p, m,
                 planspace.cotune_kernel_blocks(cfg, spec, p, m,
                                                raw_model))
                for s, p, m in ranked]
    return ranked


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--shape", default="train_4k", choices=sorted(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=5)
    ap.add_argument("--devices", type=int, default=None,
                    help="sweep every (data × model) factorization of this "
                         "chip count instead of the fixed 16x16 mesh")
    ap.add_argument("--tune-kernels", action="store_true",
                    help="co-tune kernel block sizes for the ranked cells")
    ap.add_argument("--stream-chunk", type=int, default=None, metavar="N",
                    help="score the sweep in streamed chunks of ~N cells "
                         "(bounded memory; HBM-infeasible cells pruned)")
    ap.add_argument("--model", default=None,
                    help="cost-model registry device name (default: the "
                         "analytic tpu-v5e seed); see python -m "
                         "repro.calibration --list")
    ap.add_argument("--trace-json", default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto JSON of the sweep "
                         "(measured spans + predicted overlay)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="dump the metrics registry (cache counters, "
                         "report-line tallies) as JSON")
    ap.add_argument("--explain", action="store_true",
                    help="print the basis-term attribution of the winning "
                         "cell (per-term seconds and cost categories)")
    args = ap.parse_args()

    if args.trace_json:
        _obs_trace.enable(process_name="autoshard")

    # model provenance: which weights are scoring this sweep (source /
    # revision matter once online refits start bumping registry files)
    resolved = predictor.resolve_model(args.model)
    meta = resolved.meta
    prov = [f"device={resolved.device}",
            f"source={meta.get('source', 'analytic-seed')}"]
    if "revision" in meta:
        prov.append(f"revision={meta['revision']}")
    if "fit_geomean_rel_err" in meta:
        prov.append(f"fit_rel_err={meta['fit_geomean_rel_err']:.3f}")
    if "refit_epoch" in meta:
        prov.append(f"refit_epoch={meta['refit_epoch']}")
    _obs_report.emit("autoshard", text=f"cost model: {' '.join(prov)}")

    with _obs_trace.get_tracer().span("autoshard.search", arch=args.arch,
                                      shape=args.shape):
        ranked = search(args.arch, args.shape, multi_pod=args.multi_pod,
                        model=args.model, top_k=args.top,
                        n_devices=args.devices,
                        tune_kernels=args.tune_kernels,
                        stream_chunk_cells=args.stream_chunk)
    # None resolves to the built-in analytic seed, which an explicit
    # "--model tpu-v5e" does NOT (a fitted registry file would shadow it)
    model_label = args.model or "tpu-v5e analytic seed"
    mesh_label = (f"{args.devices}-chip factorization sweep" if args.devices
                  else ("2x16x16" if args.multi_pod else "16x16"))
    print(f"top-{args.top} plans for {args.arch} × {args.shape} "
          f"({mesh_label}, model={model_label}):")
    for entry in ranked:
        t, p, mesh = entry[0], entry[1], entry[2]
        mesh_s = "x".join(f"{k}={v}" for k, v in sorted(mesh.items()))
        print(f"  {t*1e3:9.2f} ms  [{mesh_s}] fsdp={p.fsdp} "
              f"sp={p.sequence_parallel} mb={p.microbatches} "
              f"remat={p.remat_policy} moe={p.moe_mode} "
              f"comp={p.compression} cache_seq={p.cache_seq_axes}")
        if args.tune_kernels:
            for kern, blocks in entry[3].items():
                print(f"{'':14}· {kern}: {blocks}")
    if args.explain and ranked:
        t, p, mesh = ranked[0][0], ranked[0][1], ranked[0][2]
        from repro.obs.explain import score_explain
        exp = score_explain(ARCHS[args.arch],
                            wl.from_shape(SHAPES[args.shape]), p, mesh,
                            model=resolved)
        print("winning cell attribution:")
        print(exp.report())
    # persistent fused-program cache telemetry: a repeat invocation of the
    # same search reports "warm" (all programs loaded, zero compiles) —
    # CI's compile-cache smoke step asserts exactly that
    from repro.core import exprops
    print(exprops.disk_cache_report())

    if args.trace_json:
        _obs_trace.get_tracer().save(args.trace_json)
        print(f"[autoshard] trace written to {args.trace_json}")
    if args.metrics_json:
        _obs_metrics.REGISTRY.save_json(args.metrics_json)
        print(f"[autoshard] metrics written to {args.metrics_json}")


if __name__ == "__main__":
    main()
