"""ShapeDtypeStruct input specs + sharding trees for every (arch × shape)
cell — the dry-run's stand-ins (weak-type-correct, shardable, no device
allocation).

``step_and_specs`` returns everything ``dryrun.py`` needs to
``jax.jit(fn, in_shardings=…).lower(*specs)`` a cell:

  * train_4k      → train_step(TrainState, batch)
  * prefill_32k   → prefill_step(params, batch)
  * decode_32k / long_500k → serve_step(params, state, tokens, rng)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import workload as wl
from repro.distributed.plan import Plan
from repro.distributed.sharding import ShardingCtx, is_axes_leaf
from repro.models import transformer
from repro.optim import optimizers as opt
from repro.runtime import steps


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


# ---------------------------------------------------------------------------
# Batch specs
# ---------------------------------------------------------------------------


def batch_specs(cfg: ArchConfig, B: int, S: int) -> Dict[str, Any]:
    tok = (B, S, cfg.n_input_codebooks) if cfg.n_input_codebooks > 1 else (B, S)
    out = {
        "tokens": _sds(tok, jnp.int32),
        "labels": _sds(tok, jnp.int32),
    }
    if cfg.vision_tokens:
        out["vision_embeds"] = _sds(
            (B, cfg.vision_tokens, cfg.d_model), jnp.dtype(cfg.param_dtype))
        out["loss_mask"] = _sds((B, S), jnp.float32)
    return out


def batch_axes(cfg: ArchConfig) -> Dict[str, Any]:
    tok = ("act_batch", None, None) if cfg.n_input_codebooks > 1 \
        else ("act_batch", None)
    out = {"tokens": tok, "labels": tok}
    if cfg.vision_tokens:
        out["vision_embeds"] = ("act_batch", None, None)
        out["loss_mask"] = ("act_batch", None)
    return out


# ---------------------------------------------------------------------------
# Sharding trees
# ---------------------------------------------------------------------------


def _tree_shardings(mesh: Mesh, plan: Plan, axes_tree, shapes_tree,
                    kind: str):
    ctx = ShardingCtx(mesh, plan)
    fn = ctx.param_spec if kind == "param" else ctx.act_spec

    def one(axes, shp):
        shape = shp.shape if hasattr(shp, "shape") else shp
        return NamedSharding(mesh, fn(axes, shape))

    return jax.tree.map(one, axes_tree, shapes_tree, is_leaf=is_axes_leaf)


def _scalar(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Per-phase assembly — one helper, three thin wrappers
# ---------------------------------------------------------------------------


def phase_cell(cfg: ArchConfig, workload: wl.WorkloadLike, mesh: Mesh,
               plan: Plan):
    """-> (step_fn, arg_specs tuple, in_shardings tuple, out_shardings)
    for any workload phase.

    The parameter shapes/axes/shardings plumbing is identical across
    phases and computed once here; the phase then decides what travels
    next to the params — the optimizer-carrying ``TrainState`` (train),
    a token batch (prefill), or the decode caches + sampled-token inputs
    (decode).

    For train cells ``out_shardings`` pins the NEW TrainState to the input
    layout: without it GSPMD may materialize replicated f32 gradients
    (all-reduce + slice) instead of reduce-scattering into the sharded
    parameter layout (observed: 8–12 GB per-layer ARs on the 405B lowering
    — §Perf iter B).
    """
    spec = wl.as_spec(workload)
    B, S = spec.global_batch, spec.seq_len
    p_shapes = transformer.param_shapes(cfg)
    p_axes = transformer.param_axes(cfg)
    p_sh = _tree_shardings(mesh, plan, p_axes, p_shapes, "param")

    if spec.phase == "train":
        optimizer = opt.get_optimizer(cfg.optimizer)
        step_fn = steps.make_step(cfg, spec, plan, optimizer=optimizer)
        o_shapes = jax.eval_shape(optimizer.init, p_shapes)
        o_axes = opt.opt_state_axes(cfg.optimizer, p_axes)
        state_specs = steps.TrainState(
            params=p_shapes, opt_state=o_shapes,
            step=_sds((), jnp.int32))
        state_sh = steps.TrainState(
            params=p_sh,
            opt_state=_tree_shardings(mesh, plan, o_axes, o_shapes,
                                      "param"),
            step=_scalar(mesh))
        b_specs = batch_specs(cfg, B, S)
        b_sh = _tree_shardings(mesh, plan, batch_axes(cfg), b_specs, "act")
        metrics_sh = {"loss": _scalar(mesh), "grad_norm": _scalar(mesh),
                      "lr": _scalar(mesh)}
        return (step_fn, (state_specs, b_specs), (state_sh, b_sh),
                (state_sh, metrics_sh))

    if spec.phase == "prefill":
        step_fn = steps.make_step(cfg, spec, plan)
        b_specs = batch_specs(cfg, B, S)
        b_sh = _tree_shardings(mesh, plan, batch_axes(cfg), b_specs, "act")
        return step_fn, (p_shapes, b_specs), (p_sh, b_sh), None

    step_fn = steps.make_step(cfg, spec, plan, sample=True)
    s_shapes = jax.eval_shape(
        lambda: transformer.init_decode_state(cfg, B, S))
    s_axes = transformer.decode_state_axes(cfg)
    s_sh = _tree_shardings(mesh, plan, s_axes, s_shapes, "act")

    tok = (B, 1, cfg.n_input_codebooks) if cfg.n_input_codebooks > 1 \
        else (B, 1)
    tok_specs = _sds(tok, jnp.int32)
    tok_sh = NamedSharding(
        mesh, ShardingCtx(mesh, plan).act_spec(
            ("act_batch",) + (None,) * (len(tok) - 1), tok))
    rng_specs = _sds((2,), jnp.uint32)
    return (step_fn, (p_shapes, s_shapes, tok_specs, rng_specs),
            (p_sh, s_sh, tok_sh, _scalar(mesh)),
            None)  # outputs inferred (next-token rank varies per family)


def train_cell(cfg: ArchConfig, shape, mesh: Mesh, plan: Plan):
    return phase_cell(cfg, wl.as_spec(shape).with_(phase="train"), mesh,
                      plan)


def prefill_cell(cfg: ArchConfig, shape, mesh: Mesh, plan: Plan):
    return phase_cell(cfg, wl.as_spec(shape).with_(phase="prefill"), mesh,
                      plan)


def decode_cell(cfg: ArchConfig, shape, mesh: Mesh, plan: Plan):
    return phase_cell(cfg, wl.as_spec(shape).with_(phase="decode"), mesh,
                      plan)


def step_and_specs(cfg: ArchConfig, workload: wl.WorkloadLike, mesh: Mesh,
                   plan: Plan):
    return phase_cell(cfg, workload, mesh, plan)
