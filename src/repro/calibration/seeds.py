"""Analytic (datasheet-seeded) cost models for the registry.

The paper's headline claim is a *unified, vendor-independent* model: the same
property taxonomy fits GPUs "from multiple hardware generations and vendors"
(§5 fits NVIDIA Titan X / C2070 / K40 and AMD R9 Fury side by side).  The
registry exercises that claim with analytic seeds for several accelerators —
weights derived from public datasheet rates rather than fitted measurements.

An analytic seed plays the same role the datasheet-seeded v5e weights play in
``core.predictor``: a sane starting point that the black-box calibration
driver (``repro.calibration.calibrate``) would refine on real hardware.  Every
seed covers the *full* property taxonomy, so any property vector the
extractors emit is priced.

Only ``repro.core`` is imported here (calibration sits above core; core never
imports calibration at module load).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

from repro.core import predictor
from repro.core import properties as props
from repro.core.model import LinearCostModel


@dataclass(frozen=True)
class Datasheet:
    """Public peak rates for one accelerator — everything the analytic seed
    derives its seconds-per-event weights from."""
    name: str
    vendor: str
    matmul_flops: Dict[int, float]   # operand bits -> dense matmul FLOP/s
    vector_flops_f32: float          # FLOP/s, non-matmul f32 ALU rate
    mem_bw: float                    # B/s, HBM/GDDR stream bandwidth
    link_bw: float                   # B/s, per-device interconnect (one dir)
    launch_s: float = 5e-6           # per-dispatch overhead
    local_bw_mult: float = 20.0      # shared-mem/VMEM bandwidth vs HBM
    gather_penalty: float = 4.0      # uncoalesced-access bandwidth penalty
    notes: str = ""


def analytic_model(ds: Datasheet) -> LinearCostModel:
    """Seconds-per-event weights over the full taxonomy (the generalization
    of ``predictor.tpu_v5e_weights`` to any datasheet)."""
    w: Dict[str, float] = {}
    for bits, flops in ds.matmul_flops.items():
        w[props.mxu_key(bits)] = 1.0 / flops
    for kind, mult in (("add", 1.0), ("mul", 1.0), ("div", 4.0),
                       ("exp", 8.0), ("special", 8.0)):
        w[props.flop_key(32, kind)] = mult / ds.vector_flops_f32
        w[props.flop_key(16, kind)] = mult / (2 * ds.vector_flops_f32)
    for bits in props.SIZES:
        by = bits // 8
        for d in props.DIRECTIONS:
            w[props.mem_key(d, bits, "s0")] = 0.0        # broadcast: cached
            w[props.mem_key(d, bits, "s1")] = by / ds.mem_bw
            w[props.mem_key(d, bits, "gather")] = \
                ds.gather_penalty * by / ds.mem_bw
            for s in (2, 3, 4):
                for k in range(1, s + 1):
                    # stride-s with k/s utilization: pay the full footprint
                    w[props.mem_key(d, bits, f"s{s}_{k}/{s}")] = \
                        by * (s / k) / ds.mem_bw
            for k in range(1, 5):
                w[props.mem_key(d, bits, f"s>4_{k}/>4")] = \
                    ds.gather_penalty * by / ds.mem_bw
        w[props.minls_key(bits)] = 0.0
        w[props.local_key(bits)] = by / (ds.local_bw_mult * ds.mem_bw)
    for c in props.COLLECTIVES:
        # ring collectives saturate the link; all_to_all crosses bisection
        w[props.coll_key(c)] = (1.0 / ds.link_bw if c != "all_to_all"
                                else 2.0 / ds.link_bw)
    w[props.BARRIER] = 1e-7
    w[props.GROUPS] = 1e-7
    w[props.CONST1] = ds.launch_s
    return LinearCostModel.from_dict(
        w, device=ds.name,
        meta={"source": "datasheet-seed", "vendor": ds.vendor,
              "notes": ds.notes})


# ---------------------------------------------------------------------------
# The seed catalog — cross-vendor, as the paper demands
# ---------------------------------------------------------------------------

GPU_DATASHEETS: Dict[str, Datasheet] = {
    "gpu-a100": Datasheet(
        name="gpu-a100", vendor="nvidia",
        matmul_flops={16: 312e12, 32: 19.5e12},   # TF32-off f32 path
        vector_flops_f32=19.5e12, mem_bw=2039e9, link_bw=300e9,
        notes="A100-SXM 80GB: 312 TFLOP/s bf16 TC, 2.0 TB/s HBM2e, "
              "600 GB/s NVLink bidir"),
    "gpu-h100": Datasheet(
        name="gpu-h100", vendor="nvidia",
        matmul_flops={16: 989e12, 32: 67e12},
        vector_flops_f32=67e12, mem_bw=3350e9, link_bw=450e9,
        notes="H100-SXM: 989 TFLOP/s bf16 TC dense, 3.35 TB/s HBM3, "
              "900 GB/s NVLink bidir"),
    "gpu-mi300x": Datasheet(
        name="gpu-mi300x", vendor="amd",
        matmul_flops={16: 1307e12, 32: 163e12},
        vector_flops_f32=163e12, mem_bw=5300e9, link_bw=448e9,
        notes="MI300X: 1.3 PFLOP/s bf16 MFMA, 5.3 TB/s HBM3, "
              "~896 GB/s Infinity Fabric bidir"),
}


def _seed_builders() -> Dict[str, "callable"]:
    out: Dict[str, "callable"] = {
        # the v5e seed stays defined in core.predictor (it predates the
        # registry and tests/benchmarks use it directly); expose it verbatim
        "tpu-v5e": predictor.tpu_v5e_weights,
    }
    for name, ds in GPU_DATASHEETS.items():
        out[name] = (lambda d=ds: analytic_model(d))
    return out


#: device name -> zero-arg builder returning a fresh ``LinearCostModel``
ANALYTIC_SEEDS: Dict[str, "callable"] = _seed_builders()
