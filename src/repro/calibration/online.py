"""Online calibration: streaming RLS refit + drift watch over live timings.

This is the robustness layer over every model-guided decision the
framework makes: a scheduler serving live traffic must notice when the
hardware it runs on stops matching the model it plans with.  Three pieces,
composed by ``OnlineCalibrator``:

  * a ``TelemetrySink`` (``calibration/telemetry.py``) buffering the
    (property vector, measured seconds) samples the trainer / server feed;
  * an ``RLSState`` (``core/fit.py``) tracking the relative-error fit
    recursively, warm-started from the registered model;
  * a ``DriftMonitor`` — two-sided CUSUM over normalized residuals against
    the *tracked* fit (styled after ``runtime/straggler.py``'s monitor:
    observe per step, accumulate evidence, emit typed events).

On a drift event the calibrator refits from the samples since the CUSUM's
own change-point estimate (the excursion onset), swaps in a NEW
``LinearCostModel`` instance — never mutating weights in place, which
would leave stale folded-weight entries in every ``BasisProgram`` that
ever scored the old instance — bumps the registered revision through
``calibration/registry.register_revision`` (the mtime change rolls the
``registry.fingerprint`` every fingerprint-keyed memo checks), and clears
any ``BasisCache`` handed to it, so no prediction path can keep serving
the diverged model silently.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.calibration import registry
from repro.calibration.telemetry import TelemetrySink
from repro.core import fit
from repro.core.model import LinearCostModel, geomean
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs_trace

_CUSUM = _obs_metrics.REGISTRY.gauge(
    "repro_drift_cusum_evidence",
    "current CUSUM excursion height of the drift monitor (0 = quiet)")
_DRIFT_EVENTS = _obs_metrics.REGISTRY.counter(
    "repro_drift_events_total",
    "drift alarms emitted by the CUSUM monitor, by direction and phase")
_REFITS = _obs_metrics.REGISTRY.counter(
    "repro_calibration_refits_total",
    "model refits performed by online calibrators")


@dataclass(frozen=True)
class DriftEvent:
    seq: int                  # telemetry seq at detection
    step: Optional[int]       # producer step counter at detection
    onset_seq: int            # CUSUM excursion start — change-point estimate
    magnitude: float          # EWMA of the normalized residual at detection
    direction: str            # "slow" (device slower than model) | "fast"
    phase: str = "train"      # workload phase of the tipping sample


@dataclass
class DriftMonitor:
    """Two-sided CUSUM on normalized residuals ``(T_obs − T̂)/T̂``.

    The dead zone ``slack`` absorbs timing noise; evidence beyond it
    accumulates into ``g_pos`` (device slower than predicted) / ``g_neg``
    (faster), and either excursion crossing ``threshold`` emits a
    ``DriftEvent`` carrying the excursion's onset seq — the standard CUSUM
    change-point estimate, which the calibrator uses as its refit-window
    start.  A 1.5× slowdown with slack 0.15 accumulates ~0.35/sample, so
    the default threshold flags within ~25 samples; pure noise at σ ≲
    slack/2 accumulates nothing (the no-false-positive property the tests
    pin).  State resets after each event.
    """

    slack: float = 0.15
    threshold: float = 8.0
    ewma: float = 0.2              # weight of the newest residual
    g_pos: float = 0.0
    g_neg: float = 0.0
    mean: float = 0.0
    n: int = 0
    _onset_pos: Optional[int] = None
    _onset_neg: Optional[int] = None
    events: List[DriftEvent] = field(default_factory=list)

    def observe(self, seq: int, residual: float,
                step: Optional[int] = None,
                phase: str = "train") -> Optional[DriftEvent]:
        """Feed one normalized residual; returns a new event on alarm."""
        self.n += 1
        self.mean = (1 - self.ewma) * self.mean + self.ewma * residual

        g = max(0.0, self.g_pos + residual - self.slack)
        if g > 0 and self.g_pos == 0:
            self._onset_pos = seq
        self.g_pos = g
        if g == 0:
            self._onset_pos = None

        g = max(0.0, self.g_neg - residual - self.slack)
        if g > 0 and self.g_neg == 0:
            self._onset_neg = seq
        self.g_neg = g
        if g == 0:
            self._onset_neg = None

        if self.g_pos > self.threshold or self.g_neg > self.threshold:
            slow = self.g_pos > self.threshold
            onset = (self._onset_pos if slow else self._onset_neg)
            ev = DriftEvent(seq=seq, step=step,
                            onset_seq=seq if onset is None else onset,
                            magnitude=self.mean,
                            direction="slow" if slow else "fast",
                            phase=phase)
            self.events.append(ev)
            self.reset()
            return ev
        return None

    def reset(self) -> None:
        self.g_pos = self.g_neg = self.mean = 0.0
        self._onset_pos = self._onset_neg = None

    @property
    def status(self) -> str:
        return "ok" if max(self.g_pos, self.g_neg) <= self.threshold \
            else "drift"

    @property
    def evidence(self) -> float:
        """Current CUSUM excursion height (0 = fully quiet)."""
        return max(self.g_pos, self.g_neg)


class OnlineCalibrator:
    """Ties sink + RLS + drift watch + registry into one observe() loop.

    ``model`` is anything ``registry.resolve_model`` accepts.  Residuals
    for the drift watch are measured against the RLS-tracked prediction
    (not the static registered model) so a fixed model-vs-device offset is
    absorbed during ``warmup`` and only *changes* in device behavior
    accumulate drift evidence.  ``caches`` are ``exprops.BasisCache``
    instances to clear on refit; ``auto_register`` writes each refit model
    into the registry under ``device`` with a bumped revision.

    ``phase`` scopes the calibrator to one workload phase ("train" |
    "prefill" | "decode"): samples from other phases still land in the
    telemetry sink (phase-tagged), but never reach the RLS tracker or the
    drift CUSUM — one linear model fits one phase's regime, and a prefill
    burst must not read as train-time drift.  ``phase=None`` (default)
    accepts every sample, preserving the single-stream behavior for
    producers that feed one phase only; refit windows are ALWAYS filtered
    to the drift event's own phase.
    """

    def __init__(self, model=None, *, device: Optional[str] = None,
                 registry_dir: Optional[str] = None,
                 sink: Optional[TelemetrySink] = None,
                 drift: Optional[DriftMonitor] = None,
                 forgetting: float = 0.995, delta: float = 1e12,
                 warmup: int = 16, auto_register: bool = False,
                 caches: Sequence = (), residual: bool = False,
                 min_refit_samples: int = 2,
                 phase: Optional[str] = None):
        self.model = registry.resolve_model(model, registry_dir=registry_dir)
        self.device = device or self.model.device
        self.registry_dir = registry_dir
        self.sink = sink or TelemetrySink()
        self.drift = drift or DriftMonitor()
        self.forgetting = forgetting
        self.delta = delta
        self.warmup = warmup
        self.auto_register = auto_register
        self.caches = list(caches)
        self.fit_residual_head = residual
        self.min_refit_samples = min_refit_samples
        self.phase = phase
        self.rls = fit.RLSState.from_model(self.model, lam=forgetting,
                                           delta=delta)
        self.residual_head: Optional[fit.ResidualHead] = None
        self.refits = 0
        self.revision = int(self.model.meta.get("revision", 0))
        self.registry_path: Optional[str] = None
        self.events: List[DriftEvent] = []

    # ------------------------------------------------------------------
    def observe(self, pv: Mapping[str, float], seconds: float, *,
                step: Optional[int] = None, tag: str = "",
                phase: str = "train") -> Optional[DriftEvent]:
        """Ingest one live timing sample; returns a drift event if this
        sample tipped the CUSUM (the refit has already happened by then).
        Samples whose ``phase`` does not match a phase-scoped calibrator
        are buffered (tagged) but excluded from the fit and the drift
        watch."""
        seq = self.sink.record(pv, seconds, step=step, tag=tag, phase=phase)
        if seq is None:          # non-positive timing: no fit information
            return None
        if self.phase is not None and phase != self.phase:
            return None          # out-of-scope phase: telemetry only
        pred = self.rls.predict(pv)
        self.rls.observe(pv, seconds)
        if self.sink.n_recorded <= self.warmup or pred <= 0:
            return None
        ev = self.drift.observe(seq, (seconds - pred) / pred, step=step,
                                phase=phase)
        _CUSUM.set(self.drift.evidence)
        if ev is not None:
            self.events.append(ev)
            _DRIFT_EVENTS.inc(1, direction=ev.direction, phase=ev.phase)
            _obs_trace.get_tracer().instant(
                "drift_event", seq=ev.seq, direction=ev.direction,
                phase=ev.phase, magnitude=ev.magnitude)
            self._refit(ev)
        return ev

    # ------------------------------------------------------------------
    def _refit(self, ev: DriftEvent) -> None:
        """Refit from the post-onset window and swap the model atomically.

        The window starts at the CUSUM's change-point estimate, so the
        pre-drift regime does not dilute the new fit.  Warm-starting from
        the outgoing model keeps directions the window never exercises
        anchored instead of collapsing them to zero (the window from a
        single workload is rank-1).  Windows filter to the event's own
        phase: a decode-drift refit must never absorb train rows."""
        pvs, times = self.sink.window(since_seq=ev.onset_seq,
                                      phase=ev.phase)
        if len(times) < self.min_refit_samples:
            pvs, times = self.sink.window(n=self.min_refit_samples,
                                          phase=ev.phase)
        state = fit.RLSState.from_model(self.model, lam=1.0,
                                        delta=self.delta)
        state.observe_many(pvs, times)
        self.refits += 1
        _REFITS.inc()
        meta = dict(self.model.meta)
        meta.update({"refit_epoch": self.refits,
                     "refit_samples": len(times),
                     "refit_onset_seq": ev.onset_seq})
        self.model = state.model(device=self.device, meta=meta)
        if self.fit_residual_head:
            self.residual_head = fit.fit_residual(pvs, times, self.model)
        # restart the tracker from the refit estimate
        self.rls = fit.RLSState.from_model(self.model, lam=self.forgetting,
                                           delta=self.delta)
        if self.auto_register:
            self.registry_path, self.revision = registry.register_revision(
                self.model, self.registry_dir, name=self.device)
        else:
            self.revision += 1
            self.model.meta["revision"] = self.revision
        for c in self.caches:
            c.clear()

    # ------------------------------------------------------------------
    def window_rel_err(self, n: int = 64) -> float:
        """Geomean relative error of the ACTIVE model over the last ``n``
        buffered samples (inf-safe; inf when nothing is buffered)."""
        pvs, times = self.sink.window(n=n)
        if not times:
            return float("inf")
        preds = [self.model.predict(pv) for pv in pvs]
        errs = fit.safe_relative_errors(preds, times)
        finite = errs[np.isfinite(errs)]
        return geomean(finite) if len(finite) else float("inf")

    def report_line(self) -> str:
        """One observability line: sample counts, current windowed error,
        drift status, refit epochs — the trainer/autoshard surface."""
        s = self.sink.stats()
        err = self.window_rel_err()
        err_s = f"{err:.3f}" if np.isfinite(err) else "inf"
        return (f"samples={s['n_recorded']} (buffered={s['n_buffered']}, "
                f"pvs={s['n_unique_pvs']}) window_rel_err={err_s} "
                f"drift={self.drift.status} cusum={self.drift.evidence:.2f} "
                f"refits={self.refits} revision={self.revision}")

    def residual_attribution(self, n: int = 64):
        """Project the last ``n`` samples' measured-vs-predicted error onto
        the model's property basis (``obs.explain.attribute_residual_pv``),
        so a drift report can NAME the miss — "memory terms account for 78%
        of it" — instead of just flagging it.  None when the window is
        empty."""
        from repro.obs.explain import attribute_residual_pv
        pvs, times = self.sink.window(n=n, phase=self.phase)
        if not times:
            return None
        return attribute_residual_pv(self.model, pvs, times)

    def final_report(self) -> str:
        """Multi-line refit report for end-of-run printing."""
        base_err = self.window_rel_err()
        lines = [self.report_line(),
                 f"rls: n={self.rls.n_samples} "
                 f"forgetting={self.forgetting}",
                 f"active model: device={self.model.device} "
                 f"source={self.model.meta.get('source', '?')} "
                 f"refit_epoch={self.model.meta.get('refit_epoch', 0)}"]
        if np.isfinite(base_err):
            lines[-1] += f" window_rel_err={base_err:.3f}"
        if self.residual_head is not None:
            lines.append(f"residual head: "
                         f"n={self.residual_head.meta.get('n_samples')} "
                         f"ridge={self.residual_head.meta.get('ridge')}")
        for ev in self.events:
            lines.append(f"drift event: seq={ev.seq} step={ev.step} "
                         f"onset={ev.onset_seq} phase={ev.phase} "
                         f"direction={ev.direction} "
                         f"magnitude={ev.magnitude:+.3f}")
        att = self.residual_attribution()
        if att is not None and att.n_samples:
            lines.append(f"residual attribution: {att.line()} "
                         f"(n={att.n_samples})")
        return "\n".join(lines)
