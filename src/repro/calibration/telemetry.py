"""Streaming telemetry — the live-samples side of online calibration.

The trainer (``runtime/trainer.py``, its ``time.perf_counter`` step loop)
and the decode server (``runtime/server.py``) feed a ``TelemetrySink``
with (property-vector, measured seconds) samples as real steps execute.
The sink is a bounded ring buffer with the property vectors stored ONCE
per distinct fingerprint — a training run emits thousands of samples that
all share one step vector, so samples are (fingerprint, seconds, step)
records over a small deduplicated vector table.

Consumers: ``calibration/online.py`` (RLS refit windows, drift residuals)
and the telemetry JSON artifact the CI online-calibration step uploads.
"""
from __future__ import annotations

import hashlib
import json
import math
import os
import tempfile
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Mapping, Optional, Tuple

from repro.obs import metrics as _obs_metrics

_SAMPLES = _obs_metrics.REGISTRY.counter(
    "repro_telemetry_samples_total",
    "timing samples accepted into telemetry sinks, by phase")
_DROPPED = _obs_metrics.REGISTRY.counter(
    "repro_telemetry_dropped_total",
    "non-positive timings rejected by telemetry sinks")
_OCCUPANCY = _obs_metrics.REGISTRY.gauge(
    "repro_telemetry_ring_occupancy",
    "buffered samples in the most recently touched telemetry ring")
_UNIQUE_PVS = _obs_metrics.REGISTRY.gauge(
    "repro_telemetry_unique_pvs",
    "distinct property vectors in the most recently touched sink's table")


def pv_fingerprint(pv: Mapping[str, float], phase: str = "") -> str:
    """Stable content hash of a property vector (zero entries ignored, so
    a finalized and a sparse form of the same vector agree).  A truthy
    ``phase`` is hashed in: a train step and a decode iteration whose
    vectors happen to collide numerically must still never share a table
    entry, because refit windows select by phase."""
    h = hashlib.blake2b(digest_size=12)
    if phase:
        h.update(f"phase={phase};".encode())
    for k in sorted(pv):
        v = float(pv[k])
        if v:
            h.update(f"{k}={v!r};".encode())
    return h.hexdigest()


@dataclass(frozen=True)
class TelemetrySample:
    seq: int                 # global monotone sample index (never reused)
    fingerprint: str         # key into the sink's vector table
    seconds: float           # measured wall seconds
    step: Optional[int]      # producer's step counter, if any
    tag: str                 # producer label, free-form
    phase: str = "train"     # workload phase: "train" | "prefill" | "decode"


class TelemetrySink:
    """Bounded ring buffer of timing samples + deduplicated vector table.

    ``record`` assigns each sample a monotone ``seq``; eviction drops the
    oldest sample and garbage-collects its property vector when no buffered
    sample references it anymore.  Non-positive timings are counted and
    dropped — they carry no fit information and would poison the
    relative-error system downstream.
    """

    def __init__(self, capacity: int = 4096):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive: {capacity}")
        self.capacity = capacity
        self._buf: Deque[TelemetrySample] = deque()
        self._pvs: Dict[str, Dict[str, float]] = {}
        self._refs: Dict[str, int] = {}
        self.n_recorded = 0      # accepted samples, including evicted ones
        self.n_dropped = 0       # rejected non-positive timings

    # ------------------------------------------------------------------
    def record(self, pv: Mapping[str, float], seconds: float, *,
               step: Optional[int] = None, tag: str = "",
               phase: str = "train") -> Optional[int]:
        """Append one sample; returns its ``seq`` (None when dropped).
        ``phase`` keys the sample (and its vector-table entry) by workload
        phase, so refit windows never mix prefill/decode rows into a train
        fit."""
        # `not seconds > 0` alone already rejects NaN (NaN > 0 is False)
        # but would let +inf through into the ring — and a non-finite pv
        # entry would poison any refit window that selects it
        if not (math.isfinite(seconds) and seconds > 0) or \
                any(not math.isfinite(float(v)) for v in pv.values()):
            self.n_dropped += 1
            _DROPPED.inc()
            return None
        fp = pv_fingerprint(pv, phase)
        if fp not in self._pvs:
            self._pvs[fp] = {k: float(v) for k, v in pv.items() if v}
            self._refs[fp] = 0
        self._refs[fp] += 1
        seq = self.n_recorded
        self._buf.append(TelemetrySample(seq, fp, float(seconds), step, tag,
                                         phase))
        self.n_recorded += 1
        while len(self._buf) > self.capacity:
            old = self._buf.popleft()
            self._refs[old.fingerprint] -= 1
            if self._refs[old.fingerprint] == 0:
                del self._refs[old.fingerprint]
                del self._pvs[old.fingerprint]
        _SAMPLES.inc(1, phase=phase)
        _OCCUPANCY.set(len(self._buf))
        _UNIQUE_PVS.set(len(self._pvs))
        return seq

    def pv(self, fingerprint: str) -> Dict[str, float]:
        return self._pvs[fingerprint]

    # ------------------------------------------------------------------
    def samples(self, *, n: Optional[int] = None,
                since_seq: Optional[int] = None,
                tag: Optional[str] = None,
                phase: Optional[str] = None) -> List[TelemetrySample]:
        """Buffered samples, oldest first, filtered by window/tag/phase
        (None = no filtering on that key)."""
        out = [s for s in self._buf
               if (since_seq is None or s.seq >= since_seq)
               and (tag is None or s.tag == tag)
               and (phase is None or s.phase == phase)]
        if n is not None:
            out = out[-n:]
        return out

    def window(self, *, n: Optional[int] = None,
               since_seq: Optional[int] = None, tag: Optional[str] = None,
               phase: Optional[str] = None
               ) -> Tuple[List[Dict[str, float]], List[float]]:
        """(property vectors, times) for a sample window — the exact
        argument pair ``fit_relative`` / ``RLSState.observe_many`` take."""
        sel = self.samples(n=n, since_seq=since_seq, tag=tag, phase=phase)
        return [self._pvs[s.fingerprint] for s in sel], \
               [s.seconds for s in sel]

    def __len__(self) -> int:
        return len(self._buf)

    def stats(self) -> Dict[str, int]:
        return {"n_recorded": self.n_recorded, "n_buffered": len(self._buf),
                "n_dropped": self.n_dropped, "n_unique_pvs": len(self._pvs)}

    def clear(self) -> None:
        self._buf.clear()
        self._pvs.clear()
        self._refs.clear()

    # ------------------------------------------------------------------
    def to_json_dict(self) -> Dict[str, object]:
        return {
            "schema": 2,          # 2 adds the per-sample phase column
            "kind": "telemetry",
            "capacity": self.capacity,
            "n_recorded": self.n_recorded,
            "n_dropped": self.n_dropped,
            "pvs": self._pvs,
            "samples": [[s.seq, s.fingerprint, s.seconds, s.step, s.tag,
                         s.phase]
                        for s in self._buf],
        }

    def save(self, path: str) -> None:
        """Atomic write (temp file + ``os.replace``): a crash or kill mid-
        save leaves the previous artifact intact instead of a truncated
        JSON the next ``load`` would choke on."""
        d = os.path.dirname(path) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self.to_json_dict(), f, indent=1)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    @classmethod
    def from_json_dict(cls, d: Mapping[str, object]) -> "TelemetrySink":
        if d.get("kind") != "telemetry":
            raise ValueError(f"not a telemetry record: {d.get('kind')!r}")
        sink = cls(capacity=int(d["capacity"]))
        sink.n_dropped = int(d.get("n_dropped", 0))
        for fp, pv in dict(d["pvs"]).items():
            sink._pvs[fp] = {k: float(v) for k, v in pv.items()}
            sink._refs[fp] = 0
        for row in d["samples"]:
            # schema-1 rows carry no phase column: every pre-phase sample
            # came from the trainer, so they migrate as phase="train"
            seq, fp, seconds, step, tag = row[:5]
            phase = row[5] if len(row) > 5 else "train"
            sink._buf.append(TelemetrySample(int(seq), fp, float(seconds),
                                             None if step is None
                                             else int(step), tag, phase))
            sink._refs[fp] += 1
        sink.n_recorded = int(d["n_recorded"])
        return sink

    @classmethod
    def load(cls, path: str) -> "TelemetrySink":
        with open(path) as f:
            return cls.from_json_dict(json.load(f))
