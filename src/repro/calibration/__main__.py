"""CLI for the calibration subsystem.

    # fit a model for this machine and register it under "cpu"
    PYTHONPATH=src python -m repro.calibration --device cpu \
        --out experiments/registry

    # quick partial recalibration (two kernel classes, fewer runs)
    PYTHONPATH=src python -m repro.calibration --device cpu --scale tiny \
        --runs 8 --classes stride1_global,arith

    # inspect the registry
    PYTHONPATH=src python -m repro.calibration --list
    PYTHONPATH=src python -m repro.calibration --show tpu-v5e
"""
from __future__ import annotations

import argparse
import sys

from repro.calibration import registry
from repro.calibration.calibrate import calibrate
from repro.core.model import ModelSchemaError


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.calibration",
        description="Fit, register and inspect per-device cost models.")
    ap.add_argument("--device", default="cpu",
                    help="registry name for the fitted model (default: cpu)")
    ap.add_argument("--out", metavar="DIR", default=None,
                    help="registry directory (default: $REPRO_MODEL_REGISTRY "
                         f"or {registry.DEFAULT_REGISTRY_DIR})")
    ap.add_argument("--scale", default="cpu", choices=("cpu", "tiny"),
                    help="measurement-kernel size ladder (tiny = smoke)")
    ap.add_argument("--runs", type=int, default=30,
                    help="timing runs per kernel (paper: 30)")
    ap.add_argument("--drop", type=int, default=4,
                    help="warmup runs discarded (paper: 4)")
    ap.add_argument("--ridge", type=float, default=1e-4,
                    help="unit-free ridge strength (0 disables)")
    ap.add_argument("--nonneg", action="store_true",
                    help="project weights to >= 0 (paper default: off)")
    ap.add_argument("--classes", default=None,
                    help="comma-separated kernel classes to measure "
                         "(default: full 9-class suite)")
    ap.add_argument("--dry-run", action="store_true",
                    help="fit and report but do not write the registry")
    ap.add_argument("--list", action="store_true", dest="list_",
                    help="list registered devices and exit")
    ap.add_argument("--show", metavar="DEVICE", default=None,
                    help="print a registered model's weight report and exit")
    args = ap.parse_args(argv)

    if args.list_:
        models = registry.list_models(args.out)
        width = max((len(n) for n in models), default=6)
        print(f"registry: {args.out or registry.default_registry_dir()}")
        for name, kind in sorted(models.items()):
            print(f"  {name:<{width}}  {kind}")
        return 0

    if args.show:
        try:
            model = registry.load_model(args.show, args.out)
        except (registry.UnknownDeviceError, ModelSchemaError) as e:
            # unknown device OR a registry file with a mismatched/unreadable
            # SCHEMA_VERSION: report clearly, don't traceback
            print(f"cannot load model {args.show!r}: {e}", file=sys.stderr)
            return 1
        print(model.interpretation_report())
        return 0

    classes = ([c.strip() for c in args.classes.split(",") if c.strip()]
               if args.classes else None)
    result = calibrate(
        args.device, scale=args.scale, runs=args.runs, drop=args.drop,
        ridge=args.ridge, nonneg=args.nonneg, classes=classes,
        registry_dir=args.out, write_registry=not args.dry_run)
    return 0 if result.model is not None else 1


if __name__ == "__main__":
    sys.exit(main())
