"""The calibration driver — the paper's fit-once-per-device loop, end to end.

One call (or ``python -m repro.calibration``) runs the full black-box
procedure of §4 on the *current* runtime device:

  1. measure launch overhead (empty-kernel floor, §4.2);
  2. time the 9-class measurement-kernel suite (``core.mkernels``) under the
     paper's protocol — 30 runs, drop 4, take the minimum;
  3. extract each kernel's property vector automatically from the jaxpr
     (``core.extract``) plus schedule-declared properties;
  4. fit weights by relative-error least squares (``core.fit.fit_relative``);
  5. report per-kernel relative error and the Table-2-style weight
     interpretation;
  6. write the fitted model into the device-model registry, where
     ``registry.load_model(device)`` — and through it the autoshard /
     straggler / elastic layers — picks it up.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.calibration import registry
from repro.core import fit, measure, mkernels
from repro.core.model import LinearCostModel


@dataclass
class CalibrationResult:
    model: LinearCostModel
    report: Dict[str, object]        # fit.fit_report output on the fit set
    launch_overhead_s: float
    registry_path: Optional[str]     # None when write_registry=False
    wall_s: float
    labels: List[str] = field(default_factory=list)


def calibrate(device: str = "cpu", *, scale: str = "cpu",
              runs: int = 30, drop: int = 4,
              ridge: float = 1e-4, nonneg: bool = False,
              classes: Optional[Sequence[str]] = None,
              registry_dir: Optional[str] = None,
              write_registry: bool = True,
              seed: int = 0, verbose: bool = True) -> CalibrationResult:
    """Fit a ``LinearCostModel`` named ``device`` from live measurements.

    ``classes`` restricts the suite to the named measurement-kernel classes
    (e.g. ``("stride1_global", "arith")``) — useful for quick partial
    recalibration and for tests; the default is the full 9-class suite.
    """
    if runs <= drop:
        raise ValueError(f"runs ({runs}) must exceed dropped warmup runs "
                         f"({drop}) — no timing samples would remain")
    t_start = time.time()
    launch = measure.measure_launch_overhead(runs=runs, drop=drop)
    if verbose:
        print(f"# launch overhead: {launch * 1e6:.1f} µs")

    cases = mkernels.measurement_cases(scale, seed=seed)
    if classes is not None:
        wanted = set(classes)
        have = {c.klass for c in cases}
        unknown = wanted - have
        if unknown:
            raise ValueError(f"unknown kernel classes {sorted(unknown)}; "
                             f"available: {sorted(have)}")
        cases = [c for c in cases if c.klass in wanted]
    if not cases:
        raise ValueError("no measurement kernels selected")

    pvs, times, labels = [], [], []
    for i, c in enumerate(cases):
        pv = c.properties()
        tr = measure.time_kernel(c.jitted(), runs=runs, drop=drop,
                                 min_time_s=4 * launch)
        pvs.append(pv)
        times.append(tr.min_s)
        labels.append(c.name)
        if verbose and (i + 1) % 10 == 0:
            print(f"# measured {i + 1}/{len(cases)} kernels "
                  f"({time.time() - t_start:.0f}s)")

    model = fit.fit_relative(pvs, times, device=device, ridge=ridge,
                             nonneg=nonneg)
    model.meta.update({
        "scale": scale, "runs": runs, "drop": drop,
        "launch_overhead_s": launch,
        "classes": sorted({c.klass for c in cases}),
        "source": "calibrated",
    })
    report = fit.fit_report(model, pvs, times, labels)
    model.meta["fit_geomean_rel_err"] = report["geomean_rel_err"]

    path = None
    if write_registry:
        path = registry.save_model(model, registry_dir)

    wall = time.time() - t_start
    if verbose:
        print(f"\n{'kernel':<28} {'pred ms':>10} {'actual ms':>10} "
              f"{'rel err':>8}")
        for r in report["rows"]:
            print(f"{r['label']:<28} {r['predicted_s'] * 1e3:10.3f} "
                  f"{r['actual_s'] * 1e3:10.3f} {r['rel_err']:8.3f}")
        print(f"\nfit geomean rel |err|: {report['geomean_rel_err']:.3f} "
              f"over {report['n']} kernels "
              f"(max {report['max_rel_err']:.3f})")
        print()
        print(model.interpretation_report())
        if path:
            print(f"\n# model written to {path}")
        print(f"# calibration wall time: {wall:.0f}s")

    return CalibrationResult(model=model, report=report,
                             launch_overhead_s=launch, registry_path=path,
                             wall_s=wall, labels=labels)
