"""The device-model registry — fit once per device, load anywhere.

A registry is a plain directory of ``<device>.json`` files, each a
schema-versioned ``LinearCostModel`` (see ``core.model.SCHEMA_VERSION``).
Lookup order for ``load_model(device)``:

  1. a **fitted** model file in the registry directory (written by the
     calibration driver, ``python -m repro.calibration``);
  2. a built-in **analytic** seed (``seeds.ANALYTIC_SEEDS``: the TPU-v5e
     datasheet seed plus cross-vendor GPU datasheet seeds).

The registry directory defaults to ``$REPRO_MODEL_REGISTRY`` or
``experiments/registry`` under the current working directory.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Dict, Optional, Tuple

from repro.calibration import seeds
from repro.core.model import (FutureSchemaError, LinearCostModel,
                              ModelSchemaError)
from repro.obs import metrics as _obs_metrics
from repro.obs import report as _obs_report

REGISTRY_ENV = "REPRO_MODEL_REGISTRY"
DEFAULT_REGISTRY_DIR = os.path.join("experiments", "registry")

#: revision backups kept per device (``<safe>.rev<NNNN>.json``), written by
#: ``register_revision`` so a corrupted active file has somewhere to fall
#: back to
KEEP_REVISION_BACKUPS = 3

_FALLBACKS = _obs_metrics.REGISTRY.counter(
    "repro_registry_fallbacks_total",
    "corrupt registry model files quarantined and recovered from a "
    "previous revision or analytic seed, by device")


class UnknownDeviceError(KeyError):
    """No fitted or analytic model exists for the requested device."""

    def __init__(self, device: str, available: Dict[str, str]):
        self.device = device
        self.available = available
        listing = ", ".join(f"{n} ({k})" for n, k in sorted(available.items())) \
            or "<none>"
        super().__init__(
            f"no model for device {device!r}; available: {listing}. "
            f"Fit one with: python -m repro.calibration --device {device}")

    def __str__(self) -> str:  # KeyError would repr() the message
        return self.args[0]


def default_registry_dir() -> str:
    return os.environ.get(REGISTRY_ENV, DEFAULT_REGISTRY_DIR)


def _model_path(registry_dir: str, device: str) -> str:
    safe = re.sub(r"[^A-Za-z0-9._+-]", "_", device)
    return os.path.join(registry_dir, f"{safe}.json")


def _revision_backups(registry_dir: str, device: str):
    """Revision-backup paths for ``device``, newest revision first."""
    safe = re.sub(r"[^A-Za-z0-9._+-]", "_", device)
    pat = re.compile(re.escape(safe) + r"\.rev(\d+)\.json$")
    out = []
    try:
        names = os.listdir(registry_dir)
    except OSError:
        return []
    for fn in names:
        m = pat.fullmatch(fn)
        if m:
            out.append((int(m.group(1)), os.path.join(registry_dir, fn)))
    return [p for _, p in sorted(out, reverse=True)]


def _quarantine(path: str) -> Optional[str]:
    """Move a corrupt file aside as ``<path>.corrupt`` (best-effort)."""
    qpath = path + ".corrupt"
    try:
        os.replace(path, qpath)
        return qpath
    except OSError:
        return None


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


def save_model(model: LinearCostModel, registry_dir: Optional[str] = None,
               name: Optional[str] = None) -> str:
    """Write ``model`` into the registry under ``name`` (default: its
    ``device`` field).  Returns the file path."""
    registry_dir = registry_dir or default_registry_dir()
    os.makedirs(registry_dir, exist_ok=True)
    path = _model_path(registry_dir, name or model.device)
    model.save(path)
    return path


def register_revision(model: LinearCostModel,
                      registry_dir: Optional[str] = None,
                      name: Optional[str] = None) -> Tuple[str, int]:
    """Register ``model`` as the next revision of ``name``'s entry.

    The online-calibration path (``calibration/online.py``) calls this on
    every drift refit: the existing registry file's ``meta["revision"]``
    (0 when absent or unreadable) is bumped by one, stamped into the model,
    and the file is rewritten.  The rewrite rolls the file mtime, so every
    consumer memoizing per-device conclusions on ``fingerprint(device)``
    (e.g. the kernel autotuner's block-choice memo) misses and re-derives
    against the refit weights.  Returns (path, new revision)."""
    registry_dir = registry_dir or default_registry_dir()
    name = name or model.device
    path = _model_path(registry_dir, name)
    prev = 0
    prev_valid = False
    if os.path.exists(path):
        try:
            with open(path) as f:
                prev = int(LinearCostModel.from_json_dict(
                    json.load(f)).meta.get("revision", 0))
            prev_valid = True
        except (OSError, ValueError, KeyError, TypeError):
            prev = 0
    if prev_valid:
        # keep the outgoing revision as a fallback target: the hardened
        # ``load_model`` degrades to the newest backup when the active
        # file is later found corrupt
        safe = re.sub(r"[^A-Za-z0-9._+-]", "_", name)
        try:
            shutil.copyfile(path, os.path.join(
                registry_dir, f"{safe}.rev{prev:04d}.json"))
            for old in _revision_backups(registry_dir,
                                         name)[KEEP_REVISION_BACKUPS:]:
                os.remove(old)
        except OSError:
            pass   # backups are best-effort; never fail the refit
    model.meta["revision"] = prev + 1
    return save_model(model, registry_dir, name=name), prev + 1


#: analytic seeds are pure functions of the datasheet constants, so one
#: shared instance per name serves every caller.  Returning the SAME
#: object each time also lets identity-keyed downstream memos hit — the
#: fused engine's per-program weight folds (``exprops.BasisProgram``)
#: cache per model instance, and the replan/straggler fast paths resolve
#: a model on every call.  Treated as read-only everywhere.
_SEED_CACHE: Dict[str, LinearCostModel] = {}


def _analytic_seed(device: str) -> Optional[LinearCostModel]:
    model = _SEED_CACHE.get(device)
    if model is None:
        builder = seeds.ANALYTIC_SEEDS.get(device)
        if builder is None:
            return None
        model = _SEED_CACHE[device] = builder()
    return model


def _load_hardened(device: str, registry_dir: Optional[str] = None
                   ) -> Tuple[LinearCostModel, Optional[str]]:
    """``load_model`` plus provenance: returns ``(model, fellback)`` where
    ``fellback`` is ``None`` on a clean load (fitted file or plain analytic
    seed) and ``"backup"``/``"seed"`` when a corrupt active file was
    quarantined and the load degraded — what ``load_models`` rolls up so a
    fleet caller can see at a glance which pools run on degraded models."""
    registry_dir = registry_dir or default_registry_dir()
    path = _model_path(registry_dir, device)
    if os.path.exists(path):
        try:
            return LinearCostModel.load(path), None
        except FutureSchemaError:
            raise
        except (OSError, ValueError, KeyError, TypeError) as exc:
            qpath = _quarantine(path)
            _FALLBACKS.inc(1, device=device)
            _obs_report.emit("registry", {
                "device": device, "action": "fallback",
                "quarantined": qpath or "<failed>"},
                text=f"corrupt model file ({type(exc).__name__}); "
                     f"falling back")
            for bpath in _revision_backups(registry_dir, device):
                try:
                    model = LinearCostModel.load(bpath)
                except (OSError, ValueError, KeyError, TypeError):
                    continue
                _obs_report.emit("registry", {
                    "device": device, "action": "fallback",
                    "revision": model.meta.get("revision", "?")},
                    text=f"recovered from backup {os.path.basename(bpath)}")
                return model, "backup"
            model = _analytic_seed(device)
            if model is not None:
                return model, "seed"
            raise UnknownDeviceError(device, list_models(registry_dir))
    model = _analytic_seed(device)
    if model is not None:
        return model, None
    raise UnknownDeviceError(device, list_models(registry_dir))


def load_model(device: str, registry_dir: Optional[str] = None
               ) -> LinearCostModel:
    """Load the model for ``device``: fitted registry file first, then the
    built-in analytic seeds.  Raises ``UnknownDeviceError`` otherwise.

    Hardened against corruption (ISSUE 9): a truncated/garbled active
    file is quarantined as ``*.corrupt`` and the load falls back to the
    newest valid revision backup (written by ``register_revision``), then
    the analytic seed — counted in ``repro_registry_fallbacks_total``.
    A FUTURE schema re-raises (an upgrade problem, not corruption)."""
    return _load_hardened(device, registry_dir)[0]


def load_models(names, registry_dir: Optional[str] = None
                ) -> Dict[str, LinearCostModel]:
    """Batch loader for a heterogeneous fleet: one hardened ``load_model``
    per distinct name, plus ONE ``[registry]`` rollup line naming which
    devices fell back (quarantined active file recovered from a revision
    backup or the analytic seed).  A corrupt model for one device type
    therefore degrades only that pool's placements — the other models load
    clean and the caller learns exactly which pool is priced on stale
    weights.  Unknown devices still raise ``UnknownDeviceError``: a pool
    naming a device nobody can price is a manifest error, not churn."""
    models: Dict[str, LinearCostModel] = {}
    fellback = []
    for name in dict.fromkeys(names):
        model, fb = _load_hardened(name, registry_dir)
        models[name] = model
        if fb:
            fellback.append(f"{name}:{fb}")
    _obs_report.emit("registry", {
        "loaded": len(models),
        "fallbacks": ",".join(fellback) or "none"},
        text="batch load")
    return models


def list_models(registry_dir: Optional[str] = None) -> Dict[str, str]:
    """Every loadable device name -> "fitted" | "analytic".  A fitted file
    shadows an analytic seed of the same name (as in ``load_model``)."""
    registry_dir = registry_dir or default_registry_dir()
    out: Dict[str, str] = {n: "analytic" for n in seeds.ANALYTIC_SEEDS}
    if os.path.isdir(registry_dir):
        for fn in sorted(os.listdir(registry_dir)):
            if not fn.endswith(".json") or re.search(r"\.rev\d+\.json$", fn):
                continue   # revision backups are fallbacks, not entries
            path = os.path.join(registry_dir, fn)
            try:
                with open(path) as f:
                    d = json.load(f)
                LinearCostModel.from_json_dict(d)
            except (OSError, ValueError, KeyError, TypeError, AttributeError):
                continue  # not a readable model file; skip, don't crash
            out[fn[:-len(".json")]] = "fitted"
    return out


def fingerprint(device: str, registry_dir: Optional[str] = None):
    """Cache-key stamp for ``device``'s registry state: (registry dir,
    fitted-file mtime or None).  Changes whenever a recalibration rewrites
    the fitted model or the registry dir is redirected — callers memoizing
    per-device results (e.g. the kernel autotuner) key on this so they
    never serve conclusions from a superseded model."""
    registry_dir = registry_dir or default_registry_dir()
    path = _model_path(registry_dir, device)
    try:
        mtime = os.stat(path).st_mtime_ns
    except OSError:
        mtime = None
    return (registry_dir, mtime)


def resolve_model(model, default: str = "tpu-v5e",
                  registry_dir: Optional[str] = None) -> LinearCostModel:
    """Normalize a model argument: ``None`` -> the ``default`` *analytic*
    seed (deterministic — a fitted file never shadows the None default),
    ``str`` -> registry lookup (fitted shadows analytic), and a
    ``LinearCostModel`` passes through.

    Same rules as ``core.predictor.resolve_model`` (which the plan-search /
    straggler / elastic layers call), plus the ``registry_dir`` override.
    """
    if model is None:
        seed = _analytic_seed(default)
        if seed is None:
            raise UnknownDeviceError(default, list_models(registry_dir))
        return seed
    if isinstance(model, str):
        return load_model(model, registry_dir)
    if isinstance(model, LinearCostModel):
        return model
    raise TypeError(f"expected model name, LinearCostModel or None; "
                    f"got {type(model).__name__}")
