"""Calibration & device-model registry — the paper's fit-once-per-device,
predict-cheaply-anywhere loop as a subsystem.

Public surface:

  * ``calibrate(device, ...)`` — run the measurement-kernel suite on the
    current runtime device, fit, report, and register the model
    (``python -m repro.calibration`` is the CLI);
  * ``load_model(device)`` / ``save_model(model)`` / ``list_models()`` —
    the registry of fitted and analytic per-device models;
  * ``resolve_model(x)`` — normalize ``None | name | LinearCostModel``
    (the autoshard / straggler / elastic layers apply the same rules via
    ``core.predictor.resolve_model``, which delegates names to this
    registry).
"""
from repro.calibration.calibrate import CalibrationResult, calibrate
from repro.calibration.registry import (UnknownDeviceError,
                                        default_registry_dir, list_models,
                                        load_model, resolve_model, save_model)
from repro.calibration.seeds import ANALYTIC_SEEDS, Datasheet, analytic_model

__all__ = [
    "ANALYTIC_SEEDS", "CalibrationResult", "Datasheet", "UnknownDeviceError",
    "analytic_model", "calibrate", "default_registry_dir", "list_models",
    "load_model", "resolve_model", "save_model",
]
