"""Calibration & device-model registry — the paper's fit-once-per-device,
predict-cheaply-anywhere loop as a subsystem.

Public surface:

  * ``calibrate(device, ...)`` — run the measurement-kernel suite on the
    current runtime device, fit, report, and register the model
    (``python -m repro.calibration`` is the CLI);
  * ``load_model(device)`` / ``save_model(model)`` / ``list_models()`` —
    the registry of fitted and analytic per-device models;
  * ``resolve_model(x)`` — normalize ``None | name | LinearCostModel``
    (the autoshard / straggler / elastic layers apply the same rules via
    ``core.predictor.resolve_model``, which delegates names to this
    registry);
  * the **online** path — ``TelemetrySink`` (``telemetry.py``) buffering
    live (property vector, seconds) samples, ``OnlineCalibrator`` /
    ``DriftMonitor`` (``online.py``) tracking the fit with streaming RLS,
    flagging drift, and re-registering refit models with
    ``register_revision``.
"""
from repro.calibration.calibrate import CalibrationResult, calibrate
from repro.calibration.online import (DriftEvent, DriftMonitor,
                                      OnlineCalibrator)
from repro.calibration.registry import (UnknownDeviceError,
                                        default_registry_dir, list_models,
                                        load_model, register_revision,
                                        resolve_model, save_model)
from repro.calibration.seeds import ANALYTIC_SEEDS, Datasheet, analytic_model
from repro.calibration.telemetry import (TelemetrySample, TelemetrySink,
                                         pv_fingerprint)

__all__ = [
    "ANALYTIC_SEEDS", "CalibrationResult", "Datasheet", "DriftEvent",
    "DriftMonitor", "OnlineCalibrator", "TelemetrySample", "TelemetrySink",
    "UnknownDeviceError", "analytic_model", "calibrate",
    "default_registry_dir", "list_models", "load_model", "pv_fingerprint",
    "register_revision", "resolve_model", "save_model",
]
