"""Tiled transpose Pallas TPU kernel — the paper's *Transpose* measurement
class (prefetch variant).

On GPU the tile pass through shared memory converts uncoalesced reads into
coalesced ones; the TPU analog is a VMEM-tile relayout: blocks stream in
(bt × bt) tiles, transpose in-register, and stream out, so both HBM
directions stay contiguous ('stride-1') — exactly the access-class change
the fitted model prices.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import compiler_params


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].T


def transpose(x, *, block: int = 256, interpret: bool = True) -> jnp.ndarray:
    """(M, N) -> (N, M) via VMEM tiles."""
    M, N = x.shape
    bm = min(block, M)
    bn = min(block, N)
    assert M % bm == 0 and N % bn == 0
    return pl.pallas_call(
        _kernel,
        grid=(M // bm, N // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (j, i)),
        out_shape=jax.ShapeDtypeStruct((N, M), x.dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x)


def schedule_props(M: int, N: int, *, block: int = 256, bits: int = 32) -> dict:
    from repro.core import properties as props
    cells = (M // block) * (N // block)
    return {
        props.local_key(bits): float(M * N),
        props.BARRIER: float(cells),
        props.GROUPS: float(cells),
    }
