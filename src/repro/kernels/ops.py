"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (kernel bodies execute in Python for
validation) and False on TPU (compiled for the MXU/VMEM target).  Model code
calls these wrappers; swapping the XLA production path for the Pallas hot
path is a Plan-level switch (``Plan.use_pallas`` in the runtime).

Every wrapper accepts ``block_sizes``:

  * ``None`` (default) — use the explicit ``block_*`` keyword arguments;
  * a mapping — override the block keywords wholesale;
  * ``"auto"`` — ask the cost-model-guided autotuner
    (``repro.kernels.autotune.best_block_sizes``) to pick them for this
    shape, scoring candidates through ``model`` (None → analytic v5e seed,
    a registry device name, or an in-memory ``LinearCostModel``).

``"auto"`` resolution happens in plain Python before the jitted inner call,
so it runs once per (shape, model) at trace time and is memoized.
"""
from __future__ import annotations

import functools
from typing import Mapping, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.experimental.pallas import tpu as _pltpu

# --- version shim -----------------------------------------------------------
# The TPU compiler-params record was renamed across JAX releases:
# ``pltpu.TPUCompilerParams`` (≤0.4.x) became ``pltpu.CompilerParams``
# (≥0.5).  All kernel modules route through this alias so they run on both.
CompilerParams = getattr(_pltpu, "CompilerParams", None) \
    or getattr(_pltpu, "TPUCompilerParams")


def compiler_params(*, dimension_semantics: Tuple[str, ...]):
    """Build TPU compiler params portably across JAX versions."""
    return CompilerParams(dimension_semantics=dimension_semantics)


from repro.kernels import flash_attention as _fa
from repro.kernels import matmul as _mm
from repro.kernels import ssd_scan as _ssd
from repro.kernels import transpose as _tr

BlockSizes = Union[None, str, Mapping[str, int]]


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _dtype_bits(dtype) -> int:
    return jnp.dtype(dtype).itemsize * 8


def _resolve_blocks(kernel: str, shape: dict, block_sizes: BlockSizes,
                    explicit: dict, model) -> dict:
    """Merge the three block-size sources (explicit kwargs < mapping <
    autotuner) into concrete ints."""
    if block_sizes is None:
        return explicit
    if block_sizes == "auto":
        from repro.kernels import autotune
        return dict(autotune.best_block_sizes(kernel, shape, model=model))
    if isinstance(block_sizes, Mapping):
        out = dict(explicit)
        out.update(block_sizes)
        return out
    raise TypeError(f"block_sizes must be None, 'auto' or a mapping; "
                    f"got {block_sizes!r}")


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def _flash_attention_jit(q, k, v, *, causal, window, block_q, block_k,
                         interpret):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    block_sizes: BlockSizes = None, model=None,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """q (B,H,Sq,dh) × k,v (B,KVH,Skv,dh) → (B,H,Sq,dh)."""
    if interpret is None:
        interpret = _default_interpret()
    B, H, Sq, dh = q.shape
    shape = {"B": B, "H": H, "KVH": k.shape[1], "Sq": Sq, "Skv": k.shape[2],
             "dh": dh, "causal": causal, "window": window,
             "bits": _dtype_bits(q.dtype)}
    blocks = _resolve_blocks("flash_attention", shape, block_sizes,
                             {"block_q": block_q, "block_k": block_k}, model)
    return _flash_attention_jit(q, k, v, causal=causal, window=window,
                                block_q=blocks["block_q"],
                                block_k=blocks["block_k"],
                                interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _ssd_scan_jit(x, dt, A, B, C, *, chunk, interpret):
    return _ssd.ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=interpret)


def ssd_scan(x, dt, A, B, C, *, chunk: int = 128,
             block_sizes: BlockSizes = None, model=None,
             interpret: Optional[bool] = None) -> Tuple[jnp.ndarray,
                                                        jnp.ndarray]:
    """Chunked SSD: x (Bz,H,L,P), dt (Bz,H,L), A (H,), B/C (Bz,G,L,N)."""
    if interpret is None:
        interpret = _default_interpret()
    Bz, H, L, P = x.shape
    shape = {"Bz": Bz, "H": H, "L": L, "P": P, "N": B.shape[3],
             "bits": _dtype_bits(x.dtype)}
    blocks = _resolve_blocks("ssd_scan", shape, block_sizes,
                             {"chunk": chunk}, model)
    return _ssd_scan_jit(x, dt, A, B, C, chunk=blocks["chunk"],
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "block_m", "block_n", "block_k", "interpret"))
def _matmul_jit(a, b, *, block_m, block_n, block_k, interpret):
    return _mm.matmul(a, b, block_m=block_m, block_n=block_n,
                      block_k=block_k, interpret=interpret)


def matmul(a, b, *, block_m: int = 128, block_n: int = 128,
           block_k: int = 128, block_sizes: BlockSizes = None, model=None,
           interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _default_interpret()
    shape = {"M": a.shape[0], "K": a.shape[1], "N": b.shape[1],
             "bits": _dtype_bits(a.dtype)}
    blocks = _resolve_blocks(
        "matmul", shape, block_sizes,
        {"block_m": block_m, "block_n": block_n, "block_k": block_k}, model)
    return _matmul_jit(a, b, block_m=blocks["block_m"],
                       block_n=blocks["block_n"], block_k=blocks["block_k"],
                       interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _transpose_jit(x, *, block, interpret):
    return _tr.transpose(x, block=block, interpret=interpret)


def transpose(x, *, block: int = 256, block_sizes: BlockSizes = None,
              model=None, interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _default_interpret()
    shape = {"M": x.shape[0], "N": x.shape[1],
             "bits": _dtype_bits(x.dtype)}
    blocks = _resolve_blocks("transpose", shape, block_sizes,
                             {"block": block}, model)
    return _transpose_jit(x, block=blocks["block"], interpret=interpret)
