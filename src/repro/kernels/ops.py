"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (kernel bodies execute in Python for
validation) and False on TPU (compiled for the MXU/VMEM target).  Model code
calls these wrappers; swapping the XLA production path for the Pallas hot
path is a Plan-level switch (``Plan.use_pallas`` in the runtime).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import matmul as _mm
from repro.kernels import ssd_scan as _ssd
from repro.kernels import transpose as _tr


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """q (B,H,Sq,dh) × k,v (B,KVH,Skv,dh) → (B,H,Sq,dh)."""
    if interpret is None:
        interpret = _default_interpret()
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 128,
             interpret: Optional[bool] = None) -> Tuple[jnp.ndarray,
                                                        jnp.ndarray]:
    """Chunked SSD: x (Bz,H,L,P), dt (Bz,H,L), A (H,), B/C (Bz,G,L,N)."""
    if interpret is None:
        interpret = _default_interpret()
    return _ssd.ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "block_m", "block_n", "block_k", "interpret"))
def matmul(a, b, *, block_m: int = 128, block_n: int = 128,
           block_k: int = 128, interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _default_interpret()
    return _mm.matmul(a, b, block_m=block_m, block_n=block_n,
                      block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def transpose(x, *, block: int = 256, interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _default_interpret()
    return _tr.transpose(x, block=block, interpret=interpret)
