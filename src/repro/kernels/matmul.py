"""Tiled matmul Pallas TPU kernel — the paper's *Matrix Multiplication*
measurement-kernel class as a TPU-native kernel.

The paper's GPU version prefetches gsize×gsize tiles into shared memory;
the TPU analog streams (bm × bk) / (bk × bn) tiles HBM→VMEM via BlockSpec
and accumulates the (bm × bn) product in fp32 VMEM scratch across the
sequential k grid dimension, feeding the MXU with hardware-aligned
(multiples of 128) tile shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import compiler_params


def _kernel(a_ref, b_ref, o_ref, acc_scr, *, n_k: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        a_ref[...].astype(jnp.float32), b_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _store():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def matmul(a, b, *, block_m: int = 128, block_n: int = 128,
           block_k: int = 128, interpret: bool = True) -> jnp.ndarray:
    """(M, K) @ (K, N) with fp32 accumulation."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0
    n_k = K // block_k
    kernel = functools.partial(_kernel, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(M // block_m, N // block_n, n_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)


def schedule_props(M: int, N: int, K: int, *, block_m: int = 128,
                   block_n: int = 128, block_k: int = 128,
                   bits: int = 32) -> dict:
    from repro.core import properties as props
    cells = (M // block_m) * (N // block_n) * (K // block_k)
    local = cells * (block_m * block_k + block_k * block_n
                     + block_m * block_n)
    return {
        props.local_key(bits): float(local),
        props.BARRIER: float(cells),
        props.GROUPS: float((M // block_m) * (N // block_n)),
        props.mxu_key(bits): 2.0 * M * N * K,
    }
