"""Flash attention (online-softmax) Pallas TPU kernel with GQA + causal +
sliding-window masking.

TPU adaptation of the memory-tiling insight: Q/K/V stream HBM→VMEM in
(block_q × head_dim) / (block_k × head_dim) tiles sized for VMEM; the
(block_q × block_k) logit tile lives only in VMEM/VREGs; the softmax
running max/sum and the output accumulator are VMEM scratch carried across
the *sequential* innermost grid dimension (the kv-block walk).  MXU does the
two matmuls per tile pair; block shapes are multiples of (8, 128) so the
MXU/VPU tiling is hardware-aligned.

Fully-masked (q-block, k-block) pairs in the causal/SWA lower triangle are
skipped with ``pl.when`` — on TPU the grid step still issues, but no
compute/copy runs (the paper's 'barrier'-style schedule effect; counted by
``schedule_props``).

Validated on CPU via ``interpret=True`` against ``ref.attention``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            block_q: int, block_k: int, n_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # block-level skip predicates (compile-time structure, runtime ids)
    needed = jnp.bool_(True)
    if causal:
        needed &= k_start <= q_start + block_q - 1
    if window is not None:
        needed &= q_start - (k_start + block_k - 1) < window

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones(s.shape, jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-20)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q (B,H,Sq,dh) × k,v (B,KVH,Skv,dh) → (B,H,Sq,dh).

    ``interpret=True`` executes the kernel body on CPU (validation mode);
    on a TPU runtime pass ``interpret=False``.
    """
    B, H, Sq, dh = q.shape
    KVH, Skv = k.shape[1], k.shape[2]
    assert H % KVH == 0, (H, KVH)
    G = H // KVH
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0
    n_q, n_k = Sq // block_q, Skv // block_k
    scale = 1.0 / math.sqrt(dh)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_k=n_k)

    grid = (B, H, n_q, n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)


def schedule_props(B: int, H: int, KVH: int, Sq: int, Skv: int, dh: int,
                   *, causal: bool = True, window: Optional[int] = None,
                   block_q: int = 128, block_k: int = 128,
                   bits: int = 16) -> dict:
    """Schedule-derived property vector (paper §3.2: barriers/local loads
    need the *schedule*) for the fitted model: grid cells, VMEM block
    traffic, and the *executed* (non-skipped) tile-pair count."""
    from repro.core import properties as props
    n_q, n_k = Sq // block_q, Skv // block_k
    cells = B * H * n_q * n_k
    # executed pairs after causal/SWA skip
    exec_pairs = 0
    for qi in range(n_q):
        for ki in range(n_k):
            ok = True
            if causal and ki * block_k > qi * block_q + block_q - 1:
                ok = False
            if window is not None and \
                    qi * block_q - (ki * block_k + block_k - 1) >= window:
                ok = False
            exec_pairs += ok
    exec_cells = B * H * exec_pairs
    local = exec_cells * (block_q * dh + 2 * block_k * dh)
    return {
        props.local_key(bits): float(local),
        props.BARRIER: float(cells),
        props.GROUPS: float(cells),
        props.mxu_key(bits): 4.0 * exec_cells * block_q * block_k * dh,
    }
