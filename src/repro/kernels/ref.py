"""Pure-jnp oracles for every Pallas kernel (the allclose references).

These are deliberately *naive* — O(S²) attention with materialized logits,
O(L) sequential SSD recurrence — so they are independent of both the Pallas
kernels and the chunked XLA production paths in ``repro.models``.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention(q, k, v, *, causal: bool = True,
              window: Optional[int] = None) -> jnp.ndarray:
    """q (B,H,Sq,dh) × k,v (B,KVH,Skv,dh) → (B,H,Sq,dh).  f32 math."""
    B, H, Sq, dh = q.shape
    KVH, Skv = k.shape[1], k.shape[2]
    G = H // KVH
    kf = jnp.repeat(k.astype(jnp.float32), G, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf)
    s = s / math.sqrt(dh)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vf)
    return o.astype(q.dtype)


def ssd(x, dt, A, B, C, h0=None):
    """Sequential SSD recurrence — the definitionally-correct oracle.

    x (Bz,H,L,P); dt (Bz,H,L); A (H,) negative; B,C (Bz,G,L,N), G | H.
    h_t = h_{t-1}·exp(dt_t A) + dt_t · B_t ⊗ x_t ;  y_t = C_t · h_t (+ skip
    handled by caller).  Returns (y (Bz,H,L,P), h_final (Bz,H,P,N)).
    """
    Bz, H, L, P = x.shape
    G, N = B.shape[1], B.shape[3]
    rep = H // G
    Bf = jnp.repeat(B.astype(jnp.float32), rep, axis=1)  # (Bz,H,L,N)
    Cf = jnp.repeat(C.astype(jnp.float32), rep, axis=1)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((Bz, H, P, N), jnp.float32)

    def step(h, t):
        dA = jnp.exp(dtf[:, :, t] * A[None, :])  # (Bz,H)
        upd = jnp.einsum("bhn,bhp->bhpn", Bf[:, :, t] * dtf[:, :, t, None],
                         xf[:, :, t])
        h = h * dA[..., None, None] + upd
        y = jnp.einsum("bhn,bhpn->bhp", Cf[:, :, t], h)
        return h, y

    h_fin, ys = jax.lax.scan(step, h0, jnp.arange(L))
    y = jnp.moveaxis(ys, 0, 2)  # (Bz,H,L,P)
    return y.astype(x.dtype), h_fin


def matmul(a, b):
    """f32-accumulated matmul oracle."""
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)
                   ).astype(a.dtype)


def transpose(x):
    return x.T
