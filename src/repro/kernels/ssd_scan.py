"""Mamba2 SSD (state-space duality) Pallas TPU kernel.

TPU adaptation of the SSD insight (arXiv:2405.21060): within a chunk of Q
timesteps the recurrence is a *masked matmul* (MXU work); across chunks only
the (P × N) state is carried.  The kernel walks chunks on the sequential
innermost grid dimension with the state in VMEM scratch — the carried state
never round-trips to HBM (the GPU version holds it in registers/SMEM; the
TPU analog is VMEM residency across grid steps).

Per (batch, head, chunk) grid cell:
    cum   = cumsum(dt·A)                       (Q,)
    Lmat  = tril(exp(cum_i − cum_j))           (Q, Q)   decay matrix
    W     = (C Bᵀ) ⊙ Lmat ⊙ dt_j               (Q, Q)   MXU + VPU
    y     = W x  +  (C ⊙ exp(cum)) h_prevᵀ     (Q, P)   MXU
    h_new = exp(cum_Q) h_prev + (B ⊙ dt ⊙ decay_to_end)ᵀ x    (P, N)

Validated on CPU via ``interpret=True`` against the naive O(L) recurrence
``ref.ssd`` and the chunked XLA path ``models.ssm._ssd_chunked``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import compiler_params


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_scr, *,
            n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(jnp.float32)      # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)    # (Q,)
    A = a_ref[0].astype(jnp.float32)         # scalar (negative)
    Bm = b_ref[0, 0].astype(jnp.float32)     # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)     # (Q, N)

    dA = dt * A                              # (Q,) ≤ 0
    cum = jnp.cumsum(dA)                     # (Q,)
    Q = x.shape[0]

    diff = cum[:, None] - cum[None, :]       # (Q, Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    # mask the exponent, not the product (avoids inf·0 in the bwd pass)
    diff = jnp.where(ii >= jj, diff, -1e30)
    Lmat = jnp.exp(diff)

    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    W = CB * Lmat * dt[None, :]
    y_intra = jax.lax.dot_general(W, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    h_prev = h_scr[...]                      # (P, N)
    y_inter = jax.lax.dot_general(
        Cm * jnp.exp(cum)[:, None], h_prev,
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    decay_end = jnp.exp(cum[-1] - cum)       # (Q,)
    Bw = Bm * (dt * decay_end)[:, None]      # (Q, N)
    S_c = jax.lax.dot_general(x, Bw, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    h_new = h_prev * jnp.exp(cum[-1]) + S_c
    h_scr[...] = h_new

    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _write_state():
        hout_ref[0, 0] = h_new.astype(hout_ref.dtype)


def ssd_scan(x, dt, A, B, C, *, chunk: int = 128, interpret: bool = True):
    """x (Bz,H,L,P); dt (Bz,H,L); A (H,); B,C (Bz,G,L,N) with G | H.

    Returns (y (Bz,H,L,P), h_final (Bz,H,P,N) fp32).
    """
    Bz, H, L, P = x.shape
    G, N = B.shape[1], B.shape[3]
    assert H % G == 0
    rep = H // G
    chunk = min(chunk, L)
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk

    kernel = functools.partial(_kernel, n_chunks=nc)
    grid = (Bz, H, nc)
    y, h_fin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, ci: (b, h, ci)),
            pl.BlockSpec((1,), lambda b, h, ci: (h,)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda b, h, ci, rep=rep: (b, h // rep, ci, 0)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda b, h, ci, rep=rep: (b, h // rep, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((Bz, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A, B, C)
    return y, h_fin


def schedule_props(Bz: int, H: int, L: int, P: int, N: int, *,
                   chunk: int = 128, bits: int = 16) -> dict:
    """Schedule-derived properties: per grid cell, x/B/C blocks move
    HBM→VMEM and the (P, N) state stays VMEM-resident."""
    from repro.core import properties as props
    nc = L // chunk
    cells = Bz * H * nc
    local = cells * (chunk * P + 2 * chunk * N + P * N)
    mxu = cells * 2.0 * (chunk * chunk * N      # CB
                         + chunk * chunk * P    # y_intra
                         + chunk * P * N * 2)   # y_inter + state update
    return {
        props.local_key(bits): float(local),
        props.BARRIER: float(cells),
        props.GROUPS: float(cells),
        props.mxu_key(bits): mxu,
    }
