"""Cost-model-guided block-size autotuning — the paper's §6.2 payoff
("select the optimal set of kernel configurations") at kernel granularity.

For a kernel family (``core.kernelmodel.KERNELS``) and a concrete problem
shape, the tuner:

  1. enumerates the hardware-valid candidate grid (power-of-two blocks that
     divide the shape, filtered by a VMEM-footprint budget);
  2. builds the kernel's symbolic property vector with the block sizes left
     as ``symcount`` variables, compiles each property once
     (``Expr.compile``), and evaluates the WHOLE candidate grid as numpy
     arrays — no per-point tree-walks;
  3. scores every candidate through a ``LinearCostModel`` (an in-memory
     model, a registry device name like ``"gpu-h100"``, or None for the
     analytic v5e seed) as one weighted sum of property arrays.

``best_block_sizes`` results are memoized per (kernel, shape, model-name),
so ``block_sizes="auto"`` kernel calls (see ``repro.kernels.ops``) pay the
sweep once per shape, at trace time.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core import kernelmodel
from repro.core.model import LinearCostModel
from repro.core.symcount import evaluate_vector


def _resolve_model(model) -> LinearCostModel:
    from repro.core import predictor  # accepts None | registry name | model
    return predictor.resolve_model(model)


# ---------------------------------------------------------------------------
# Candidate enumeration
# ---------------------------------------------------------------------------


def candidate_configs(kernel, shape: Mapping[str, int],
                      vmem_budget: Optional[float] = None
                      ) -> List[Dict[str, int]]:
    """Valid block-size candidates for ``kernel`` at ``shape``: the
    power-of-two divisor grid, minus configurations whose VMEM working set
    exceeds the budget (default 75% of a v5e core's 16 MiB)."""
    km = kernelmodel.get(kernel)
    if vmem_budget is None:
        vmem_budget = kernelmodel.VMEM_BYTES * kernelmodel.VMEM_BUDGET
    cands = km.candidates(shape)
    ok = [c for c in cands if km.vmem_bytes(shape, c) <= vmem_budget]
    if not ok:  # nothing fits the budget: keep the smallest footprint
        ok = [min(cands, key=lambda c: km.vmem_bytes(shape, c))]
    return ok


# ---------------------------------------------------------------------------
# Scoring
# ---------------------------------------------------------------------------


# Bounded memo (LRU, like predictor._STEP_PV_CACHE): keys are the kernel
# name plus the *sorted* shape items, so equal shapes hit regardless of
# caller dict order, and old shapes evict instead of accumulating.
@functools.lru_cache(maxsize=128)
def _fused_program(kernel_name: str,
                   shape_items: Tuple[Tuple[str, object], ...]):
    from repro.core import exprops
    km = kernelmodel.get(kernel_name)
    dk = exprops.program_key("kernel", kernel_name, shape_items)
    return exprops.load_or_build(
        dk, lambda: km.vector(dict(shape_items), km.symbolic_blocks()))


def score_configs(kernel, shape: Mapping[str, int],
                  configs: Sequence[Mapping[str, int]],
                  model=None) -> np.ndarray:
    """Predicted seconds for every candidate — the fused fast path.

    The kernel's property vector (shape baked in as constants, block sizes
    free) lowers to one basis program (``core.exprops``: canonicalized,
    cross-property CSE'd, memoized per shape in memory and on disk); the
    model's weights fold through the coefficient matrix once, and the whole
    candidate grid scores as a single GEMV.
    """
    from repro.core import exprops
    km = kernelmodel.get(kernel)
    model = _resolve_model(model)
    prog = _fused_program(km.name, tuple(sorted(shape.items())))
    env = {b: np.asarray([c[b] for c in configs], dtype=np.int64)
           for b in km.block_params}
    return exprops.score_cells(prog, env, len(configs), model)


def score_configs_interpreted(kernel, shape: Mapping[str, int],
                              configs: Sequence[Mapping[str, int]],
                              model=None) -> np.ndarray:
    """Reference scorer: per-point ``Expr.eval`` + ``model.predict``.
    Semantically identical to ``score_configs``; kept as the oracle the
    compiled path is tested (and benchmarked) against."""
    km = kernelmodel.get(kernel)
    model = _resolve_model(model)
    out = np.empty(len(configs), dtype=np.float64)
    for i, c in enumerate(configs):
        pv = km.vector(shape, c)
        out[i] = model.predict(evaluate_vector(pv, {}))
    return out


def rank_block_sizes(kernel, shape: Mapping[str, int], model=None,
                     configs: Optional[Sequence[Mapping[str, int]]] = None
                     ) -> List[Tuple[float, Dict[str, int]]]:
    """All candidates sorted by predicted time (ascending)."""
    if configs is None:
        configs = candidate_configs(kernel, shape)
    secs = score_configs(kernel, shape, configs, model)
    order = np.argsort(secs, kind="stable")
    return [(float(secs[i]), dict(configs[i])) for i in order]


# ---------------------------------------------------------------------------
# Public entry point (+ memo for "auto" kernel calls)
# ---------------------------------------------------------------------------


# Bounded LRU memo; the registry fingerprint ``_stamp`` is part of the key
# so recalibration invalidates block choices tuned against a stale model.
@functools.lru_cache(maxsize=128)
def _best_cached(kernel_name: str, shape_items: Tuple[Tuple[str, object], ...],
                 model_name: Optional[str],
                 _stamp) -> Tuple[Tuple[str, int], ...]:
    shape = dict(shape_items)
    ranked = rank_block_sizes(kernel_name, shape, model_name)
    best = ranked[0][1]
    return tuple(sorted(best.items()))


def best_block_sizes(kernel, shape: Mapping[str, int],
                     model=None) -> Dict[str, int]:
    """Model-chosen block sizes for ``kernel`` at ``shape``.

    ``model`` is anything ``core.predictor.resolve_model`` accepts: None
    (analytic v5e seed), a registry device name (fitted model shadows the
    analytic seed of the same name), or an in-memory ``LinearCostModel``.
    """
    km = kernelmodel.get(kernel)
    if model is None or isinstance(model, str):
        # stamp the registry state into the key: a recalibration (or a
        # registry-dir redirect) must invalidate block choices tuned
        # against the superseded fitted model
        stamp = None
        if isinstance(model, str):
            from repro.calibration import registry
            stamp = registry.fingerprint(model)
        items = tuple(sorted(shape.items()))
        return dict(_best_cached(km.name, items, model, stamp))
    return rank_block_sizes(km, shape, model)[0][1]


# ---------------------------------------------------------------------------
# Workload-level tuning — a WorkloadSpec names the step, this derives the
# per-kernel problem shapes
# ---------------------------------------------------------------------------


def workload_kernel_shapes(cfg, workload, *, dp: int = 1, tp: int = 1,
                           microbatches: int = 1
                           ) -> Dict[str, Dict[str, object]]:
    """The dominant kernels' concrete *per-device* problem shapes for one
    step of ``cfg`` under ``workload`` (a ``repro.core.workload``
    ``WorkloadLike``), sharded ``dp`` × ``tp`` ways with ``microbatches``
    grad-accumulation chunks.

    Decode steps tune only the per-token matmul (its cache-streaming
    attention / recurrent update has no Pallas kernel here); train/prefill
    add flash-attention and/or ssd_scan per the config family.
    """
    from repro.core import workload as wl
    spec = wl.as_spec(workload)
    bits = 16 if "16" in cfg.compute_dtype else 32
    if spec.phase == "decode":
        rows = spec.global_batch if spec.active_slots is None \
            else spec.active_slots
        tok = max((rows * spec.spec_len) // dp, 1)
        b_dev = tok
    else:
        b_dev = max(spec.global_batch // (dp * max(microbatches, 1)), 1)
        tok = b_dev * spec.seq_len

    out: Dict[str, Dict[str, object]] = {}
    if cfg.d_ff:
        out["matmul"] = {"M": tok, "N": max(cfg.d_ff // tp, 1),
                         "K": cfg.d_model, "bits": bits}
    if cfg.n_heads and spec.phase != "decode":
        out["flash_attention"] = {
            "B": b_dev, "H": max(cfg.n_heads // tp, 1),
            "KVH": max(cfg.n_kv_heads // tp, 1),
            "Sq": spec.seq_len, "Skv": spec.seq_len,
            "dh": cfg.head_dim_, "causal": True,
            "window": cfg.sliding_window, "bits": bits}
    if cfg.ssm is not None and spec.phase != "decode":
        out["ssd_scan"] = {
            "Bz": b_dev, "H": max(cfg.ssm_heads // tp, 1),
            "L": spec.seq_len, "P": cfg.ssm.head_dim,
            "N": cfg.ssm.d_state, "bits": bits}
    return out


def best_blocks_for_workload(cfg, workload, model=None, *, dp: int = 1,
                             tp: int = 1, microbatches: int = 1
                             ) -> Dict[str, Dict[str, int]]:
    """Model-chosen block sizes for every dominant kernel of one step of
    ``cfg`` under ``workload`` — ``workload_kernel_shapes`` fed through
    ``best_block_sizes`` kernel by kernel."""
    return {kern: best_block_sizes(kern, shape, model)
            for kern, shape in workload_kernel_shapes(
                cfg, workload, dp=dp, tp=tp,
                microbatches=microbatches).items()}
