"""Optimizers (functional, optax-style but dependency-free).

- ``adamw``     : fp32 m/v (dtype configurable) + decoupled weight decay.
- ``adafactor`` : factored second moment (Shampoo-free memory diet) — used by
                  llama3-405b whose fp32 Adam states would not fit v5e HBM
                  (see DESIGN.md §2 / EXPERIMENTS.md §Dry-run).
- ``sgd``       : momentum SGD (measurement baseline).

All updates are computed in fp32 and cast back to the param dtype.
Optimizer state mirrors the param tree, so the FSDP/TP shardings of the
params apply leaf-wise to the state (see ``repro.distributed.sharding``).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], tuple]
    # update(grads, state, params, lr) -> (new_params, new_state)


def _cast_like(x, ref):
    return x.astype(ref.dtype)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, state_dtype=jnp.float32) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        bc1 = 1.0 - b1 ** c
        bc2 = 1.0 - b2 ** c

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
            step = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr * step
            return (_cast_like(p_new, p), m_new.astype(state_dtype),
                    v_new.astype(state_dtype))

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v, "count": count}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored v; optional bf16 momentum)
# ---------------------------------------------------------------------------


def adafactor(decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, momentum: Optional[float] = None,
              momentum_dtype=jnp.bfloat16) -> Optimizer:
    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def one(p):
            if _factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        st = {"v": jax.tree.map(one, params), "count": jnp.zeros((), jnp.int32)}
        if momentum is not None:
            st["m"] = jax.tree.map(
                lambda p: jnp.zeros(p.shape, momentum_dtype), params)
        return st

    def update(grads, state, params, lr):
        count = state["count"] + 1
        beta = 1.0 - (count.astype(jnp.float32) + 1.0) ** (-decay)

        def upd(g, v, p, m=None):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(jnp.mean(vr, axis=-1,
                                                keepdims=True)[..., None], eps))
                upd_v = {"vr": vr, "vc": vc}
                u = g * jax.lax.rsqrt(denom + eps)
            else:
                vf = beta * v["v"] + (1 - beta) * g2
                upd_v = {"v": vf}
                u = g * jax.lax.rsqrt(vf + eps)
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if m is not None:
                u = momentum * m.astype(jnp.float32) + (1 - momentum) * u
                new_m = u.astype(momentum_dtype)
            else:
                new_m = None
            p_new = p.astype(jnp.float32) - lr * u
            return _cast_like(p_new, p), upd_v, new_m

        is_v = lambda x: isinstance(x, dict) and ("vr" in x or "v" in x)
        if momentum is not None:
            out = jax.tree.map(upd, grads, state["v"], params, state["m"],
                               is_leaf=lambda x: is_v(x) or hasattr(x, "shape"))
        else:
            out = jax.tree.map(lambda g, v, p: upd(g, v, p),
                               grads, state["v"], params, is_leaf=is_v)
        tup = lambda x: isinstance(x, tuple)
        new_params = jax.tree.map(lambda o: o[0], out, is_leaf=tup)
        new_v = jax.tree.map(lambda o: o[1], out, is_leaf=tup)
        new_state = {"v": new_v, "count": count}
        if momentum is not None:
            new_state["m"] = jax.tree.map(lambda o: o[2], out, is_leaf=tup)
        return new_params, new_state

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# SGD + momentum
# ---------------------------------------------------------------------------


def sgd(momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        def upd(g, m, p):
            m_new = momentum * m + g.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr * m_new
            return _cast_like(p_new, p), m_new

        out = jax.tree.map(upd, grads, state["m"], params)
        tup = lambda x: isinstance(x, tuple)
        return (jax.tree.map(lambda o: o[0], out, is_leaf=tup),
                {"m": jax.tree.map(lambda o: o[1], out, is_leaf=tup),
                 "count": state["count"] + 1})

    return Optimizer(init, update)


def get_optimizer(name: str) -> Optimizer:
    if name == "adamw":
        return adamw()
    if name == "adafactor":
        return adafactor()
    if name == "sgd":
        return sgd()
    raise KeyError(name)


# ---------------------------------------------------------------------------
# LR schedules + grad clipping
# ---------------------------------------------------------------------------


def warmup_cosine(peak: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak * jnp.minimum(1.0, step / max(warmup, 1))
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    g2 = sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(g2)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


# ---------------------------------------------------------------------------
# Optimizer-state logical axes (for distributed sharding of TrainState)
# ---------------------------------------------------------------------------


def opt_state_axes(name: str, params_axes):
    """Logical-axes tree mirroring ``get_optimizer(name).init(params)``.

    Leaf-wise: AdamW m/v inherit the param axes; Adafactor's factored vr/vc
    drop the last / second-to-last axis.  ``count`` is a replicated scalar.
    """
    is_ax = lambda x: isinstance(x, tuple)
    if name == "adamw":
        return {
            "m": params_axes,
            "v": params_axes,
            "count": (),
        }
    if name == "adafactor":
        def one(ax):
            if len(ax) >= 2:
                return {"vr": ax[:-1], "vc": ax[:-2] + ax[-1:]}
            return {"v": ax}
        return {"v": jax.tree.map(one, params_axes, is_leaf=is_ax),
                "count": ()}
    if name == "sgd":
        return {"m": params_axes, "count": ()}
    raise KeyError(name)
