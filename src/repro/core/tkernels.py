"""The test-kernel set — paper §5 (held out from fitting).

Four kernels, each with four size cases on a 2^{p+t} ladder:

  * Finite Differences — 5-point stencil + quadratic source on an n×n grid,
    tiled prefetch (halo) into local memory.
  * 'Skinny' Matrix Multiplication — tiled (n × m)(m × l) with n = l = m/8.
  * Convolution — three 7×7 filters applied to three n×n RGB images.
  * N-Body — sum of inverse distances between each of n positions and every
    other position (3×n column-major), block-prefetched.

Exactly as with the measurement kernels, property vectors are extracted
automatically from the jaxpr; tile/prefetch schedules contribute their
local-load/barrier/group properties via the helpers in ``mkernels``.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.core import properties as props
from repro.core.mkernels import (
    GSIZE, GROUP_1D, KernelCase, _rand, nbody_tile_props, stencil_tile_props,
    tiled_mm_props,
)


# ---------------------------------------------------------------------------
# 1. Finite differences (5-point stencil + quadratic source)
# ---------------------------------------------------------------------------


def _fd_kernel(u):
    """y = u_xx + u_yy (5-point) + u² source, interior points only."""
    c = u[1:-1, 1:-1]
    lap = (u[:-2, 1:-1] + u[2:, 1:-1] + u[1:-1, :-2] + u[1:-1, 2:]
           - 4.0 * c)
    return lap + c * c


def _fd_cases(p: int, key) -> List[KernelCase]:
    cases = []
    for t in range(4):
        n = 2 ** (p + t)
        k1, key = jax.random.split(key)
        u = _rand(k1, (n + 2, n + 2))
        cases.append(KernelCase(
            name=f"fd_{n}", klass="finite_difference",
            fn=_fd_kernel, args=(u,),
            extra_props=stencil_tile_props(n),
            meta={"n": n}))
    return cases


# ---------------------------------------------------------------------------
# 2. Skinny matrix multiplication (n = l = m/8)
# ---------------------------------------------------------------------------


def _skinny_cases(p: int, key) -> List[KernelCase]:
    cases = []
    for t in range(4):
        n = 2 ** (p + t)
        m = 8 * n
        k1, k2, key = jax.random.split(key, 3)
        a = _rand(k1, (n, m))
        b = _rand(k2, (m, n))
        cases.append(KernelCase(
            name=f"skinny_mm_{n}x{m}x{n}", klass="skinny_mm",
            fn=lambda a, b: a @ b, args=(a, b),
            extra_props=tiled_mm_props(n, m, n),
            meta={"n": n, "m": m, "l": n}))
    return cases


# ---------------------------------------------------------------------------
# 3. Convolution: three 7×7 filters × three n×n RGB images
# ---------------------------------------------------------------------------

_W = 3  # filter half-width (7 = 2w+1)


def _conv_kernel(imgs, filts):
    """imgs (3, n+2w, n+2w, 3[c]); filts (3, 7, 7, 3) -> r (3, 3, n, n).

    r[i,j,x,y] = Σ_{ξ,η,c} m[i, w+x-ξ, w+y-η, c] · f[j, w+ξ, w+η, c]
    (implemented as a sum of shifted slices — the literal stencil the GPU
    kernel runs, with a multiply-add per filter tap)."""
    n = imgs.shape[1] - 2 * _W
    acc = jnp.zeros((imgs.shape[0], filts.shape[0], n, n), jnp.float32)
    for dx in range(-_W, _W + 1):
        for dy in range(-_W, _W + 1):
            # m[i, w+x-dx, w+y-dy, c] — a shifted n×n window
            win = jax.lax.slice(
                imgs, (0, _W - dx, _W - dy, 0),
                (imgs.shape[0], _W - dx + n, _W - dy + n, imgs.shape[3]))
            tap = filts[:, _W + dx, _W + dy, :]  # (3 filters, 3 channels)
            acc = acc + jnp.einsum("ixyc,jc->ijxy", win, tap)
    return acc


def _conv_tile_props(n: int) -> dict:
    """Each gsize² tile prefetches interior+halo once per image; every tap
    reads image + filter values from local memory."""
    tiles = 3 * (n // GSIZE) ** 2  # per image
    taps = 49 * 3  # 7×7 × channels
    halo_cells = float(tiles * (4 * GSIZE * _W + 4 * _W * _W) * 3)
    return {
        props.mem_key("load", 32, "s1"): halo_cells,
        props.local_key(32): float(3 * 3 * n * n * taps * 2),  # img+filter reads
        props.BARRIER: float(tiles),
        props.GROUPS: float(tiles),
    }


def _conv_cases(p: int, key) -> List[KernelCase]:
    cases = []
    for t in range(4):
        n = 2 ** (p + t)
        k1, k2, key = jax.random.split(key, 3)
        imgs = _rand(k1, (3, n + 2 * _W, n + 2 * _W, 3))
        filts = _rand(k2, (3, 7, 7, 3))
        cases.append(KernelCase(
            name=f"conv_{n}", klass="convolution",
            fn=_conv_kernel, args=(imgs, filts),
            extra_props=_conv_tile_props(n),
            meta={"n": n}))
    return cases


# ---------------------------------------------------------------------------
# 4. N-Body (sum of inverse pairwise distances)
# ---------------------------------------------------------------------------


def _nbody_kernel(pos):
    """pos (3, n) -> (n,): Σ_j 1/‖x_i − x_j‖ (j ≠ i)."""
    d = pos[:, :, None] - pos[:, None, :]  # (3, n, n)
    r2 = jnp.sum(d * d, axis=0)  # (n, n)
    inv = jax.lax.rsqrt(r2 + 1e-12)
    n = pos.shape[1]
    inv = inv * (1.0 - jnp.eye(n, dtype=pos.dtype))
    return jnp.sum(inv, axis=1)


def _nbody_cases(p: int, key) -> List[KernelCase]:
    cases = []
    for t in range(4):
        n = 2 ** (p + t)
        k1, key = jax.random.split(key)
        pos = _rand(k1, (3, n))
        cases.append(KernelCase(
            name=f"nbody_{n}", klass="nbody",
            fn=_nbody_kernel, args=(pos,),
            extra_props=nbody_tile_props(n),
            meta={"n": n}))
    return cases


# ---------------------------------------------------------------------------
# Assembly (p per device scale, the paper's per-GPU p choice)
# ---------------------------------------------------------------------------

_P = {
    "cpu":  {"fd": 9, "skinny": 7, "conv": 7, "nbody": 10},
    "tiny": {"fd": 6, "skinny": 4, "conv": 4, "nbody": 6},
}


def test_cases(scale: str = "cpu", seed: int = 17) -> List[KernelCase]:
    P = _P[scale]
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    cases: List[KernelCase] = []
    cases += _fd_cases(P["fd"], ks[0])
    cases += _skinny_cases(P["skinny"], ks[1])
    cases += _conv_cases(P["conv"], ks[2])
    cases += _nbody_cases(P["nbody"], ks[3])
    return cases
