"""Array-batched search-space engine: score a whole (plan × mesh ×
block-size) candidate space in one vectorized pass.

The paper's payoff is that prediction is "a small inner product" — cheap
enough to sweep entire configuration spaces (§6.2).  ``predict_plans``
already batched the final ``A @ w``; this module batches everything
*upstream* of it, so a sweep of thousands of (plan, mesh-factorization)
cells runs as array ops end to end with no per-candidate Python:

  * candidate sets are struct-of-arrays (``PlanSpace``): parallel numpy
    arrays of dp/tp ways, device counts and microbatches next to the plan
    objects themselves;
  * step property vectors evaluate through the COMPILED
    ``predictor.step_vector_fn`` closures (``symcount.Expr.compile`` — the
    ≥10× fast path proven in the block-size autotuner), one call per
    distinct remat schedule with the microbatch column as an array env;
  * collective counts compile once per (kind, topology-class)
    (``archcount.collective_counts_symbolic``) with the mesh gates lowered
    to ``np.where`` over the DP/TP arrays;
  * HBM feasibility (``peak_bytes`` / ``feasible_mask``) is a single numpy
    pass over the candidate arrays, not a per-plan list comprehension.

Consumers: ``launch/autoshard.py`` (plan × mesh sweep + optional kernel
block co-tuning), ``distributed/elastic.replan`` and
``runtime/straggler.StragglerMonitor.from_model`` (both via
``predictor.predict_plans``, which routes here).

``benchmarks/search_bench.py`` times this engine against the per-plan
interpreted loop (``predictor.predict_plans_loop``) and records the
speedup in ``experiments/BENCH_search.json``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from math import prod
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import archcount
from repro.core import predictor
from repro.core import properties as props
from repro.core.lru import LRUCache

Mesh = Dict[str, int]
Cell = Tuple[object, Mapping[str, int]]  # (Plan, mesh_shape)

#: (cfg, kind, topology-class) -> CompiledVector over {B, S, M, DP, TP}.
#: Bounded: configs come and go (smoke variants, sweeps over reduced archs)
#: and each entry pins a whole ArchConfig, so evict beyond recent use.
_COLL_CV_CACHE: LRUCache = LRUCache(maxsize=128)


def _collective_vector_fn(cfg: ArchConfig, kind: str, topology):
    from repro.core.symcount import compile_vector
    key = (cfg, kind, topology)
    cv = _COLL_CV_CACHE.get(key)
    if cv is None:
        cv = compile_vector(
            archcount.collective_counts_symbolic(cfg, kind, topology))
        _COLL_CV_CACHE[key] = cv
    return cv


# ---------------------------------------------------------------------------
# Mesh-factorization space (promoted from distributed/elastic.py)
# ---------------------------------------------------------------------------


def factor_pairs(n: int) -> List[Tuple[int, int]]:
    """All ordered (a, b) with a·b == n — the 2-axis mesh factorizations."""
    out = []
    d = 1
    while d * d <= n:
        if n % d == 0:
            out.append((d, n // d))
            if d != n // d:
                out.append((n // d, d))
        d += 1
    return sorted(set(out))


def mesh_factorizations(n_devices: int,
                        axes: Tuple[str, str] = ("data", "model"),
                        max_candidates: Optional[int] = None) -> List[Mesh]:
    """Every 2-axis mesh shape with ``n_devices`` chips — the sweep space
    ``autoshard.search(n_devices=...)`` and ``elastic.replan`` score."""
    if len(axes) != 2:
        raise ValueError(f"mesh_factorizations is 2-axis; got {axes!r}")
    pairs = factor_pairs(n_devices)
    if max_candidates is not None:
        pairs = pairs[:max_candidates]
    return [{axes[0]: a, axes[1]: b} for a, b in pairs]


# ---------------------------------------------------------------------------
# The candidate space
# ---------------------------------------------------------------------------


def _axis_product(mesh: Mapping[str, int], axes) -> int:
    out = 1
    for ax in axes:
        out *= mesh.get(ax, 1)
    return out


def _group_indices(keys: Sequence) -> Dict[object, np.ndarray]:
    groups: Dict[object, List[int]] = {}
    for i, k in enumerate(keys):
        groups.setdefault(k, []).append(i)
    return {k: np.asarray(v, dtype=np.intp) for k, v in groups.items()}


def plan_sort_key(plan) -> tuple:
    """Deterministic, enumeration-order-free ordering of plans — the
    tie-break ``rank_plans`` / ``PlanSpace.rank`` apply after seconds."""
    return (plan.fsdp, plan.sequence_parallel, plan.microbatches,
            plan.remat_policy or "", plan.compression or "",
            plan.moe_mode, plan.dp_axes, plan.tp_axis or "",
            plan.cache_seq_axes)


def mesh_sort_key(mesh: Mapping[str, int]) -> tuple:
    return tuple(sorted(mesh.items()))


@dataclass
class PlanSpace:
    """A candidate set of (plan, mesh) cells as struct-of-arrays.

    ``plans[i]`` / ``mesh_shapes[i]`` describe cell *i*; the numpy columns
    (``dp``, ``tp``, ``n_dev``, ``microbatches``) are what the vectorized
    evaluators consume.  Build with ``from_cells`` / ``from_product``.
    """
    cfg: ArchConfig
    shape: ShapeConfig
    plans: List[object]
    mesh_shapes: List[Mesh]
    dp: np.ndarray            # data-parallel ways per cell (int64)
    tp: np.ndarray            # tensor-parallel ways per cell (int64)
    n_dev: np.ndarray         # total devices per cell (int64)
    microbatches: np.ndarray  # grad-accumulation chunks per cell (int64)
    #: optional precomputed cell-index groups (set by ``from_product``,
    #: which derives them from the small plan list instead of walking all
    #: n_plans × n_meshes cells): {group_key: (n_group_cells,) intp}
    remat_groups: Optional[Dict[object, np.ndarray]] = field(default=None)
    topo_groups: Optional[Dict[object, np.ndarray]] = field(default=None)

    # -- construction ------------------------------------------------------
    @classmethod
    def from_cells(cls, cfg: ArchConfig, shape: ShapeConfig,
                   cells: Sequence[Cell]) -> "PlanSpace":
        plans = [p for p, _ in cells]
        meshes = [dict(m) for _, m in cells]
        dp = np.asarray([_axis_product(m, p.dp_axes)
                         for p, m in zip(plans, meshes)], dtype=np.int64)
        tp = np.asarray([m.get(p.tp_axis, 1) if p.tp_axis else 1
                         for p, m in zip(plans, meshes)], dtype=np.int64)
        n_dev = np.asarray([max(prod(m.values()), 1) if m else 1
                            for m in meshes], dtype=np.int64)
        mb = np.asarray([p.microbatches for p in plans], dtype=np.int64)
        return cls(cfg=cfg, shape=shape, plans=plans, mesh_shapes=meshes,
                   dp=dp, tp=tp, n_dev=n_dev, microbatches=mb)

    @classmethod
    def from_product(cls, cfg: ArchConfig, shape: ShapeConfig,
                     plans: Sequence, meshes: Sequence[Mapping[str, int]]
                     ) -> "PlanSpace":
        """Plan-major cross product: cell (i·len(meshes) + j) = plan i on
        mesh j — so a single-mesh product keeps the plans' order.

        The struct-of-arrays columns come from ``np.repeat``/``np.tile``
        of the per-plan and per-mesh vectors — O(n_plans + n_meshes)
        Python, not O(n_cells) — and the evaluation groups (remat
        schedule, collective topology class) are computed on the plan
        list and expanded arithmetically."""
        plans = list(plans)
        meshes = [dict(m) for m in meshes]
        n_p, n_m = len(plans), len(meshes)
        mesh_ndev = np.asarray([max(prod(m.values()), 1) if m else 1
                                for m in meshes], dtype=np.int64)
        dp_rows: Dict[tuple, np.ndarray] = {}
        tp_rows: Dict[Optional[str], np.ndarray] = {}
        for p in plans:
            if p.dp_axes not in dp_rows:
                dp_rows[p.dp_axes] = np.asarray(
                    [_axis_product(m, p.dp_axes) for m in meshes],
                    dtype=np.int64)
            if p.tp_axis not in tp_rows:
                tp_rows[p.tp_axis] = np.asarray(
                    [m.get(p.tp_axis, 1) if p.tp_axis else 1
                     for m in meshes], dtype=np.int64)
        dp = np.concatenate([dp_rows[p.dp_axes] for p in plans]) \
            if n_p else np.zeros(0, dtype=np.int64)
        tp = np.concatenate([tp_rows[p.tp_axis] for p in plans]) \
            if n_p else np.zeros(0, dtype=np.int64)
        n_dev = np.tile(mesh_ndev, n_p)
        mb = np.repeat(np.asarray([p.microbatches for p in plans],
                                  dtype=np.int64), n_m)

        def expand(groups: Dict[object, np.ndarray]):
            j = np.arange(n_m, dtype=np.intp)
            return {k: (idx[:, None] * n_m + j).ravel()
                    for k, idx in groups.items()}

        remat = expand(_group_indices([p.remat_policy for p in plans]))
        topo = expand(_group_indices(
            [archcount.collective_topology(p) for p in plans]))
        return cls(cfg=cfg, shape=shape,
                   plans=[p for p in plans for _ in range(n_m)],
                   mesh_shapes=meshes * n_p,
                   dp=dp, tp=tp, n_dev=n_dev, microbatches=mb,
                   remat_groups=remat, topo_groups=topo)

    def __len__(self) -> int:
        return len(self.plans)

    def subset(self, idx) -> "PlanSpace":
        """Cells at ``idx`` (a boolean mask or an array of UNIQUE cell
        indices, in any order) as a new space."""
        idx = np.asarray(idx)
        if idx.dtype == bool:
            idx = np.nonzero(idx)[0]

        def remap(groups):
            # old cell index -> position in the subset (O(n) numpy), so a
            # feasibility-filtered space keeps its precomputed groups
            # instead of re-walking every surviving cell in Python
            if groups is None:
                return None
            pos = np.full(len(self), -1, dtype=np.intp)
            pos[idx] = np.arange(len(idx), dtype=np.intp)
            out = {}
            for k, g in groups.items():
                kept = pos[g]
                kept = kept[kept >= 0]
                if len(kept):
                    out[k] = kept
            return out

        return PlanSpace(
            cfg=self.cfg, shape=self.shape,
            plans=[self.plans[i] for i in idx],
            mesh_shapes=[self.mesh_shapes[i] for i in idx],
            dp=self.dp[idx], tp=self.tp[idx], n_dev=self.n_dev[idx],
            microbatches=self.microbatches[idx],
            remat_groups=remap(self.remat_groups),
            topo_groups=remap(self.topo_groups))

    # -- vectorized property assembly --------------------------------------
    def property_arrays(self) -> Dict[str, np.ndarray]:
        """The whole candidate set's property vectors as columns:
        ``{key: (n_cells,) float64}``.  Row i of the implied matrix equals
        ``predictor.plan_property_vector`` for cell i (absent keys = 0)."""
        n = len(self)
        kind = self.shape.kind
        B, S = self.shape.global_batch, self.shape.seq_len
        out: Dict[str, np.ndarray] = {}

        def acc(key: str, idx: np.ndarray, vals: np.ndarray) -> None:
            col = out.get(key)
            if col is None:
                col = np.zeros(n, dtype=np.float64)
                out[key] = col
            col[idx] += vals

        # step terms: one compiled evaluation per distinct remat schedule,
        # microbatches as an array env; compute/memory divide over the mesh
        remat_groups = self.remat_groups if self.remat_groups is not None \
            else _group_indices([p.remat_policy for p in self.plans])
        for remat, idx in remat_groups.items():
            cv = predictor.step_vector_fn(self.cfg, kind, remat)
            env = {"B": B, "S": S, "M": self.microbatches[idx]}
            for k, v in cv(env).items():
                v = np.broadcast_to(
                    np.asarray(v, dtype=np.float64), idx.shape)
                acc(k, idx, v / self.n_dev[idx])

        # collective terms: one compiled evaluation per topology class,
        # already per-device (DP/TP gates lowered to np.where)
        topo_groups = self.topo_groups if self.topo_groups is not None \
            else _group_indices(
                [archcount.collective_topology(p) for p in self.plans])
        for topo, idx in topo_groups.items():
            cv = _collective_vector_fn(self.cfg, kind, topo)
            env = {"B": B, "S": S, "M": self.microbatches[idx],
                   "DP": self.dp[idx], "TP": self.tp[idx]}
            for k, v in cv(env).items():
                acc(k, idx, np.broadcast_to(
                    np.asarray(v, dtype=np.float64), idx.shape))

        out[props.CONST1] = np.ones(n, dtype=np.float64)
        return out

    # -- scoring -----------------------------------------------------------
    def scores(self, model=None) -> np.ndarray:
        """Predicted step seconds for every cell — `<α, p>` as a weighted
        sum of property columns (identical to ``predict_many`` restricted
        to the model's keys, without materializing the dense matrix)."""
        m = predictor.resolve_model(model)
        arrs = self.property_arrays()
        total = np.zeros(len(self), dtype=np.float64)
        for key, w in zip(m.keys, m.weights):
            col = arrs.get(key)
            if col is not None and w:
                total += float(w) * col
        return total

    def rank(self, model=None) -> List[Tuple[float, object, Mesh]]:
        """All cells as (seconds, plan, mesh), ascending; ties broken on
        plan fields then mesh shape — never on enumeration order."""
        secs = self.scores(model)
        order = sorted(range(len(self)),
                       key=lambda i: (secs[i], plan_sort_key(self.plans[i]),
                                      mesh_sort_key(self.mesh_shapes[i])))
        return [(float(secs[i]), self.plans[i], self.mesh_shapes[i])
                for i in order]

    # -- feasibility -------------------------------------------------------
    def peak_bytes(self) -> np.ndarray:
        """Closed-form peak HBM bytes/device per cell, one numpy pass."""
        return _peak_bytes_soa(self.cfg, self.shape, self.plans,
                               self.dp, self.tp)

    def feasible_mask(self, budget: Optional[float] = None) -> np.ndarray:
        if budget is None:
            budget = predictor.HBM_BYTES
        return self.peak_bytes() <= budget


# ---------------------------------------------------------------------------
# Vectorized HBM feasibility (the predictor's napkin math, column-wise)
# ---------------------------------------------------------------------------


def _peak_bytes_soa(cfg: ArchConfig, shape: ShapeConfig, plans: Sequence,
                    dp: np.ndarray, tp: np.ndarray) -> np.ndarray:
    """``predictor.estimate_peak_bytes`` over candidate arrays.  The plan
    booleans become masks, the mesh ways are the dp/tp columns, and every
    branch of the scalar formula lowers to ``np.where`` — the scalar
    version delegates here with single-element arrays, so there is exactly
    one copy of the napkin math."""
    dp = np.asarray(dp, dtype=np.float64)
    tp = np.asarray(tp, dtype=np.float64)
    # dtype=bool: an empty list would otherwise default to float64 and
    # break the mask arithmetic below
    fsdp = np.asarray([bool(p.fsdp) for p in plans], dtype=bool)
    sp = np.asarray([bool(p.sequence_parallel) for p in plans], dtype=bool)
    mb = np.asarray([max(p.microbatches, 1) for p in plans],
                    dtype=np.float64)

    P = cfg.n_params()
    bytes_p = 2 if "16" in cfg.param_dtype else 4
    pshard = tp * np.where(fsdp, dp, 1.0)
    total = P * bytes_p / pshard

    if shape.kind == "train":
        opt_bytes = {"adamw": 8.0, "adafactor": 0.1,
                     "sgd": 4.0}[cfg.optimizer]
        total += P * opt_bytes / pshard           # optimizer state
        total += P * 4.0 / pshard                 # f32 grads (transient)
        # scan-over-layers gathers ONE layer's shard at a time (FSDP)
        total += np.where(fsdp & (dp > 1),
                          P * bytes_p / (tp * max(cfg.n_layers, 1)), 0.0)
        Bm = shape.global_batch / mb
        tok = Bm * shape.seq_len / dp
        act_shard = np.where(sp, tp, 1.0)
        saves_by = {"full": 1.0, "nothing": 1.0, "dots": 4.0,
                    "none": 10.0, None: 1.0}
        saves = np.asarray(
            [saves_by[p.remat_policy or cfg.remat_policy] for p in plans],
            dtype=np.float64)
        total += saves * cfg.n_layers * tok * cfg.d_model * 2 / act_shard
        total += 12.0 * tok * cfg.d_model * 2 / act_shard  # live layer
        # logits in f32 for the loss
        total += tok * cfg.vocab_size * cfg.n_output_heads * 4 / tp
    elif shape.kind == "prefill":
        tok = shape.global_batch * shape.seq_len / dp
        total += 16.0 * tok * cfg.d_model * 2 / np.where(sp, tp, 1.0)
        total += tok * cfg.vocab_size * cfg.n_output_heads * 2 / tp
    else:  # decode: KV/SSM caches dominate
        Bd = shape.global_batch / dp
        if cfg.n_heads:
            has_cs = np.asarray([bool(p.cache_seq_axes) for p in plans],
                                dtype=bool)
            ctx = min(shape.seq_len, cfg.sliding_window or shape.seq_len)
            n_attn = (cfg.n_layers // cfg.hybrid.attn_every
                      if cfg.family == "hybrid" else cfg.n_layers)
            kv_shard = np.where(has_cs, tp,
                                np.minimum(tp, cfg.n_kv_heads))
            total += (2 * Bd * ctx * cfg.n_kv_heads * cfg.head_dim_
                      * 2 * n_attn) / kv_shard
        if cfg.ssm is not None:
            total += (cfg.n_layers * Bd * cfg.ssm_heads * cfg.ssm.head_dim
                      * cfg.ssm.d_state * 4) / np.minimum(tp, cfg.ssm_heads)
    return np.asarray(total, dtype=np.float64)


def peak_bytes(cfg: ArchConfig, shape: ShapeConfig, plans: Sequence,
               mesh_shapes: Sequence[Mapping[str, int]]) -> np.ndarray:
    """Peak HBM bytes/device for parallel (plan, mesh) candidate lists."""
    dp = np.asarray([_axis_product(m, p.dp_axes)
                     for p, m in zip(plans, mesh_shapes)], dtype=np.int64)
    tp = np.asarray([m.get(p.tp_axis, 1) if p.tp_axis else 1
                     for p, m in zip(plans, mesh_shapes)], dtype=np.int64)
    return _peak_bytes_soa(cfg, shape, plans, dp, tp)


# ---------------------------------------------------------------------------
# Joint plan × kernel-block co-tuning
# ---------------------------------------------------------------------------


def cotune_kernel_blocks(cfg: ArchConfig, shape: ShapeConfig, plan,
                         mesh_shape: Mapping[str, int], model=None
                         ) -> Dict[str, Dict[str, int]]:
    """Model-chosen block sizes for the step's dominant kernels at this
    (plan, mesh) cell's *per-device* shard shapes — the joint plan × block
    co-tuning hook, reusing ``kernels/autotune.py``'s compiled grids."""
    from repro.kernels import autotune
    dp = _axis_product(mesh_shape, plan.dp_axes)
    tp = mesh_shape.get(plan.tp_axis, 1) if plan.tp_axis else 1
    bits = 16 if "16" in cfg.compute_dtype else 32
    if shape.kind == "decode":
        tok = max(shape.global_batch // dp, 1)
        b_dev = tok
    else:
        b_dev = max(shape.global_batch // (dp * max(plan.microbatches, 1)),
                    1)
        tok = b_dev * shape.seq_len

    out: Dict[str, Dict[str, int]] = {}
    if cfg.d_ff:
        out["matmul"] = autotune.best_block_sizes(
            "matmul", {"M": tok, "N": max(cfg.d_ff // tp, 1),
                       "K": cfg.d_model, "bits": bits}, model)
    if cfg.n_heads and shape.kind != "decode":
        out["flash_attention"] = autotune.best_block_sizes(
            "flash_attention",
            {"B": b_dev, "H": max(cfg.n_heads // tp, 1),
             "KVH": max(cfg.n_kv_heads // tp, 1),
             "Sq": shape.seq_len, "Skv": shape.seq_len,
             "dh": cfg.head_dim_, "causal": True,
             "window": cfg.sliding_window, "bits": bits}, model)
    if cfg.ssm is not None and shape.kind != "decode":
        out["ssd_scan"] = autotune.best_block_sizes(
            "ssd_scan",
            {"Bz": b_dev, "H": max(cfg.ssm_heads // tp, 1),
             "L": shape.seq_len, "P": cfg.ssm.head_dim,
             "N": cfg.ssm.d_state, "bits": bits}, model)
    return out
