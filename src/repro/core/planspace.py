"""Array-batched search-space engine: score a whole (plan × mesh ×
block-size) candidate space in one vectorized pass.

The paper's payoff is that prediction is "a small inner product" — cheap
enough to sweep entire configuration spaces (§6.2).  ``predict_plans``
already batched the final ``A @ w``; this module batches everything
*upstream* of it, so a sweep of thousands of (plan, mesh-factorization)
cells runs as array ops end to end with no per-candidate Python:

  * candidate sets are struct-of-arrays (``PlanSpace``): parallel numpy
    arrays of dp/tp ways, device counts and microbatches next to the plan
    objects themselves;
  * step property vectors evaluate through the COMPILED
    ``predictor.step_vector_fn`` closures (``symcount.Expr.compile`` — the
    ≥10× fast path proven in the block-size autotuner), one call per
    distinct remat schedule with the microbatch column as an array env;
  * collective counts compile once per (kind, topology-class)
    (``archcount.collective_counts_symbolic``) with the mesh gates lowered
    to ``np.where`` over the DP/TP arrays;
  * HBM feasibility (``peak_bytes`` / ``feasible_mask``) is a single numpy
    pass over the candidate arrays, not a per-plan list comprehension.

Consumers: ``launch/autoshard.py`` (plan × mesh sweep + optional kernel
block co-tuning), ``distributed/elastic.replan`` and
``runtime/straggler.StragglerMonitor.from_model`` (both via
``predictor.predict_plans``, which routes here).

``benchmarks/search_bench.py`` times this engine against the per-plan
interpreted loop (``predictor.predict_plans_loop``) and records the
speedup in ``experiments/BENCH_search.json``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from math import prod
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import archcount
from repro.core import exprops
from repro.core import predictor
from repro.core import properties as props
from repro.core import workload as wl
from repro.core.lru import LRUCache
from repro.core.workload import WorkloadSpec
from repro.obs import trace as _obs_trace

Mesh = Dict[str, int]
Cell = Tuple[object, Mapping[str, int]]  # (Plan, mesh_shape)

#: (cfg, kind, topology-class) -> CompiledVector over {B, S, M, DP, TP}.
#: Bounded: configs come and go (smoke variants, sweeps over reduced archs)
#: and each entry pins a whole ArchConfig, so evict beyond recent use.
_COLL_CV_CACHE: LRUCache = LRUCache(maxsize=128)

#: (cfg, kind, topology-class) -> exprops.BasisProgram (the fused form).
_COLL_PROG_CACHE: LRUCache = LRUCache(maxsize=128)


def _collective_vector_fn(cfg: ArchConfig, kind: str, topology):
    from repro.core.symcount import compile_vector
    key = (cfg, kind, topology)
    cv = _COLL_CV_CACHE.get(key)
    if cv is None:
        cv = compile_vector(
            archcount.collective_counts_symbolic(cfg, kind, topology))
        _COLL_CV_CACHE[key] = cv
    return cv


def _collective_program(cfg: ArchConfig, kind: str, topology):
    """Fused basis program for one (kind, topology-class): the symbolic
    collectives canonicalized + CSE'd into one GEMV scorer, persisted in
    the on-disk compile cache like the step programs."""
    key = (cfg, kind, topology)
    prog = _COLL_PROG_CACHE.get(key)
    if prog is None:
        dk = exprops.program_key("coll", cfg, kind, topology)
        prog = exprops.load_or_build(
            dk, lambda: archcount.collective_counts_symbolic(cfg, kind,
                                                             topology))
        _COLL_PROG_CACHE[key] = prog
    return prog


# ---------------------------------------------------------------------------
# Mesh-factorization space (promoted from distributed/elastic.py)
# ---------------------------------------------------------------------------


def factor_pairs(n: int) -> List[Tuple[int, int]]:
    """All ordered (a, b) with a·b == n — the 2-axis mesh factorizations."""
    out = []
    d = 1
    while d * d <= n:
        if n % d == 0:
            out.append((d, n // d))
            if d != n // d:
                out.append((n // d, d))
        d += 1
    return sorted(set(out))


def mesh_factorizations(n_devices: int,
                        axes: Tuple[str, str] = ("data", "model"),
                        max_candidates: Optional[int] = None) -> List[Mesh]:
    """Every 2-axis mesh shape with ``n_devices`` chips — the sweep space
    ``autoshard.search(n_devices=...)`` and ``elastic.replan`` score."""
    if len(axes) != 2:
        raise ValueError(f"mesh_factorizations is 2-axis; got {axes!r}")
    pairs = factor_pairs(n_devices)
    if max_candidates is not None:
        pairs = pairs[:max_candidates]
    return [{axes[0]: a, axes[1]: b} for a, b in pairs]


# ---------------------------------------------------------------------------
# The candidate space
# ---------------------------------------------------------------------------


def _axis_product(mesh: Mapping[str, int], axes) -> int:
    out = 1
    for ax in axes:
        out *= mesh.get(ax, 1)
    return out


def _group_indices(keys: Sequence) -> Dict[object, np.ndarray]:
    groups: Dict[object, List[int]] = {}
    for i, k in enumerate(keys):
        groups.setdefault(k, []).append(i)
    return {k: np.asarray(v, dtype=np.intp) for k, v in groups.items()}


def plan_sort_key(plan) -> tuple:
    """Deterministic, enumeration-order-free ordering of plans — the
    tie-break ``rank_plans`` / ``PlanSpace.rank`` apply after seconds."""
    return (plan.fsdp, plan.sequence_parallel, plan.microbatches,
            plan.remat_policy or "", plan.compression or "",
            plan.moe_mode, plan.dp_axes, plan.tp_axis or "",
            plan.cache_seq_axes)


def mesh_sort_key(mesh: Mapping[str, int]) -> tuple:
    return tuple(sorted(mesh.items()))


def _key_column(objs: Sequence, keyfn) -> np.ndarray:
    """Sort-key tuples → an int64 ordinal column whose numeric order is the
    tuples' lexicographic order (equal tuples ⇒ equal ordinals) — what lets
    ``np.lexsort`` replace a Python tuple-key sort.  Key computation is
    memoized per object identity: candidate spaces repeat a small set of
    plan/mesh objects across many cells."""
    memo: Dict[int, tuple] = {}
    keys = []
    for o in objs:
        k = memo.get(id(o))
        if k is None:
            k = keyfn(o)
            memo[id(o)] = k
        keys.append(k)
    rank = {k: i for i, k in enumerate(sorted(set(keys)))}
    return np.asarray([rank[k] for k in keys], dtype=np.int64)


def _rank_order(secs: np.ndarray, plans: Sequence,
                meshes: Sequence[Mapping[str, int]]) -> np.ndarray:
    """The ``rank`` ordering as one vectorized ``np.lexsort`` over
    (seconds, plan-key ordinal, mesh-key ordinal) — identical to sorting
    with ``key=lambda i: (secs[i], plan_sort_key(...), mesh_sort_key(...))``
    and pinned against that reference in tests."""
    return np.lexsort((_key_column(meshes, mesh_sort_key),
                       _key_column(plans, plan_sort_key),
                       secs))


@dataclass
class _ProductInfo:
    """The factored structure of a ``from_product`` space — what lets the
    fused scorer evaluate per (plan-profile × mesh) instead of per cell.

    A product space's environment columns are rank-1: every step-term row
    repeats one of ``n_plans`` microbatch counts, every collective row is
    one of a handful of (microbatches, dp-axes, tp-axis) *profiles* crossed
    with the mesh list.  Scoring therefore needs one program evaluation of
    size ≈ n_profiles·n_meshes per group, expanded to cells by
    repeat/tile-shaped gathers — the basis matrix never reaches n_cells
    rows."""
    n_m: int
    mesh_ndev: np.ndarray                     # (n_m,)
    dp_rows: Dict[tuple, np.ndarray]          # dp_axes -> (n_m,)
    tp_rows: Dict[Optional[str], np.ndarray]  # tp_axis -> (n_m,)
    plan_mb: np.ndarray                       # (n_p,)
    plan_dp_axes: List[tuple]
    plan_tp_axis: List[Optional[str]]
    remat_plan_groups: Dict[object, np.ndarray]  # PLAN (not cell) indices
    topo_plan_groups: Dict[object, np.ndarray]
    #: lazily built evaluation structure (model-independent): see
    #: ``step_envs`` / ``topo_envs``
    _step_envs: Optional[list] = field(default=None, repr=False)
    _topo_envs: Optional[tuple] = field(default=None, repr=False)

    def step_envs(self) -> list:
        """[(remat, plan-idx array, unique microbatches, inverse)] — the
        distinct step environments per remat schedule."""
        if self._step_envs is None:
            out = []
            for remat, pidx in self.remat_plan_groups.items():
                mbs = self.plan_mb[pidx].tolist()
                umb = sorted(set(mbs))
                pos = {v: i for i, v in enumerate(umb)}
                inv = np.asarray([pos[v] for v in mbs], dtype=np.intp)
                out.append((remat, pidx, np.asarray(umb, dtype=np.int64),
                            inv))
            self._step_envs = out
        return self._step_envs

    def topo_envs(self) -> tuple:
        """(per-group [(topo, n_prof, M, DP, TP columns)], global plan →
        profile-row index) — the (profile × mesh) collective environments,
        rows concatenated across topology groups."""
        if self._topo_envs is None:
            n_m = self.n_m
            mb_l = self.plan_mb.tolist()
            prof_row = np.empty(len(mb_l), dtype=np.intp)
            groups = []
            base = 0
            for topo, pidx in self.topo_plan_groups.items():
                profiles: Dict[tuple, int] = {}
                envs: List[tuple] = []
                for p in pidx.tolist():
                    key = (mb_l[p], self.plan_dp_axes[p],
                           self.plan_tp_axis[p])
                    k = profiles.get(key)
                    if k is None:
                        k = profiles[key] = len(envs)
                        envs.append(key)
                    prof_row[p] = base + k
                n_prof = len(envs)
                Mc = np.empty(n_prof * n_m, dtype=np.int64)
                DPc = np.empty(n_prof * n_m, dtype=np.int64)
                TPc = np.empty(n_prof * n_m, dtype=np.int64)
                for k, (mb, dpa, tpa) in enumerate(envs):
                    sl = slice(k * n_m, (k + 1) * n_m)
                    Mc[sl] = mb
                    DPc[sl] = self.dp_rows[dpa]
                    TPc[sl] = self.tp_rows[tpa]
                groups.append((topo, n_prof, Mc, DPc, TPc))
                base += n_prof
            self._topo_envs = (groups, prof_row, base)
        return self._topo_envs


@dataclass
class PlanSpace:
    """A candidate set of (plan, mesh) cells as struct-of-arrays.

    ``plans[i]`` / ``mesh_shapes[i]`` describe cell *i*; the numpy columns
    (``dp``, ``tp``, ``n_dev``, ``microbatches``) are what the vectorized
    evaluators consume.  Build with ``from_cells`` / ``from_product`` —
    both accept any ``workload.WorkloadLike`` (a ``WorkloadSpec``, a
    ``ShapeConfig``, or the deprecated phase string) and normalize it.
    """
    cfg: ArchConfig
    workload: WorkloadSpec
    plans: List[object]
    mesh_shapes: List[Mesh]
    dp: np.ndarray            # data-parallel ways per cell (int64)
    tp: np.ndarray            # tensor-parallel ways per cell (int64)
    n_dev: np.ndarray         # total devices per cell (int64)
    microbatches: np.ndarray  # grad-accumulation chunks per cell (int64)
    #: optional precomputed cell-index groups (set by ``from_product``,
    #: which derives them from the small plan list instead of walking all
    #: n_plans × n_meshes cells): {group_key: (n_group_cells,) intp}
    remat_groups: Optional[Dict[object, np.ndarray]] = field(default=None)
    topo_groups: Optional[Dict[object, np.ndarray]] = field(default=None)
    #: set by ``from_product`` only; ``subset`` drops it (a filtered space
    #: loses the rank-1 structure) and the scorers fall back to the generic
    #: unique-row path
    product: Optional[_ProductInfo] = field(default=None, repr=False)
    #: per-space memo of the group → BasisProgram lookups (saves re-hashing
    #: the frozen ArchConfig key on every repeat ``scores`` call)
    _progs: Dict[object, object] = field(default_factory=dict, repr=False)

    @property
    def shape(self) -> WorkloadSpec:
        """Backward-compat alias: the workload duck-types the old
        ``ShapeConfig`` attribute surface (``kind``/``global_batch``/
        ``seq_len``)."""
        return self.workload

    def _group_program(self, group_key, remat) -> object:
        prog = self._progs.get(group_key)
        if prog is None:
            if group_key[0] == "step":
                prog = predictor.step_program(self.cfg, self.workload,
                                              remat)
            else:
                prog = _collective_program(self.cfg, self.workload.phase,
                                           remat)
            self._progs[group_key] = prog
        return prog

    # -- construction ------------------------------------------------------
    @classmethod
    def from_cells(cls, cfg: ArchConfig, workload: wl.WorkloadLike,
                   cells: Sequence[Cell]) -> "PlanSpace":
        spec = wl.as_spec(workload)
        plans = [p for p, _ in cells]
        meshes = [dict(m) for _, m in cells]
        dp = np.asarray([_axis_product(m, p.dp_axes)
                         for p, m in zip(plans, meshes)], dtype=np.int64)
        tp = np.asarray([m.get(p.tp_axis, 1) if p.tp_axis else 1
                         for p, m in zip(plans, meshes)], dtype=np.int64)
        n_dev = np.asarray([max(prod(m.values()), 1) if m else 1
                            for m in meshes], dtype=np.int64)
        mb = np.asarray([p.microbatches for p in plans], dtype=np.int64)
        return cls(cfg=cfg, workload=spec, plans=plans, mesh_shapes=meshes,
                   dp=dp, tp=tp, n_dev=n_dev, microbatches=mb)

    @classmethod
    def from_product(cls, cfg: ArchConfig, workload: wl.WorkloadLike,
                     plans: Sequence, meshes: Sequence[Mapping[str, int]]
                     ) -> "PlanSpace":
        """Plan-major cross product: cell (i·len(meshes) + j) = plan i on
        mesh j — so a single-mesh product keeps the plans' order.

        The struct-of-arrays columns come from ``np.repeat``/``np.tile``
        of the per-plan and per-mesh vectors — O(n_plans + n_meshes)
        Python, not O(n_cells) — and the evaluation groups (remat
        schedule, collective topology class) are computed on the plan
        list and expanded arithmetically."""
        spec = wl.as_spec(workload)
        plans = list(plans)
        meshes = [dict(m) for m in meshes]
        n_p, n_m = len(plans), len(meshes)
        mesh_ndev = np.asarray([max(prod(m.values()), 1) if m else 1
                                for m in meshes], dtype=np.int64)
        dp_rows: Dict[tuple, np.ndarray] = {}
        tp_rows: Dict[Optional[str], np.ndarray] = {}
        for p in plans:
            if p.dp_axes not in dp_rows:
                dp_rows[p.dp_axes] = np.asarray(
                    [_axis_product(m, p.dp_axes) for m in meshes],
                    dtype=np.int64)
            if p.tp_axis not in tp_rows:
                tp_rows[p.tp_axis] = np.asarray(
                    [m.get(p.tp_axis, 1) if p.tp_axis else 1
                     for m in meshes], dtype=np.int64)
        dp = np.concatenate([dp_rows[p.dp_axes] for p in plans]) \
            if n_p else np.zeros(0, dtype=np.int64)
        tp = np.concatenate([tp_rows[p.tp_axis] for p in plans]) \
            if n_p else np.zeros(0, dtype=np.int64)
        n_dev = np.tile(mesh_ndev, n_p)
        plan_mb = np.asarray([p.microbatches for p in plans],
                             dtype=np.int64)
        mb = np.repeat(plan_mb, n_m)

        def expand(groups: Dict[object, np.ndarray]):
            j = np.arange(n_m, dtype=np.intp)
            return {k: (idx[:, None] * n_m + j).ravel()
                    for k, idx in groups.items()}
        remat_p = _group_indices([p.remat_policy for p in plans])
        topo_p = _group_indices(
            [archcount.collective_topology(p) for p in plans])
        info = _ProductInfo(
            n_m=n_m, mesh_ndev=mesh_ndev, dp_rows=dp_rows, tp_rows=tp_rows,
            plan_mb=plan_mb,
            plan_dp_axes=[p.dp_axes for p in plans],
            plan_tp_axis=[p.tp_axis for p in plans],
            remat_plan_groups=remat_p, topo_plan_groups=topo_p)
        return cls(cfg=cfg, workload=spec,
                   plans=[p for p in plans for _ in range(n_m)],
                   mesh_shapes=meshes * n_p,
                   dp=dp, tp=tp, n_dev=n_dev, microbatches=mb,
                   remat_groups=expand(remat_p), topo_groups=expand(topo_p),
                   product=info)

    def __len__(self) -> int:
        return len(self.plans)

    def subset(self, idx) -> "PlanSpace":
        """Cells at ``idx`` (a boolean mask or an array of UNIQUE cell
        indices, in any order) as a new space."""
        idx = np.asarray(idx)
        if idx.dtype == bool:
            idx = np.nonzero(idx)[0]

        def remap(groups):
            # old cell index -> position in the subset (O(n) numpy), so a
            # feasibility-filtered space keeps its precomputed groups
            # instead of re-walking every surviving cell in Python
            if groups is None:
                return None
            pos = np.full(len(self), -1, dtype=np.intp)
            pos[idx] = np.arange(len(idx), dtype=np.intp)
            out = {}
            for k, g in groups.items():
                kept = pos[g]
                kept = kept[kept >= 0]
                if len(kept):
                    out[k] = kept
            return out

        return PlanSpace(
            cfg=self.cfg, workload=self.workload,
            plans=[self.plans[i] for i in idx],
            mesh_shapes=[self.mesh_shapes[i] for i in idx],
            dp=self.dp[idx], tp=self.tp[idx], n_dev=self.n_dev[idx],
            microbatches=self.microbatches[idx],
            remat_groups=remap(self.remat_groups),
            topo_groups=remap(self.topo_groups))

    # -- vectorized property assembly --------------------------------------
    def property_arrays(self) -> Dict[str, np.ndarray]:
        """The whole candidate set's property vectors as columns:
        ``{key: (n_cells,) float64}``.  Row i of the implied matrix equals
        ``predictor.plan_property_vector`` for cell i (absent keys = 0)."""
        n = len(self)
        base_env = self.workload.env(self.cfg)
        out: Dict[str, np.ndarray] = {}

        def acc(key: str, idx: np.ndarray, vals: np.ndarray) -> None:
            col = out.get(key)
            if col is None:
                col = np.zeros(n, dtype=np.float64)
                out[key] = col
            col[idx] += vals

        # step terms: one compiled evaluation per distinct remat schedule,
        # microbatches as an array env; compute/memory divide over the mesh
        remat_groups = self.remat_groups if self.remat_groups is not None \
            else _group_indices([p.remat_policy for p in self.plans])
        for remat, idx in remat_groups.items():
            cv = predictor.step_vector_fn(self.cfg, self.workload, remat)
            env = {**base_env, "M": self.microbatches[idx]}
            for k, v in cv(env).items():
                v = np.broadcast_to(
                    np.asarray(v, dtype=np.float64), idx.shape)
                acc(k, idx, v / self.n_dev[idx])

        # collective terms: one compiled evaluation per topology class,
        # already per-device (DP/TP gates lowered to np.where)
        topo_groups = self.topo_groups if self.topo_groups is not None \
            else _group_indices(
                [archcount.collective_topology(p) for p in self.plans])
        for topo, idx in topo_groups.items():
            cv = _collective_vector_fn(self.cfg, self.workload.phase, topo)
            env = {**base_env, "M": self.microbatches[idx],
                   "DP": self.dp[idx], "TP": self.tp[idx]}
            for k, v in cv(env).items():
                acc(k, idx, np.broadcast_to(
                    np.asarray(v, dtype=np.float64), idx.shape))

        out[props.CONST1] = np.ones(n, dtype=np.float64)
        return out

    # -- scoring -----------------------------------------------------------
    def scores(self, model=None, cache=None) -> np.ndarray:
        """Predicted step seconds for every cell, through the FUSED basis
        programs (``core.exprops``): per evaluation group the model's
        weights fold through the program's coefficient matrix into one
        per-term vector, the deduped basis terms evaluate once per UNIQUE
        environment row, and the group scores as a single GEMV — `<α, p>`
        with the linearity exploited end to end.  ``cache`` (an
        ``exprops.BasisCache``) switches to incremental per-column
        evaluation for warm rescores.  ``scores_columns`` is the per-key
        column path this is pinned against (rtol ≤ 1e-9)."""
        tr = _obs_trace.get_tracer()
        if tr.enabled:      # one span per sweep; off = one attribute check
            with tr.span("planspace.scores", cells=len(self),
                         phase=self.workload.phase,
                         cached=cache is not None):
                return self._scores(model, cache)
        return self._scores(model, cache)

    def _scores(self, model=None, cache=None) -> np.ndarray:
        m = predictor.resolve_model(model)
        n = len(self)
        base_env = self.workload.env(self.cfg)
        w1 = 0.0
        for k, w in zip(m.keys, m.weights):
            if k == props.CONST1:
                w1 = float(w)
        total = np.full(n, w1, dtype=np.float64)
        if not n:
            return total
        if self.product is not None and cache is None:
            return self._scores_product(m, total)

        remat_groups = self.remat_groups if self.remat_groups is not None \
            else _group_indices([p.remat_policy for p in self.plans])
        for remat, idx in remat_groups.items():
            prog = predictor.step_program(self.cfg, self.workload, remat)
            env = {**base_env, "M": self.microbatches[idx]}
            s = exprops.score_cells(prog, env, len(idx), m, cache)
            total[idx] += s / self.n_dev[idx]   # SPMD work division

        topo_groups = self.topo_groups if self.topo_groups is not None \
            else _group_indices(
                [archcount.collective_topology(p) for p in self.plans])
        for topo, idx in topo_groups.items():
            prog = _collective_program(self.cfg, self.workload.phase, topo)
            env = {**base_env, "M": self.microbatches[idx],
                   "DP": self.dp[idx], "TP": self.tp[idx]}
            total[idx] += exprops.score_cells(prog, env, len(idx), m, cache)
        return total

    def _scores_product(self, m, total: np.ndarray) -> np.ndarray:
        """The ``from_product`` fast path: the env columns are rank-1
        (plan-profile × mesh), so each group's basis matrix is evaluated at
        profile granularity — distinct microbatch counts for the step
        terms, (microbatches, dp-axes, tp-axis) profiles × meshes for the
        collectives — and the cell scores assemble as ONE outer-product
        expression over the (n_plans, n_meshes) grid.  n_cells never
        enters a program evaluation."""
        pi = self.product
        base_env = self.workload.env(self.cfg)
        n_m = pi.n_m
        n_p = len(pi.plan_mb)

        # step terms: one evaluation per DISTINCT microbatch per schedule
        s_plan = np.zeros(n_p, dtype=np.float64)
        for remat, pidx, umb, inv in pi.step_envs():
            prog = self._group_program(("step", remat), remat)
            s = np.asarray(prog.score({**base_env, "M": umb}, m),
                           dtype=np.float64)
            if s.shape != umb.shape:
                s = np.broadcast_to(s, umb.shape)
            s_plan[pidx] = s[inv]

        # collective terms: rows of a (profiles, n_m) matrix; each plan
        # points at its profile's row
        groups, prof_row, n_rows = pi.topo_envs()
        S_rows = np.empty((n_rows, n_m), dtype=np.float64)
        base = 0
        for topo, n_prof, Mc, DPc, TPc in groups:
            prog = self._group_program(("coll", topo), topo)
            s = np.asarray(prog.score(
                {**base_env, "M": Mc, "DP": DPc, "TP": TPc}, m),
                dtype=np.float64)
            if s.shape != (n_prof * n_m,):
                s = np.broadcast_to(s, (n_prof * n_m,))
            S_rows[base:base + n_prof] = s.reshape(n_prof, n_m)
            base += n_prof

        # one outer-product assembly for the whole grid (total carries the
        # const1 launch weight already; cells are plan-major)
        grid = s_plan[:, None] / pi.mesh_ndev
        if n_rows:
            grid += S_rows[prof_row]
        total += grid.ravel()
        return total

    def scores_columns(self, model=None) -> np.ndarray:
        """Reference scorer: per-key weighted sum over ``property_arrays``
        (the PR 3 column engine).  Semantically identical to ``scores``;
        kept as the oracle the fused-GEMV path is tested against and the
        named baseline ``benchmarks/fused_bench.py`` times it over."""
        m = predictor.resolve_model(model)
        arrs = self.property_arrays()
        total = np.zeros(len(self), dtype=np.float64)
        for key, w in zip(m.keys, m.weights):
            col = arrs.get(key)
            if col is not None and w:
                total += float(w) * col
        return total

    def rank(self, model=None, top_k: Optional[int] = None
             ) -> List[Tuple[float, object, Mesh]]:
        """Cells as (seconds, plan, mesh), ascending; ties broken on plan
        fields then mesh shape — never on enumeration order.  The ordering
        is one ``np.lexsort`` over (seconds, plan-key ordinal, mesh-key
        ordinal) columns; ``top_k`` takes the ``np.argpartition`` fast
        path (tie-closed at the k-th score, so the result is exactly the
        full ranking's prefix)."""
        secs = self.scores(model)
        n = len(self)
        idx = np.arange(n, dtype=np.intp)
        if top_k is not None:
            if top_k <= 0:
                return []
            if top_k < n:
                part = np.argpartition(secs, top_k - 1)[:top_k]
                # close over ties at the boundary so the full sort's
                # plan/mesh tie-breaks stay authoritative
                idx = np.nonzero(secs <= secs[part].max())[0]
        order = idx[_rank_order(secs[idx],
                                [self.plans[i] for i in idx],
                                [self.mesh_shapes[i] for i in idx])]
        if top_k is not None:
            order = order[:top_k]
        return [(float(secs[i]), self.plans[i], self.mesh_shapes[i])
                for i in order]

    # -- feasibility -------------------------------------------------------
    def peak_bytes(self) -> np.ndarray:
        """Closed-form peak HBM bytes/device per cell, one numpy pass."""
        return _peak_bytes_soa(self.cfg, self.workload, self.plans,
                               self.dp, self.tp)

    def feasible_mask(self, budget: Optional[float] = None) -> np.ndarray:
        if budget is None:
            budget = predictor.HBM_BYTES
        return self.peak_bytes() <= budget


# ---------------------------------------------------------------------------
# Vectorized HBM feasibility (the predictor's napkin math, column-wise)
# ---------------------------------------------------------------------------


def _peak_bytes_soa(cfg: ArchConfig, shape, plans: Sequence,
                    dp: np.ndarray, tp: np.ndarray) -> np.ndarray:
    """``predictor.estimate_peak_bytes`` over candidate arrays.  The plan
    booleans become masks, the mesh ways are the dp/tp columns, and every
    branch of the scalar formula lowers to ``np.where`` — the scalar
    version delegates here with single-element arrays, so there is exactly
    one copy of the napkin math.  ``shape`` is anything exposing
    ``kind``/``global_batch``/``seq_len`` (a ``WorkloadSpec`` or a
    ``ShapeConfig``)."""
    dp = np.asarray(dp, dtype=np.float64)
    tp = np.asarray(tp, dtype=np.float64)
    # dtype=bool: an empty list would otherwise default to float64 and
    # break the mask arithmetic below
    fsdp = np.asarray([bool(p.fsdp) for p in plans], dtype=bool)
    sp = np.asarray([bool(p.sequence_parallel) for p in plans], dtype=bool)
    mb = np.asarray([max(p.microbatches, 1) for p in plans],
                    dtype=np.float64)

    P = cfg.n_params()
    bytes_p = 2 if "16" in cfg.param_dtype else 4
    pshard = tp * np.where(fsdp, dp, 1.0)
    total = P * bytes_p / pshard

    if shape.kind == "train":
        opt_bytes = {"adamw": 8.0, "adafactor": 0.1,
                     "sgd": 4.0}[cfg.optimizer]
        total += P * opt_bytes / pshard           # optimizer state
        total += P * 4.0 / pshard                 # f32 grads (transient)
        # scan-over-layers gathers ONE layer's shard at a time (FSDP)
        total += np.where(fsdp & (dp > 1),
                          P * bytes_p / (tp * max(cfg.n_layers, 1)), 0.0)
        Bm = shape.global_batch / mb
        tok = Bm * shape.seq_len / dp
        act_shard = np.where(sp, tp, 1.0)
        saves_by = {"full": 1.0, "nothing": 1.0, "dots": 4.0,
                    "none": 10.0, None: 1.0}
        saves = np.asarray(
            [saves_by[p.remat_policy or cfg.remat_policy] for p in plans],
            dtype=np.float64)
        total += saves * cfg.n_layers * tok * cfg.d_model * 2 / act_shard
        total += 12.0 * tok * cfg.d_model * 2 / act_shard  # live layer
        # logits in f32 for the loss
        total += tok * cfg.vocab_size * cfg.n_output_heads * 4 / tp
    elif shape.kind == "prefill":
        tok = shape.global_batch * shape.seq_len / dp
        total += 16.0 * tok * cfg.d_model * 2 / np.where(sp, tp, 1.0)
        total += tok * cfg.vocab_size * cfg.n_output_heads * 2 / tp
    else:  # decode: KV/SSM caches dominate
        Bd = shape.global_batch / dp
        if cfg.n_heads:
            has_cs = np.asarray([bool(p.cache_seq_axes) for p in plans],
                                dtype=bool)
            ctx = min(shape.seq_len, cfg.sliding_window or shape.seq_len)
            n_attn = (cfg.n_layers // cfg.hybrid.attn_every
                      if cfg.family == "hybrid" else cfg.n_layers)
            kv_shard = np.where(has_cs, tp,
                                np.minimum(tp, cfg.n_kv_heads))
            total += (2 * Bd * ctx * cfg.n_kv_heads * cfg.head_dim_
                      * 2 * n_attn) / kv_shard
        if cfg.ssm is not None:
            total += (cfg.n_layers * Bd * cfg.ssm_heads * cfg.ssm.head_dim
                      * cfg.ssm.d_state * 4) / np.minimum(tp, cfg.ssm_heads)
    return np.asarray(total, dtype=np.float64)


def peak_bytes(cfg: ArchConfig, workload: wl.WorkloadLike, plans: Sequence,
               mesh_shapes: Sequence[Mapping[str, int]]) -> np.ndarray:
    """Peak HBM bytes/device for parallel (plan, mesh) candidate lists."""
    spec = wl.as_spec(workload)
    dp = np.asarray([_axis_product(m, p.dp_axes)
                     for p, m in zip(plans, mesh_shapes)], dtype=np.int64)
    tp = np.asarray([m.get(p.tp_axis, 1) if p.tp_axis else 1
                     for p, m in zip(plans, mesh_shapes)], dtype=np.int64)
    return _peak_bytes_soa(cfg, spec, plans, dp, tp)


# ---------------------------------------------------------------------------
# Streaming sweeps — million-cell spaces in bounded memory
# ---------------------------------------------------------------------------


def iter_product_chunks(cfg: ArchConfig, workload: wl.WorkloadLike,
                        plans: Sequence, meshes: Sequence[Mapping[str, int]],
                        chunk_cells: int = 65536):
    """Yield ``(cell_offset, PlanSpace)`` tiles of the plan-major product
    space, each at most ~``chunk_cells`` cells.

    Tiles are themselves ``from_product`` spaces (plan-block × mesh-block),
    so every chunk scores through the rank-1 profile fast path and its
    cells land at ``offset + local_index`` in the full product's plan-major
    order — per-cell results are bit-identical to scoring the whole space
    at once, only the peak footprint changes."""
    spec = wl.as_spec(workload)
    plans = list(plans)
    meshes = [dict(m) for m in meshes]
    n_p, n_m = len(plans), len(meshes)
    if not n_p or not n_m:
        return
    chunk_cells = max(int(chunk_cells), 1)
    if n_m > chunk_cells:
        for i in range(n_p):             # one plan row, mesh-tiled
            for j0 in range(0, n_m, chunk_cells):
                sub = PlanSpace.from_product(
                    cfg, spec, plans[i:i + 1],
                    meshes[j0:j0 + chunk_cells])
                yield i * n_m + j0, sub
    else:
        p_step = max(chunk_cells // n_m, 1)
        for i0 in range(0, n_p, p_step):
            sub = PlanSpace.from_product(cfg, spec, plans[i0:i0 + p_step],
                                         meshes)
            yield i0 * n_m, sub


def stream_topk(cfg: ArchConfig, workload: wl.WorkloadLike, plans: Sequence,
                meshes: Sequence[Mapping[str, int]], model=None,
                k: int = 5, chunk_cells: int = 65536,
                hbm_budget: Optional[float] = None,
                stats: Optional[dict] = None
                ) -> List[Tuple[float, object, Mesh]]:
    """Top-``k`` cells of a (plan × mesh) product of ANY size in bounded
    memory: chunks stream through the fused scorer, an ``np.argpartition``
    pool keeps only candidates at or below the running k-th score (closed
    over ties, so the result is exactly the full ``rank``'s prefix), and
    ``hbm_budget`` prunes infeasible cells from the pool — a chunk whose
    cells ALL bust the budget skips scoring entirely.

    Peak working set is one chunk's columns plus the candidate pool — the
    full space's property columns are never materialized.  ``stats`` (any
    dict) receives ``{cells, chunks, max_chunk_cells, pool_high_water,
    pruned_cells}`` telemetry."""
    if k <= 0:
        return []
    m = predictor.resolve_model(model)
    spec = wl.as_spec(workload)
    plans = list(plans)
    meshes = [dict(mm) for mm in meshes]
    n_m = len(meshes)
    best_secs = np.zeros(0, dtype=np.float64)
    best_idx = np.zeros(0, dtype=np.int64)
    n_chunks = max_chunk = pool_hw = pruned = total_cells = 0
    for off, sub in iter_product_chunks(cfg, spec, plans, meshes,
                                        chunk_cells):
        n_chunks += 1
        max_chunk = max(max_chunk, len(sub))
        total_cells += len(sub)
        gidx = off + np.arange(len(sub), dtype=np.int64)
        if hbm_budget is not None:
            fits = sub.feasible_mask(hbm_budget)
            pruned += int(len(sub) - fits.sum())
            if not fits.any():
                continue                 # pruned before any scoring
        secs = sub.scores(m)
        if hbm_budget is not None:
            secs, gidx = secs[fits], gidx[fits]
        secs = np.concatenate([best_secs, secs])
        gidx = np.concatenate([best_idx, gidx])
        if len(secs) > k > 0:
            kth = secs[np.argpartition(secs, k - 1)[k - 1]]
            keep = secs <= kth           # tie closure at the k-th score
            secs, gidx = secs[keep], gidx[keep]
            if len(secs) > k + 512:
                # massive score ties (e.g. a model blind to the mesh) would
                # otherwise grow the pool toward n_cells; the plan/mesh
                # tie-break order is total and stable, so truncating to
                # exactly k through it preserves the rank-prefix contract
                # while keeping the pool bounded
                order = _rank_order(secs, [plans[i // n_m] for i in gidx],
                                    [meshes[i % n_m] for i in gidx])[:k]
                secs, gidx = secs[order], gidx[order]
        best_secs, best_idx = secs, gidx
        pool_hw = max(pool_hw, len(best_secs))
    if stats is not None:
        stats.update(cells=total_cells, chunks=n_chunks,
                     max_chunk_cells=max_chunk, pool_high_water=pool_hw,
                     pruned_cells=pruned)
    if not len(best_secs):
        return []
    pool_plans = [plans[i // n_m] for i in best_idx]
    pool_meshes = [meshes[i % n_m] for i in best_idx]
    order = _rank_order(best_secs, pool_plans, pool_meshes)[:k]
    return [(float(best_secs[i]), pool_plans[i], pool_meshes[i])
            for i in order]


# ---------------------------------------------------------------------------
# Joint plan × kernel-block co-tuning
# ---------------------------------------------------------------------------


def cotune_kernel_blocks(cfg: ArchConfig, workload: wl.WorkloadLike, plan,
                         mesh_shape: Mapping[str, int], model=None
                         ) -> Dict[str, Dict[str, int]]:
    """Model-chosen block sizes for the step's dominant kernels at this
    (plan, mesh) cell's *per-device* shard shapes — the joint plan × block
    co-tuning hook.  The plan/mesh pin the sharding (dp/tp ways, schedule);
    the per-kernel shape derivation and tuning live in
    ``kernels/autotune.best_blocks_for_workload``."""
    from repro.kernels import autotune
    spec = wl.as_spec(workload)
    dp = _axis_product(mesh_shape, plan.dp_axes)
    tp = mesh_shape.get(plan.tp_axis, 1) if plan.tp_axis else 1
    return autotune.best_blocks_for_workload(
        cfg, spec, model, dp=dp, tp=tp, microbatches=plan.microbatches)
