"""A tiny bounded LRU mapping for the framework's compile/memo caches.

``functools.lru_cache`` wraps a *function*; several hot paths here memoize
by explicit key (compiled step vectors keyed on ``(ArchConfig, kind,
remat)``, compiled collective vectors keyed on topology class) and need an
*object* with dict-like access.  This is that object: insertion is O(1),
hits refresh recency, and inserts beyond ``maxsize`` evict the least
recently used entry — so caches keyed on whole frozen ``ArchConfig``
dataclasses stay small instead of pinning every config ever scored.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Generic, Hashable, Optional, TypeVar

from repro.obs import metrics as _obs_metrics

V = TypeVar("V")

_EVICTIONS = _obs_metrics.REGISTRY.counter(
    "repro_lru_evictions_total",
    "entries dropped from bounded LRU caches on capacity overflow")


class LRUCache(Generic[V]):
    __slots__ = ("maxsize", "_data")

    def __init__(self, maxsize: int = 64):
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        self.maxsize = maxsize
        self._data: "OrderedDict[Hashable, V]" = OrderedDict()

    def get(self, key: Hashable, default: Optional[V] = None) -> Optional[V]:
        try:
            self._data.move_to_end(key)
        except KeyError:
            return default
        return self._data[key]

    def __setitem__(self, key: Hashable, value: V) -> None:
        if key in self._data:
            self._data.move_to_end(key)
        self._data[key] = value
        while len(self._data) > self.maxsize:
            self._data.popitem(last=False)
            _EVICTIONS.inc()

    def __contains__(self, key: Hashable) -> bool:
        return key in self._data

    def __len__(self) -> int:
        return len(self._data)

    def clear(self) -> None:
        self._data.clear()
