"""The measurement-kernel library — paper §4.1 (9 classes).

Each class yields several ``KernelCase``s (shape × size sweep).  Property
vectors are extracted *automatically* from the jaxpr (``core.extract``);
tiled kernels additionally declare their schedule-derived properties
(local-memory loads, barriers, group counts, tile re-reads) through the
``tiled_*_props`` helpers — the analog of the paper needing the Loopy
*schedule* to count barriers (§3.2).

Problem sizes follow the paper's 2^{p+t} ladders, with ``p`` chosen for the
runtime device (the container CPU here) the same way the paper chose p per
GPU: large enough to exceed launch overhead, small enough to fit memory and
a sane wall-clock budget.

The kernels express the *algorithm the GPU kernel would run* (strides,
tiling) in pure jnp; XLA-CPU may compile them differently, but the model is
fitted to *this device's* sustained rates for each property — which is
precisely the paper's black-box premise.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import extract
from repro.core import properties as props

GSIZE = 16           # 2-D tile edge (16×16 = 256-lane groups, paper's 2-D Med)
GROUP_1D = 256       # 1-D group size


@dataclass
class KernelCase:
    name: str
    klass: str                     # measurement class id
    fn: Callable                   # python function (pre-jit)
    args: Tuple                    # staged inputs
    extra_props: Dict[str, float] = field(default_factory=dict)
    meta: Dict[str, object] = field(default_factory=dict)

    _pv: Optional[Dict[str, float]] = None
    _jitted: Optional[Callable] = None

    def properties(self) -> Dict[str, float]:
        if self._pv is None:
            pv = extract.extract_jaxpr(self.fn, *self.args,
                                       extra_props=self.extra_props)
            if props.GROUPS in self.extra_props:
                # explicit schedule-declared group count replaces the nominal
                pv[props.GROUPS] = self.extra_props[props.GROUPS]
            self._pv = pv
        return self._pv

    def jitted(self) -> Callable:
        if self._jitted is None:
            j = jax.jit(self.fn)
            self._jitted = lambda: j(*self.args)
        return self._jitted


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.uniform(key, shape, dtype, 0.1, 1.0)


# ---------------------------------------------------------------------------
# Schedule-derived property helpers (tiling visible only to the scheduler)
# ---------------------------------------------------------------------------


def tiled_mm_props(n: int, m: int, l: int, gs: int = GSIZE) -> Dict[str, float]:
    """GPU tiled matmul: each (i,j) group re-fetches its A-row / B-col tiles.

    Global loads beyond the single jaxpr-visible read:
      A is read l/gs times, B n/gs times (s1, coalesced tile rows).
    Local loads: every MAC reads its 2 operands from the tile in local
    memory: 2·n·l·m.  Barriers: one per k-step per group = (m/gs)·(n·l/gs²).
    """
    groups = (n // gs) * (l // gs)
    extra_a = n * m * (l // gs - 1)
    extra_b = m * l * (n // gs - 1)
    return {
        props.mem_key("load", 32, "s1"): float(max(extra_a, 0) + max(extra_b, 0)),
        props.local_key(32): 2.0 * n * l * m,
        props.BARRIER: float((m // gs) * groups),
        props.GROUPS: float(groups),
    }


def tiled_transpose_props(n: int, gs: int = GSIZE) -> Dict[str, float]:
    """Prefetched transpose: tile in (s1 read), barrier, tile out (s1 write).
    Each element passes through local memory once."""
    groups = (n // gs) ** 2
    return {
        props.local_key(32): float(n * n),
        props.BARRIER: float(groups),
        props.GROUPS: float(groups),
    }


def stencil_tile_props(n: int, gs: int = GSIZE, halo: int = 1) -> Dict[str, float]:
    """FD tile prefetch: interior + halo cells per tile; 5 local reads/cell."""
    tiles = (n // gs) ** 2
    halo_cells = float(tiles * (4 * gs * halo + 4 * halo * halo))
    return {
        props.mem_key("load", 32, "s1"): halo_cells,  # halo re-reads
        props.local_key(32): 5.0 * n * n,
        props.BARRIER: float(tiles),
        props.GROUPS: float(tiles),
    }


def nbody_tile_props(n: int, gs: int = GROUP_1D) -> Dict[str, float]:
    """N-body: position blocks are prefetched (3×gs) per group per block;
    every pair interaction reads 3 coords from local memory."""
    groups = n // gs
    return {
        props.mem_key("load", 32, "s1"): float(3 * n * (groups - 1)),
        props.local_key(32): float(3 * n * n),
        props.BARRIER: float(groups * (n // gs)),
        props.GROUPS: float(groups),
    }


# ---------------------------------------------------------------------------
# 1+2. Matrix multiplication (tiled + naive)
# ---------------------------------------------------------------------------


def _mm_cases(tiled: bool, p: int, key) -> List[KernelCase]:
    cases = []
    shapes = []
    for t in range(4):
        n = 2 ** (p + t)
        shapes += [(n, n, n), (n, n, n // 2), (n, n // 2, n), (n // 2, n, n)]
    if not tiled:  # naive: square only (paper)
        shapes = [(2 ** (p + t),) * 3 for t in range(4)]
    for i, (n, m, l) in enumerate(shapes):
        k1, k2, key = jax.random.split(key, 3)
        a = _rand(k1, (n, m))
        b = _rand(k2, (m, l))
        extra = tiled_mm_props(n, m, l) if tiled else {}
        klass = "mm_tiled" if tiled else "mm_naive"
        cases.append(KernelCase(
            name=f"{klass}_{n}x{m}x{l}", klass=klass,
            fn=lambda a, b: a @ b, args=(a, b), extra_props=extra,
            meta={"n": n, "m": m, "l": l}))
    return cases


# ---------------------------------------------------------------------------
# 3. Vector scale and add (strides 1/2/3)
# ---------------------------------------------------------------------------


def _vsa_cases(p: int, key) -> List[KernelCase]:
    cases = []
    for stride in (1, 2, 3):
        for t in range(4):
            n = 2 ** (p + 2 * t)
            k1, k2, key = jax.random.split(key, 3)
            a = _rand(k1, (n * stride,))
            b = _rand(k2, (n * stride,))
            lim = n * stride

            def fn(a, b, s=stride, lim=lim):
                return 2.5 * jax.lax.slice(a, (0,), (lim,), (s,)) \
                    + 1.5 * jax.lax.slice(b, (0,), (lim,), (s,))

            cases.append(KernelCase(
                name=f"vsa_s{stride}_n{n}", klass="vector_scale_add",
                fn=fn, args=(a, b), meta={"n": n, "stride": stride}))
    return cases


# ---------------------------------------------------------------------------
# 4. Transpose (3 variants)
# ---------------------------------------------------------------------------


def _transpose_cases(p: int, key) -> List[KernelCase]:
    cases = []
    for t in range(4):
        n = 2 ** (p + t)
        k1, key = jax.random.split(key)
        x = _rand(k1, (n, n))

        # v1: prefetch-tiled (s1 reads AND writes; local memory round-trip)
        cases.append(KernelCase(
            name=f"transpose_tiled_{n}", klass="transpose",
            fn=lambda x: x.T + 0.0, args=(x,),
            extra_props={
                **tiled_transpose_props(n),
                # the tile pass converts the gather-read into s1 read+write
                props.mem_key("load", 32, "s1"): float(n * n),
                props.mem_key("load", 32, "gather"): -float(n * n),
            },
            meta={"n": n, "variant": "tiled"}))

        # v2: no prefetch — s1 writes, uncoalesced reads
        cases.append(KernelCase(
            name=f"transpose_plain_{n}", klass="transpose",
            fn=lambda x: x.T + 0.0, args=(x,), meta={"n": n, "variant": "plain"}))

        # v3: no prefetch — s1 reads, uncoalesced (scatter) writes
        def scat(x, n=n):
            i = jnp.arange(n * n)
            dest = (i % n) * n + i // n
            return jnp.zeros((n * n,), x.dtype).at[dest].set(x.reshape(-1))

        cases.append(KernelCase(
            name=f"transpose_scatter_{n}", klass="transpose",
            fn=scat, args=(x,), meta={"n": n, "variant": "scatter"}))
    return cases


# ---------------------------------------------------------------------------
# 5. Stride-1 global access (copy / 4-add / index store)
# ---------------------------------------------------------------------------


def _stride1_cases(p: int, key) -> List[KernelCase]:
    cases = []
    for t in range(0, 9, 2):  # 5 of the paper's 9 ladder points
        n = 2 ** (p + t)
        ks = jax.random.split(key, 6)
        key = ks[5]
        arrs = [_rand(ks[i], (n,)) for i in range(5)]
        cases.append(KernelCase(
            name=f"s1_copy_{n}", klass="stride1_global",
            fn=lambda a: a + 0.0, args=(arrs[0],), meta={"n": n}))
        cases.append(KernelCase(
            name=f"s1_add4_{n}", klass="stride1_global",
            fn=lambda a, b, c, d: a + b + c + d,
            args=tuple(arrs[:4]), meta={"n": n}))
        cases.append(KernelCase(
            name=f"s1_store_iota_{n}", klass="stride1_global",
            fn=lambda n=n: jnp.arange(n, dtype=jnp.float32) + 0.0,
            args=(), meta={"n": n}))
    return cases


# ---------------------------------------------------------------------------
# 6+7. Stride-2 / stride-3 *filled* access (all phases touched)
# ---------------------------------------------------------------------------


def _filled_cases(stride: int, p: int, key) -> List[KernelCase]:
    cases = []
    R = 256  # pair-sums reduced per output element (paper's 256)
    for t in range(3):
        n = 2 ** (p + t)
        k1, key = jax.random.split(key)
        a = _rand(k1, (stride * n,))

        def fn(a, s=stride, n=n):
            phases = [jax.lax.slice(a, (i,), (i + s * n - s + 1,), (s,))
                      for i in range(s)]
            ps = sum(phases)  # pairwise/trio-wise sums (n,)
            return ps.reshape(n // R, R).sum(axis=1)

        cases.append(KernelCase(
            name=f"filled_s{stride}_n{n}", klass=f"stride{stride}_filled",
            fn=fn, args=(a,), meta={"n": n, "stride": stride}))
    return cases


# ---------------------------------------------------------------------------
# 8. Arithmetic operations (per kind, no global reads)
# ---------------------------------------------------------------------------


_ARITH_EXPRS = {
    # each body applies 6-10 ops of one kind to the lane value (paper §4.1)
    "add": lambda x, q: x + q + 1.0 + (x - 2.0) + (q - x) + (x + 0.5) + q,
    "mul": lambda x, q: x * q * 1.01 * (x * 0.99) * (q * 1.02) * (x * 0.5),
    "div": lambda x, q: ((((x / (q + 1.0)) / 1.01) / (x + 2.0)) / 0.99) / 1.5,
    "exp": lambda x, q: jnp.exp(-x) + jnp.exp(-q) + jnp.exp(-(x + q) * 0.5),
    "rsqrt": lambda x, q: (jax.lax.rsqrt(x + 1.0) + jax.lax.rsqrt(q + 2.0)
                           + jax.lax.rsqrt(x + q + 3.0)),
}


def _arith_cases(p: int, key) -> List[KernelCase]:
    cases = []
    for kind, body in _ARITH_EXPRS.items():
        for t in range(3):
            n = 2 ** (p + t)
            k_red = 64  # reduction length (paper: 256..728; CPU-scaled)

            def fn(kind=kind, n=n, k_red=k_red):
                base = (jnp.arange(n * n, dtype=jnp.float32)
                        .reshape(n, n) * 1e-6 + 0.5)

                def step(acc, q):
                    return acc + _ARITH_EXPRS[kind](base, q), None

                acc, _ = jax.lax.scan(
                    step, jnp.zeros((n, n), jnp.float32),
                    jnp.arange(k_red, dtype=jnp.float32) * 1e-3 + 0.25)
                return acc

            cases.append(KernelCase(
                name=f"arith_{kind}_n{n}", klass="arith",
                fn=fn, args=(), meta={"n": n, "kind": kind, "k": k_red}))
    return cases


# ---------------------------------------------------------------------------
# 9. Empty kernel (launch overhead)
# ---------------------------------------------------------------------------


def _empty_cases(p: int) -> List[KernelCase]:
    cases = []
    for t in range(0, 6, 2):
        n = 2 ** (p + t)
        groups = (n // GSIZE) ** 2
        cases.append(KernelCase(
            name=f"empty_{n}", klass="empty",
            fn=lambda: jnp.zeros((), jnp.float32), args=(),
            extra_props={props.GROUPS: float(groups)},
            meta={"n": n}))
    return cases


# ---------------------------------------------------------------------------
# Assembly
# ---------------------------------------------------------------------------

# p-ladders per device scale; 'cpu' sizes target 1–50 ms/kernel on the
# container CPU (the paper's per-GPU p choice, same role)
_P = {
    "cpu":  {"mm": 7, "naive": 7, "vsa": 16, "transpose": 9, "s1": 14,
             "filled": 15, "arith": 7, "empty": 8},
    "tiny": {"mm": 5, "naive": 5, "vsa": 8, "transpose": 6, "s1": 8,
             "filled": 10, "arith": 4, "empty": 6},
}


def measurement_cases(scale: str = "cpu", seed: int = 0) -> List[KernelCase]:
    P = _P[scale]
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 8)
    cases: List[KernelCase] = []
    cases += _mm_cases(True, P["mm"], ks[0])
    cases += _mm_cases(False, P["naive"], ks[1])
    cases += _vsa_cases(P["vsa"], ks[2])
    cases += _transpose_cases(P["transpose"], ks[3])
    cases += _stride1_cases(P["s1"], ks[4])
    cases += _filled_cases(2, P["filled"], ks[5])
    cases += _filled_cases(3, P["filled"], ks[6])
    cases += _arith_cases(P["arith"], ks[7])
    cases += _empty_cases(P["empty"])
    return cases
