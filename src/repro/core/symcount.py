"""Symbolic count expressions — the piecewise-quasi-polynomial analog.

The paper represents every kernel property as a piecewise quasi-polynomial
in the size parameters ``n`` (produced by Barvinok counting), so that a
property vector can be *cheaply re-evaluated for changed problem sizes*
("our model is fully parametric").  This module supplies the same capability
for our JAX-based extraction: a tiny, dependency-free expression language

    Expr := Const | Var | Add | Mul | FloorDiv | CeilDiv | Max | Min | Piecewise

with operator overloading, substitution, evaluation and pretty-printing.
Counts produced by ``core.archcount`` (closed-form per-architecture) are
Exprs; ``core.extract`` produces concrete integers for a concrete ``n`` and
tests assert the two agree on sweeps.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, Mapping, Tuple, Union

Number = Union[int, float]


def as_expr(x: "ExprLike") -> "Expr":
    if isinstance(x, Expr):
        return x
    if isinstance(x, (int, float)):
        return Const(x)
    raise TypeError(f"cannot convert {type(x)} to Expr")


class Expr:
    """Base class.  Immutable; hashable by structure string.

    Nodes never mutate after construction, so the structure string and its
    hash are computed once and cached on the instance (``_repr_c`` /
    ``_hash_c``): repeated hashing / equality probes — e.g. the LRU lookups
    in ``predictor.step_vector_fn`` or the term-dedup passes in
    ``core.exprops`` — cost O(1) tree walks, not one full re-serialization
    per probe.  Subclasses implement ``_render`` (the one-shot serializer);
    ``__repr__`` is final and memoizing.
    """

    def eval(self, env: Mapping[str, Number]) -> Number:
        raise NotImplementedError

    def free_vars(self) -> set:
        raise NotImplementedError

    def _emit(self, names: Mapping[str, str]) -> str:
        """Lower to a numpy expression string (see ``compile``)."""
        raise NotImplementedError

    def compile(self) -> "CompiledExpr":
        """Lower this expression tree to a vectorized numpy closure.

        The returned callable evaluates the tree for a whole *array* of
        environments at once: pass scalars and/or broadcastable numpy arrays
        for the free variables and every node becomes one numpy ufunc over
        the full grid.  This is the config-sweep fast path — scoring a
        block-size grid through a compiled expression replaces one
        interpreted tree-walk per point with a handful of array ops total.
        Semantics match ``eval`` exactly on integer/float scalars.
        """
        return CompiledExpr(self)

    # -- operator sugar ----------------------------------------------------
    def __add__(self, o):  return Add(self, as_expr(o))
    def __radd__(self, o): return Add(as_expr(o), self)
    def __mul__(self, o):  return Mul(self, as_expr(o))
    def __rmul__(self, o): return Mul(as_expr(o), self)
    def __sub__(self, o):  return Add(self, Mul(Const(-1), as_expr(o)))
    def __rsub__(self, o): return Add(as_expr(o), Mul(Const(-1), self))
    def __floordiv__(self, o): return FloorDiv(self, as_expr(o))
    def __truediv__(self, o):  return Mul(self, Pow(as_expr(o), -1))
    def __pow__(self, k: int): return Pow(self, k)

    def _render(self) -> str:
        raise NotImplementedError

    def __repr__(self):
        r = getattr(self, "_repr_c", None)
        if r is None:
            r = self._render()
            self._repr_c = r
        return r

    def __eq__(self, o):
        if self is o:
            return True
        return (isinstance(o, Expr) and hash(self) == hash(o)
                and repr(self) == repr(o))

    def __hash__(self):
        h = getattr(self, "_hash_c", None)
        if h is None:
            h = hash(repr(self))
            self._hash_c = h
        return h


class Const(Expr):
    def __init__(self, v: Number):
        self.v = v

    def eval(self, env):
        return self.v

    def free_vars(self):
        return set()

    def _render(self):
        if isinstance(self.v, float) and self.v.is_integer():
            return repr(int(self.v))
        return repr(self.v)

    def _emit(self, names):
        return repr(self.v)


class Var(Expr):
    def __init__(self, name: str):
        self.name = name

    def eval(self, env):
        if self.name not in env:
            raise KeyError(f"unbound size parameter {self.name!r}")
        return env[self.name]

    def free_vars(self):
        return {self.name}

    def _render(self):
        return self.name

    def _emit(self, names):
        return names[self.name]


class Add(Expr):
    def __init__(self, a: Expr, b: Expr):
        self.a, self.b = a, b

    def eval(self, env):
        return self.a.eval(env) + self.b.eval(env)

    def free_vars(self):
        return self.a.free_vars() | self.b.free_vars()

    def _render(self):
        return f"({self.a} + {self.b})"

    def _emit(self, names):
        return f"({self.a._emit(names)} + {self.b._emit(names)})"


class Mul(Expr):
    def __init__(self, a: Expr, b: Expr):
        self.a, self.b = a, b

    def eval(self, env):
        return self.a.eval(env) * self.b.eval(env)

    def free_vars(self):
        return self.a.free_vars() | self.b.free_vars()

    def _render(self):
        return f"{self._p(self.a)}*{self._p(self.b)}"

    @staticmethod
    def _p(e):
        return f"({e})" if isinstance(e, Add) else repr(e)

    def _emit(self, names):
        return f"({self.a._emit(names)} * {self.b._emit(names)})"


class Pow(Expr):
    def __init__(self, a: Expr, k: int):
        self.a, self.k = a, k

    def eval(self, env):
        return self.a.eval(env) ** self.k

    def free_vars(self):
        return self.a.free_vars()

    def _render(self):
        return f"{Mul._p(self.a)}^{self.k}"

    def _emit(self, names):
        a = self.a._emit(names)
        if self.k < 0:  # int arrays reject negative powers; go via float64
            return f"(_np.asarray({a}, dtype=_np.float64) ** {self.k})"
        return f"({a} ** {self.k})"


class FloorDiv(Expr):
    def __init__(self, a: Expr, b: Expr):
        self.a, self.b = a, b

    def eval(self, env):
        return self.a.eval(env) // self.b.eval(env)

    def free_vars(self):
        return self.a.free_vars() | self.b.free_vars()

    def _render(self):
        return f"floor({self.a} / {self.b})"

    def _emit(self, names):
        return (f"_np.floor_divide({self.a._emit(names)}, "
                f"{self.b._emit(names)})")


class CeilDiv(Expr):
    def __init__(self, a: Expr, b: Expr):
        self.a, self.b = a, b

    def eval(self, env):
        return -((-self.a.eval(env)) // self.b.eval(env))

    def free_vars(self):
        return self.a.free_vars() | self.b.free_vars()

    def _render(self):
        return f"ceil({self.a} / {self.b})"

    def _emit(self, names):
        return (f"(-_np.floor_divide(-({self.a._emit(names)}), "
                f"{self.b._emit(names)}))")


class Max(Expr):
    def __init__(self, *args: Expr):
        self.args = tuple(as_expr(a) for a in args)

    def eval(self, env):
        return max(a.eval(env) for a in self.args)

    def free_vars(self):
        return set().union(*(a.free_vars() for a in self.args))

    def _render(self):
        return f"max({', '.join(map(repr, self.args))})"

    def _emit(self, names):
        out = self.args[0]._emit(names)
        for a in self.args[1:]:
            out = f"_np.maximum({out}, {a._emit(names)})"
        return out


class Min(Expr):
    def __init__(self, *args: Expr):
        self.args = tuple(as_expr(a) for a in args)

    def eval(self, env):
        return min(a.eval(env) for a in self.args)

    def free_vars(self):
        return set().union(*(a.free_vars() for a in self.args))

    def _render(self):
        return f"min({', '.join(map(repr, self.args))})"

    def _emit(self, names):
        out = self.args[0]._emit(names)
        for a in self.args[1:]:
            out = f"_np.minimum({out}, {a._emit(names)})"
        return out


class Piecewise(Expr):
    """[(cond_fn_expr_pair)...] — the 'piecewise' in piecewise quasi-polynomial.

    ``branches`` is a list of (guard, value); guard is an Expr evaluated
    truthy (>0), the first truthy guard wins; ``otherwise`` is the default.
    """

    def __init__(self, branches: Iterable[Tuple[Expr, Expr]], otherwise: Expr):
        self.branches = [(as_expr(g), as_expr(v)) for g, v in branches]
        self.otherwise = as_expr(otherwise)

    def eval(self, env):
        for g, v in self.branches:
            if g.eval(env) > 0:
                return v.eval(env)
        return self.otherwise.eval(env)

    def free_vars(self):
        s = self.otherwise.free_vars()
        for g, v in self.branches:
            s |= g.free_vars() | v.free_vars()
        return s

    def _render(self):
        bs = "; ".join(f"{v} if {g}>0" for g, v in self.branches)
        return f"piecewise({bs}; else {self.otherwise})"

    def _emit(self, names):
        out = self.otherwise._emit(names)
        for g, v in reversed(self.branches):  # first truthy guard wins
            out = (f"_np.where({g._emit(names)} > 0, "
                   f"{v._emit(names)}, {out})")
        return out


ExprLike = Union[Expr, int, float]


# ---------------------------------------------------------------------------
# Compilation — vectorized numpy lowering (paper: "cheap re-evaluation",
# here made literal: a whole parameter grid per call, not one point)
# ---------------------------------------------------------------------------


class CompiledExpr:
    """An ``Expr`` lowered to one numpy closure over its free variables.

    Built once per tree (``Expr.compile()``); calls take an env mapping each
    free variable to a scalar or a broadcastable array and return the
    evaluated scalar/array.  ``FloorDiv``/``CeilDiv``/``Max``/``Min``/
    ``Piecewise`` lower to ``floor_divide``/``maximum``/``minimum``/``where``
    so integer semantics match ``Expr.eval`` bit-for-bit.
    """

    __slots__ = ("expr", "params", "_fn")

    def __init__(self, expr: Expr):
        import numpy as np
        self.expr = expr
        self.params = tuple(sorted(expr.free_vars()))
        # positional arg names avoid collisions with numpy / builtins
        names = {v: f"_a{i}" for i, v in enumerate(self.params)}
        args = ", ".join(names[v] for v in self.params)
        src = f"lambda _np{', ' if args else ''}{args}: {expr._emit(names)}"
        self._fn = eval(compile(src, "<symcount.compile>", "eval"))

    def __call__(self, env: Mapping[str, object]):
        import numpy as np
        return self._fn(np, *(env[v] for v in self.params))

    def __repr__(self):
        return f"compiled({self.expr!r})"


class CompiledVector:
    """A property vector compiled property-by-property.

    ``__call__(env)`` returns ``{key: scalar-or-array}``; plain numbers pass
    through untouched (broadcast by numpy where mixed with arrays).
    """

    def __init__(self, pv: Mapping[str, ExprLike]):
        self.consts: Dict[str, Number] = {}
        self.fns: Dict[str, CompiledExpr] = {}
        for k, v in pv.items():
            if isinstance(v, Expr):
                self.fns[k] = v.compile()
            else:
                self.consts[k] = v

    def free_vars(self) -> set:
        out = set()
        for f in self.fns.values():
            out.update(f.params)
        return out

    def __call__(self, env: Mapping[str, object]) -> Dict[str, object]:
        out: Dict[str, object] = dict(self.consts)
        for k, f in self.fns.items():
            out[k] = f(env)
        return out


def compile_vector(pv: Mapping[str, ExprLike]) -> CompiledVector:
    return CompiledVector(pv)


# ---------------------------------------------------------------------------
# Property-vector helpers (dict of name -> Expr | number)
# ---------------------------------------------------------------------------


def evaluate_vector(pv: Mapping[str, ExprLike], env: Mapping[str, Number]
                    ) -> Dict[str, Number]:
    out = {}
    for k, v in pv.items():
        out[k] = v.eval(env) if isinstance(v, Expr) else v
    return out


def add_vectors(*vecs: Mapping[str, ExprLike]) -> Dict[str, ExprLike]:
    out: Dict[str, ExprLike] = {}
    for v in vecs:
        for k, x in v.items():
            if k in out:
                out[k] = as_expr(out[k]) + as_expr(x) \
                    if isinstance(out[k], Expr) or isinstance(x, Expr) \
                    else out[k] + x
            else:
                out[k] = x
    return out


def scale_vector(pv: Mapping[str, ExprLike], c: ExprLike) -> Dict[str, ExprLike]:
    out = {}
    for k, v in pv.items():
        if isinstance(v, Expr) or isinstance(c, Expr):
            out[k] = as_expr(v) * as_expr(c)
        else:
            out[k] = v * c
    return out
