"""The linear cost model — paper §2:  T_wall(n) ≈ Σ_i α_i · p_i(n).

A ``LinearCostModel`` is just (ordered property names, weights α, metadata).
Prediction is the small inner product the paper advertises; weights carry
units of seconds/event and are directly interpretable (Table 2 analog via
``interpretation_report``).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional

import numpy as np

from repro.core import properties as props

# Registry file-format version (see repro.calibration.registry).  v1 adds the
# explicit "schema"/"kind" envelope; files without it are legacy v0 and are
# accepted by ``from_json_dict`` for backward compatibility.
SCHEMA_VERSION = 1


class ModelSchemaError(ValueError):
    """A serialized model has an unreadable or future schema."""


class FutureSchemaError(ModelSchemaError):
    """The schema postdates this checkout — a VERSION problem, not file
    corruption.  The hardened registry fallback re-raises this instead of
    degrading to an older revision: falling back would silently mask the
    need to upgrade."""


@dataclass
class LinearCostModel:
    keys: List[str]
    weights: np.ndarray  # (len(keys),) seconds per event
    device: str = "unknown"
    meta: Dict[str, object] = field(default_factory=dict)

    # ------------------------------------------------------------------
    def predict(self, pv: Mapping[str, float]) -> float:
        """<α, p> — evaluation is a small inner product (paper §1, item 5)."""
        t = 0.0
        for k, w in zip(self.keys, self.weights):
            v = pv.get(k)
            if v:
                t += w * v
        return float(t)

    def predict_many(self, pvs: List[Mapping[str, float]]) -> np.ndarray:
        A = props.to_matrix(pvs, self.keys)
        return A @ self.weights

    def breakdown(self, pv: Mapping[str, float]) -> Dict[str, float]:
        """Per-property contribution in seconds (cost attribution)."""
        out = {}
        for k, w in zip(self.keys, self.weights):
            v = pv.get(k)
            if v:
                out[k] = float(w * v)
        return dict(sorted(out.items(), key=lambda kv: -abs(kv[1])))

    # ------------------------------------------------------------------
    def interpretation_report(self) -> str:
        """Table-2 analog: weight per property, seconds/operation."""
        lines = [f"# fitted weights — device: {self.device}",
                 f"{'property':<44} {'weight (s/event)':>16}"]
        for k, w in sorted(zip(self.keys, self.weights),
                           key=lambda kw: -abs(kw[1])):
            lines.append(f"{props.pretty(k):<44} {w: .3e}")
        return "\n".join(lines)

    # ------------------------------------------------------------------
    def to_json_dict(self) -> Dict[str, object]:
        """Versioned JSON envelope.  ``json`` emits float64 via ``repr``
        (shortest exact form), so weights round-trip bitwise."""
        return {
            "schema": SCHEMA_VERSION,
            "kind": "linear_cost_model",
            "device": self.device,
            "keys": list(self.keys),
            "weights": [float(w) for w in self.weights],
            "meta": self.meta,
        }

    @classmethod
    def from_json_dict(cls, d: Mapping[str, object]) -> "LinearCostModel":
        schema = d.get("schema", 0)  # pre-versioning files are legacy v0
        if isinstance(schema, int) and schema > SCHEMA_VERSION:
            raise FutureSchemaError(
                f"model schema {schema!r} is newer than supported "
                f"({SCHEMA_VERSION}); upgrade this checkout to read it")
        if not isinstance(schema, int):
            raise ModelSchemaError(f"model schema {schema!r} is not an int")
        if schema >= 1 and d.get("kind") != "linear_cost_model":
            raise ModelSchemaError(
                f"not a linear_cost_model record: kind={d.get('kind')!r}")
        keys = list(d["keys"])
        weights = np.asarray(d["weights"], dtype=np.float64)
        if len(keys) != len(weights):
            raise ModelSchemaError(
                f"{len(keys)} keys but {len(weights)} weights")
        return cls(keys=keys, weights=weights,
                   device=str(d.get("device", "unknown")),
                   meta=dict(d.get("meta", {})))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json_dict(), f, indent=1)

    @classmethod
    def load(cls, path: str) -> "LinearCostModel":
        with open(path) as f:
            return cls.from_json_dict(json.load(f))

    @classmethod
    def from_dict(cls, weights: Mapping[str, float], device: str = "analytic",
                  meta: Optional[dict] = None) -> "LinearCostModel":
        keys = sorted(weights)
        return cls(keys=keys, weights=np.asarray([weights[k] for k in keys]),
                   device=device, meta=meta or {})


def relative_error(pred: float, actual: float) -> float:
    """|pred - actual| / actual — the paper's §5 error metric."""
    return abs(pred - actual) / actual


def geomean(xs) -> float:
    """Geometric mean — Fleming & Wallace summary of normalized values."""
    xs = np.asarray(list(xs), dtype=np.float64)
    xs = np.maximum(xs, 1e-12)
    return float(np.exp(np.mean(np.log(xs))))
