"""Symbolic per-architecture property counts — closed-form p_i(n).

The paper's extraction produces *piecewise quasi-polynomials in the size
parameters* so the model can be "cheaply re-evaluated for changed values of
the parameter vector n".  This module provides the same for whole model
steps: given an ``ArchConfig``, it emits a property vector whose values are
``symcount.Expr``s in the free variables

    B  global batch            S  sequence length
    M  microbatches            (mesh sizes enter via ``shard_env``)

for each of the three phases (train / prefill / decode) of a
``core.workload.WorkloadSpec``.  Decode specs carrying refinements
introduce additional variables (only when the spec sets the field — see
``WorkloadSpec.structure``):

    CT  total context tokens read across slots (KV/SSM cache traffic)
    AS  occupied decode slots (occupancy-aware per-token work)
    SL  speculative-decode tokens verified per iteration
    MI  MoE hottest-expert load multiplier

Downstream:

  * ``core.predictor`` evaluates these against a fitted/analytic weight set
    in O(|properties|) — the paper's "small inner product";
  * ``launch/autoshard.py`` re-evaluates them per candidate Plan in µs,
    realizing the paper's §6.2 'optimal configuration selection' extension;
  * tests pin them against ``extract_jaxpr`` / XLA ``cost_analysis`` on
    reduced configs.

Counting conventions
  * MXU flops: 2·MACs of every projection / attention / expert contraction,
    per token.  MoE uses the *active* expert count (top-k) + the dense
    dispatch/combine einsum cost at the configured capacity.
  * VPU flops: norms, softmax, rope, silu, residuals — one bucketed count
    per op kind (add/mul/div/exp/special), coefficients from the literal
    jnp implementation in ``repro.models`` (kept in sync by tests).
  * Bytes move as s1 loads/stores of the *compute dtype* except where the
    access is genuinely strided/gathered (embedding lookup = gather).
  * train = fwd + bwd (2× fwd flops for dW, 1× for dX ⇒ 3× multiplier on
    MXU terms (+1× more with full remat), plus optimizer update traffic.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Mapping, Optional, Tuple

from repro.configs.base import ArchConfig
from repro.core import properties as props
from repro.core import workload as wl
from repro.core.symcount import (
    CeilDiv, Const, Expr, ExprLike, Max, Min, Piecewise, Var, add_vectors,
    as_expr, evaluate_vector, scale_vector,
)

B = Var("B")   # global batch
S = Var("S")   # sequence length (train/prefill) or KV length (decode)
M = Var("M")   # microbatches
DP = Var("DP")  # data-parallel ways (product of the plan's dp-axis sizes)
TP = Var("TP")  # tensor-parallel ways (the plan's tp-axis size)
CT = Var("CT")  # total cache-context tokens across decode slots
AS = Var("AS")  # occupied decode slots
SL = Var("SL")  # speculative-decode length (tokens/iteration/slot)
MI = Var("MI")  # MoE hottest-expert load multiplier


def _bits(cfg: ArchConfig) -> int:
    return 16 if "16" in cfg.compute_dtype else 32


# ---------------------------------------------------------------------------
# Per-block MAC counts (per token)
# ---------------------------------------------------------------------------


def _attn_proj_macs(cfg: ArchConfig) -> int:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    return d * H * hd + 2 * d * KV * hd + H * hd * d  # q,k,v,o


def _attn_score_macs_train(cfg: ArchConfig) -> Expr:
    """Per-token QK^T + PV MACs over a length-S causal (or SWA) context."""
    H, hd = cfg.n_heads, cfg.head_dim_
    if cfg.sliding_window is not None:
        ctx = Min(S, Const(cfg.sliding_window))
        eff = ctx  # every token sees ≤ window
    else:
        eff = S * 0.5  # causal average context
    return 2 * H * hd * eff  # qk + pv


def _ffn_macs(cfg: ArchConfig, active_experts: float = 1.0) -> float:
    return active_experts * 3 * cfg.d_model * cfg.d_ff  # gate, up, down


def _moe_active(cfg: ArchConfig) -> float:
    """Dense (GShard) dispatch really computes capacity-PADDED expert FFNs:
    top_k · capacity_factor expert-equivalents per token."""
    return cfg.moe.top_k * cfg.moe.capacity_factor


def _ssm_macs(cfg: ArchConfig) -> Expr:
    """Mamba2/SSD per-token MACs: projections + chunked SSD terms."""
    s = cfg.ssm
    d, din = cfg.d_model, cfg.d_inner
    nH, P, N, G = cfg.ssm_heads, s.head_dim, s.d_state, s.n_groups
    proj = d * (2 * din + 2 * G * N + nH) + din * d  # in_proj + out_proj
    conv = (din + 2 * G * N) * s.d_conv
    Q = Const(s.chunk)
    # intra-chunk: CB (Q·N per token·head) + y_intra (Q·P) ;
    # inter-chunk + state update: 2·P·N per token·head
    ssd = nH * (Q * N + Q * P + 2 * P * N)
    return proj + conv + ssd


def _moe_dispatch_macs(cfg: ArchConfig, tokens: ExprLike = None) -> Expr:
    """Dense GShard dispatch/combine einsum MACs per token.

    dispatch xe=einsum(gtec,gtd) + combine y=einsum(egcd,gtec) each cost
    t·(E·C·d) per group with E·C ≈ top_k·cf·t — i.e. per-token cost scales
    with the dispatch GROUP SIZE t = min(tokens, GROUP_TOKENS): the
    quadratic-in-group-size price of dense dispatch (this is why the
    group-size cap exists)."""
    from repro.models.moe import GROUP_TOKENS
    m = cfg.moe
    E = m.n_experts
    d = cfg.d_model
    tg = Min(as_expr(tokens if tokens is not None else B * S),
             Const(GROUP_TOKENS))
    return (as_expr(2 * m.top_k * m.capacity_factor * d) * tg
            + d * E)  # + router


# ---------------------------------------------------------------------------
# VPU (elementwise) per-token flop buckets, per layer
# ---------------------------------------------------------------------------


def _vpu_layer(cfg: ArchConfig) -> Dict[str, ExprLike]:
    d = cfg.d_model
    out: Dict[str, ExprLike] = {}
    add = mul = div = exp = special = as_expr(0)
    # 2 rmsnorms: mean(x²) (2d add+mul) + rsqrt + scale (d mul)
    add = add + 4 * d
    mul = mul + 6 * d
    special = special + 2  # rsqrt
    add = add + 2 * d  # residuals
    if cfg.n_heads:
        H, hd = cfg.n_heads, cfg.head_dim_
        # rope: 4 mul + 2 add per q/k element pair + sin/cos
        rope_elems = (cfg.n_heads + cfg.n_kv_heads) * hd
        mul = mul + 2 * rope_elems
        add = add + rope_elems
        special = special + rope_elems  # sin/cos pairs
        # softmax over context: exp + sum + div per score
        ctx = Min(S, Const(cfg.sliding_window)) if cfg.sliding_window \
            else S * 0.5
        exp = exp + H * ctx
        add = add + H * ctx
        div = div + H * ctx
    if cfg.ssm is not None:
        din = cfg.d_inner
        # silu(conv) + silu(z)·y + gated norm + softplus(dt) + exp(dA)
        special = special + 2 * din + 2 * cfg.ssm_heads
        mul = mul + 3 * din
        add = add + 2 * din
    if cfg.d_ff and cfg.moe is None:
        special = special + cfg.d_ff   # silu
        mul = mul + cfg.d_ff
    if cfg.moe is not None:
        E = cfg.moe.n_experts
        exp = exp + E            # router softmax
        add = add + 3 * E
        div = div + E
        special = special + cfg.moe.top_k * cfg.moe.capacity_factor * cfg.d_ff
        mul = mul + cfg.moe.top_k * cfg.moe.capacity_factor * cfg.d_ff
    b = _bits(cfg)
    for k, v in (("add", add), ("mul", mul), ("div", div), ("exp", exp),
                 ("special", special)):
        out[props.flop_key(32, k)] = v  # VPU math runs f32 in our models
    return out


# ---------------------------------------------------------------------------
# Whole-step property vectors (symbolic)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StepCounts:
    """Symbolic property vector + the MODEL_FLOPS closed form."""
    pv: Dict[str, ExprLike]
    model_flops: ExprLike  # 6·N·D (train) / 2·N_active·D (inference)

    def concrete(self, env: Mapping[str, float]) -> Dict[str, float]:
        full = dict(env)
        full.setdefault("M", 1)
        return evaluate_vector(self.pv, full)

    def concrete_model_flops(self, env: Mapping[str, float]) -> float:
        e = self.model_flops
        full = dict(env); full.setdefault("M", 1)
        return e.eval(full) if isinstance(e, Expr) else float(e)


def _layer_macs(cfg: ArchConfig) -> Expr:
    """Per-token MACs of one *average* layer (MoE: active experts)."""
    if cfg.family == "ssm":
        return as_expr(_ssm_macs(cfg))
    if cfg.family == "hybrid":
        k = cfg.hybrid.attn_every
        shared = (_attn_proj_macs(cfg) + _ffn_macs(cfg)
                  + _attn_score_macs_train(cfg))
        return as_expr(_ssm_macs(cfg)) + as_expr(shared) * (1.0 / k)
    macs = as_expr(_attn_proj_macs(cfg)) + _attn_score_macs_train(cfg)
    if cfg.moe is not None:
        macs = macs + _ffn_macs(cfg, _moe_active(cfg)) + _moe_dispatch_macs(cfg)
    else:
        macs = macs + _ffn_macs(cfg)
    return macs


def _embed_head_macs(cfg: ArchConfig) -> ExprLike:
    # embedding lookup is a gather (no MACs); head is d×V per output head
    return cfg.d_model * cfg.vocab_size * cfg.n_output_heads


def forward_counts(cfg: ArchConfig) -> Dict[str, ExprLike]:
    """Symbolic property vector of ONE forward pass over (B, S) tokens."""
    T = B * S
    bits = _bits(cfg)
    L = cfg.n_layers
    pv: Dict[str, ExprLike] = {}

    macs = _layer_macs(cfg) * L + _embed_head_macs(cfg)
    pv[props.mxu_key(bits)] = as_expr(2) * macs * T

    pv = add_vectors(pv, scale_vector(_vpu_layer(cfg), T * L))
    # final norm + softmax-xent flops
    pv = add_vectors(pv, {
        props.flop_key(32, "add"): T * 2 * cfg.d_model,
        props.flop_key(32, "exp"): T * cfg.vocab_size * cfg.n_output_heads,
    })

    # --- data motion (elems) ---
    d = cfg.d_model
    # params stream HBM→chip once per step
    pv[props.mem_key("load", bits, "s1")] = as_expr(cfg.n_params())
    # embedding lookup: gather of T·d
    pv[props.mem_key("load", bits, "gather")] = T * d
    # residual stream activations: ~4 reads + 2 writes per layer
    pv[props.mem_key("load", bits, "s1")] = (
        pv[props.mem_key("load", bits, "s1")] + T * d * 4 * L)
    pv[props.mem_key("store", bits, "s1")] = (
        T * d * 2 * L + T * cfg.vocab_size * cfg.n_output_heads)
    return pv


def train_fwd_multiplier(cfg: ArchConfig,
                         remat_policy: Optional[str] = None) -> float:
    """fwd+bwd compute multiplier: bwd ≈ 2× fwd MXU flops, and full remat
    re-runs the forward once more inside bwd."""
    policy = remat_policy or cfg.remat_policy
    return 3.0 + (1.0 if policy in ("full", "nothing") else 0.0)


def train_counts(cfg: ArchConfig,
                 remat_policy: Optional[str] = None) -> StepCounts:
    """fwd + bwd + optimizer.  bwd ≈ 2× fwd MXU flops; full remat re-runs
    the forward once more inside bwd."""
    fwd = forward_counts(cfg)
    mult = train_fwd_multiplier(cfg, remat_policy)
    pv = scale_vector(fwd, mult)
    bits = _bits(cfg)
    Np = cfg.n_params()
    # optimizer: read params+grads+m+v, write params+m+v (f32 states)
    pv = add_vectors(pv, {
        props.mem_key("load", 32, "s1"): 4.0 * Np,
        props.mem_key("store", 32, "s1"): 3.0 * Np,
        props.flop_key(32, "mul"): 8.0 * Np,
        props.flop_key(32, "add"): 6.0 * Np,
        props.flop_key(32, "special"): Np,  # rsqrt
        props.GROUPS: CeilDiv(B * S, Const(2 ** 14)),
    })
    model_flops = as_expr(6.0 * cfg.n_active_params()) * B * S
    return StepCounts(pv=pv, model_flops=model_flops)


def _n_attn_layers(cfg: ArchConfig) -> int:
    if not cfg.n_heads:
        return 0
    return (cfg.n_layers // cfg.hybrid.attn_every
            if cfg.family == "hybrid" else cfg.n_layers)


def _cache_write_elems(cfg: ArchConfig) -> ExprLike:
    """KV/SSM cache elements written when (B, S) prompt tokens prefill
    their slots: every attention layer stores the tokens' K and V rows;
    SSM layers store one final recurrent state per sequence."""
    out: ExprLike = as_expr(0)
    n_attn = _n_attn_layers(cfg)
    if n_attn:
        out = out + as_expr(2 * cfg.n_kv_heads * cfg.head_dim_
                            * n_attn) * B * S
    if cfg.ssm is not None:
        out = out + as_expr(cfg.n_layers * cfg.ssm_heads * cfg.ssm.head_dim
                            * cfg.ssm.d_state) * B
    return out


def prefill_counts(cfg: ArchConfig) -> StepCounts:
    """Serving prefill: one forward pass over (B, S) prompt tokens that
    additionally writes those tokens' KV/SSM cache rows on the way out —
    the cache-write traffic a pure forward pass does not pay."""
    pv = dict(forward_counts(cfg))
    bits = _bits(cfg)
    sk = props.mem_key("store", bits, "s1")
    pv[sk] = as_expr(pv[sk]) + _cache_write_elems(cfg)
    pv[props.GROUPS] = CeilDiv(B * S, Const(2 ** 14))
    return StepCounts(pv=pv,
                      model_flops=as_expr(2.0 * cfg.n_active_params()) * B * S)


def decode_counts(cfg: ArchConfig,
                  spec: Optional[wl.WorkloadSpec] = None) -> StepCounts:
    """One decode iteration against KV/SSM caches over B allocated slots.

    With a default ``spec`` (or None) this is the classic per-token count:
    one token per slot, every slot occupied and full — bitwise the
    pre-``WorkloadSpec`` closed forms.  Spec refinements swap dedicated
    free variables into the forms (``WorkloadSpec.structure`` is the
    program-cache key, so unrefined specs share the default programs):

      * ``cache_tokens`` → ``CT`` replaces the ``B·min(S, window)``
        cache-read/attention footprint — the total context actually
        resident across slots;
      * ``active_slots`` → per-token work (projections, FFN, head, VPU,
        cache writes) scales with ``AS`` instead of the allocated ``B``;
      * ``spec_len`` → ``SL`` multiplies token throughput (speculative
        decoding verifies SL tokens per iteration, each attending the
        full context);
      * ``moe_imbalance`` → ``MI`` multiplies expert-FFN compute (the
        hottest expert paces an EP decode step).
    """
    flags = frozenset(spec.structure()[1:]) if spec is not None \
        else frozenset()
    bits = _bits(cfg)
    L = cfg.n_layers
    pv: Dict[str, ExprLike] = {}
    d = cfg.d_model

    rows = AS if "as" in flags else B            # token rows computed
    tok = rows * SL if "sl" in flags else rows   # token positions/iteration
    # total context read this iteration, summed across slots
    ctx = Min(S, Const(cfg.sliding_window)) if cfg.sliding_window else S
    ctx_total = CT if "ct" in flags else ctx * B

    # per-token projection MACs (no sequence dim)
    if cfg.family == "ssm":
        mac = as_expr(_ssm_macs(cfg)) * L
        cache_elems = as_expr(L) * (cfg.ssm_heads * cfg.ssm.head_dim
                                    * cfg.ssm.d_state
                                    + (cfg.ssm.d_conv - 1)
                                    * (cfg.d_inner + 2 * cfg.ssm.n_groups
                                       * cfg.ssm.d_state)) * rows
        attn_flops = as_expr(0)
    else:
        proj = _attn_proj_macs(cfg)
        if cfg.moe is not None:
            expert = as_expr(_ffn_macs(cfg, _moe_active(cfg)))
            if "mi" in flags:
                expert = expert * MI
            ff = expert + _moe_dispatch_macs(cfg, tokens=tok)  # group = tok
        else:
            ff = as_expr(_ffn_macs(cfg))
        per_layer = as_expr(proj) + ff
        if cfg.family == "hybrid":
            k = cfg.hybrid.attn_every
            per_layer = as_expr(_ssm_macs(cfg)) \
                + (as_expr(proj) + as_expr(_ffn_macs(cfg))) * (1.0 / k)
        mac = per_layer * L
        # attention over the caches: 2·H·hd MACs per (new token × context
        # token) per attention layer (GQA shares the KV rows, not the MACs)
        n_attn = _n_attn_layers(cfg)
        attn_flops = as_expr(4 * cfg.n_heads * cfg.head_dim_
                             * n_attn) * ctx_total
        if "sl" in flags:
            attn_flops = attn_flops * SL
        cache_elems = (as_expr(2 * cfg.n_kv_heads * cfg.head_dim_ * n_attn)
                       * ctx_total)
        if cfg.family == "hybrid":
            cache_elems = cache_elems + as_expr(L) * rows * (
                cfg.ssm_heads * cfg.ssm.head_dim * cfg.ssm.d_state)
    pv[props.mxu_key(bits)] = \
        as_expr(2) * (mac + _embed_head_macs(cfg)) * tok + attn_flops

    pv = add_vectors(pv, scale_vector(_vpu_layer(cfg), tok * L))
    # params + cache stream once per decode step
    pv = add_vectors(pv, {
        props.mem_key("load", bits, "s1"): as_expr(cfg.n_params()) + cache_elems,
        props.mem_key("store", bits, "s1"):
            tok * (2 * max(cfg.n_kv_heads, 1) * cfg.head_dim_ if cfg.n_heads
                   else cfg.d_inner) * L
            + tok * cfg.vocab_size * cfg.n_output_heads,
        props.mem_key("load", bits, "gather"): tok * d,
        props.GROUPS: CeilDiv(B, Const(256)),
    })
    return StepCounts(pv=pv,
                      model_flops=as_expr(2.0 * cfg.n_active_params()) * tok)


def counts_for(cfg: ArchConfig, workload: wl.WorkloadLike,
               remat_policy: Optional[str] = None) -> StepCounts:
    """Symbolic step counts for a workload — a ``WorkloadSpec``, a
    ``ShapeConfig``, or (deprecated, warns) a bare phase string."""
    spec = wl.as_spec(workload)
    if spec.phase == "train":
        return train_counts(cfg, remat_policy=remat_policy)
    if spec.phase == "prefill":
        return prefill_counts(cfg)
    return decode_counts(cfg, spec)


# ---------------------------------------------------------------------------
# Collective counts for a (Plan, mesh) — the beyond-paper distributed terms
# ---------------------------------------------------------------------------


def collective_topology(plan) -> Tuple[bool, Optional[str], str]:
    """The plan fields that select *which* collective terms exist — the
    'topology class' of ``collective_counts_symbolic``.  Plans sharing a
    class share one compiled collective vector; everything else about the
    mesh (dp/tp ways) and the schedule (microbatches) enters through the
    free variables DP/TP/M."""
    return (bool(plan.fsdp), plan.compression, plan.moe_mode)


def collective_counts_symbolic(cfg: ArchConfig, kind,
                               topology: Tuple[bool, Optional[str], str]
                               ) -> Dict[str, ExprLike]:
    """Per-device collective bytes as Exprs in {B, S, M, DP, TP}.

    ``kind`` may be a phase string or anything with a ``.kind`` (a
    ``WorkloadSpec`` or ``ShapeConfig``) — collectives depend only on the
    phase, so the bare string stays first-class here.

    The closed forms are ``collective_counts``'s, with the mesh-dependent
    gates (``dp > 1``, ``tp > 1``) expressed as ``Piecewise`` guards on
    ``DP - 1`` / ``TP - 1`` instead of Python ``if``s — so ONE compiled
    vector per (kind, topology class) scores a whole mesh-factorization
    sweep as array ops (``np.where`` over the DP/TP arrays).  The batched
    search engine (``core.planspace``) compiles these per class; the
    interpreted ``collective_counts`` stays the per-plan reference and
    tests pin the two pointwise.
    """
    kind = getattr(kind, "kind", kind)  # WorkloadSpec/ShapeConfig → phase
    fsdp, compression, moe_mode = topology
    bits = _bits(cfg)
    bytes_per = bits // 8
    out: Dict[str, ExprLike] = {}
    T_dev = B * S / DP            # tokens per device
    d = cfg.d_model
    zero = Const(0)

    param_bytes_tp = as_expr(cfg.n_params() * bytes_per) / TP
    if fsdp:
        n_gather = (2.0 * M) if kind == "train" else as_expr(1.0)
        gather = n_gather * ((DP - 1) / DP) * param_bytes_tp
        out[props.coll_key("all_gather")] = Piecewise([(DP - 1, gather)],
                                                      zero)
    if kind == "train":
        grad_bytes = as_expr(4.0 * cfg.n_params()) / TP  # f32, TP-sharded
        if compression == "int8_ef":
            grad_bytes = grad_bytes / 4.0
        if fsdp:  # grads land sharded: reduce-scatter, 1× wire
            out[props.coll_key("reduce_scatter")] = Piecewise(
                [(DP - 1, ((DP - 1) / DP) * grad_bytes)], zero)
        else:
            out[props.coll_key("all_reduce")] = Piecewise(
                [(DP - 1, 2.0 * ((DP - 1) / DP) * grad_bytes)], zero)
    if cfg.n_heads:
        # Megatron TP: 2 all-reduces of the residual per layer fwd (+2 bwd)
        n_ar = 2.0 * cfg.n_layers * (2.0 if kind == "train" else 1.0)
        act = (as_expr(B) * d * bytes_per if kind == "decode"
               else T_dev * d * bytes_per)
        term = Piecewise(
            [(TP - 1, as_expr(n_ar * 2.0) * ((TP - 1) / TP) * act)], zero)
        prev = out.get(props.coll_key("all_reduce"))
        out[props.coll_key("all_reduce")] = \
            term if prev is None else as_expr(prev) + term
    if cfg.moe is not None and moe_mode == "ep":
        tok = as_expr(B) if kind == "decode" else T_dev
        a2a = tok * d * bytes_per * cfg.moe.top_k * 2.0  # dispatch + combine
        out[props.coll_key("all_to_all")] = Piecewise(
            [(TP - 1, a2a * ((TP - 1) / TP))], zero)
    # canonicalize: the gate/traffic products above repeat subterms (the
    # (DP-1)/DP wire factors, the TP-sharded byte counts); simplifying here
    # benefits both the per-property compiled vectors and the fused basis
    # programs built from this map
    from repro.core import exprops
    return {k: exprops.simplify(v) for k, v in out.items()}


def collective_counts(cfg: ArchConfig, kind, plan, mesh_shape:
                      Mapping[str, int]) -> Dict[str, ExprLike]:
    """Per-device collective *bytes* per step for a sharding plan.

    ``kind`` may be a phase string or anything with a ``.kind`` (a
    ``WorkloadSpec``/``ShapeConfig``) — collectives depend only on phase.

    Closed forms (ring algorithms, per-device traffic ≈ 2·(n−1)/n·bytes for
    all-reduce, (n−1)/n for all-gather / reduce-scatter):
      · DP gradients: all-reduce (replicated params) or reduce-scatter
        (FSDP, grads land sharded) — int8 compression divides by 4
      · FSDP param all-gather: 2·(fwd+bwd) per microbatch, bf16
      · TP activation collectives per layer (Megatron: 2 AR fwd (+2 bwd))
      · EP all-to-all dispatch+combine (MoE)
    """
    kind = getattr(kind, "kind", kind)
    bits = _bits(cfg)
    bytes_per = bits // 8
    dp = 1
    for ax in plan.dp_axes:
        dp *= mesh_shape.get(ax, 1)
    tp = mesh_shape.get(plan.tp_axis, 1) if plan.tp_axis else 1
    out: Dict[str, ExprLike] = {}
    T_dev = B * S / Const(max(dp, 1))  # tokens per device
    d = cfg.d_model
    M_ = plan.microbatches

    param_bytes_tp = cfg.n_params() * bytes_per / max(tp, 1)
    if plan.fsdp and dp > 1:
        # each microbatch re-gathers the dp-sharded params (fwd + bwd)
        n_gather = (2.0 * M_ if kind == "train" else 1.0)
        out[props.coll_key("all_gather")] = \
            n_gather * (dp - 1) / dp * param_bytes_tp
    if kind == "train" and dp > 1:
        grad_bytes = 4.0 * cfg.n_params() / max(tp, 1)  # f32 grads, TP-sharded
        if plan.compression == "int8_ef":
            grad_bytes /= 4.0
        if plan.fsdp:  # grads land sharded: reduce-scatter, 1× wire
            out[props.coll_key("reduce_scatter")] = \
                (dp - 1) / dp * grad_bytes
        else:
            out[props.coll_key("all_reduce")] = \
                2.0 * (dp - 1) / dp * grad_bytes
    if tp > 1 and cfg.n_heads:
        # Megatron TP: 2 all-reduces of the (T_dev × d) residual per layer
        # fwd (+2 bwd for train)
        n_ar = 2.0 * cfg.n_layers * (2.0 if kind == "train" else 1.0)
        if kind == "decode":
            act = as_expr(B) * d * bytes_per
        else:
            act = T_dev * d * bytes_per
        out[props.coll_key("all_reduce")] = out.get(
            props.coll_key("all_reduce"), as_expr(0)) \
            + as_expr(n_ar * 2.0 * (tp - 1) / tp) * act
    if cfg.moe is not None and plan.moe_mode == "ep" and tp > 1:
        tok = as_expr(B) if kind == "decode" else T_dev
        a2a = tok * d * bytes_per * cfg.moe.top_k * 2.0  # dispatch + combine
        out[props.coll_key("all_to_all")] = a2a * (tp - 1) / tp
    return out
