"""Weight fitting — paper §4.3.

The paper minimizes *relative* squared error

    Σ_j (1 − Σ_i α_i p_ij / T_j)²,

which is ordinary least squares on the property matrix with row j scaled by
1/T_j and unit targets.  We solve it with numpy lstsq; a small ridge term is
available (useful when the runtime device collapses rate distinctions the
taxonomy keeps separate — e.g. a CPU has no coalescing cliff, so stride
columns become near-collinear; see EXPERIMENTS.md, "Caveats: ridge and
NNLS"), as is projected
non-negative refinement (the paper's fitted weights may legitimately be
negative — Table 2 has negative local-load and min(L,S) entries — so NNLS
is *off* by default).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core import properties as props
from repro.core.model import LinearCostModel
from repro.obs import metrics as _obs_metrics
from repro.obs import report as _obs_report

_RLS_QUARANTINED = _obs_metrics.REGISTRY.counter(
    "repro_rls_quarantined_total",
    "streaming calibration samples quarantined (non-finite/non-positive "
    "seconds or non-finite property values) instead of entering the RLS "
    "state")


def fit_relative(pvs: Sequence[Mapping[str, float]],
                 times: Sequence[float],
                 device: str = "unknown",
                 ridge: float = 0.0,
                 nonneg: bool = False,
                 keys: Optional[List[str]] = None) -> LinearCostModel:
    """Fit α minimizing Σ_j (1 − <α, p_j>/T_j)²  (+ ridge ‖D α‖²).

    The ridge penalty is scaled per column by the column norm of the
    T-normalized design matrix, so regularization strength is unit-free.
    """
    assert len(pvs) == len(times) and len(pvs) > 0
    keys = keys or props.union_keys(pvs)
    A = props.to_matrix(list(pvs), keys)  # (J, I)
    T = np.asarray(list(times), dtype=np.float64)
    assert np.all(T > 0), "non-positive measured times"
    An = A / T[:, None]  # row scaling by 1/T_j
    b = np.ones(len(T))

    if ridge > 0.0:
        col = np.linalg.norm(An, axis=0)
        col = np.where(col > 0, col, 1.0)
        R = np.diag(np.sqrt(ridge) * col)
        An = np.vstack([An, R])
        b = np.concatenate([b, np.zeros(len(keys))])

    w, *_ = np.linalg.lstsq(An, b, rcond=None)

    if nonneg:
        w = _nnls_projected(An, b, w)

    model = LinearCostModel(keys=keys, weights=w, device=device,
                            meta={"ridge": ridge, "nonneg": nonneg,
                                  "n_measurements": len(T)})
    return model


def _nnls_projected(A: np.ndarray, b: np.ndarray, w0: np.ndarray,
                    iters: int = 2000, tol: float = 1e-14) -> np.ndarray:
    """Projected-gradient NNLS refinement (scipy-free)."""
    L = np.linalg.norm(A, 2) ** 2
    if L == 0:
        return np.maximum(w0, 0.0)
    step = 1.0 / L
    w = np.maximum(w0, 0.0)
    AtA, Atb = A.T @ A, A.T @ b
    last = np.inf
    for _ in range(iters):
        g = AtA @ w - Atb
        w = np.maximum(w - step * g, 0.0)
        f = 0.5 * w @ AtA @ w - Atb @ w
        if abs(last - f) < tol * max(abs(f), 1.0):
            break
        last = f
    return w


# ---------------------------------------------------------------------------
# Streaming refit — recursive least squares on the relative-error system
# ---------------------------------------------------------------------------


class RLSState:
    """Recursive least squares over the paper's relative-error rows.

    Each sample (property vector ``p``, measured seconds ``T``) contributes
    the row ``a = p / T`` with unit target — exactly ``fit_relative``'s
    T-normalized system, fed one measurement at a time.

    The state is the *information form*: the exponentially-discounted Gram
    ``G = Σ_j lam^(n-j) ã_j ã_jᵀ + lam^n/delta · I`` and right-hand side,
    updated in O(k²) per sample, with the weights solved on demand (k is
    the taxonomy size, ≤ a few dozen, so the O(k³) solve is trivial).  The
    classic covariance form (propagating P = G⁻¹) is O(k²) throughout but
    numerically treacherous here: taxonomy columns span ~9 orders of
    magnitude (an mxu flop count vs the const1 launch term), and the
    P-update's cancellation then corrupts the gains — the well-known RLS
    divergence.  Rows are also column-preconditioned by the first observed
    row (``col_scale``, a pure reparameterization), so the Gram stays
    near-unit scale regardless of the taxonomy's dynamic range.

    Exactness: with forgetting factor ``lam`` and prior ``(w0, delta)``
    this solves

        min_w  Σ_j lam^(n-j) (1 − <a_j, w>)²  +  (lam^n/delta)·‖S(w−w0)‖²

    (S the first-row column scaling), so with ``lam = 1`` and ``delta``
    large it equals batch ``fit_relative`` (ridge 0) on the same sample
    stream up to a vanishing prior term — ``tests/test_online_calibration``
    pins the two at rtol 1e-7.  With ``lam < 1`` it is the exponentially-
    windowed fit that tracks drift: samples older than ~1/(1−lam) steps
    fade from the solution.  A warm start ``from_model`` anchors
    rank-deficient streams (a trainer feeding one property vector forever)
    to the registered weights instead of collapsing unobserved directions
    to zero.
    """

    def __init__(self, keys: Sequence[str], lam: float = 1.0,
                 delta: float = 1e12, w0: Optional[np.ndarray] = None):
        if not 0.0 < lam <= 1.0:
            raise ValueError(f"forgetting factor must be in (0, 1]: {lam}")
        self.keys: List[str] = list(keys)
        self.lam = float(lam)
        self.delta = float(delta)
        k = len(self.keys)
        self.w0 = (np.zeros(k) if w0 is None
                   else np.asarray(w0, dtype=np.float64).copy())
        self.n_samples = 0
        self.n_quarantined = 0
        self.col_scale: Optional[np.ndarray] = None
        self._G: Optional[np.ndarray] = None   # scaled-space Gram + prior
        self._b: Optional[np.ndarray] = None   # scaled-space RHS
        self._w: Optional[np.ndarray] = None   # lazy solve cache

    @classmethod
    def init(cls, keys: Sequence[str], lam: float = 1.0,
             delta: float = 1e12,
             w0: Optional[np.ndarray] = None) -> "RLSState":
        return cls(keys, lam=lam, delta=delta, w0=w0)

    @classmethod
    def from_model(cls, model: LinearCostModel, lam: float = 1.0,
                   delta: float = 1e12) -> "RLSState":
        """Warm-start from a registered model (prior centered on its α)."""
        return cls(model.keys, lam=lam, delta=delta, w0=model.weights)

    # ------------------------------------------------------------------
    @property
    def w(self) -> np.ndarray:
        """Current weight estimate, natural (seconds/event) space."""
        if self._G is None:
            return self.w0.copy()
        if self._w is None:
            v, *_ = np.linalg.lstsq(self._G, self._b, rcond=None)
            self._w = v / self.col_scale
        return self._w

    def row(self, pv: Mapping[str, float], seconds: float) -> np.ndarray:
        if not seconds > 0:
            raise ValueError(f"non-positive measured time: {seconds}")
        return np.asarray([pv.get(k, 0.0) for k in self.keys],
                          dtype=np.float64) / seconds

    def update(self, a: np.ndarray, y: float) -> None:
        """One generic (row, target) recursive step."""
        a = np.asarray(a, dtype=np.float64)
        if self.col_scale is None:
            s = np.abs(a)
            self.col_scale = np.where(s > 0, s, 1.0)
            k = len(self.keys)
            self._G = np.eye(k) / self.delta
            self._b = (self.w0 * self.col_scale) / self.delta
        a = a / self.col_scale
        self._G = self.lam * self._G + np.outer(a, a)
        self._b = self.lam * self._b + a * y
        self._w = None
        self.n_samples += 1

    def observe(self, pv: Mapping[str, float], seconds: float) -> bool:
        """Ingest one (property vector, measured seconds) sample.

        The streaming path must survive a poisoned measurement (a clock
        glitch, an injected NaN): a non-finite/non-positive ``seconds``
        or a non-finite property value is QUARANTINED — counted in
        ``repro_rls_quarantined_total``, reported on a ``[calib]`` line,
        and the state left untouched — instead of raising the
        ``ValueError`` the strict batch path (``fit_relative``) keeps.
        Returns True when the sample entered the state."""
        bad = None
        if not (np.isfinite(seconds) and seconds > 0):
            bad = f"seconds={seconds}"
        else:
            vals = np.asarray([pv.get(k, 0.0) for k in self.keys],
                              dtype=np.float64)
            if not np.all(np.isfinite(vals)):
                bad = "non-finite property value"
        if bad is not None:
            self.n_quarantined += 1
            _RLS_QUARANTINED.inc()
            _obs_report.emit("calib", {
                "action": "quarantine", "n": self.n_quarantined},
                text=f"sample rejected ({bad})")
            return False
        self.update(self.row(pv, seconds), 1.0)
        return True

    def observe_many(self, pvs: Sequence[Mapping[str, float]],
                     times: Sequence[float]) -> None:
        for pv, t in zip(pvs, times):
            self.observe(pv, t)

    def predict(self, pv: Mapping[str, float]) -> float:
        """<w, p> under the current streaming estimate."""
        return float(sum(w * pv.get(k, 0.0)
                         for k, w in zip(self.keys, self.w) if pv.get(k)))

    def model(self, device: str = "rls",
              meta: Optional[dict] = None) -> LinearCostModel:
        """Materialize the current estimate as a ``LinearCostModel``."""
        m = dict(meta or {})
        m.setdefault("source", "rls-refit")
        m.update({"forgetting": self.lam, "n_samples": self.n_samples})
        return LinearCostModel(keys=list(self.keys), weights=self.w.copy(),
                               device=device, meta=m)


# ---------------------------------------------------------------------------
# Learned residual head — ridge regression on the basis features
# ---------------------------------------------------------------------------


@dataclass
class ResidualHead:
    """Multiplicative learned correction on top of the analytic model.

    The hybrid form: the analytic prediction supplies the physics, a small
    ridge-regularized linear head on the (log-compressed, standardized)
    property-vector features learns what the fixed basis cannot express —
    ``T̂ = <α, p> · exp(clip(<β, z(p)>))``.  Working in log space makes the
    correction multiplicative and symmetric (a 2× underprediction and a 2×
    overprediction are equal-magnitude targets); the clip bounds the head's
    authority so a wild extrapolation can never flip a ranking by orders of
    magnitude.
    """

    keys: List[str]
    mean: np.ndarray               # feature standardization, log1p space
    scale: np.ndarray
    beta: np.ndarray               # (len(keys) + 1,), last entry = bias
    clip: float = 2.0              # bound on |log correction|
    meta: Dict[str, object] = field(default_factory=dict)

    def _features(self, pv: Mapping[str, float]) -> np.ndarray:
        x = np.log1p(np.asarray([pv.get(k, 0.0) for k in self.keys],
                                dtype=np.float64))
        return (x - self.mean) / self.scale

    def log_correction(self, pv: Mapping[str, float]) -> float:
        z = self._features(pv)
        raw = float(z @ self.beta[:-1] + self.beta[-1])
        return float(np.clip(raw, -self.clip, self.clip))

    def correction(self, pv: Mapping[str, float]) -> float:
        """The multiplicative factor applied to the analytic prediction."""
        return float(np.exp(self.log_correction(pv)))

    def predict(self, model: LinearCostModel,
                pv: Mapping[str, float]) -> float:
        return model.predict(pv) * self.correction(pv)

    # -- serialization -----------------------------------------------------
    def to_json_dict(self) -> Dict[str, object]:
        return {"kind": "residual_head", "keys": list(self.keys),
                "mean": self.mean.tolist(), "scale": self.scale.tolist(),
                "beta": self.beta.tolist(), "clip": self.clip,
                "meta": self.meta}

    @classmethod
    def from_json_dict(cls, d: Mapping[str, object]) -> "ResidualHead":
        if d.get("kind") != "residual_head":
            raise ValueError(f"not a residual_head record: {d.get('kind')!r}")
        return cls(keys=list(d["keys"]),
                   mean=np.asarray(d["mean"], dtype=np.float64),
                   scale=np.asarray(d["scale"], dtype=np.float64),
                   beta=np.asarray(d["beta"], dtype=np.float64),
                   clip=float(d.get("clip", 2.0)),
                   meta=dict(d.get("meta", {})))


def fit_residual(pvs: Sequence[Mapping[str, float]],
                 times: Sequence[float], model: LinearCostModel,
                 ridge: float = 1e-2, clip: float = 2.0,
                 keys: Optional[List[str]] = None
                 ) -> Optional[ResidualHead]:
    """Fit a ``ResidualHead`` on the samples' log-ratio residuals.

    Targets are ``log(T_j / <α, p_j>)``; rows where either side is
    non-positive carry no usable log-ratio and are skipped.  Returns None
    when fewer than 2 usable samples remain (no head is better than a head
    fit on nothing).
    """
    keys = keys or props.union_keys(pvs)
    preds = np.asarray(model.predict_many(list(pvs)), dtype=np.float64)
    T = np.asarray(list(times), dtype=np.float64)
    ok = (preds > 0) & (T > 0)
    if int(ok.sum()) < 2:
        return None
    X = np.log1p(props.to_matrix([pvs[i] for i in np.nonzero(ok)[0]], keys))
    y = np.log(T[ok] / preds[ok])
    mean = X.mean(axis=0)
    scale = X.std(axis=0)
    scale = np.where(scale > 1e-12, scale, 1.0)
    Z = np.hstack([(X - mean) / scale, np.ones((X.shape[0], 1))])
    # ridge on the feature weights only; the bias column stays unpenalized
    R = np.sqrt(ridge * len(y)) * np.eye(len(keys) + 1)
    R[-1, -1] = 0.0
    A = np.vstack([Z, R])
    b = np.concatenate([y, np.zeros(len(keys) + 1)])
    beta, *_ = np.linalg.lstsq(A, b, rcond=None)
    return ResidualHead(keys=list(keys), mean=mean, scale=scale, beta=beta,
                        clip=clip,
                        meta={"ridge": ridge, "n_samples": int(ok.sum())})


# ---------------------------------------------------------------------------
# Fit diagnostics
# ---------------------------------------------------------------------------


def safe_relative_errors(preds: Sequence[float], times: Sequence[float],
                         floor: float = 1e-12) -> np.ndarray:
    """|pred − actual| / actual with zero/near-zero timings mapped to inf.

    Fast measurement kernels can legitimately time at (or below) clock
    resolution; a report must flag those rows as unreliable, not crash on
    the division.  Rows with ``actual <= floor`` come back as ``inf``.
    """
    p = np.asarray(list(preds), dtype=np.float64)
    t = np.asarray(list(times), dtype=np.float64)
    out = np.full(t.shape, np.inf)
    ok = t > floor
    out[ok] = np.abs(p[ok] - t[ok]) / t[ok]
    return out


def fit_report(model: LinearCostModel, pvs: Sequence[Mapping[str, float]],
               times: Sequence[float],
               labels: Optional[Sequence[str]] = None) -> Dict[str, object]:
    """Per-kernel relative errors + geomean (paper Table 1 bottom row).

    Zero/near-zero timings report ``inf`` per-row errors (see
    ``safe_relative_errors``) and are excluded from the geomean/max
    summaries, which cover the ``n_finite`` reliable rows."""
    from repro.core.model import geomean
    preds = model.predict_many(list(pvs))
    errs = safe_relative_errors(preds, times)
    rows = []
    for i, (p, t, e) in enumerate(zip(preds, times, errs)):
        rows.append({
            "label": labels[i] if labels else str(i),
            "predicted_s": float(p), "actual_s": float(t),
            "rel_err": float(e),
        })
    finite = errs[np.isfinite(errs)]
    return {"rows": rows,
            "geomean_rel_err": geomean(finite) if len(finite)
            else float("inf"),
            "max_rel_err": float(finite.max()) if len(finite)
            else float("inf"),
            "n": len(errs), "n_finite": int(len(finite))}


def condition_report(pvs: Sequence[Mapping[str, float]],
                     times: Sequence[float]) -> Dict[str, float]:
    """Design-matrix conditioning of the T-normalized system.

    Rows with zero/near-zero timings cannot be T-normalized; they are
    dropped from the conditioning analysis and counted in ``n_dropped``."""
    keys = props.union_keys(pvs)
    T = np.asarray(list(times), dtype=np.float64)
    ok = T > 1e-12
    A = props.to_matrix([pv for pv, k in zip(pvs, ok) if k],
                        keys) / T[ok][:, None]
    if A.shape[0] == 0:
        return {"n_rows": 0, "n_cols": len(keys), "rank": 0,
                "cond": float("inf"), "n_dropped": int((~ok).sum())}
    s = np.linalg.svd(A, compute_uv=False)
    s = s[s > 0]
    return {"n_rows": A.shape[0], "n_cols": A.shape[1],
            "rank": int(np.linalg.matrix_rank(A)),
            "cond": float(s[0] / s[-1]) if len(s) else float("inf"),
            "n_dropped": int((~ok).sum())}
