"""Weight fitting — paper §4.3.

The paper minimizes *relative* squared error

    Σ_j (1 − Σ_i α_i p_ij / T_j)²,

which is ordinary least squares on the property matrix with row j scaled by
1/T_j and unit targets.  We solve it with numpy lstsq; a small ridge term is
available (useful when the runtime device collapses rate distinctions the
taxonomy keeps separate — e.g. a CPU has no coalescing cliff, so stride
columns become near-collinear; see EXPERIMENTS.md, "Caveats: ridge and
NNLS"), as is projected
non-negative refinement (the paper's fitted weights may legitimately be
negative — Table 2 has negative local-load and min(L,S) entries — so NNLS
is *off* by default).
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core import properties as props
from repro.core.model import LinearCostModel


def fit_relative(pvs: Sequence[Mapping[str, float]],
                 times: Sequence[float],
                 device: str = "unknown",
                 ridge: float = 0.0,
                 nonneg: bool = False,
                 keys: Optional[List[str]] = None) -> LinearCostModel:
    """Fit α minimizing Σ_j (1 − <α, p_j>/T_j)²  (+ ridge ‖D α‖²).

    The ridge penalty is scaled per column by the column norm of the
    T-normalized design matrix, so regularization strength is unit-free.
    """
    assert len(pvs) == len(times) and len(pvs) > 0
    keys = keys or props.union_keys(pvs)
    A = props.to_matrix(list(pvs), keys)  # (J, I)
    T = np.asarray(list(times), dtype=np.float64)
    assert np.all(T > 0), "non-positive measured times"
    An = A / T[:, None]  # row scaling by 1/T_j
    b = np.ones(len(T))

    if ridge > 0.0:
        col = np.linalg.norm(An, axis=0)
        col = np.where(col > 0, col, 1.0)
        R = np.diag(np.sqrt(ridge) * col)
        An = np.vstack([An, R])
        b = np.concatenate([b, np.zeros(len(keys))])

    w, *_ = np.linalg.lstsq(An, b, rcond=None)

    if nonneg:
        w = _nnls_projected(An, b, w)

    model = LinearCostModel(keys=keys, weights=w, device=device,
                            meta={"ridge": ridge, "nonneg": nonneg,
                                  "n_measurements": len(T)})
    return model


def _nnls_projected(A: np.ndarray, b: np.ndarray, w0: np.ndarray,
                    iters: int = 2000, tol: float = 1e-14) -> np.ndarray:
    """Projected-gradient NNLS refinement (scipy-free)."""
    L = np.linalg.norm(A, 2) ** 2
    if L == 0:
        return np.maximum(w0, 0.0)
    step = 1.0 / L
    w = np.maximum(w0, 0.0)
    AtA, Atb = A.T @ A, A.T @ b
    last = np.inf
    for _ in range(iters):
        g = AtA @ w - Atb
        w = np.maximum(w - step * g, 0.0)
        f = 0.5 * w @ AtA @ w - Atb @ w
        if abs(last - f) < tol * max(abs(f), 1.0):
            break
        last = f
    return w


# ---------------------------------------------------------------------------
# Fit diagnostics
# ---------------------------------------------------------------------------


def fit_report(model: LinearCostModel, pvs: Sequence[Mapping[str, float]],
               times: Sequence[float],
               labels: Optional[Sequence[str]] = None) -> Dict[str, object]:
    """Per-kernel relative errors + geomean (paper Table 1 bottom row)."""
    from repro.core.model import geomean, relative_error
    preds = model.predict_many(list(pvs))
    errs = [relative_error(p, t) for p, t in zip(preds, times)]
    rows = []
    for i, (p, t, e) in enumerate(zip(preds, times, errs)):
        rows.append({
            "label": labels[i] if labels else str(i),
            "predicted_s": float(p), "actual_s": float(t),
            "rel_err": float(e),
        })
    return {"rows": rows, "geomean_rel_err": geomean(errs),
            "max_rel_err": float(max(errs)), "n": len(errs)}


def condition_report(pvs: Sequence[Mapping[str, float]],
                     times: Sequence[float]) -> Dict[str, float]:
    """Design-matrix conditioning of the T-normalized system."""
    keys = props.union_keys(pvs)
    A = props.to_matrix(list(pvs), keys) / np.asarray(times)[:, None]
    s = np.linalg.svd(A, compute_uv=False)
    s = s[s > 0]
    return {"n_rows": A.shape[0], "n_cols": A.shape[1],
            "rank": int(np.linalg.matrix_rank(A)),
            "cond": float(s[0] / s[-1]) if len(s) else float("inf")}
