"""Kernel-level symbolic property vectors — the per-kernel unit of
prediction (paper §6.2, and the follow-up cross-machine models).

Where ``core.archcount`` emits one property vector per *training step*,
this module emits one per *Pallas kernel launch*, parameterized over both
the problem shape AND the launch configuration (block/tile sizes) as
``symcount`` variables.  That makes a block-size sweep a pure array
evaluation: compile each property once (``Expr.compile``), feed the whole
candidate grid as numpy arrays, and score every configuration through a
fitted ``LinearCostModel`` with a handful of ufuncs — no per-point Python
tree-walks, no kernel launches.

Per kernel we count (mirroring the concrete ``schedule_props`` in
``repro.kernels.*``, but closed-form in the block variables):

  * ``mxu:<bits>``    — dot MACs×2 at *block-rounded* granularity, so a
                        block that overshoots the shape pays for its padding
                        (the real kernel does too);
  * ``local:<bits>``  — VMEM block traffic per grid cell;
  * ``barrier``       — grid steps (sequential-dimension synchronisations);
  * ``groups``        — parallel grid cells (launch/occupancy proxy);
  * ``const1``        — 1 per launch.

The causal / sliding-window skip structure of flash attention is priced
with exact closed forms where they exist (square-block causal triangle) and
documented closed-form bounds otherwise — the tuner only needs the vector
family to be *self-consistent* across the candidate grid.

``step_kernel_vectors`` recomposes a whole forward pass out of these
per-kernel vectors (projections/FFN/head → matmul, attention → flash,
SSD → ssd_scan), which is what ``core.predictor`` now uses for its compute
term — the step predictor and the kernel autotuner score the SAME counts.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core import properties as props
from repro.core.symcount import (
    CeilDiv, Const, Expr, ExprLike, Max, Min, Var, add_vectors, as_expr,
    compile_vector, evaluate_vector, scale_vector,
)

# Free variables of the step-level composition (same names as archcount)
B = Var("B")   # global batch
S = Var("S")   # sequence length


# ---------------------------------------------------------------------------
# Per-kernel symbolic property vectors
# ---------------------------------------------------------------------------


def matmul_vector(M: ExprLike, N: ExprLike, K: ExprLike, *,
                  block_m: ExprLike = 128, block_n: ExprLike = 128,
                  block_k: ExprLike = 128, bits: int = 32
                  ) -> Dict[str, ExprLike]:
    """(M,K)@(K,N) tiled matmul: (bm×bk)+(bk×bn) tiles stream HBM→VMEM per
    grid cell, fp32 (bm×bn) accumulator carried across the sequential k
    walk."""
    M, N, K = as_expr(M), as_expr(N), as_expr(K)
    bm, bn, bk = as_expr(block_m), as_expr(block_n), as_expr(block_k)
    n_m, n_n, n_k = CeilDiv(M, bm), CeilDiv(N, bn), CeilDiv(K, bk)
    cells = n_m * n_n * n_k
    local = cells * (bm * bk + bk * bn + bm * bn)
    return {
        props.local_key(bits): local,
        props.BARRIER: cells,
        props.GROUPS: n_m * n_n,
        props.mxu_key(bits): 2 * cells * bm * bn * bk,
        props.CONST1: 1.0,
    }


def _fa_exec_blocks(n_q: Expr, n_k: Expr, *, causal: bool,
                    window: Optional[int], block_q: ExprLike,
                    block_k: ExprLike) -> Expr:
    """Executed (non-skipped) (q-block, k-block) pairs per (batch, head).

    causal: ceil((n_q·n_k + max(n_q, n_k)) / 2) — exact for the square
    case (block_q == block_k, Sq == Skv): triangle + diagonal.
    window w: at most ceil(w / block_k) + 1 k-blocks intersect a q-row's
    band; combined with causal by taking the tighter bound.
    """
    full = n_q * n_k
    execd = full
    if causal:
        execd = CeilDiv(full + Max(n_q, n_k), Const(2))
    if window is not None:
        band = Min(n_k, CeilDiv(Const(window), as_expr(block_k)) + 1)
        execd = Min(execd, n_q * band)
    return execd


def flash_attention_vector(B_: ExprLike, H: ExprLike, KVH: ExprLike,
                           Sq: ExprLike, Skv: ExprLike, dh: ExprLike, *,
                           causal: bool = True, window: Optional[int] = None,
                           block_q: ExprLike = 128, block_k: ExprLike = 128,
                           bits: int = 16) -> Dict[str, ExprLike]:
    """Online-softmax attention: q/k/v tiles stream per executed pair; the
    (bq×bk) logit tile never leaves VMEM; fully-masked pairs are skipped
    (but their grid steps still barrier)."""
    bq, bk = as_expr(block_q), as_expr(block_k)
    n_q, n_k = CeilDiv(as_expr(Sq), bq), CeilDiv(as_expr(Skv), bk)
    cells = as_expr(B_) * as_expr(H) * n_q * n_k
    execd = _fa_exec_blocks(n_q, n_k, causal=causal, window=window,
                            block_q=bq, block_k=bk)
    exec_cells = as_expr(B_) * as_expr(H) * execd
    local = exec_cells * (bq * as_expr(dh) + 2 * bk * as_expr(dh))
    return {
        props.local_key(bits): local,
        props.BARRIER: cells,
        props.GROUPS: cells,
        props.mxu_key(bits): 4 * exec_cells * bq * bk * as_expr(dh),
        props.CONST1: 1.0,
    }


def ssd_scan_vector(Bz: ExprLike, H: ExprLike, L: ExprLike, P: ExprLike,
                    N: ExprLike, *, chunk: ExprLike = 128, bits: int = 16
                    ) -> Dict[str, ExprLike]:
    """Chunked SSD: per (batch, head, chunk) cell the x/B/C blocks move
    HBM→VMEM and the (P×N) state stays VMEM-resident.  Intra-chunk work is
    quadratic in the chunk; the state update is paid once per chunk — the
    block-size tradeoff the tuner balances."""
    Q = as_expr(chunk)
    nc = CeilDiv(as_expr(L), Q)
    cells = as_expr(Bz) * as_expr(H) * nc
    local = cells * (Q * as_expr(P) + 2 * Q * as_expr(N)
                     + as_expr(P) * as_expr(N))
    mxu = cells * 2 * (Q * Q * as_expr(N)          # C·Bᵀ
                       + Q * Q * as_expr(P)        # W·x (intra)
                       + Q * as_expr(P) * as_expr(N) * 2)  # inter + state
    return {
        props.local_key(bits): local,
        props.BARRIER: cells,
        props.GROUPS: cells,
        props.mxu_key(bits): mxu,
        props.CONST1: 1.0,
    }


def transpose_vector(M: ExprLike, N: ExprLike, *, block: ExprLike = 256,
                     bits: int = 32) -> Dict[str, ExprLike]:
    """VMEM-tile relayout: each (b×b) tile passes through VMEM twice
    (stream in, stream out) so both HBM directions stay stride-1."""
    b = as_expr(block)
    bm, bn = Min(b, as_expr(M)), Min(b, as_expr(N))
    cells = CeilDiv(as_expr(M), bm) * CeilDiv(as_expr(N), bn)
    return {
        props.local_key(bits): cells * 2 * bm * bn,
        props.BARRIER: cells,
        props.GROUPS: cells,
        props.CONST1: 1.0,
    }


# ---------------------------------------------------------------------------
# Kernel registry — shape/block parameter spaces + VMEM footprints
# ---------------------------------------------------------------------------

VMEM_BYTES = 16 * 2 ** 20   # v5e VMEM per core
VMEM_BUDGET = 0.75          # leave headroom for compiler temporaries


def _pow2_divisors(n: int, lo: int, hi: int) -> List[int]:
    out, b = [], lo
    while b <= min(n, hi):
        if n % b == 0:
            out.append(b)
        b *= 2
    return out or [min(n, hi)]


@dataclass(frozen=True)
class KernelModel:
    """One kernel family: symbolic vector builder + its config space."""
    name: str
    shape_params: Tuple[str, ...]
    block_params: Tuple[str, ...]
    #: (shape, blocks) -> Dict[str, ExprLike]; entries of either mapping may
    #: be symcount Exprs, so one builder serves sweeps and step composition
    builder: Callable[..., Dict[str, ExprLike]]
    #: shape -> list of concrete candidate block dicts (pre-VMEM-filter)
    candidates: Callable[[Mapping[str, int]], List[Dict[str, int]]]
    #: (shape, blocks) -> concrete VMEM bytes for feasibility filtering
    vmem_bytes: Callable[[Mapping[str, int], Mapping[str, int]], float]

    def vector(self, shape: Mapping[str, ExprLike],
               blocks: Mapping[str, ExprLike]) -> Dict[str, ExprLike]:
        return self.builder(shape, blocks)

    def symbolic_blocks(self) -> Dict[str, Var]:
        return {b: Var(b) for b in self.block_params}


def _mm_builder(shape, blocks):
    return matmul_vector(shape["M"], shape["N"], shape["K"],
                         block_m=blocks["block_m"], block_n=blocks["block_n"],
                         block_k=blocks["block_k"],
                         bits=int(shape.get("bits", 32)))


def _mm_candidates(shape):
    return [{"block_m": bm, "block_n": bn, "block_k": bk}
            for bm in _pow2_divisors(int(shape["M"]), 32, 512)
            for bn in _pow2_divisors(int(shape["N"]), 32, 512)
            for bk in _pow2_divisors(int(shape["K"]), 32, 512)]


def _mm_vmem(shape, blocks):
    by = int(shape.get("bits", 32)) // 8
    bm, bn, bk = blocks["block_m"], blocks["block_n"], blocks["block_k"]
    return (bm * bk + bk * bn) * by + bm * bn * (4 + by)  # tiles + f32 acc


def _fa_builder(shape, blocks):
    return flash_attention_vector(
        shape["B"], shape["H"], shape["KVH"], shape["Sq"], shape["Skv"],
        shape["dh"], causal=bool(shape.get("causal", True)),
        window=shape.get("window"), block_q=blocks["block_q"],
        block_k=blocks["block_k"], bits=int(shape.get("bits", 16)))


def _fa_candidates(shape):
    return [{"block_q": bq, "block_k": bk}
            for bq in _pow2_divisors(int(shape["Sq"]), 32, 512)
            for bk in _pow2_divisors(int(shape["Skv"]), 32, 512)]


def _fa_vmem(shape, blocks):
    by = int(shape.get("bits", 16)) // 8
    dh = int(shape["dh"])
    bq, bk = blocks["block_q"], blocks["block_k"]
    # q/k/v tiles + (m, l, acc) f32 scratch + the (bq×bk) logit tile
    return ((bq + 2 * bk) * dh * by + (2 * bq + bq * dh) * 4
            + bq * bk * 4)


def _ssd_builder(shape, blocks):
    return ssd_scan_vector(shape["Bz"], shape["H"], shape["L"], shape["P"],
                           shape["N"], chunk=blocks["chunk"],
                           bits=int(shape.get("bits", 16)))


def _ssd_candidates(shape):
    return [{"chunk": c} for c in _pow2_divisors(int(shape["L"]), 16, 256)]


def _ssd_vmem(shape, blocks):
    by = int(shape.get("bits", 16)) // 8
    P, N = int(shape["P"]), int(shape["N"])
    Q = blocks["chunk"]
    # x/dt/B/C tiles + f32 state + the three (Q×Q) f32 intermediates
    return (Q * (P + 2 * N + 1) * by + P * N * 4 + 3 * Q * Q * 4)


def _tr_builder(shape, blocks):
    return transpose_vector(shape["M"], shape["N"], block=blocks["block"],
                            bits=int(shape.get("bits", 32)))


def _tr_candidates(shape):
    M, N = int(shape["M"]), int(shape["N"])
    blocks = sorted(set(_pow2_divisors(M, 32, 512))
                    & set(_pow2_divisors(N, 32, 512))) \
        or sorted(set(_pow2_divisors(M, 32, 512))
                  | set(_pow2_divisors(N, 32, 512)))
    return [{"block": b} for b in blocks]


def _tr_vmem(shape, blocks):
    by = int(shape.get("bits", 32)) // 8
    b = blocks["block"]
    return 2 * b * b * by


KERNELS: Dict[str, KernelModel] = {
    "matmul": KernelModel(
        "matmul", ("M", "N", "K"), ("block_m", "block_n", "block_k"),
        _mm_builder, _mm_candidates, _mm_vmem),
    "flash_attention": KernelModel(
        "flash_attention", ("B", "H", "KVH", "Sq", "Skv", "dh"),
        ("block_q", "block_k"), _fa_builder, _fa_candidates, _fa_vmem),
    "ssd_scan": KernelModel(
        "ssd_scan", ("Bz", "H", "L", "P", "N"), ("chunk",),
        _ssd_builder, _ssd_candidates, _ssd_vmem),
    "transpose": KernelModel(
        "transpose", ("M", "N"), ("block",),
        _tr_builder, _tr_candidates, _tr_vmem),
}


def get(kernel) -> KernelModel:
    if isinstance(kernel, KernelModel):
        return kernel
    try:
        return KERNELS[kernel]
    except KeyError:
        raise KeyError(f"unknown kernel {kernel!r}; "
                       f"known: {sorted(KERNELS)}") from None


# ---------------------------------------------------------------------------
# Step-level composition — the predictor's compute term, per kernel
# ---------------------------------------------------------------------------


def _attn_matmul_shapes(cfg, T: ExprLike) -> List[Tuple[ExprLike, ExprLike,
                                                        ExprLike]]:
    """Dense projection matmuls of one attention layer, (M, N, K) with the
    token dim ``T`` symbolic."""
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    return [(T, H * hd, d), (T, KV * hd, d), (T, KV * hd, d), (T, d, H * hd)]


def _ffn_matmul_shapes(cfg, T: ExprLike) -> List[Tuple[ExprLike, ExprLike,
                                                       ExprLike]]:
    return [(T, cfg.d_ff, cfg.d_model), (T, cfg.d_ff, cfg.d_model),
            (T, cfg.d_model, cfg.d_ff)]


def step_kernel_vectors(cfg, workload="train") -> Dict[str, Dict[str, ExprLike]]:
    """Per-kernel symbolic property vectors for ONE pass of ``cfg``, at the
    kernels' default block sizes, for any ``workload``
    (``repro.core.workload.WorkloadLike``; bare phase strings are the
    deprecated legacy form and warn).

    Returns ``{kernel_name: property_vector}`` with the same free variables
    as ``archcount`` (B, S — plus AS/SL/MI when a decode spec sets the
    corresponding refinement).  The composition mirrors
    ``archcount._layer_macs`` contraction-for-contraction, so the mxu totals
    agree in the leading term; kernel-level block rounding and the VMEM
    (``local:``) traffic are what this granularity adds.  Contractions with
    no Pallas kernel (MoE dispatch einsum, the SSM short conv, embedding
    gather) stay with archcount's step counts and are NOT counted here.

    Decode emits the per-token dense matmuls only (projections, FFN, LM
    head, token dim = occupied slots × speculative length): the
    cache-streaming attention / recurrent-state update of a decode step has
    no Pallas kernel in this repo, so those counts stay with
    ``archcount.decode_counts``.
    """
    from repro.core import archcount  # late import: archcount is heavier
    from repro.core import workload as wl
    spec = wl.as_spec(workload, _stacklevel=4)
    bits = 16 if "16" in cfg.compute_dtype else 32
    L = cfg.n_layers
    out: Dict[str, Dict[str, ExprLike]] = {}

    decode = spec.phase == "decode"
    flags = frozenset(spec.structure()[1:])
    if decode:
        rows = archcount.AS if "as" in flags else B
        T = rows * archcount.SL if "sl" in flags else rows
    else:
        T = B * S

    mm_shapes: List[Tuple[ExprLike, ExprLike, ExprLike, ExprLike]] = []
    n_attn = 0
    if cfg.family == "ssm":
        n_ssm = L
    elif cfg.family == "hybrid":
        n_ssm = L
        n_attn = L // cfg.hybrid.attn_every
    else:
        n_ssm = 0
        n_attn = L

    if n_attn:
        for (m, n, k) in _attn_matmul_shapes(cfg, T):
            mm_shapes.append((m, n, k, float(n_attn)))
        if cfg.moe is not None:
            active = cfg.moe.top_k * cfg.moe.capacity_factor
            expert_mult: ExprLike = float(n_attn) * active
            if decode and "mi" in flags:
                expert_mult = as_expr(expert_mult) * archcount.MI
            for (m, n, k) in _ffn_matmul_shapes(cfg, T):
                mm_shapes.append((m, n, k, expert_mult))
        else:
            for (m, n, k) in _ffn_matmul_shapes(cfg, T):
                mm_shapes.append((m, n, k, float(n_attn)))
    if n_ssm:
        s = cfg.ssm
        d, din = cfg.d_model, cfg.d_inner
        G, N = s.n_groups, s.d_state
        # in_proj (x, z, B, C, dt) + out_proj
        mm_shapes.append((T, 2 * din + 2 * G * N + cfg.ssm_heads, d,
                          float(n_ssm)))
        mm_shapes.append((T, d, din, float(n_ssm)))
    # LM head
    mm_shapes.append((T, cfg.vocab_size * cfg.n_output_heads, cfg.d_model,
                      1.0))

    mm_pv: Dict[str, ExprLike] = {}
    for (m, n, k, mult) in mm_shapes:
        mm_pv = add_vectors(mm_pv, scale_vector(
            matmul_vector(m, n, k, bits=bits), mult))
    out["matmul"] = mm_pv

    if n_attn and not decode:
        out["flash_attention"] = scale_vector(
            flash_attention_vector(B, cfg.n_heads, cfg.n_kv_heads, S, S,
                                   cfg.head_dim_, causal=True,
                                   window=cfg.sliding_window, bits=bits),
            float(n_attn))
    if n_ssm and not decode:
        s = cfg.ssm
        out["ssd_scan"] = scale_vector(
            ssd_scan_vector(B, cfg.ssm_heads, S, s.head_dim, s.d_state,
                            chunk=s.chunk, bits=bits),
            float(n_ssm))

    # contractions with no Pallas kernel: keep their archcount-style MAC
    # closed forms so the kernel-composed mxu total replaces the step count
    # without dropping terms (MoE dense dispatch/combine, SSM short conv)
    extra = as_expr(0)
    if n_attn and cfg.moe is not None:
        dispatch = archcount._moe_dispatch_macs(cfg, tokens=T) if decode \
            else archcount._moe_dispatch_macs(cfg)
        extra = extra + dispatch * float(n_attn)
    if n_ssm and not decode:
        s = cfg.ssm
        extra = extra + float((cfg.d_inner + 2 * s.n_groups * s.d_state)
                              * s.d_conv * n_ssm)
    if not (isinstance(extra, Const) and extra.v == 0):
        out["unkernelized"] = {props.mxu_key(bits): 2 * extra * T}
    return out


def step_compute_vector(cfg, workload="train") -> Dict[str, ExprLike]:
    """The summed compute-side (mxu + VMEM local) vector of one forward
    pass, built from the per-kernel vectors.  barrier/groups/const1 stay at
    STEP granularity (archcount's), not per-launch — a fitted per-launch
    barrier weight does not add up across thousands of fused launches.

    Entries are CANONICALIZED (``exprops.simplify``): the layer-by-layer
    composition piles up dozens of structurally repeated addends (every
    projection matmul contributes the same CeilDiv tiles), and collapsing
    them here shrinks both the per-property compiled closures and the
    fused basis programs built downstream."""
    from repro.core import exprops
    from repro.core import workload as wl
    total = add_vectors(
        *step_kernel_vectors(cfg, wl.as_spec(workload, _stacklevel=4))
        .values())
    keep = ("mxu:", "local:")
    return {k: exprops.simplify(v) for k, v in total.items()
            if k.startswith(keep)}
