"""Automatic property extraction — the Loopy/Barvinok analog (paper §3).

The paper walks its polyhedral IR and counts integer points of projected
loop domains to obtain symbolic per-instruction execution counts.  Our IR is
the **jaxpr**: every equation carries static shapes, so the number of
executions of each scalar operation is the product of the output dimensions
— the 'integer point count' is immediate — and loop structure (scan) carries
explicit trip counts.  The walk below tallies, per paper §2:

  * global-memory accesses: an access is counted when an equation consumes a
    *global view* (a value aliased to a kernel input) or produces a kernel
    output; classified by (element bits × direction × access class), where
    the class is the paper's amortized-stride-fraction quantization
    (``properties.stride_class``): slices with stride k contribute the phase
    set of their start offsets — the union footprint over all accesses of an
    array determines the utilization numerator exactly as Algorithm 2 unions
    per-access index maps;
  * flops by kind × dtype for every floating-point equation (integer
    arithmetic is excluded, per paper §2.2);
  * MXU (dot_general) MAC flops — the TPU adaptation: matrix contraction
    runs on the systolic array at a different rate than VPU elementwise ops;
  * control-flow: ``scan`` multiplies inner counts by its trip count;
    ``cond`` takes the elementwise max over branches (conservative);
    ``while`` consumes a user hint (the paper's §2 'human operator supplies
    statistics' escape hatch for data-dependent control flow).

Local-memory loads, barriers and group counts are not jaxpr-visible (they
are codegen artifacts — the paper likewise needs the *schedule* for
barriers); kernels that tile declare them via ``pallas_props`` computed from
their grid/BlockSpec structure, and plain kernels get a nominal group count
``ceil(out_elems / GROUP_SIZE)`` (one lane per output element, as in the
paper's measurement kernels).

For whole distributed training steps we additionally extract from the
*compiled* HLO (``extract_compiled``): FLOPs/bytes from XLA cost analysis
and per-kind collective bytes from ``hloparse`` — those feed the roofline
and the fleet-level predictor.
"""
from __future__ import annotations

import math
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.extend import core as jcore

from repro.core import properties as props
from repro.core import hloparse

GROUP_SIZE = 256  # nominal lanes per work group (paper uses 128–512)
MXU_MIN_K = 16    # contractions shorter than this run at vector, not
                  # systolic-array, rates (TPU MXU is 128×128; CPU BLAS
                  # µkernels likewise need depth to amortize)

# primitive name -> flop kind (paper's five §2.2 categories)
_FLOP_KIND = {
    "add": "add", "sub": "add", "neg": "add", "abs": "add",
    "max": "add", "min": "add", "floor": "add", "ceil": "add",
    "round": "add", "sign": "add", "clamp": "add",
    "mul": "mul",
    "div": "div", "rem": "div",
    "exp": "exp", "exp2": "exp", "expm1": "exp", "pow": "exp",
    "integer_pow": "exp", "log": "exp", "log1p": "exp", "log2": "exp",
    "rsqrt": "special", "sqrt": "special", "cbrt": "special",
    "tanh": "special", "erf": "special", "erfc": "special",
    "erf_inv": "special", "logistic": "special",
    "sin": "special", "cos": "special", "tan": "special",
    "asin": "special", "acos": "special", "atan": "special",
    "atan2": "special", "sinh": "special", "cosh": "special",
    "square": "mul",
    "cumsum": "add", "cumlogsumexp": "exp", "cummax": "add",
    "cumprod": "mul",
}

# reduce primitives: flops = input elems, kind as mapped
_REDUCE_KIND = {
    "reduce_sum": "add", "reduce_max": "add", "reduce_min": "add",
    "reduce_prod": "mul", "argmax": "add", "argmin": "add",
    "reduce_and": None, "reduce_or": None,
    "logsumexp": "exp",
}

# alias-preserving primitives: output is still a view of the same global
# (element *order* unchanged; convert keeps origin bits for access size)
_ALIAS = ("reshape", "convert_element_type", "bitcast_convert_type",
          "stop_gradient", "copy")

_SUBJAXPR_CALLS = ("pjit", "closed_call", "core_call", "remat2", "remat",
                   "checkpoint", "custom_jvp_call", "custom_vjp_call",
                   "custom_jvp_call_jaxpr", "custom_vjp_call_jaxpr")


def _bits_of(aval) -> int:
    try:
        return np.dtype(aval.dtype).itemsize * 8
    except Exception:
        return 32


def _is_float(aval) -> bool:
    try:
        # jnp.issubdtype understands ml_dtypes (bfloat16, fp8) — numpy's
        # issubdtype classifies them as void and would drop their flops
        import jax.numpy as jnp
        return jnp.issubdtype(aval.dtype, jnp.floating)
    except Exception:
        return False


def _nbits(bits: int) -> int:
    """Snap to a tracked size bucket."""
    if bits <= 16:
        return 16
    if bits <= 32:
        return 32
    return 64


@dataclass
class _GlobalView:
    """Value aliased to a kernel input (array id + original element bits)."""
    gid: int
    bits: int


@dataclass
class _Access:
    gid: int
    bits: int
    direction: str  # load | store
    stride: int     # innermost-axis stride (0 = uniform, 1 = contiguous)
    phase: int      # start offset mod stride (for stride >= 2)
    elems: float    # elements touched per kernel execution
    kind: str = ""  # '' = strided/contig; 'gather' = data-dependent


@dataclass
class Extraction:
    flops: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    accesses: List[_Access] = field(default_factory=list)
    out_elems: float = 0.0
    warnings: List[str] = field(default_factory=list)

    # ------------------------------------------------------------------
    def add_flops(self, bits: int, kind: str, n: float):
        if n:
            self.flops[props.flop_key(_nbits(bits), kind)] += n

    def add_mxu(self, bits: int, n: float):
        if n:
            self.flops[props.mxu_key(_nbits(bits))] += n

    def add_access(self, a: _Access):
        if a.elems:
            self.accesses.append(a)

    def merge_scaled(self, other: "Extraction", mult: float):
        for k, v in other.flops.items():
            self.flops[k] += v * mult
        for a in other.accesses:
            self.add_access(_Access(a.gid, a.bits, a.direction, a.stride,
                                    a.phase, a.elems * mult, a.kind))
        self.out_elems += other.out_elems * mult
        self.warnings.extend(other.warnings)

    # ------------------------------------------------------------------
    def property_vector(self, group_size: int = GROUP_SIZE,
                        extra: Optional[Mapping[str, float]] = None
                        ) -> props.PropertyVector:
        pv: Dict[str, float] = defaultdict(float)
        pv.update(self.flops)

        # ---- classify accesses (paper Alg. 2 union-footprint per array) --
        # group strided accesses by (gid, direction, stride); the distinct
        # phase count is the utilization numerator
        strided: Dict[Tuple, Dict[str, Any]] = defaultdict(
            lambda: {"phases": set(), "elems": 0.0, "bits": 32})
        for a in self.accesses:
            if a.kind == "gather":
                pv[props.mem_key(a.direction, _nbits(a.bits), "gather")] += a.elems
            elif a.stride in (0, 1):
                cls = "s0" if a.stride == 0 else "s1"
                pv[props.mem_key(a.direction, _nbits(a.bits), cls)] += a.elems
            else:
                g = strided[(a.gid, a.direction, a.stride)]
                g["phases"].add(a.phase % a.stride)
                g["elems"] += a.elems
                g["bits"] = a.bits
        for (gid, direction, stride), g in strided.items():
            util = len(g["phases"]) / stride
            cls = props.stride_class(stride, util)
            pv[props.mem_key(direction, _nbits(g["bits"]), cls)] += g["elems"]

        pv[props.GROUPS] = math.ceil(max(self.out_elems, 1) / group_size)
        if extra:
            for k, v in extra.items():
                pv[k] = pv.get(k, 0.0) + v
        return props.finalize(pv)


# ---------------------------------------------------------------------------
# The jaxpr walker
# ---------------------------------------------------------------------------


def _slice_stride_phase(eqn) -> Tuple[int, int]:
    """Innermost-axis (stride, phase) of a `slice` equation."""
    strides = eqn.params.get("strides")
    starts = eqn.params["start_indices"]
    if strides is None:
        return 1, 0
    return int(strides[-1]), int(starts[-1])


def _affine_of(v, producers: Dict[Any, Any]) -> Optional[Tuple[int, int]]:
    """Recognize an affine index map ``stride*iota + phase`` (paper Alg. 2's
    index-mapping analysis, e.g. I(i) = 2i+1).  Returns (stride, phase)."""
    for _ in range(16):  # bounded chain walk
        if isinstance(v, jcore.Literal):
            return None
        eqn = producers.get(v)
        if eqn is None:
            return None
        name = eqn.primitive.name
        if name == "iota":
            return (1, 0)
        if name in ("broadcast_in_dim", "reshape", "convert_element_type"):
            v = eqn.invars[0]
            continue
        if name in ("add", "mul"):
            lit = None
            other = None
            for iv in eqn.invars:
                if isinstance(iv, jcore.Literal) and np.ndim(iv.val) == 0:
                    lit = int(iv.val)
                else:
                    other = iv
            if lit is None or other is None:
                return None
            sub = _affine_of(other, producers)
            if sub is None:
                return None
            s, p = sub
            return (s, p + lit) if name == "add" else (s * lit, p * lit)
        return None
    return None


def _walk(jaxpr: jcore.Jaxpr, global_map: Dict[Any, _GlobalView],
          ext: Extraction, hints: Mapping[str, float],
          consts: Sequence[Any] = ()) -> Dict[Any, _GlobalView]:
    """Walk one jaxpr; ``global_map`` maps Vars to global views."""
    producers: Dict[Any, Any] = {}
    for eqn in jaxpr.eqns:
        for ov in eqn.outvars:
            producers[ov] = eqn

    def gv(v) -> Optional[_GlobalView]:
        if isinstance(v, jcore.Literal):
            return None
        return global_map.get(v)

    def read(v, elems: float, stride: int = 1, phase: int = 0, kind: str = ""):
        """Record a load if v is a global view."""
        g = gv(v)
        if g is not None and elems:
            ext.add_access(_Access(g.gid, g.bits, "load", stride, phase,
                                   elems, kind))

    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if not eqn.outvars:  # effect-only primitives (callbacks, prints)
            continue
        out = eqn.outvars[0]
        out_aval = out.aval
        out_elems = float(np.prod(out_aval.shape)) if out_aval.shape else 1.0

        # ---- alias-preserving ----------------------------------------
        if name in _ALIAS:
            g = gv(eqn.invars[0])
            if g is not None:
                global_map[out] = g  # keep ORIGIN bits: the stream is read
                # at the stored size regardless of later converts
            continue

        # ---- sub-jaxpr calls ------------------------------------------
        if name in _SUBJAXPR_CALLS:
            closed = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
            inner = closed.jaxpr if hasattr(closed, "jaxpr") else closed
            sub_map: Dict[Any, _GlobalView] = {}
            for iv, ov in zip(inner.invars, eqn.invars):
                g = gv(ov)
                if g is not None:
                    sub_map[iv] = g
            sub_ext = Extraction()
            _walk(inner, sub_map, sub_ext, hints)
            ext.merge_scaled(sub_ext, 1.0)
            for ov_outer, ov_inner in zip(eqn.outvars, inner.outvars):
                if not isinstance(ov_inner, jcore.Literal) \
                        and ov_inner in sub_map:
                    global_map[ov_outer] = sub_map[ov_inner]
            continue

        if name == "scan":
            closed = eqn.params["jaxpr"]
            inner = closed.jaxpr
            length = eqn.params["length"]
            nc, ncar = eqn.params["num_consts"], eqn.params["num_carry"]
            sub_map = {}
            for i, (iv, ov) in enumerate(zip(inner.invars, eqn.invars)):
                g = gv(ov)
                if g is not None and (i < nc or i >= nc + ncar):
                    # consts + xs keep globality; carries do not
                    sub_map[iv] = g
            sub_ext = Extraction()
            _walk(inner, sub_map, sub_ext, hints)
            ext.merge_scaled(sub_ext, float(length))
            continue

        if name == "while":
            mult = float(hints.get("while_trip_count", 1.0))
            if "while_trip_count" not in hints:
                ext.warnings.append("while-loop trip count defaulted to 1 "
                                    "(supply hints={'while_trip_count': k})")
            body = eqn.params["body_jaxpr"].jaxpr
            nb = eqn.params["body_nconsts"]
            cond_n = eqn.params["cond_nconsts"]
            sub_map = {}
            body_ops = eqn.invars[cond_n:]
            for i, iv in enumerate(inner_iv for inner_iv in body.invars):
                if i < nb and i < len(body_ops):
                    g = gv(body_ops[i])
                    if g is not None:
                        sub_map[iv] = g
            sub_ext = Extraction()
            _walk(body, sub_map, sub_ext, hints)
            ext.merge_scaled(sub_ext, mult)
            continue

        if name == "cond":
            branches = eqn.params["branches"]
            best: Optional[Extraction] = None
            for br in branches:
                inner = br.jaxpr
                sub_map = {}
                for iv, ov in zip(inner.invars, eqn.invars[1:]):
                    g = gv(ov)
                    if g is not None:
                        sub_map[iv] = g
                sub_ext = Extraction()
                _walk(inner, sub_map, sub_ext, hints)
                tot = sum(sub_ext.flops.values()) + sum(
                    a.elems for a in sub_ext.accesses)
                if best is None or tot > sum(best.flops.values()) + sum(
                        a.elems for a in best.accesses):
                    best = sub_ext
            if best is not None:
                ext.merge_scaled(best, 1.0)  # conservative: max branch
            continue

        # ---- memory-pattern primitives ---------------------------------
        if name == "slice":
            stride, phase = _slice_stride_phase(eqn)
            # multi-axis windows (e.g. conv taps m[:, x:x+n, y:y+n, :])
            # read many SHORT contiguous runs: if the run length is below
            # a line/sector, the access behaves uncoalesced (paper §2.1's
            # 'gaps caused by striding', generalized to middle axes)
            in_shape = eqn.invars[0].aval.shape
            out_shape = eqn.outvars[0].aval.shape
            run = 1
            for ax in range(len(in_shape) - 1, -1, -1):
                run *= out_shape[ax]
                if out_shape[ax] != in_shape[ax]:
                    break
            if stride == 1 and run < 16 and out_elems > run:
                read(eqn.invars[0], out_elems, kind="gather")
            else:
                read(eqn.invars[0], out_elems, stride=stride, phase=phase)
            continue

        if name in ("gather", "take", "dynamic_slice", "dynamic_update_slice",
                    "scatter", "scatter-add", "scatter_add"):
            if name.startswith("scatter") :
                # operand read + data-dependent stores
                read(eqn.invars[0], out_elems)
                upd = eqn.invars[-1]
                upd_elems = float(np.prod(upd.aval.shape)) if upd.aval.shape else 1.0
                g = gv(eqn.invars[0])
                gid = g.gid if g else id(eqn)
                bits = g.bits if g else _bits_of(out_aval)
                ext.add_access(_Access(gid, bits, "store", 1, 0, upd_elems,
                                       "gather"))
                global_map[out] = g if g else _GlobalView(gid, bits)
            elif name == "dynamic_slice":
                read(eqn.invars[0], out_elems)  # contiguous block
            elif name == "dynamic_update_slice":
                read(eqn.invars[0], 0.0)
                g = gv(eqn.invars[0])
                if g is not None:
                    global_map[out] = g
            else:  # gather / take
                # affine iota-gather (how jnp lowers x[b::k]) is a *strided*
                # access, not a data-dependent one — recover (k, b)
                aff = _affine_of(eqn.invars[-1], producers) \
                    if len(eqn.invars) >= 2 else None
                if aff is not None:
                    s, ph = aff
                    if s in (0, 1):
                        read(eqn.invars[0], out_elems, stride=s, phase=0)
                    else:
                        read(eqn.invars[0], out_elems, stride=s, phase=ph)
                else:
                    read(eqn.invars[0], out_elems, kind="gather")
            continue

        if name == "broadcast_in_dim":
            in_aval = eqn.invars[0].aval
            in_elems = float(np.prod(in_aval.shape)) if in_aval.shape else 1.0
            bdims = eqn.params.get("broadcast_dimensions", ())
            minor = len(out_aval.shape) - 1
            # if the minor axis of out is NOT fed by the input's minor axis,
            # every lane re-reads the same element -> uniform (stride-0)
            uniform = (minor not in bdims) or in_elems == 1.0
            if uniform:
                read(eqn.invars[0], out_elems, stride=0)
            else:
                read(eqn.invars[0], in_elems, stride=1)
            continue

        if name == "transpose":
            perm = eqn.params["permutation"]
            minor = len(perm) - 1
            if perm[minor] == minor:  # minor axis unchanged: stream copy
                read(eqn.invars[0], out_elems, stride=1)
            else:  # relayout: uncoalesced read
                read(eqn.invars[0], out_elems, kind="gather")
            continue

        if name == "rev":
            read(eqn.invars[0], out_elems, kind="gather")
            continue

        if name in ("concatenate", "pad"):
            for v in eqn.invars:
                av = v.aval
                read(v, float(np.prod(av.shape)) if av.shape else 1.0)
            continue

        if name == "iota":
            continue

        # ---- compute primitives -----------------------------------------
        if name in ("dot_general",):
            dnums = eqn.params["dimension_numbers"]
            (lc, rc), (lb, rb) = dnums
            l_aval, r_aval = eqn.invars[0].aval, eqn.invars[1].aval
            k = 1.0
            for d in lc:
                k *= l_aval.shape[d]
            batch = 1.0
            for d in lb:
                batch *= l_aval.shape[d]
            # out_elems already includes batch dims
            macs = out_elems * k
            bits = _bits_of(l_aval)
            if k >= MXU_MIN_K:
                ext.add_mxu(bits, 2.0 * macs)  # MAC = 2 flops
            else:
                # tiny contraction: the systolic array (or BLAS µkernel)
                # cannot amortize — charge as vector mul+add instead
                ext.add_flops(bits, "mul", macs)
                ext.add_flops(bits, "add", macs)
            for v in (eqn.invars[0], eqn.invars[1]):
                av = v.aval
                read(v, float(np.prod(av.shape)) if av.shape else 1.0)
            continue

        if name in ("conv_general_dilated",):
            # flops = 2 * out_elems * (kernel window size * in channels)
            rhs = eqn.invars[1].aval
            window = float(np.prod(rhs.shape[2:])) if len(rhs.shape) > 2 else 1.0
            cin = rhs.shape[1] if len(rhs.shape) > 1 else 1
            macs = out_elems * window * cin
            if window * cin >= MXU_MIN_K:
                ext.add_mxu(_bits_of(rhs), 2.0 * macs)
            else:
                ext.add_flops(_bits_of(rhs), "mul", macs)
                ext.add_flops(_bits_of(rhs), "add", macs)
            for v in eqn.invars:
                av = v.aval
                read(v, float(np.prod(av.shape)) if av.shape else 1.0)
            continue

        if name in _REDUCE_KIND:
            kind = _REDUCE_KIND[name]
            in_aval = eqn.invars[0].aval
            in_elems = float(np.prod(in_aval.shape)) if in_aval.shape else 1.0
            if kind and _is_float(in_aval):
                ext.add_flops(_bits_of(in_aval), kind, in_elems)
            read(eqn.invars[0], in_elems)
            continue

        # ---- generic elementwise -----------------------------------------
        kind = _FLOP_KIND.get(name)
        if kind is not None and _is_float(out_aval):
            n = out_elems
            if name == "integer_pow":
                # x**k costs ~log2(k) multiplies
                n = out_elems * max(1, int(math.log2(max(
                    abs(eqn.params.get("y", 2)), 2))))
                kind = "mul"
            ext.add_flops(_bits_of(out_aval), kind, n)
        # loads for any global operands of an elementwise/compute op;
        # NON-global (intermediate) operands are charged as LOCAL loads —
        # on a perfectly-fusing device they are free-ish, on one that
        # materializes them they cost cache/HBM traffic: the fitted
        # local-load weight captures the device's fusion quality (this is
        # the paper's local-memory class, put to work)
        for v in eqn.invars:
            if isinstance(v, jcore.Literal):
                continue
            av = v.aval
            elems = float(np.prod(av.shape)) if av.shape else 1.0
            if gv(v) is not None:
                read(v, elems)
            elif _is_float(av) and elems > 1:
                ext.flops[props.local_key(_nbits(_bits_of(av)))] += elems

    return global_map


def extract_jaxpr(fn, *args, hints: Optional[Mapping[str, float]] = None,
                  extra_props: Optional[Mapping[str, float]] = None,
                  group_size: int = GROUP_SIZE,
                  ) -> props.PropertyVector:
    """Fully-automatic property extraction for ``fn(*args)`` (paper §3.2).

    Returns the finalized property vector (loads/stores by class, flops by
    kind, min(L,S), groups, const1).  ``extra_props`` lets tiled kernels add
    their schedule-derived properties (local loads, barriers) — see
    ``pallas_props``.
    """
    closed = jax.make_jaxpr(fn)(*args)
    jaxpr = closed.jaxpr
    ext = Extraction()
    gmap: Dict[Any, _GlobalView] = {}
    for i, iv in enumerate(jaxpr.invars):
        aval = iv.aval
        if getattr(aval, "shape", None) is not None:
            gmap[iv] = _GlobalView(gid=i, bits=_bits_of(aval))
    gmap = _walk(jaxpr, gmap, ext, hints or {})

    # stores: kernel outputs are written as contiguous streams unless the
    # producing op was a scatter (already recorded)
    for ov in jaxpr.outvars:
        if isinstance(ov, jcore.Literal):
            continue
        aval = ov.aval
        elems = float(np.prod(aval.shape)) if aval.shape else 1.0
        ext.out_elems += elems
        g = gmap.get(ov)
        if g is not None and any(a.gid == g.gid and a.direction == "store"
                                 for a in ext.accesses):
            continue  # scatter store already counted
        ext.add_access(_Access(-1 - len(ext.accesses), _bits_of(aval),
                               "store", 1, 0, elems))
    return ext.property_vector(group_size=group_size, extra=extra_props)


# ---------------------------------------------------------------------------
# Schedule-derived properties for tiled (Pallas) kernels
# ---------------------------------------------------------------------------


def pallas_props(grid: Sequence[int], block_elems_in: Sequence[int],
                 block_elems_out: Sequence[int], bits: int = 32,
                 barriers_per_step: int = 1) -> Dict[str, float]:
    """Properties visible only in the *schedule* (paper §3.2 last ¶).

    grid cells = work groups; each grid step moves its input blocks
    HBM→VMEM (local loads when re-read from VMEM) and synchronizes.
    """
    cells = float(np.prod(list(grid))) if len(grid) else 1.0
    local = cells * float(sum(block_elems_in))
    return {
        props.local_key(_nbits(bits)): local,
        props.BARRIER: cells * barriers_per_step,
        props.GROUPS: cells,
    }


# ---------------------------------------------------------------------------
# Compiled-HLO extraction (roofline + fleet predictor substrate)
# ---------------------------------------------------------------------------


@dataclass
class CompiledCosts:
    flops: float
    bytes_accessed: float
    collective_bytes: Dict[str, float]
    peak_bytes_per_device: float
    output_bytes: float
    # XLA's own cost_analysis numbers, for comparison: these count while
    # (scan) bodies ONCE and so under-report by ~n_layers× on scanned
    # models — the loop-aware rollup above is the corrected account.
    xla_flops: float = 0.0
    xla_bytes: float = 0.0


_COLL_KEY_MAP = {
    "all-reduce": "all_reduce",
    "all-gather": "all_gather",
    "reduce-scatter": "reduce_scatter",
    "all-to-all": "all_to_all",
    "collective-permute": "permute",
}


def extract_compiled(compiled) -> CompiledCosts:
    """Costs from a ``lowered.compile()`` artifact.

    FLOPs/bytes/collective bytes come from the loop-aware HLO rollup
    (``hloparse.rollup``): XLA's ``cost_analysis()`` counts while (scan)
    bodies once — ~n_layers× under-reporting for scan-over-layers models —
    and omits collective bytes entirely.  The raw cost_analysis values are
    kept in ``xla_*`` for the §Dry-run comparison.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    ca = ca or {}
    text = compiled.as_text()
    costs = hloparse.rollup(text)
    coll_out = {_COLL_KEY_MAP.get(k, k): float(v)
                for k, v in costs.coll.items()}
    ma = None
    try:
        ma = compiled.memory_analysis()
    except Exception:
        pass
    peak = 0.0
    if ma is not None:
        peak = float(
            getattr(ma, "temp_size_in_bytes", 0)
            + getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
            - getattr(ma, "alias_size_in_bytes", 0))
    return CompiledCosts(
        flops=float(costs.flops),
        bytes_accessed=float(costs.bytes),
        collective_bytes=coll_out,
        peak_bytes_per_device=peak,
        output_bytes=float(ca.get("bytes accessed output", 0.0)),
        xla_flops=float(ca.get("flops", 0.0)),
        xla_bytes=float(ca.get("bytes accessed", 0.0)),
    )


def collective_property_vector(compiled_text: str) -> Dict[str, float]:
    """coll:* properties (bytes) from compiled HLO text."""
    out = {}
    for k, v in hloparse.collective_summary(compiled_text).items():
        out[props.coll_key(_COLL_KEY_MAP.get(k, k))] = float(v)
    return out
