"""Step-time prediction for (arch × shape × mesh × plan) — the framework's
first-class use of the paper's fitted linear model.

Two weight sources:

  * a **fitted** ``LinearCostModel`` (e.g. the CPU model produced by
    ``benchmarks/paper_table1.py``, or a model fitted on real TPU timings
    by the same black-box procedure);
  * the **analytic v5e seed** (``tpu_v5e_weights``): weights seeded from
    datasheet rates (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI) —
    the starting point the black-box fit would refine on real hardware.

The prediction is the paper's inner product <α, p>, with compute/memory
properties scaled down by the device count (data-parallel work division) and
collective properties already expressed per-device by
``archcount.collective_counts``.

This predictor powers:
  * ``launch/autoshard.py`` — plan search (µs per candidate);
  * ``runtime/straggler.py`` — expected-step-time thresholds;
  * ``distributed/elastic.py`` — re-planning after node loss.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence, Union

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import archcount
from repro.core import properties as props
from repro.core import workload as wl
from repro.core.lru import LRUCache
from repro.core.model import LinearCostModel
from repro.core.workload import WorkloadSpec

# --- v5e hardware constants (per chip) ---
PEAK_FLOPS_BF16 = 197e12     # FLOP/s
PEAK_FLOPS_F32 = 49e12       # VPU-ish f32 rate
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link (≈3 links usable per axis-dir)
LAUNCH_S = 5e-6              # per-dispatch overhead


def tpu_v5e_weights() -> LinearCostModel:
    """Analytic seconds-per-event weights for the property taxonomy."""
    w: Dict[str, float] = {}
    w[props.mxu_key(16)] = 1.0 / PEAK_FLOPS_BF16
    w[props.mxu_key(32)] = 1.0 / (PEAK_FLOPS_BF16 / 4)  # f32 matmul 1/4 rate
    for kind, mult in (("add", 1.0), ("mul", 1.0), ("div", 4.0),
                       ("exp", 8.0), ("special", 8.0)):
        w[props.flop_key(32, kind)] = mult / PEAK_FLOPS_F32
        w[props.flop_key(16, kind)] = mult / (2 * PEAK_FLOPS_F32)
    for bits in props.SIZES:
        by = bits // 8
        for d in props.DIRECTIONS:
            w[props.mem_key(d, bits, "s0")] = 0.0          # broadcast: cached
            w[props.mem_key(d, bits, "s1")] = by / HBM_BW
            w[props.mem_key(d, bits, "gather")] = 4.0 * by / HBM_BW
            for s in (2, 3, 4):
                for k in range(1, s + 1):
                    # stride-s with k/s utilization: pay the full footprint
                    w[props.mem_key(d, bits, f"s{s}_{k}/{s}")] = \
                        by * (s / k) / HBM_BW
            for k in range(1, 5):
                w[props.mem_key(d, bits, f"s>4_{k}/>4")] = 4.0 * by / HBM_BW
        w[props.minls_key(bits)] = 0.0   # duplex HBM: no extra gain modeled
        w[props.local_key(bits)] = by / (20 * HBM_BW)  # VMEM ≈ 20× HBM BW
    for c in props.COLLECTIVES:
        # ring collectives over ICI; all_to_all crosses bisection
        w[props.coll_key(c)] = 1.0 / (3 * ICI_BW) if c != "all_to_all" \
            else 1.0 / (2 * ICI_BW)
    w[props.BARRIER] = 1e-7
    w[props.GROUPS] = 1e-7
    w[props.CONST1] = LAUNCH_S
    return LinearCostModel.from_dict(w, device="tpu-v5e-analytic",
                                     meta={"source": "datasheet-seed"})


# ---------------------------------------------------------------------------

#: what every prediction entry point accepts: an in-memory model, a registry
#: device name (resolved via ``repro.calibration``), or None (v5e seed).
ModelLike = Union[LinearCostModel, str, None]


def resolve_model(model: ModelLike) -> LinearCostModel:
    """Normalize a model argument.  ``None`` is the deterministic default —
    the built-in analytic v5e seed, never a registry file; a string is a
    registry device name (where a fitted model shadows a same-named seed).

    Delegates to ``repro.calibration.registry.resolve_model`` (the single
    home of these rules; its ``"tpu-v5e"`` default seed IS
    ``tpu_v5e_weights``), imported lazily because calibration sits above
    core."""
    from repro.calibration import registry
    return registry.resolve_model(model)


@dataclass
class StepPrediction:
    seconds: float
    breakdown: Dict[str, float]      # per-property seconds
    terms: Dict[str, float]          # compute / memory / collective seconds
    model_flops: float
    mfu: float                       # MODEL_FLOPS / (chips·peak·seconds)


def _env_for(spec: WorkloadSpec, cfg: Optional[ArchConfig] = None,
             microbatches: int = 1) -> Dict[str, float]:
    # one env for every phase: the spec pins B/S (+ decode refinements),
    # the plan's schedule overrides M
    env = spec.env(cfg)
    env["M"] = microbatches
    return env


# ---------------------------------------------------------------------------
# Compiled step vectors — kernel-granularity compute terms
# ---------------------------------------------------------------------------

#: (cfg, spec.structure(), remat_policy) -> symcount.CompiledVector.  Step
#: vectors are pure functions of those three — a spec's SHAPE enters only
#: through the evaluation env, so every spec sharing a structure (phase +
#: which decode refinements are modeled) shares one compiled vector.
#: Bounded LRU: each key pins a whole frozen ``ArchConfig`` (plus its
#: compiled closures), so the cache must not grow with every config a
#: long-lived process ever scores.
_STEP_PV_CACHE: LRUCache = LRUCache(maxsize=64)


def _step_pv_sym(cfg: ArchConfig, spec: WorkloadSpec,
                 remat_policy: Optional[str] = None, _sc=None):
    """The symbolic property-vector map of one step of ``cfg`` — the shared
    source for both the per-property compiled path (``step_vector_fn``) and
    the fused basis program (``step_program``).

    For train/prefill the compute terms come from the PER-KERNEL property
    vectors (``core.kernelmodel.step_kernel_vectors``): the mxu count is the
    block-rounded sum over the step's matmul / flash-attention / ssd_scan
    launches (plus unkernelized contractions), and the kernels' VMEM
    (``local:``) traffic joins the vector — the same counts the block-size
    autotuner scores.  Memory / VPU / optimizer / structural terms stay at
    archcount's step granularity, as does everything for decode (its cache-
    streaming attention has no Pallas kernel here).
    """
    from repro.core import kernelmodel
    from repro.core.symcount import as_expr
    sc = _sc or archcount.counts_for(cfg, spec, remat_policy=remat_policy)
    pv_sym = dict(sc.pv)
    if spec.phase in ("train", "prefill"):
        mult = archcount.train_fwd_multiplier(cfg, remat_policy) \
            if spec.phase == "train" else 1.0
        kpv = kernelmodel.step_compute_vector(cfg, spec)
        for k, v in kpv.items():
            scaled = as_expr(v) * mult
            if k.startswith("mxu:"):
                pv_sym[k] = scaled          # replaces the step count
            else:
                pv_sym[k] = scaled + as_expr(pv_sym[k]) \
                    if k in pv_sym else scaled
    return pv_sym


def _structure_key(spec: WorkloadSpec):
    """Cache-key part for a spec: the bare phase string when no refinement
    is modeled (bit-compatible with the pre-spec ``kind=`` disk keys, so
    existing compile caches stay warm), the full structure tuple otherwise."""
    st = spec.structure()
    return st[0] if len(st) == 1 else st


def step_vector_fn(cfg: ArchConfig, workload: wl.WorkloadLike,
                   remat_policy: Optional[str] = None, _sc=None):
    """Compiled symbolic property vector for one step of ``cfg`` (one
    closure per property — see ``_step_pv_sym`` for what the vector holds).
    The batched engine's hot path uses the FUSED form (``step_program``);
    this per-property form stays as the reference the fused path is pinned
    against, and serves ``plan_property_vector`` / ``predict_step``."""
    from repro.core.symcount import compile_vector
    spec = wl.as_spec(workload)
    key = (cfg, spec.structure(), remat_policy)
    cv = _STEP_PV_CACHE.get(key)
    if cv is None:
        cv = compile_vector(_step_pv_sym(cfg, spec, remat_policy, _sc=_sc))
        _STEP_PV_CACHE[key] = cv
    return cv


#: (cfg, structure, remat) -> exprops.BasisProgram — the fused-GEMV step
#: scorer.
_STEP_PROG_CACHE: LRUCache = LRUCache(maxsize=64)


def step_program(cfg: ArchConfig, workload: wl.WorkloadLike,
                 remat_policy: Optional[str] = None):
    """The step property vector as a FUSED basis program
    (``core.exprops``): canonicalized, cross-property CSE'd, scored as one
    GEMV.  In-memory LRU over the persistent on-disk compile cache — the
    disk key derives from (cfg, spec structure, remat) so a warm cache
    skips building the symbolic counts entirely."""
    from repro.core import exprops
    spec = wl.as_spec(workload)
    key = (cfg, spec.structure(), remat_policy)
    prog = _STEP_PROG_CACHE.get(key)
    if prog is None:
        dk = exprops.program_key("step", cfg, _structure_key(spec),
                                 remat_policy)
        prog = exprops.load_or_build(
            dk, lambda: _step_pv_sym(cfg, spec, remat_policy))
        _STEP_PROG_CACHE[key] = prog
    return prog


def plan_property_vector(cfg: ArchConfig, workload: wl.WorkloadLike, plan,
                         mesh_shape: Mapping[str, int],
                         _count_cache: Optional[dict] = None,
                         _sc=None) -> Dict[str, float]:
    """The concrete per-device property vector for one (plan, mesh) cell.

    ``_count_cache`` memoizes the expensive symbolic-count evaluation across
    plans that share (remat_policy, microbatches) — the batched scorer passes
    one cache over the whole candidate set, so an autoshard sweep evaluates
    the per-arch counts once per distinct schedule, not once per plan.
    ``_sc`` lets a caller that already built the ``StepCounts`` (e.g.
    ``predict_step``, which also needs ``concrete_model_flops``) avoid
    rebuilding them.
    """
    spec = wl.as_spec(workload)
    n_dev = int(np.prod(list(mesh_shape.values()))) or 1
    env = _env_for(spec, cfg, plan.microbatches)

    ck = (plan.remat_policy, plan.microbatches)
    cached = _count_cache.get(ck) if _count_cache is not None else None
    if cached is None:
        cv = step_vector_fn(cfg, spec, plan.remat_policy, _sc=_sc)
        cached = {k: float(v) for k, v in cv(env).items()}
        if _count_cache is not None:
            _count_cache[ck] = cached
    # compute/memory events divide over the mesh (SPMD work division)
    pv = {k: v / n_dev for k, v in cached.items()}
    coll = archcount.collective_counts(cfg, spec, plan, mesh_shape)
    from repro.core.symcount import evaluate_vector
    pv.update(evaluate_vector(coll, env))
    pv[props.CONST1] = 1.0
    return pv


def predict_step(cfg: ArchConfig, workload: wl.WorkloadLike, plan,
                 mesh_shape: Mapping[str, int],
                 weights: ModelLike = None,
                 residual=None) -> StepPrediction:
    """Predict one step's wall time on ``mesh_shape`` under ``plan``.

    ``residual`` (a ``core.fit.ResidualHead``, e.g. from an
    ``OnlineCalibrator`` running with ``residual=True``) applies the
    learned multiplicative correction on top of the analytic inner product
    — the hybrid analytic+learned prediction.  The per-property breakdown
    stays analytic; the head's contribution appears as a ``residual``
    term and scales ``seconds``/``mfu``."""
    weights = resolve_model(weights)
    spec = wl.as_spec(workload)
    n_dev = int(np.prod(list(mesh_shape.values()))) or 1
    env = _env_for(spec, cfg, plan.microbatches)

    sc = archcount.counts_for(cfg, spec,
                              remat_policy=plan.remat_policy)
    pv = plan_property_vector(cfg, spec, plan, mesh_shape, _sc=sc)

    bd = weights.breakdown(pv)
    total = sum(bd.values())
    terms = {c: 0.0 for c in props.CATEGORIES}
    for k, v in bd.items():
        terms[props.category(k)] += v
    if residual is not None:
        corrected = total * residual.correction(pv)
        terms["residual"] = corrected - total
        total = corrected
    mf = sc.concrete_model_flops(env)
    mfu = mf / (n_dev * PEAK_FLOPS_BF16 * total) if total > 0 else 0.0
    return StepPrediction(seconds=total, breakdown=bd, terms=terms,
                          model_flops=mf, mfu=mfu)


def score_explain(cfg: ArchConfig, workload: wl.WorkloadLike, plan,
                  mesh_shape: Mapping[str, int], weights: ModelLike = None):
    """Decompose one cell's predicted step seconds into basis-term
    contributions — per term, per cost category, per program source (step
    / collective / launch) — summing exactly to the fused
    ``PlanSpace.scores`` cell.  Returns an ``obs.explain.Explanation``
    (lazy import; ``obs.explain`` sits above core)."""
    from repro.obs.explain import score_explain as _score_explain
    return _score_explain(cfg, workload, plan, mesh_shape,
                          model=resolve_model(weights))


def predict_plans(cfg: ArchConfig, workload: wl.WorkloadLike,
                  plans: Sequence, mesh_shape: Mapping[str, int],
                  weights: ModelLike = None, cache=None) -> np.ndarray:
    """Batched step-time prediction: seconds for every candidate plan.

    This is the plan-search hot path, routed through the array-batched
    search-space engine (``core.planspace``): the whole candidate set
    scores through FUSED basis programs (``core.exprops``) — deduped basis
    terms evaluated once per unique environment row, folded model weights,
    one GEMV — with no per-plan interpreted tree-walks anywhere.  The
    per-plan interpreted path survives as ``predict_plans_loop``, the
    oracle the engine is tested and benchmarked against.

    ``cache`` (an ``exprops.BasisCache``) switches on incremental
    rescoring: basis columns keyed by their own free-variable values, so a
    repeat call after a small delta (device count, mesh shape) recomputes
    only the touched columns — the ``elastic.replan`` /
    ``StragglerMonitor`` fast path.
    """
    weights = resolve_model(weights)
    spec = wl.as_spec(workload)
    if not len(plans):
        return np.zeros((0,))
    from repro.core import planspace  # planspace sits above predictor
    space = planspace.PlanSpace.from_product(cfg, spec, list(plans),
                                             [dict(mesh_shape)])
    return space.scores(weights, cache=cache)


def predict_plans_loop(cfg: ArchConfig, workload: wl.WorkloadLike,
                       plans: Sequence, mesh_shape: Mapping[str, int],
                       weights: ModelLike = None) -> np.ndarray:
    """Reference scorer: per-plan ``plan_property_vector`` + one
    ``predict_many``.  Semantically identical to ``predict_plans``; kept as
    the oracle the batched engine is pinned against (tests) and the
    baseline ``benchmarks/search_bench.py`` times the engine's speedup
    over."""
    weights = resolve_model(weights)
    spec = wl.as_spec(workload)
    count_cache: dict = {}
    pvs: List[Dict[str, float]] = [
        plan_property_vector(cfg, spec, p, mesh_shape, count_cache)
        for p in plans]
    if not pvs:
        return np.zeros((0,))
    return np.asarray(weights.predict_many(pvs), dtype=np.float64)


def rank_plans(cfg: ArchConfig, workload: wl.WorkloadLike, plans,
               mesh_shape: Mapping[str, int],
               weights: ModelLike = None):
    """Sort candidate plans by predicted step time (ascending) — the paper's
    §6.2 'select the optimal set of kernel configurations', realized.

    Scoring goes through the batched ``predict_plans`` path; ties break on
    the plans' own fields (``planspace.plan_sort_key``), never on the
    caller's enumeration order."""
    from repro.core.planspace import plan_sort_key
    secs = predict_plans(cfg, workload, plans, mesh_shape, weights)
    order = sorted(range(len(plans)),
                   key=lambda i: (secs[i], plan_sort_key(plans[i])))
    return [(float(secs[i]), plans[i]) for i in order]


# ---------------------------------------------------------------------------
# HBM feasibility (capacity is out of the paper's model scope — §2 — so the
# framework enforces it as a *constraint*, not a cost term)
# ---------------------------------------------------------------------------

HBM_BYTES = 16e9  # v5e


def estimate_peak_bytes(cfg: ArchConfig, workload: wl.WorkloadLike, plan,
                        mesh_shape: Mapping[str, int]) -> float:
    """Closed-form peak HBM bytes/device for a plan (napkin-math grade:
    params + optimizer + gradients + activation working set or caches).

    The formula itself lives in ``core.planspace`` as a single numpy pass
    over candidate arrays (``planspace.peak_bytes``); this scalar form is
    the one-cell special case, so a batched feasibility sweep and the
    per-plan call can never drift apart."""
    from repro.core import planspace
    spec = wl.as_spec(workload)
    return float(planspace.peak_bytes(cfg, spec, [plan], [mesh_shape])[0])


def feasible(cfg: ArchConfig, workload: wl.WorkloadLike, plan,
             mesh_shape: Mapping[str, int],
             budget: float = HBM_BYTES) -> bool:
    return estimate_peak_bytes(cfg, workload, plan, mesh_shape) <= budget
