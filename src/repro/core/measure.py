"""Measurement protocol — paper §4.2.

"We then time 30 runs of each kernel. … we disregard the first 4 runs and
take the minimum of the remaining execution times."  First-touch effects and
JIT compilation land in the discarded warmup runs.  ``block_until_ready`` is
the dispatch fence (the OpenCL-event analog).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, List, Optional

import jax


@dataclass
class TimingResult:
    min_s: float
    mean_s: float
    runs: List[float]

    @property
    def spread(self) -> float:
        """min-vs-mean spread — the paper found <5% when well above launch
        overhead; we record it per kernel as a measurement-quality signal."""
        return abs(self.mean_s - self.min_s) / self.min_s if self.min_s else 0.0


def time_kernel(fn: Callable[[], object], *, runs: int = 30, drop: int = 4,
                min_time_s: float = 0.0) -> TimingResult:
    """Time ``fn`` per the paper's protocol.

    ``fn`` must be a zero-arg callable returning jax arrays (already jitted,
    inputs pre-staged).  ``min_time_s``: inner-repeat the call until one
    timing sample exceeds this floor (the paper sizes kernels to exceed
    launch overhead; on very fast CPU kernels we repeat instead and divide).
    """
    # warmup: trigger compilation outside the counted runs
    jax.block_until_ready(fn())

    # determine inner repeat factor against the launch-overhead floor
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    once = time.perf_counter() - t0
    inner = max(1, int(min_time_s / max(once, 1e-9)) if min_time_s else 1)

    samples = []
    for _ in range(runs):
        t0 = time.perf_counter()
        for _ in range(inner):
            out = fn()
        jax.block_until_ready(out)
        samples.append((time.perf_counter() - t0) / inner)
    kept = samples[drop:]
    return TimingResult(min_s=min(kept), mean_s=sum(kept) / len(kept),
                        runs=samples)


def measure_launch_overhead(runs: int = 30, drop: int = 4) -> float:
    """Empty-kernel floor — the paper calibrates minimum problem sizes so
    run time meets/exceeds this."""
    f = jax.jit(lambda: jax.numpy.zeros(()))
    return time_kernel(f, runs=runs, drop=drop).min_s
