"""Algebraic optimization + fused basis-matrix lowering for the symbolic
cost model.

The paper's premise is that wall time is *linear in symbolically gathered
counts* — ``T ≈ <α, p(n)>`` — and this module exploits that linearity end
to end.  Where ``symcount.CompiledVector`` compiles each property's
``Expr`` to an independent closure (so shared subterms re-evaluate once
per property per call, and scoring loops model keys in Python), here a
whole property-vector map lowers to ONE **fused basis program**:

  1. **canonicalize** every tree (``simplify``): n-ary Add/Mul flattening
     with constant folding and like-term collection, constant Piecewise
     guards resolved and else-chains hoisted flat, ``Max``/``Min``
     flattening, ``Pow`` identities;
  2. **decompose** each property into a linear combination of non-constant
     *basis terms* (coefficients pulled out of the canonical Mul forms) and
     **deduplicate the terms across all properties** — the model's
     linearity means a term shared by three properties is worth one column,
     not three;
  3. **lower** all deduped terms into a single generated numpy function
     with DAG-level common-subexpression elimination: every distinct
     subtree becomes one assignment, evaluated once per call no matter how
     many terms (or properties) reference it.

Evaluating the program over an array environment yields the **basis
matrix** ``B`` (cells × terms); folding a ``LinearCostModel`` through the
coefficient matrix gives a per-term weight vector ``w̃ = Cᵀ·α``, so scoring
an entire candidate space is ``B @ w̃`` — one GEMV.  ``score_cells`` adds
the *gathered-counts* fast path on top: array environments in a plan sweep
carry massive duplication (every mesh repeats each plan's microbatch
count, every plan repeats each mesh's dp/tp ways), so the program
evaluates on the UNIQUE environment rows and scatters back — the basis
matrix never grows past the distinct-row count.

Two more layers ride on the same decomposition:

  * **incremental rescoring** (``BasisCache``): basis columns cache keyed
    by (term, the fingerprint of the term's OWN free-variable values), so
    a device-count delta between two ``elastic.replan`` calls recomputes
    only the DP/TP-dependent columns — everything keyed on (B, S, M) comes
    back from cache;
  * a **persistent on-disk compile cache** (``load_or_build``): programs
    serialize as (generated source + coefficient matrices) keyed by a
    canonical content hash + the model schema version, so repeated CLI
    invocations skip symbolic simplification and codegen entirely.

Consumers: ``core.planspace`` (fused ``PlanSpace.scores``), ``core.
predictor`` (fused step programs), ``kernels.autotune`` (fused block-grid
scoring), ``distributed.elastic`` / ``runtime.straggler`` (cached
incremental rescores).  ``benchmarks/fused_bench.py`` records the speedup
over the per-key column engine in ``BENCH_fused.json``.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.lru import LRUCache
from repro.core.model import SCHEMA_VERSION
from repro.core.symcount import (
    Add, CeilDiv, Const, Expr, ExprLike, FloorDiv, Max, Min, Mul, Piecewise,
    Pow, Var, as_expr,
)
from repro.obs import metrics as _obs_metrics

# registry-side telemetry (repro.obs.metrics is dependency-free, so this
# import can never cycle): BasisCache column probes and the disk compile
# cache both publish here, alongside their instance/module views.
_BASIS_HITS = _obs_metrics.REGISTRY.counter(
    "repro_basis_cache_hits_total",
    "BasisCache column probes served from cache")
_BASIS_MISSES = _obs_metrics.REGISTRY.counter(
    "repro_basis_cache_misses_total",
    "BasisCache column probes that recomputed the column")
_BASIS_INVALIDATIONS = _obs_metrics.REGISTRY.counter(
    "repro_basis_cache_invalidations_total",
    "BasisCache.clear() epochs (drift refits, explicit resets)")

#: bump when the canonical form, codegen, or serialization layout changes —
#: part of every disk-cache key, so stale programs can never load.
FORMAT_VERSION = 1


# ---------------------------------------------------------------------------
# Canonicalization — n-ary flattening, constant folding, Piecewise hoisting
# ---------------------------------------------------------------------------


def _addends(e: Expr):
    if isinstance(e, Add):
        yield from _addends(e.a)
        yield from _addends(e.b)
    else:
        yield e


def _factors(e: Expr):
    if isinstance(e, Mul):
        yield from _factors(e.a)
        yield from _factors(e.b)
    else:
        yield e


def _split_coeff(e: Expr) -> Tuple[float, Optional[Expr]]:
    """Canonical-form addend → (coefficient, non-constant part|None).

    Simplified Mul chains carry at most one ``Const`` and it leads, so this
    is a shape check, not a search."""
    if isinstance(e, Const):
        return e.v, None
    if isinstance(e, Mul) and isinstance(e.a, Const):
        return e.a.v, e.b
    return 1, e


def _rebuild_mul(coeff, factors: Sequence[Expr]) -> Expr:
    if coeff == 0 or not factors:
        return Const(coeff)
    out = factors[0]
    for f in factors[1:]:
        out = Mul(out, f)
    if coeff != 1:
        out = Mul(Const(coeff), out)
    return out


def _rebuild_add(const, pairs: Sequence[Tuple[float, Expr]]) -> Expr:
    parts = [_rebuild_mul(c, [t]) if c != 1 else t for c, t in pairs]
    if const != 0 or not parts:
        parts.append(Const(const))
    out = parts[0]
    for p in parts[1:]:
        out = Add(out, p)
    return out


def simplify(e: ExprLike, _memo: Optional[dict] = None) -> Expr:
    """Canonicalize ``e`` preserving ``eval`` semantics.

    Integer-only trees simplify *exactly* (Python int arithmetic is
    arbitrary precision and the rewrites are value-preserving); float
    constants may reassociate, changing results only at rounding level —
    the fused-vs-loop goldens pin rtol ≤ 1e-9.

    Rewrites: Add/Mul flattened n-ary with constants folded and like terms
    collected (terms ordered by canonical repr, so structurally equal sums
    canonicalize identically regardless of construction order); constant
    distribution over sums; ``Pow`` k∈{0,1} and constant-base folding;
    constant FloorDiv/CeilDiv folding; Max/Min flattened, deduped, constant
    args pre-folded; Piecewise else-chains hoisted flat, constant guards
    resolved, duplicate/dead branches dropped.
    """
    e = as_expr(e)
    memo: dict = {} if _memo is None else _memo
    return _simp(e, memo)


def _simp(e: Expr, memo: dict) -> Expr:
    out = memo.get(e)
    if out is not None:
        return out
    out = _simp_node(e, memo)
    memo[e] = out
    return out


def _simp_node(e: Expr, memo: dict) -> Expr:
    if isinstance(e, (Const, Var)):
        return e

    if isinstance(e, Add):
        const = 0
        coeffs: Dict[Expr, float] = {}
        order: List[Expr] = []
        for raw in _addends(e):
            s = _simp(raw, memo)
            for ad in _addends(s):      # children may simplify to sums
                c, t = _split_coeff(ad)
                if t is None:
                    const += c
                else:
                    if t not in coeffs:
                        coeffs[t] = 0
                        order.append(t)
                    coeffs[t] += c
        order.sort(key=repr)
        pairs = [(coeffs[t], t) for t in order if coeffs[t] != 0]
        return _rebuild_add(const, pairs)

    if isinstance(e, Mul):
        coeff = 1
        factors: List[Expr] = []
        for raw in _factors(e):
            s = _simp(raw, memo)
            for f in _factors(s):
                c, t = _split_coeff(f)
                coeff *= c
                if t is not None:
                    factors.append(t)
        if coeff == 0:
            return Const(0)
        factors.sort(key=repr)
        if len(factors) == 1 and isinstance(factors[0], Add):
            # distribute the constant over the (already canonical) sum so
            # cross-property dedup sees the shared addends, not one blob
            inner_const, pairs = _linear_parts(factors[0])
            return _rebuild_add(inner_const * coeff,
                                [(c * coeff, t) for c, t in pairs])
        return _rebuild_mul(coeff, factors)

    if isinstance(e, Pow):
        a = _simp(e.a, memo)
        if e.k == 0:
            return Const(1)
        if e.k == 1:
            return a
        if isinstance(a, Const):
            return Const(a.v ** e.k)
        return Pow(a, e.k)

    if isinstance(e, FloorDiv):
        a, b = _simp(e.a, memo), _simp(e.b, memo)
        if isinstance(a, Const) and isinstance(b, Const) and b.v != 0:
            return Const(a.v // b.v)
        return FloorDiv(a, b)

    if isinstance(e, CeilDiv):
        a, b = _simp(e.a, memo), _simp(e.b, memo)
        if isinstance(a, Const) and isinstance(b, Const) and b.v != 0:
            return Const(-((-a.v) // b.v))
        return CeilDiv(a, b)

    if isinstance(e, (Max, Min)):
        cls = type(e)
        red = max if cls is Max else min
        cval = None
        args: List[Expr] = []
        seen = set()
        for raw in e.args:
            s = _simp(raw, memo)
            flat = s.args if isinstance(s, cls) else (s,)
            for f in flat:
                if isinstance(f, Const):
                    cval = f.v if cval is None else red(cval, f.v)
                elif f not in seen:
                    seen.add(f)
                    args.append(f)
        if not args:
            return Const(cval)
        if cval is not None:
            args.append(Const(cval))
        if len(args) == 1:
            return args[0]
        return cls(*sorted(args, key=repr))

    if isinstance(e, Piecewise):
        branches: List[Tuple[Expr, Expr]] = []
        stack = [e]
        otherwise = None
        while stack:                      # hoist nested else-chains flat
            pw = stack.pop()
            branches.extend(pw.branches)
            if isinstance(pw.otherwise, Piecewise):
                stack.append(pw.otherwise)
            else:
                otherwise = pw.otherwise
        otherwise = _simp(otherwise, memo)
        out_branches: List[Tuple[Expr, Expr]] = []
        seen_guards = set()
        for g, v in branches:
            g, v = _simp(g, memo), _simp(v, memo)
            if isinstance(g, Const):
                if g.v > 0:               # always fires if reached
                    otherwise = v
                    break
                continue                  # never fires: dead branch
            if g in seen_guards:          # earlier identical guard shadows
                continue
            seen_guards.add(g)
            out_branches.append((g, v))
        while out_branches and out_branches[-1][1] == otherwise:
            out_branches.pop()            # branch value = fallthrough value
        if not out_branches:
            return otherwise
        return Piecewise(out_branches, otherwise)

    raise TypeError(f"cannot canonicalize {type(e).__name__}")


def _linear_parts(e: Expr) -> Tuple[float, List[Tuple[float, Expr]]]:
    """Top-level linear decomposition of an ALREADY simplified expr."""
    const = 0
    pairs: List[Tuple[float, Expr]] = []
    for ad in _addends(e):
        c, t = _split_coeff(ad)
        if t is None:
            const += c
        else:
            pairs.append((c, t))
    return const, pairs


def linear_terms(e: ExprLike) -> Tuple[float, List[Tuple[float, Expr]]]:
    """``simplify`` + split into (constant, [(coeff, basis term), ...])."""
    return _linear_parts(simplify(e))


# ---------------------------------------------------------------------------
# Fused lowering — one generated numpy function for ALL basis terms
# ---------------------------------------------------------------------------


class _Emitter:
    """DAG-level CSE codegen: every distinct subtree (by canonical repr)
    becomes one assignment in the generated function body."""

    def __init__(self, argnames: Mapping[str, str]):
        self.argnames = argnames
        self.lines: List[str] = []
        self._slots: Dict[Expr, str] = {}
        self._n = 0

    def _new_slot(self, rhs: str) -> str:
        name = f"_v{self._n}"
        self._n += 1
        self.lines.append(f"{name} = {rhs}")
        return name

    def ref(self, e: Expr) -> str:
        if isinstance(e, Const):
            return repr(e.v)
        if isinstance(e, Var):
            return self.argnames[e.name]
        slot = self._slots.get(e)
        if slot is None:
            slot = self._new_slot(self._rhs(e))
            self._slots[e] = slot
        return slot

    def _rhs(self, e: Expr) -> str:
        if isinstance(e, Add):
            return f"{self.ref(e.a)} + {self.ref(e.b)}"
        if isinstance(e, Mul):
            return f"{self.ref(e.a)} * {self.ref(e.b)}"
        if isinstance(e, Pow):
            a = self.ref(e.a)
            if e.k < 0:   # int arrays reject negative powers; go via float64
                return f"_np.asarray({a}, dtype=_np.float64) ** {e.k}"
            return f"{a} ** {e.k}"
        if isinstance(e, FloorDiv):
            return f"_np.floor_divide({self.ref(e.a)}, {self.ref(e.b)})"
        if isinstance(e, CeilDiv):
            return f"-_np.floor_divide(-({self.ref(e.a)}), {self.ref(e.b)})"
        if isinstance(e, Max):
            out = self.ref(e.args[0])
            for a in e.args[1:]:
                out = f"_np.maximum({out}, {self.ref(a)})"
                out = self._new_slot(out)
            return out
        if isinstance(e, Min):
            out = self.ref(e.args[0])
            for a in e.args[1:]:
                out = f"_np.minimum({out}, {self.ref(a)})"
                out = self._new_slot(out)
            return out
        if isinstance(e, Piecewise):
            out = self.ref(e.otherwise)
            for g, v in reversed(e.branches):   # first truthy guard wins
                out = self._new_slot(
                    f"_np.where({self.ref(g)} > 0, {self.ref(v)}, {out})")
            return out
        raise TypeError(f"cannot lower {type(e).__name__}")


def _codegen(terms: Sequence[Expr], params: Sequence[str]) -> str:
    names = {v: f"_a{i}" for i, v in enumerate(params)}
    em = _Emitter(names)
    outs = [em.ref(t) for t in terms]
    args = "".join(f", {names[v]}" for v in params)
    body = "\n    ".join(em.lines) if em.lines else "pass"
    ret = ", ".join(outs)
    return (f"def _fused(_np{args}):\n"
            f"    {body}\n"
            f"    return ({ret}{',' if len(outs) == 1 else ''})")


def _compile_source(source: str) -> Callable:
    ns: Dict[str, object] = {}
    exec(compile(source, "<exprops.codegen>", "exec"), ns)
    return ns["_fused"]


def _term_source(term_repr_emit: str, params: Sequence[str],
                 names: Mapping[str, str]) -> str:
    args = "".join(f", {names[v]}" for v in params)
    return f"lambda _np{args}: {term_repr_emit}"


class BasisProgram:
    """A property-vector map lowered to deduped basis terms + coefficients.

    ``keys[k]``'s value is ``const[k] + Σ_i coeff[k, i] · term_i(env)``.
    ``__call__(env)`` evaluates ALL terms through the single CSE'd
    generated function; ``score`` folds a model's weights through ``coeff``
    into one per-term vector and returns the GEMV.
    """

    __slots__ = ("keys", "params", "coeff", "const", "terms", "term_reprs",
                 "term_params", "term_srcs", "source", "_fn", "_term_fns",
                 "_fold_cache")

    def __init__(self, keys, params, coeff, const, term_reprs, term_params,
                 term_srcs, source, terms=None):
        self.keys = list(keys)
        self.params = tuple(params)
        self.coeff = np.zeros((len(self.keys), len(term_reprs)),
                              dtype=np.float64)
        if self.coeff.size:
            self.coeff[:] = np.asarray(coeff, dtype=np.float64).reshape(
                self.coeff.shape)
        self.const = np.asarray(const, dtype=np.float64)
        self.terms = terms               # Expr objects; None when disk-loaded
        self.term_reprs = list(term_reprs)
        self.term_params = [tuple(p) for p in term_params]
        self.term_srcs = list(term_srcs)
        self.source = source
        self._fn = _compile_source(source)
        self._term_fns: Dict[int, Callable] = {}
        self._fold_cache: LRUCache = LRUCache(maxsize=16)

    # -- construction ------------------------------------------------------
    @classmethod
    def build(cls, pv: Mapping[str, ExprLike]) -> "BasisProgram":
        memo: dict = {}
        keys = list(pv)
        const = np.zeros(len(keys), dtype=np.float64)
        terms: List[Expr] = []
        index: Dict[Expr, int] = {}
        entries: List[List[Tuple[int, float]]] = []
        for k, raw in pv.items():
            row: List[Tuple[int, float]] = []
            if isinstance(raw, Expr):
                c0, pairs = _linear_parts(_simp(raw, memo))
                const[len(entries)] = c0
                for c, t in pairs:
                    i = index.get(t)
                    if i is None:
                        i = index[t] = len(terms)
                        terms.append(t)
                    row.append((i, c))
            else:
                const[len(entries)] = float(raw)
            entries.append(row)
        coeff = np.zeros((len(keys), len(terms)), dtype=np.float64)
        for r, row in enumerate(entries):
            for i, c in row:
                coeff[r, i] += c
        params = sorted(set().union(*(t.free_vars() for t in terms))
                        if terms else set())
        term_params = [tuple(sorted(t.free_vars())) for t in terms]
        names = {v: f"_a{i}" for i, v in enumerate(params)}
        term_srcs = [_term_source(t._emit(names), tp, names)
                     for t, tp in zip(terms, term_params)]
        source = _codegen(terms, params)
        return cls(keys, params, coeff, const, [repr(t) for t in terms],
                   term_params, term_srcs, source, terms=terms)

    # -- evaluation --------------------------------------------------------
    @property
    def n_terms(self) -> int:
        return len(self.term_reprs)

    def __call__(self, env: Mapping[str, object]) -> tuple:
        return self._fn(np, *(env[p] for p in self.params))

    def matrix(self, env: Mapping[str, object], n: int) -> np.ndarray:
        """The basis matrix ``B``: (n, n_terms) float64."""
        vals = self(env)
        B = np.empty((n, self.n_terms), dtype=np.float64)
        for i, v in enumerate(vals):
            B[:, i] = np.broadcast_to(np.asarray(v, dtype=np.float64), (n,))
        return B

    def property_columns(self, env: Mapping[str, object], n: int
                         ) -> Dict[str, np.ndarray]:
        """Per-property columns (the ``CompiledVector`` contract), via the
        fused program: ``B @ coeffᵀ + const``."""
        P = self.matrix(env, n) @ self.coeff.T + self.const
        return {k: P[:, j] for j, k in enumerate(self.keys)}

    # -- model folding + GEMV scoring --------------------------------------
    def fold(self, model) -> Tuple[np.ndarray, float]:
        """(per-term weights ``w̃ = Cᵀ·α``, constant seconds) for ``model``.

        Memoized per model instance; the entry keeps a strong reference to
        the model so an id() can never be recycled while cached."""
        return self._folded(model)[:2]

    def _folded(self, model):
        hit = self._fold_cache.get(id(model))
        if hit is not None and hit[3] is model:
            return hit
        # id miss: fall back to a content key, so freshly-built but equal
        # models (e.g. resolve_model(None) per call) still reuse the fold
        ckey = (model.device, hash(model.weights.tobytes()),
                hash(tuple(model.keys)))
        hit = self._fold_cache.get(ckey)
        if hit is not None:
            return hit
        w = {k: float(v) for k, v in zip(model.keys, model.weights)}
        alpha = np.asarray([w.get(k, 0.0) for k in self.keys])
        w_terms = self.coeff.T @ alpha
        w_const = float(self.const @ alpha)
        # (term index, Python-float weight) pairs: the GEMV unrolled, so
        # scalar basis terms (from scalar env entries) stay in native
        # Python arithmetic instead of paying per-term ufunc dispatch
        nz = [(int(i), float(w_terms[i])) for i in np.nonzero(w_terms)[0]]
        entry = (w_terms, w_const, nz, model)
        self._fold_cache[id(model)] = entry
        self._fold_cache[ckey] = entry
        return entry

    def score(self, env: Mapping[str, object], model):
        """``B @ w̃ + const`` for one (array) environment — scalar or
        broadcastable array, matching the env entries.  (The GEMV runs
        unrolled over the folded nonzero weights; see ``_folded``.)"""
        _, w_const, nz, _ = self._folded(model)
        if not nz:
            return w_const
        vals = self._fn(np, *(env[p] for p in self.params))
        total = w_const
        for i, w in nz:
            total = total + w * vals[i]
        return total

    def term_fn(self, i: int) -> Callable:
        fn = self._term_fns.get(i)
        if fn is None:
            fn = eval(compile(self.term_srcs[i], "<exprops.term>", "eval"))
            self._term_fns[i] = fn
        return fn

    def explain(self, env: Mapping[str, object], model, *,
                scale: float = 1.0, source: str = "step"):
        """Per-term attribution of ``scale · score(env, model)``: a list of
        (term repr, seconds, category, fed property keys) rows — the folded
        constant appears as term ``"1"`` — whose seconds sum exactly to the
        fused GEMV score.  Delegates to ``repro.obs.explain`` (imported
        lazily; ``obs.explain`` sits above core)."""
        from repro.obs.explain import explain_program
        return explain_program(self, env, model, scale=scale, source=source)

    # -- serialization (the on-disk compile cache) -------------------------
    def to_json_dict(self) -> Dict[str, object]:
        return {
            "format": FORMAT_VERSION,
            "model_schema": SCHEMA_VERSION,
            "keys": self.keys,
            "params": list(self.params),
            "coeff": self.coeff.tolist(),
            "const": self.const.tolist(),
            "term_reprs": self.term_reprs,
            "term_params": [list(p) for p in self.term_params],
            "term_srcs": self.term_srcs,
            "source": self.source,
        }

    @classmethod
    def from_json_dict(cls, d: Mapping[str, object]) -> "BasisProgram":
        if d.get("format") != FORMAT_VERSION \
                or d.get("model_schema") != SCHEMA_VERSION:
            raise ValueError("stale fused-program record")
        return cls(d["keys"], d["params"], d["coeff"], d["const"],
                   d["term_reprs"], d["term_params"], d["term_srcs"],
                   d["source"])


def build_program(pv: Mapping[str, ExprLike]) -> BasisProgram:
    return BasisProgram.build(pv)


# ---------------------------------------------------------------------------
# Cell scoring — unique-environment gather/scatter + incremental cache
# ---------------------------------------------------------------------------


def _unique_rows(cols: List[np.ndarray]
                 ) -> Tuple[List[np.ndarray], np.ndarray]:
    """(unique value rows per column, inverse indices).  Integer columns
    pack into one int64 key when the value ranges allow (one ``np.unique``
    over scalars instead of a lexicographic row sort); tiny inputs dedup
    through a plain dict — numpy's sort setup dwarfs the work there."""
    n = len(cols[0])
    if n <= 64:
        pos: Dict[tuple, int] = {}
        inv = np.empty(n, dtype=np.intp)
        order: List[tuple] = []
        for i, row in enumerate(zip(*(c.tolist() for c in cols))):
            k = pos.get(row)
            if k is None:
                k = pos[row] = len(order)
                order.append(row)
            inv[i] = k
        dtypes = [c.dtype for c in cols]
        return [np.asarray([r[j] for r in order], dtype=dt)
                for j, dt in enumerate(dtypes)], inv
    if all(np.issubdtype(c.dtype, np.integer) for c in cols):
        mins = [int(c.min()) for c in cols]
        spans = [int(c.max()) - m + 1 for c, m in zip(cols, mins)]
        total = 1
        for s in spans:
            total *= s
        if total < 2 ** 62:
            key = np.zeros(len(cols[0]), dtype=np.int64)
            for c, m, s in zip(cols, mins, spans):
                key = key * s + (c.astype(np.int64) - m)
            _, first, inv = np.unique(key, return_index=True,
                                      return_inverse=True)
            return [c[first] for c in cols], inv.reshape(-1)
    stacked = np.stack([np.asarray(c) for c in cols], axis=1)
    rows, inv = np.unique(stacked, axis=0, return_inverse=True)
    return [rows[:, j] for j in range(rows.shape[1])], inv.reshape(-1)


def _is_array(v) -> bool:
    return isinstance(v, np.ndarray) and v.ndim > 0


class BasisCache:
    """Column-level cache for incremental rescoring.

    Keys are ``(term canonical repr, fingerprint of the term's own
    free-variable values)`` — the *unique rows* of exactly the variables
    the term reads.  A replan delta that changes only the device count
    leaves every (B, S, M)-keyed column's fingerprint intact, so only the
    DP/TP-dependent columns recompute.  ``hits``/``misses`` count column
    probes (the acceptance telemetry for warm replans)."""

    def __init__(self, maxsize: int = 4096):
        self._lru: LRUCache = LRUCache(maxsize=maxsize)
        self.hits = 0
        self.misses = 0
        self.invalidations = 0

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._lru),
                "invalidations": self.invalidations}

    def clear(self) -> None:
        """Drop every cached column (hit/miss telemetry survives).

        The online-calibration path calls this on a drift refit: basis
        columns themselves are weight-independent, but a monitor that
        cached columns for a now-diverged regime must re-derive against
        whatever the refit environment produces — and an explicit epoch
        here keeps 'no stale entries after refit' a checkable invariant
        rather than an argument about key structure."""
        self._lru = LRUCache(maxsize=self._lru.maxsize)
        self.invalidations += 1
        _BASIS_INVALIDATIONS.inc()


def _fingerprint(var_names: Tuple[str, ...], scalars: tuple,
                 rows: Optional[List[np.ndarray]]) -> tuple:
    if rows is None:
        return (var_names, scalars)
    h = hashlib.blake2b(digest_size=16)
    for r in rows:
        h.update(np.ascontiguousarray(r).tobytes())
        h.update(r.dtype.str.encode())
    return (var_names, scalars, len(rows[0]) if rows else 0, h.digest())


def score_cells(program: BasisProgram, env: Mapping[str, object],
                n_cells: int, model, cache: Optional[BasisCache] = None
                ) -> np.ndarray:
    """Score ``n_cells`` environments through ``program`` as one GEMV.

    ``env`` maps each program parameter to a scalar or an (n_cells,)
    column.  The gathered-counts fast path: evaluate on the UNIQUE rows of
    the array-valued parameters and scatter back through the inverse index
    — sweep environments are massively duplicated (microbatch counts
    repeat per mesh, dp/tp ways repeat per plan), so the basis matrix
    stays (distinct rows × terms) regardless of the sweep size.

    With ``cache``, evaluation switches to per-term columns keyed by each
    term's own variable fingerprint (see ``BasisCache``) — the incremental
    path ``elastic.replan`` / ``StragglerMonitor`` use.
    """
    if n_cells == 0:
        return np.zeros(0, dtype=np.float64)
    if cache is not None:
        return _score_cells_cached(program, env, n_cells, model, cache)
    w_terms, w_const = program.fold(model)
    if not np.any(w_terms):
        return np.full(n_cells, w_const, dtype=np.float64)
    arr_params = [p for p in program.params if _is_array(env[p])]
    if not arr_params:
        return np.full(n_cells, float(np.asarray(program.score(env, model))),
                       dtype=np.float64)
    rows, inv = _unique_rows([np.asarray(env[p]) for p in arr_params])
    uenv = dict(env)
    uenv.update(zip(arr_params, rows))
    s = np.asarray(program.score(uenv, model), dtype=np.float64)
    s = np.broadcast_to(s, (len(rows[0]),))
    return s[inv]


def _score_cells_cached(program: BasisProgram, env: Mapping[str, object],
                        n_cells: int, model, cache: BasisCache
                        ) -> np.ndarray:
    w_terms, w_const = program.fold(model)
    total = np.full(n_cells, w_const, dtype=np.float64)
    # group priced terms by the exact variable subset they read
    groups: Dict[Tuple[str, ...], List[int]] = {}
    for i in np.nonzero(w_terms)[0]:
        groups.setdefault(program.term_params[int(i)], []).append(int(i))
    hits = misses = 0     # batched per call: the registry lock stays off
    for var_names, term_ids in groups.items():  # the per-column hot loop
        arr_vars = [v for v in var_names if _is_array(env[v])]
        scalars = tuple((v, env[v]) for v in var_names if v not in arr_vars)
        if arr_vars:
            rows, inv = _unique_rows([np.asarray(env[v]) for v in arr_vars])
        else:
            rows, inv = None, None
        fp = _fingerprint(var_names, scalars, rows)
        uenv = dict(scalars)
        if rows is not None:
            uenv.update(zip(arr_vars, rows))
        for i in term_ids:
            ckey = (program.term_reprs[i], fp)
            col = cache._lru.get(ckey)
            if col is None:
                fn = program.term_fn(i)
                col = np.asarray(
                    fn(np, *(uenv[v] for v in program.term_params[i])),
                    dtype=np.float64)
                cache._lru[ckey] = col
                misses += 1
            else:
                hits += 1
            if inv is None:
                total += w_terms[i] * float(np.asarray(col))
            else:
                expanded = np.broadcast_to(col, (len(rows[0]),))[inv]
                total += w_terms[i] * expanded
    cache.hits += hits
    cache.misses += misses
    if hits:
        _BASIS_HITS.inc(hits)
    if misses:
        _BASIS_MISSES.inc(misses)
    return total


# ---------------------------------------------------------------------------
# Persistent on-disk compile cache
# ---------------------------------------------------------------------------

class _RegistryStats:
    """Dict-like facade over a labeled registry counter, so the existing
    ``DISK_STATS["hits"] += 1`` call sites (and test resets via
    ``DISK_STATS[k] = 0``) keep working while the metrics registry is the
    single store.  Assigning below the current value resets the counter
    family (test isolation) rather than decrementing."""

    __slots__ = ("_counter", "_label", "_fields")

    def __init__(self, counter, label: str, fields: Tuple[str, ...]):
        self._counter = counter
        self._label = label
        self._fields = fields

    def __getitem__(self, key: str) -> int:
        if key not in self._fields:
            raise KeyError(key)
        return int(self._counter.value(**{self._label: key}))

    def __setitem__(self, key: str, value: int) -> None:
        delta = int(value) - self[key]
        if delta >= 0:
            if delta:
                self._counter.inc(delta, **{self._label: key})
        else:       # a rewind is a reset (tests zeroing between cases)
            self._counter._bump(
                _obs_metrics._labelset({self._label: key}),
                int(value), absolute=True)

    def __iter__(self):
        return iter(self._fields)

    def items(self):
        return [(k, self[k]) for k in self._fields]

    def __repr__(self) -> str:
        return repr(dict(self.items()))


#: process-wide disk-cache telemetry (reported by the autoshard CLI; the CI
#: compile-cache smoke step asserts a warm second invocation).  Backed by
#: ``repro_compile_cache_events_total{event=…}`` in the metrics registry.
DISK_STATS = _RegistryStats(
    _obs_metrics.REGISTRY.counter(
        "repro_compile_cache_events_total",
        "persistent compile-cache outcomes, by event (hits/misses/errors)"),
    "event", ("hits", "misses", "errors"))


def compile_cache_dir() -> Optional[str]:
    """The on-disk program cache directory, or None when disabled.

    ``REPRO_COMPILE_CACHE`` overrides the default
    ``~/.cache/repro/exprops``; set it to ``0``/``off``/``none`` to
    disable persistence entirely."""
    v = os.environ.get("REPRO_COMPILE_CACHE")
    if v is not None:
        if v.strip().lower() in ("", "0", "off", "none", "disabled"):
            return None
        return v
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "exprops")


_EPOCH_MODULES = ("repro.core.symcount", "repro.core.archcount",
                  "repro.core.kernelmodel", "repro.core.predictor",
                  "repro.core.exprops")
_source_epoch_cache: Optional[str] = None


def _source_epoch() -> str:
    """Fingerprint of the modules that DEFINE the symbolic formulas.

    Disk keys name a program by its *generators* (config repr, step kind,
    topology class) so a warm cache can skip building the symbolic vectors
    entirely — but that means an edit to a count formula would otherwise
    keep serving the pre-edit program.  Hashing the source bytes of the
    formula modules into every key invalidates the cache on any such edit,
    with no version-bump discipline required."""
    global _source_epoch_cache
    if _source_epoch_cache is None:
        import importlib.util
        h = hashlib.sha256()
        for mod in _EPOCH_MODULES:
            spec = importlib.util.find_spec(mod)
            if spec and spec.origin:
                try:
                    with open(spec.origin, "rb") as f:
                        h.update(f.read())
                except OSError:
                    h.update(mod.encode())
        _source_epoch_cache = h.hexdigest()[:16]
    return _source_epoch_cache


def program_key(*parts: object) -> str:
    """Canonical content hash for a program's inputs.  Callers pass the
    *generators* of the property map (config repr, step kind, topology…),
    so a warm cache skips building the symbolic vectors entirely; the
    format + model-schema versions and the formula-module source epoch
    (see ``_source_epoch``) ride in every key."""
    h = hashlib.sha256()
    h.update(f"fmt={FORMAT_VERSION};schema={SCHEMA_VERSION};"
             f"epoch={_source_epoch()}".encode())
    for p in parts:
        h.update(b"|")
        h.update(repr(p).encode())
    return h.hexdigest()


def load_or_build(key: Optional[str],
                  builder: Callable[[], Mapping[str, ExprLike]]
                  ) -> BasisProgram:
    """Fetch the fused program for ``key`` from the disk cache, else build
    it from ``builder()``'s property map and persist it (atomic rename;
    best-effort — an unwritable cache dir never fails the caller)."""
    cdir = compile_cache_dir() if key else None
    path = os.path.join(cdir, f"{key}.json") if cdir else None
    if path and os.path.exists(path):
        try:
            with open(path) as f:
                prog = BasisProgram.from_json_dict(json.load(f))
            DISK_STATS["hits"] += 1
            return prog
        except Exception:   # any unreadable/corrupt record -> rebuild
            DISK_STATS["errors"] += 1
            try:
                # quarantine the corrupt entry so it stops costing a parse
                # attempt on every warm start; the rebuild below rewrites
                # the real path atomically
                os.replace(path, path + ".corrupt")
            except OSError:
                pass
    prog = BasisProgram.build(builder())
    DISK_STATS["misses"] += 1
    if path:
        try:
            os.makedirs(cdir, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=cdir, suffix=".tmp")
            with os.fdopen(fd, "w") as f:
                json.dump(prog.to_json_dict(), f)
            os.replace(tmp, path)
        except OSError:
            DISK_STATS["errors"] += 1
    return prog


def disk_cache_report() -> str:
    """One CLI-friendly line: hit/miss counts + warm/cold verdict."""
    d = compile_cache_dir()
    if d is None:
        return "compile cache: disabled"
    h, m = DISK_STATS["hits"], DISK_STATS["misses"]
    state = "warm" if h and not m else ("cold" if m else "unused")
    return f"compile cache: {h} hits, {m} misses ({state}) [{d}]"
