"""The property taxonomy — §2 of the paper, adapted GPU→TPU/XLA.

A *property* is a performance-relevant event class whose count contributes
linearly to run time.  The paper's categories and our TPU/XLA analog:

================================  ==========================================
paper (GPU / OpenCL)              this system (XLA / TPU target, CPU runtime)
================================  ==========================================
global loads/stores by            HBM-stream accesses by element size ×
(32/64/128-bit × direction ×      direction × *access class*:
 amortized stride fraction)         s0    broadcast / uniform (stride-0)
                                    s1    contiguous last-dim stream
                                    sK_U  strided slice, stride K with
                                          utilization class U (the paper's
                                          amortized stride fraction: 1/2,
                                          2/2, 1/3 … 4/>4)
                                    gather  data-dependent / relayout access
                                          (the 'uncoalesced' class)
min(loads, stores)                identical (roofline-style nonlinearity)
local (shared-memory) loads       VMEM block transfers (Pallas BlockSpec
                                  traffic; XLA fusion-internal reuse)
FLOPs by kind × dtype             VPU flops by kind × dtype, plus a separate
                                  MXU property for dot_general contractions
                                  (the dominant rate split on TPU)
barriers                          grid-step synchronisations (Pallas grid
                                  barriers / scan steps)
const(1), work-group count        launch constant + grid-cell ('group') count
—                                 **beyond-paper**: collective bytes by kind
                                  (all_reduce / all_gather / reduce_scatter /
                                  all_to_all / permute) for multi-chip steps
================================  ==========================================

Property keys are plain strings so vectors serialize to JSON:

    load:32:s1      32-bit stride-1 loads         (count = accesses)
    store:64:s0     64-bit uniform stores
    load:32:s2_1/2  stride-2, utilization 1/2
    load:32:gather  uncoalesced loads
    minls:32        min(stride-1 loads, stride-1 stores)
    local:32:load   local/VMEM loads
    flop:32:add     f32 add/sub VPU flops
    flop:32:mul | flop:32:div | flop:32:exp | flop:32:special
    mxu:16 | mxu:32 dot_general MAC flops by operand bits
    barrier         barrier events
    groups          work-group / grid-cell count
    const1          1 per launch
    coll:all_reduce (bytes)  … coll:permute (bytes)
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Mapping, Tuple

# ---------------------------------------------------------------------------
# Canonical key constructors
# ---------------------------------------------------------------------------

DIRECTIONS = ("load", "store")
SIZES = (16, 32, 64)  # element bits tracked (bf16 / f32 / f64)
FLOP_KINDS = ("add", "mul", "div", "exp", "special")

COLLECTIVES = ("all_reduce", "all_gather", "reduce_scatter",
               "all_to_all", "permute")


def stride_class(stride: int, utilization: float) -> str:
    """Quantize (stride, utilization ratio) into the paper's §2.1 classes.

    stride 0 -> 's0'; stride 1 -> 's1'; stride s in {2,3,4} -> 'sK_k/K' with
    k the quantized utilization numerator; stride > 4 -> 's>4_k/>4'.
    """
    if stride == 0:
        return "s0"
    if stride == 1:
        return "s1"
    s = stride if stride <= 4 else ">4"
    denom = stride if stride <= 4 else 4  # numerator quantized over 4 bins
    # utilization in (0,1]; numerator k = ceil(util * denom), clipped to denom
    k = max(1, min(denom, int(-(-utilization * denom // 1))))
    return f"s{s}_{k}/{s}"


def mem_key(direction: str, bits: int, cls: str) -> str:
    assert direction in DIRECTIONS
    return f"{direction}:{bits}:{cls}"


def flop_key(bits: int, kind: str) -> str:
    assert kind in FLOP_KINDS
    return f"flop:{bits}:{kind}"


def mxu_key(bits: int) -> str:
    return f"mxu:{bits}"


def minls_key(bits: int) -> str:
    return f"minls:{bits}"


def local_key(bits: int) -> str:
    return f"local:{bits}:load"


def coll_key(kind: str) -> str:
    assert kind in COLLECTIVES
    return f"coll:{kind}"


BARRIER = "barrier"
GROUPS = "groups"
CONST1 = "const1"


# ---------------------------------------------------------------------------
# PropertyVector = Dict[str, number]; helpers
# ---------------------------------------------------------------------------

PropertyVector = Dict[str, float]


def finalize(pv: Mapping[str, float]) -> PropertyVector:
    """Drop zeros, add const1, and the min(loads, stores) properties."""
    out = {k: float(v) for k, v in pv.items() if v}
    for bits in SIZES:
        l = out.get(mem_key("load", bits, "s1"), 0.0)
        s = out.get(mem_key("store", bits, "s1"), 0.0)
        m = min(l, s)
        if m:
            out[minls_key(bits)] = m
    out[CONST1] = 1.0
    return out


def union_keys(vectors: Iterable[Mapping[str, float]]) -> List[str]:
    keys = set()
    for v in vectors:
        keys.update(v.keys())
    return sorted(keys)


def to_matrix(vectors: List[Mapping[str, float]], keys: List[str]):
    import numpy as np
    A = np.zeros((len(vectors), len(keys)))
    for i, v in enumerate(vectors):
        for j, k in enumerate(keys):
            A[i, j] = v.get(k, 0.0)
    return A


#: the coarse attribution buckets reports aggregate properties into
CATEGORIES = ("compute", "memory", "collective", "other")


def category(key: str) -> str:
    """Coarse cost category of a property key — the shared classification
    ``predictor.predict_step`` terms, ``obs.explain`` groupings, and the
    drift-attribution lines all use (one mapping, not three)."""
    head = key.split(":", 1)[0]
    if head in ("mxu", "flop"):
        return "compute"
    if head in ("load", "store", "local", "minls"):
        return "memory"
    if head == "coll":
        return "collective"
    return "other"


# Human-readable names for reports (Table-2 analog)
PRETTY = {
    "s0": "uniform (stride-0)",
    "s1": "stride-1",
    "gather": "uncoalesced/gather",
}


def pretty(key: str) -> str:
    parts = key.split(":")
    if key == BARRIER:
        return "Barriers"
    if key == GROUPS:
        return "Thread groups / grid cells"
    if key == CONST1:
        return "Const(1) launch overhead"
    if parts[0] == "coll":
        return f"Collective {parts[1]} (bytes)"
    if parts[0] == "minls":
        return f"Min(stride-1 loads, stride-1 stores) [{parts[1]}-bit]"
    if parts[0] == "local":
        return f"Local/VMEM {parts[1]}-bit loads"
    if parts[0] == "mxu":
        return f"MXU (dot) flops [{parts[1]}-bit]"
    if parts[0] == "flop":
        return f"{parts[2].capitalize()} flops [{parts[1]}-bit]"
    if parts[0] in DIRECTIONS:
        cls = PRETTY.get(parts[2], parts[2])
        return f"{parts[1]}-bit {cls} {parts[0]}s"
    return key
