"""HLO text parsing + loop-aware cost rollup — the automatic
property-extraction substrate at the compiled-artifact level.

This is the TPU/XLA analog of the paper's Loopy/Barvinok machinery, applied
to the *post-SPMD-partitioning* HLO: walk the computation graph, tally
per-instruction costs, and — crucially — multiply ``while`` bodies by their
trip counts.  XLA's built-in ``compiled.cost_analysis()`` counts each loop
body ONCE, which under-reports FLOPs/bytes/collective traffic by ~L× for
scan-over-layers models (validated in tests against closed-form 6·N·D);
this module exists to fix exactly that.

Cost conventions (mirroring HloCostAnalysis where it is right):
  * dot          — 2 · out_elems · Π(lhs contracting dim sizes)
  * reduce/…     — operand elems
  * elementwise  — out elems
  * bytes        — operand bytes + output bytes for materialized ops;
                   parameter/tuple/gte/bitcast/constant are free;
                   fusion params consumed via dynamic-slice count at the
                   SLICE size (a scanned param stack streams once per
                   iteration, not in full)
  * while        — body + condition, × trip count (from the condition's
                   compare-against-constant)
  * conditional  — max over branches (conservative)
  * collectives  — operand bytes, × enclosing trip counts, by kind
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BITS = {
    "pred": 8, "s4": 4, "u4": 4, "s8": 8, "u8": 8, "s16": 16, "u16": 16,
    "s32": 32, "u32": 32, "s64": 64, "u64": 64,
    "f8e4m3": 8, "f8e5m2": 8, "bf16": 16, "f16": 16, "f32": 32, "f64": 64,
    "c64": 64, "c128": 128, "token": 0, "opaque": 0,
}

# one array type like  bf16[8,128]{1,0:T(8,128)}  or  f32[]  or s32[4]
_ARRAY_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\](?:\{[^}]*\})?")
# instruction line:  %name = TYPE opcode(args...), attrs
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s+([\w\-]+)\((.*)$")
# computation header:  %name (args) -> type {     /  ENTRY %name (...)... {
# (arg lists may nest parentheses for tuple types — match greedily)
_COMP_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{")

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_FREE_OPS = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "iota", "after-all", "partition-id", "replica-id", "opt-barrier",
}

_CALL_ATTR_RE = re.compile(
    r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
# XLA annotates unrolled-able loops with their exact trip count
_KNOWN_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _shape_bytes(dtype: str, dims: str) -> int:
    bits = _DTYPE_BITS.get(dtype)
    if bits is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return (n * bits) // 8


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def type_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    return sum(_shape_bytes(dt, dims) for dt, dims in _ARRAY_RE.findall(type_str))


def type_elems(type_str: str) -> int:
    return sum(_shape_elems(dims) for _, dims in _ARRAY_RE.findall(type_str))


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str  # args + attributes, raw
    bytes_out: int
    elems_out: int
    dtype: Optional[str]  # first array dtype

    def called(self) -> List[str]:
        return _CALL_ATTR_RE.findall(self.rest) + [
            c.strip().lstrip("%")
            for m in _BRANCH_RE.findall(self.rest)
            for c in m.split(",") if c.strip()]

    def body_and_cond(self) -> Tuple[Optional[str], Optional[str]]:
        b = re.search(r"body=%?([\w.\-]+)", self.rest)
        c = re.search(r"condition=%?([\w.\-]+)", self.rest)
        return (b.group(1) if b else None, c.group(1) if c else None)


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    by_name: Dict[str, Instr] = field(default_factory=dict)

    def operand_names(self, ins: Instr) -> List[str]:
        """Operand instruction names.  ``ins.rest`` starts INSIDE the
        opcode's argument parentheses (the instruction regex consumed the
        opening paren), so we scan until the matching close."""
        depth, cur = 1, []
        for ch in ins.rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            cur.append(ch)
        arglist = "".join(cur)
        names = re.findall(r"%([\w.\-]+)", arglist)
        if names:
            return names
        return [t.strip().split(" ")[-1]
                for t in arglist.split(",") if t.strip()]

    def operand_bytes(self, ins: Instr) -> int:
        total = 0
        for nm in self.operand_names(ins):
            op = self.by_name.get(nm)
            if op is not None:
                total += op.bytes_out
        return total


@dataclass
class HloModule:
    computations: Dict[str, Computation] = field(default_factory=dict)
    entry: Optional[str] = None

    # legacy flat view (kept for property-extraction callers)
    @property
    def instrs(self) -> List[Instr]:
        out = []
        for c in self.computations.values():
            out.extend(c.instrs)
        return out


def parse_hlo(text: str) -> HloModule:
    mod = HloModule()
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if "/*" in line:  # strip  /*index=5*/  tuple-position comments
            line = _COMMENT_RE.sub("", line)
        hdr = _COMP_RE.match(line)
        if hdr and "=" not in line.split("{")[0]:
            cur = Computation(name=hdr.group(2))
            mod.computations[cur.name] = cur
            if hdr.group(1):
                mod.entry = cur.name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        if "=" not in line or "(" not in line:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        if not _ARRAY_RE.search(type_str):
            continue
        first = _ARRAY_RE.search(type_str)
        ins = Instr(
            name=name, type_str=type_str.strip(), opcode=opcode, rest=rest,
            bytes_out=type_bytes(type_str), elems_out=type_elems(type_str),
            dtype=first.group(1) if first else None,
        )
        cur.instrs.append(ins)
        cur.by_name[name] = ins
    if mod.entry is None and mod.computations:
        mod.entry = list(mod.computations)[-1]
    return mod


# ---------------------------------------------------------------------------
# Loop-aware cost rollup
# ---------------------------------------------------------------------------


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=lambda: defaultdict(float))

    def add(self, other: "Costs", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] += v * mult


def _trip_count(cond: Computation) -> int:
    """Trip count of a scan-style loop: the s32 constant the induction var
    is compared against.  Fallback 1 if no such constant exists."""
    best = 1
    for ins in cond.instrs:
        if ins.opcode == "constant" and ins.dtype in ("s32", "u32", "s64"):
            m = _CONST_INT_RE.search(f"constant({ins.rest}")
            m2 = re.match(r"^\s*(\d+)\)?", ins.rest)
            if m2:
                best = max(best, int(m2.group(1)))
    return best


def _dot_flops(comp: Computation, ins: Instr) -> float:
    k = 1.0
    m = _LHS_CDIMS_RE.search(ins.rest)
    ops = comp.operand_names(ins)
    if m and ops:
        lhs = comp.by_name.get(ops[0])
        if lhs is not None:
            arr = _ARRAY_RE.search(lhs.type_str)
            if arr and arr.group(2):
                dims = [int(d) for d in arr.group(2).split(",")]
                for di in m.group(1).split(","):
                    if di != "" and int(di) < len(dims):
                        k *= dims[int(di)]
    return 2.0 * ins.elems_out * k


def _fusion_bytes(mod: HloModule, comp: Computation, ins: Instr) -> float:
    """Fusion bytes: output + each operand at its *consumed* footprint —
    an operand whose only internal use is a dynamic-slice streams one slice
    per execution, not the whole buffer (the scanned-params case)."""
    total = float(ins.bytes_out)
    callees = ins.called()
    inner = mod.computations.get(callees[0]) if callees else None
    ops = comp.operand_names(ins)
    slice_out: Dict[int, int] = {}
    if inner is not None:
        params: Dict[str, int] = {}
        for iin in inner.instrs:
            if iin.opcode == "parameter":
                m = re.match(r"^\s*(\d+)\)?", iin.rest)
                if m:
                    params[iin.name] = int(m.group(1))
        for iin in inner.instrs:
            if iin.opcode == "dynamic-slice":
                onames = inner.operand_names(iin)
                if onames and onames[0] in params:
                    idx = params[onames[0]]
                    slice_out[idx] = slice_out.get(idx, 0) + iin.bytes_out
    for i, nm in enumerate(ops):
        op = comp.by_name.get(nm)
        if op is None:
            continue
        total += slice_out.get(i, op.bytes_out)
    return total


def _comp_costs(mod: HloModule, name: str,
                memo: Dict[str, Costs]) -> Costs:
    if name in memo:
        return memo[name]
    comp = mod.computations.get(name)
    out = Costs()
    if comp is None:
        memo[name] = out
        return out
    memo[name] = out  # pre-insert to break cycles (none expected)
    for ins in comp.instrs:
        op = ins.opcode
        if op in _FREE_OPS:
            continue
        if op == "while":
            body, cond = ins.body_and_cond()
            m = _KNOWN_TRIP_RE.search(ins.rest)
            if m:  # XLA's own loop analysis, exact
                trips = int(m.group(1))
            else:
                trips = _trip_count(mod.computations[cond]) \
                    if cond in mod.computations else 1
            if body in mod.computations:
                out.add(_comp_costs(mod, body, memo), float(trips))
            if cond in mod.computations:
                out.add(_comp_costs(mod, cond, memo), float(trips))
            continue
        if op == "conditional":
            branches = [b for b in ins.called() if b in mod.computations]
            if branches:
                cands = [_comp_costs(mod, b, memo) for b in branches]
                best = max(cands, key=lambda c: c.flops + c.bytes)
                out.add(best)
            continue
        if op in ("call", "async-start"):
            # callee costs only: the callee's ROOT already paid for the
            # result bytes, and a call site materializes nothing extra.
            # (This matters inside while/scan bodies, where XLA wraps the
            # per-step dynamic-slice of a scanned parameter stack in a
            # parallel call — recounting the call output here billed the
            # slice an extra time on EVERY trip.)
            for b in ins.called():
                if b in mod.computations:
                    out.add(_comp_costs(mod, b, memo))
            continue
        base = op[:-6] if op.endswith("-start") else op
        if op.endswith("-done"):
            continue
        if base in COLLECTIVE_OPS:
            b = comp.operand_bytes(ins)
            if b == 0:
                b = ins.bytes_out
            out.coll[base] += b
            out.bytes += b + ins.bytes_out
            continue
        if op == "fusion":
            callees = ins.called()
            if callees and callees[0] in mod.computations:
                inner = _comp_costs(mod, callees[0], memo)
                out.flops += inner.flops       # fused dots/elementwise
                for k, v in inner.coll.items():
                    out.coll[k] += v
            out.bytes += _fusion_bytes(mod, comp, ins)
            continue
        if op == "dot":
            out.flops += _dot_flops(comp, ins)
            out.bytes += comp.operand_bytes(ins) + ins.bytes_out
            continue
        if op == "convolution":
            # approx: 2 · out · (rhs elems / out channels)  — rare in our HLO
            out.flops += 2.0 * ins.elems_out
            out.bytes += comp.operand_bytes(ins) + ins.bytes_out
            continue
        if op.startswith("reduce") or op in ("sort",):
            in_elems = sum(o.elems_out for nm in comp.operand_names(ins)
                           if (o := comp.by_name.get(nm)) is not None)
            out.flops += float(in_elems or ins.elems_out)
            out.bytes += comp.operand_bytes(ins) + ins.bytes_out
            continue
        if op in ("dynamic-slice",):
            out.bytes += 2.0 * ins.bytes_out  # read slice + write out
            continue
        if op in ("dynamic-update-slice",):
            ops_n = comp.operand_names(ins)
            upd = comp.by_name.get(ops_n[1]) if len(ops_n) > 1 else None
            out.bytes += 2.0 * (upd.bytes_out if upd else ins.bytes_out)
            continue
        if op in ("copy", "copy-start", "transpose", "reshape", "broadcast",
                  "slice", "concatenate", "pad", "gather", "scatter",
                  "dynamic-reshape", "reverse", "convert", "select",
                  "compare", "custom-call", "rng", "rng-bit-generator"):
            out.bytes += comp.operand_bytes(ins) + ins.bytes_out
            if op in ("select", "compare", "convert"):
                out.flops += ins.elems_out
            continue
        # generic elementwise / everything else
        out.flops += float(ins.elems_out)
        out.bytes += comp.operand_bytes(ins) + ins.bytes_out
    return out


def rollup(text: str) -> Costs:
    """Loop-aware whole-module costs from compiled HLO text."""
    mod = parse_hlo(text)
    memo: Dict[str, Costs] = {}
    entry = mod.entry
    # only roll up from the entry; ignore dead computations
    return _comp_costs(mod, entry, memo) if entry else Costs()


# ---------------------------------------------------------------------------
# Collective accounting (legacy API, now loop-aware)
# ---------------------------------------------------------------------------


def collective_bytes(mod_or_text) -> Dict[str, int]:
    """Per-collective-kind operand bytes (per-partition, loop-aware)."""
    text = mod_or_text if isinstance(mod_or_text, str) else None
    if text is None:
        # legacy: HloModule without rollup context — flat count
        out: Dict[str, int] = defaultdict(int)
        for ins in mod_or_text.instrs:
            op = ins.opcode
            base = op[:-6] if op.endswith("-start") else op
            if op.endswith("-done"):
                continue
            if base in COLLECTIVE_OPS:
                out[base] += ins.bytes_out
        return dict(out)
    c = rollup(text)
    return {k: int(v) for k, v in c.coll.items()}


def collective_summary(text: str) -> Dict[str, int]:
    return collective_bytes(text)
