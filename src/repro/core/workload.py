"""One ``WorkloadSpec`` — the single currency every prediction consumer
speaks.

The paper's premise is ONE symbolic counting mechanism feeding ONE linear
model; what fragments that in practice is not the model but the *workload
description*: a trainer, a plan search, a block-size autotuner and a decode
server each re-deriving "what a step is" from ad-hoc ``(cfg, shape, kind)``
tuples.  ``WorkloadSpec`` replaces those tuples with one frozen record that
all five subsystems (predictor, planspace, autotuner, trainer, server)
consume:

  * ``phase`` — ``train`` | ``prefill`` | ``decode`` (first-class, not a
    string threaded positionally through every call);
  * the batch/sequence/microbatch shape (``global_batch``, ``seq_len``,
    ``microbatches``);
  * decode-only refinements the old taxonomy could not express at all:
    KV/SSM-cache read traffic (``cache_tokens``), slot occupancy
    (``active_slots``), speculative-decode length (``spec_len``) and MoE
    routing imbalance (``moe_imbalance``).

Each refinement, when set, introduces a dedicated free variable into the
symbolic counts (``CT``/``AS``/``SL``/``MI`` next to the classic
``B``/``S``/``M``), so a fused ``BasisProgram`` compiled once can rescore a
whole sweep of occupancies or context loads as array ops — that is what
lets ``runtime/server.py`` score admission decisions per decode iteration.
When a refinement is left at its default the corresponding variable stays
OUT of the formulas (``structure()`` is the program-cache key), so default
specs compile to exactly the pre-spec programs.

``ShapeConfig`` remains a valid argument everywhere (it names a benchmark
cell, which is still useful); ``as_spec`` converts it silently.  Bare
``kind=`` strings are the deprecated legacy path: they convert too, but
with a ``DeprecationWarning`` attributed to the caller — CI promotes that
warning to an error for ``repro.*`` modules so no internal caller can keep
using them silently.
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass
from typing import Dict, Optional, Tuple, Union

from repro.configs.base import ArchConfig, ShapeConfig
from repro.configs import base as _shapes

PHASES = ("train", "prefill", "decode")


@dataclass(frozen=True)
class WorkloadSpec:
    """A complete, hashable description of one step of work.

    Shape semantics per phase:
      * ``train`` / ``prefill``: ``global_batch`` rows of ``seq_len``
        tokens each (prefill additionally writes those tokens' KV/SSM
        cache rows).
      * ``decode``: ``global_batch`` is the ALLOCATED slot count of the
        continuous-batching server, ``seq_len`` the per-slot cache
        capacity.  One iteration emits one token per slot (times
        ``spec_len`` under speculative decoding).

    Decode refinements (``None``/default = not modeled, variable absent):
      * ``cache_tokens`` — total context tokens read across slots this
        iteration (free variable ``CT``).  Default: every slot full,
        ``B · min(S, sliding_window)``.
      * ``active_slots`` — occupied slots (free variable ``AS``).  When
        set, per-token work (projections, FFN, head, VPU, cache writes)
        scales with occupancy — an occupancy-aware runtime; when unset,
        per-token work scales with the allocated ``B`` — the static-shape
        XLA execution this repo's server actually runs.
      * ``spec_len`` — tokens verified per iteration under speculative
        decoding (free variable ``SL``, multiplies token throughput).
      * ``moe_imbalance`` — hottest-expert load multiplier on expert FFN
        compute (free variable ``MI``).  Train/prefill dispatch is
        capacity-padded (GShard), where imbalance drops tokens instead of
        adding flops, so ``MI`` only enters decode counts.

    ``microbatches`` is the schedule default; a ``Plan`` carried alongside
    (plan search, predict_step) overrides it, exactly as the plan always
    overrode the shape.
    """
    phase: str = "train"
    global_batch: int = 1
    seq_len: int = 1
    microbatches: int = 1
    active_slots: Optional[int] = None
    cache_tokens: Optional[float] = None
    spec_len: int = 1
    moe_imbalance: float = 1.0
    name: str = ""

    def __post_init__(self):
        if self.phase not in PHASES:
            raise ValueError(f"unknown phase {self.phase!r}; "
                             f"expected one of {PHASES}")

    # -- identity ----------------------------------------------------------
    @property
    def kind(self) -> str:
        """Alias for ``phase`` — lets a spec duck-type a ``ShapeConfig``."""
        return self.phase

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch

    def structure(self) -> Tuple[str, ...]:
        """The program-cache key: phase plus which optional variables the
        symbolic counts must carry.  Two specs with equal structure share
        one compiled ``BasisProgram`` (their numbers differ only through
        the environment), and a spec with NO refinements shares the
        program of the pre-spec ``kind=`` era."""
        flags = []
        if self.phase == "decode":
            if self.cache_tokens is not None:
                flags.append("ct")
            if self.active_slots is not None:
                flags.append("as")
            if self.spec_len != 1:
                flags.append("sl")
            if self.moe_imbalance != 1.0:
                flags.append("mi")
        return (self.phase, *flags)

    # -- evaluation --------------------------------------------------------
    def env(self, cfg: Optional[ArchConfig] = None) -> Dict[str, float]:
        """The free-variable environment this spec pins: ``B``/``S``/``M``
        always, plus the decode refinements' variables with their defaults
        filled in (``CT`` needs ``cfg`` for the sliding-window clamp).
        Callers may override entries (a plan's microbatch count, a
        planspace column) by merging on top."""
        e: Dict[str, float] = {"B": self.global_batch, "S": self.seq_len,
                               "M": self.microbatches}
        if self.phase == "decode":
            e["AS"] = (self.global_batch if self.active_slots is None
                       else self.active_slots)
            if self.cache_tokens is None:
                ctx = self.seq_len
                if cfg is not None and cfg.sliding_window is not None:
                    ctx = min(ctx, cfg.sliding_window)
                e["CT"] = self.global_batch * ctx
            else:
                e["CT"] = self.cache_tokens
            e["SL"] = self.spec_len
            e["MI"] = self.moe_imbalance
        return e

    def with_(self, **kw) -> "WorkloadSpec":
        return dataclasses.replace(self, **kw)


#: what every spec-taking entry point accepts.
WorkloadLike = Union[WorkloadSpec, ShapeConfig, str]


def from_shape(shape: ShapeConfig) -> WorkloadSpec:
    """A ``ShapeConfig`` as a spec: same shape, no decode refinements —
    the exact workload the pre-spec code scored for that shape."""
    return WorkloadSpec(phase=shape.kind, global_batch=shape.global_batch,
                        seq_len=shape.seq_len, name=shape.name)


def as_spec(workload: WorkloadLike, *, _stacklevel: int = 3) -> WorkloadSpec:
    """Coerce any accepted workload form to a ``WorkloadSpec``.

    ``ShapeConfig`` converts silently (it is a named benchmark cell, still
    first-class).  A bare phase STRING is the legacy ``kind=`` path: it
    converts to a shapeless spec — fine for the purely symbolic builders,
    which only read ``structure()`` — but warns ``DeprecationWarning``
    attributed ``_stacklevel`` frames up (default: the caller of the public
    API that called ``as_spec``), so CI's warning-as-error filter catches
    internal ``repro.*`` callers while external callers get one release of
    grace."""
    if isinstance(workload, WorkloadSpec):
        return workload
    if isinstance(workload, ShapeConfig):
        return from_shape(workload)
    if isinstance(workload, str):
        warnings.warn(
            f"kind={workload!r} strings are deprecated; pass a "
            f"repro.core.workload.WorkloadSpec (or a ShapeConfig) instead",
            DeprecationWarning, stacklevel=_stacklevel)
        return WorkloadSpec(phase=workload)
    raise TypeError(
        f"expected WorkloadSpec | ShapeConfig | phase string, got "
        f"{type(workload).__name__}: {workload!r}")


# -- the library's canonical cells, as specs (mirrors configs.base.SHAPES) --

TRAIN_4K = from_shape(_shapes.TRAIN_4K)
PREFILL_32K = from_shape(_shapes.PREFILL_32K)
DECODE_32K = from_shape(_shapes.DECODE_32K)
LONG_500K = from_shape(_shapes.LONG_500K)

SPECS = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
