"""Basic functional layers.

Parameters are plain pytrees (nested dicts of jnp arrays).  Every ``init_*``
returns ``(params, axes)`` where ``axes`` mirrors ``params`` with tuples of
*logical axis names* per dimension — the sharding layer maps logical axes to
mesh axes (MaxText-style), see ``repro.distributed.sharding``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = dict
Axes = dict

INIT_SCALE = 0.02


def _dtype(name: str):
    return jnp.dtype(name)


def dense_init(key, in_dim: int, out_dim: int, dtype, in_axis: str, out_axis: str,
               bias: bool = False):
    w = jax.random.normal(key, (in_dim, out_dim), dtype=jnp.float32) * INIT_SCALE
    p = {"w": w.astype(dtype)}
    a = {"w": (in_axis, out_axis)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype=dtype)
        a["b"] = (out_axis,)
    return p, a


def dense(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm_init(dim: int, dtype):
    return {"scale": jnp.ones((dim,), dtype=dtype)}, {"scale": ("embed",)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# SwiGLU FFN
# ---------------------------------------------------------------------------


def ffn_init(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p, a = {}, {}
    p["gate"], a["gate"] = dense_init(k1, d_model, d_ff, dtype, "embed", "ff")
    p["up"], a["up"] = dense_init(k2, d_model, d_ff, dtype, "embed", "ff")
    p["down"], a["down"] = dense_init(k3, d_ff, d_model, dtype, "ff", "embed")
    return p, a


def ffn(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    g = dense(p["gate"], x)
    u = dense(p["up"], x)
    return dense(p["down"], jax.nn.silu(g) * u)


# ---------------------------------------------------------------------------
# Embedding / heads
# ---------------------------------------------------------------------------


def embedding_init(key, vocab: int, d_model: int, dtype, n_codebooks: int = 1):
    shape = (n_codebooks, vocab, d_model) if n_codebooks > 1 else (vocab, d_model)
    w = jax.random.normal(key, shape, dtype=jnp.float32) * INIT_SCALE
    axes = ("codebook", "vocab", "embed") if n_codebooks > 1 else ("vocab", "embed")
    return {"w": w.astype(dtype)}, {"w": axes}


def embed(p: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens (..., [n_codebooks]) int32 -> (..., d_model)."""
    w = p["w"]
    if w.ndim == 3:  # multi-codebook (MusicGen): sum codebook embeddings
        # tokens: (B, S, n_codebooks)
        outs = [jnp.take(w[c], tokens[..., c], axis=0) for c in range(w.shape[0])]
        return sum(outs)
    return jnp.take(w, tokens, axis=0)


def lm_head_init(key, d_model: int, vocab: int, dtype, n_heads: int = 1):
    shape = (n_heads, d_model, vocab) if n_heads > 1 else (d_model, vocab)
    w = jax.random.normal(key, shape, dtype=jnp.float32) * INIT_SCALE
    axes = ("head_idx", "embed", "vocab") if n_heads > 1 else ("embed", "vocab")
    return {"w": w.astype(dtype)}, {"w": axes}


def lm_head(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    w = p["w"]
    if w.ndim == 3:  # (n_heads, d, V) -> (..., n_heads, V)
        return jnp.einsum("bsd,hdv->bshv", x, w)
    return x @ w


def tied_lm_head(embed_p: Params, x: jnp.ndarray) -> jnp.ndarray:
    w = embed_p["w"]
    assert w.ndim == 2
    return x @ w.T


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean cross-entropy; logits (..., V) in any float dtype (f32 math)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
