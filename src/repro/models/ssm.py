"""Mamba2 mixer: chunked SSD (state-space duality, arXiv:2405.21060) for
train/prefill (linear in sequence length) and an O(1) recurrence for decode.

The Pallas kernel in ``repro.kernels.ssd_scan`` implements the intra-chunk
quadratic piece for the TPU hot path; this module is the XLA production path
and the kernel's reference.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical
from repro.models import layers


class SSMState(NamedTuple):
    conv: jnp.ndarray  # (B, d_conv-1, conv_dim) — trailing conv inputs
    h: jnp.ndarray     # (B, nH, P, N) — SSM recurrent state


def ssm_init(key, cfg, dtype):
    s = cfg.ssm
    d = cfg.d_model
    din = cfg.d_inner
    nH = cfg.ssm_heads
    N, G = s.d_state, s.n_groups
    conv_dim = din + 2 * G * N
    ks = jax.random.split(key, 5)
    p, a = {}, {}
    # in_proj -> [z, x, B, C, dt]
    out_dim = 2 * din + 2 * G * N + nH
    p["in_proj"], a["in_proj"] = layers.dense_init(
        ks[0], d, out_dim, dtype, "embed", "ssm_inner")
    p["conv_w"] = (jax.random.normal(ks[1], (s.d_conv, conv_dim), jnp.float32)
                   * 0.02).astype(dtype)
    a["conv_w"] = ("conv", "ssm_inner")
    p["conv_b"] = jnp.zeros((conv_dim,), dtype)
    a["conv_b"] = ("ssm_inner",)
    p["A_log"] = jnp.log(jnp.linspace(1.0, 16.0, nH).astype(jnp.float32))
    a["A_log"] = ("ssm_heads",)
    p["D"] = jnp.ones((nH,), jnp.float32)
    a["D"] = ("ssm_heads",)
    p["dt_bias"] = jnp.zeros((nH,), jnp.float32)
    a["dt_bias"] = ("ssm_heads",)
    p["norm"] = jnp.ones((din,), dtype)
    a["norm"] = ("ssm_inner",)
    p["out_proj"], a["out_proj"] = layers.dense_init(
        ks[4], din, d, dtype, "ssm_inner", "embed")
    return p, a


def _split_proj(cfg, proj):
    s = cfg.ssm
    din, nH = cfg.d_inner, cfg.ssm_heads
    GN = s.n_groups * s.d_state
    z, xbc_dt = jnp.split(proj, [din], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [din + 2 * GN], axis=-1)
    return z, xbc, dt  # (…, din), (…, din+2GN), (…, nH)


def _causal_conv(xbc, w, b):
    """Depthwise causal conv1d.  xbc (B, L, Cd); w (k, Cd)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    # windowed sum: sum_j w[j] * x[t-k+1+j]
    out = sum(pad[:, j:j + xbc.shape[1], :] * w[j] for j in range(k))
    return jax.nn.silu(out + b)


def _ssd_chunked(x, dt, A, Bm, Cm, chunk: int, h0=None):
    """Chunked SSD.

    x  (B, L, H, P)    dt (B, L, H)      A (H,) negative
    Bm (B, L, G, N)    Cm (B, L, G, N)   h0 optional (B, H, P, N)
    Returns (y (B,L,H,P), h_final (B,H,P,N)).  G must divide H.
    """
    Bsz, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert L % chunk == 0, (L, chunk)
    nc, Q = L // chunk, chunk
    rep = H // G

    dA = dt * A  # (B, L, H), <= 0
    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H)
    dAc = dA.reshape(Bsz, nc, Q, H)
    Bc = jnp.repeat(Bm.reshape(Bsz, nc, Q, G, N), rep, axis=3)  # (B,nc,Q,H,N)
    Cc = jnp.repeat(Cm.reshape(Bsz, nc, Q, G, N), rep, axis=3)

    cum = jnp.cumsum(dAc, axis=2)  # (B,nc,Q,H)

    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def chunk_body(h_prev, inp):
        xq, dtq, dAq, cumq, Bq, Cq = inp  # (B,Q,...) per chunk
        # --- intra-chunk (quadratic in Q) ---
        # decay L[i,j] = exp(cum[i]-cum[j]) for i>=j
        diff = cumq[:, :, None, :] - cumq[:, None, :, :]  # (B,Q,Q,H)
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        # mask the *exponent* (upper triangle has diff > 0 -> exp overflow
        # -> inf*0 = NaN in the backward pass if masked after exp)
        diff = jnp.where(tri[None, :, :, None], diff, -1e30)
        Lmat = jnp.exp(diff)
        CB = jnp.einsum("bihn,bjhn->bijh", Cq, Bq)  # (B,Q,Q,H)
        W = CB * Lmat * dtq[:, None, :, :]  # weight of x_j in y_i
        y_intra = jnp.einsum("bijh,bjhp->bihp", W, xq.astype(jnp.float32))
        # --- inter-chunk: contribution of h_prev ---
        y_inter = jnp.einsum("bihn,bhpn->bihp", Cq * jnp.exp(cumq)[..., None],
                             h_prev)
        # --- state update ---
        decay_to_end = jnp.exp(cumq[:, -1:, :] - cumq)  # (B,Q,H)
        S_c = jnp.einsum("bjhn,bjhp->bhpn",
                         Bq * (dtq * decay_to_end)[..., None],
                         xq.astype(jnp.float32))
        h_new = h_prev * jnp.exp(cumq[:, -1])[:, :, None, None] + S_c
        return h_new, (y_intra + y_inter).astype(x.dtype)

    xs = (
        xc.transpose(1, 0, 2, 3, 4),
        dtc.transpose(1, 0, 2, 3),
        dAc.transpose(1, 0, 2, 3),
        cum.transpose(1, 0, 2, 3),
        Bc.transpose(1, 0, 2, 3, 4),
        Cc.transpose(1, 0, 2, 3, 4),
    )
    # checkpoint the chunk body: its (B, Q, Q, H) decay/weight tensors would
    # otherwise be stacked ×nc as scan residuals for the backward pass
    # (~10 GB/layer at zamba2 scale — EXPERIMENTS.md §Perf iteration A);
    # recomputing them from the (small) carried state is near-free
    h_fin, ys = jax.lax.scan(jax.checkpoint(chunk_body), h0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, L, H, P)
    return y, h_fin


def ssm_apply(p, x, cfg, *, state: SSMState | None = None
              ) -> Tuple[jnp.ndarray, SSMState | None]:
    """Mamba2 block.  x (B, S, d).  With ``state``, runs one decode step."""
    s = cfg.ssm
    Bsz, S, d = x.shape
    din, nH, N, G = cfg.d_inner, cfg.ssm_heads, s.d_state, s.n_groups
    P = s.head_dim
    A = -jnp.exp(p["A_log"])  # (nH,)

    proj = layers.dense(p["in_proj"], x)  # (B,S,out_dim)
    z, xbc, dt_raw = _split_proj(cfg, proj)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,nH)

    new_state = None
    if state is None:
        xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
        xin, BC = jnp.split(xbc, [din], axis=-1)
        Bm, Cm = jnp.split(BC, 2, axis=-1)
        xin = logical(xin.reshape(Bsz, S, nH, P),
                      ("act_batch", "act_seq", "act_heads", None))
        Bm = Bm.reshape(Bsz, S, G, N)
        Cm = Cm.reshape(Bsz, S, G, N)
        chunk = min(s.chunk, S)
        from repro.runtime import flags
        if flags.pallas_enabled():
            from repro.kernels import ops as kops
            y, _ = kops.ssd_scan(
                xin.transpose(0, 2, 1, 3), dt.transpose(0, 2, 1), A,
                Bm.transpose(0, 2, 1, 3), Cm.transpose(0, 2, 1, 3),
                chunk=chunk, block_sizes="auto")
            y = y.transpose(0, 2, 1, 3)
        else:
            y, _ = _ssd_chunked(xin, dt, A, Bm, Cm, chunk)
    else:
        # ---- single-step decode (S == 1) ----
        conv_in = jnp.concatenate([state.conv, xbc], axis=1)  # (B,k,convdim)
        w, b = p["conv_w"], p["conv_b"]
        feat = jax.nn.silu(jnp.einsum("bkc,kc->bc", conv_in, w) + b)[:, None]
        new_conv = conv_in[:, 1:]
        xin, BC = jnp.split(feat, [din], axis=-1)
        Bm, Cm = jnp.split(BC, 2, axis=-1)
        xin = xin.reshape(Bsz, 1, nH, P).astype(jnp.float32)
        Bm = jnp.repeat(Bm.reshape(Bsz, 1, G, N), nH // G, axis=2)[:, 0]  # (B,H,N)
        Cm = jnp.repeat(Cm.reshape(Bsz, 1, G, N), nH // G, axis=2)[:, 0]
        dt1 = dt[:, 0]  # (B,H)
        dA = jnp.exp(dt1 * A)  # (B,H)
        upd = jnp.einsum("bhn,bhp->bhpn", Bm * dt1[..., None], xin[:, 0])
        h_new = state.h * dA[..., None, None] + upd
        yt = jnp.einsum("bhn,bhpn->bhp", Cm, h_new)  # (B,H,P)
        y = yt[:, None].astype(x.dtype)  # (B,1,H,P)
        new_state = SSMState(new_conv, h_new)
        xin = xin.astype(x.dtype)

    if state is None:
        xin_skip = xin
    else:
        xin_skip = xin.astype(x.dtype)
    y = y + xin_skip * p["D"][:, None].astype(x.dtype)
    y = y.reshape(Bsz, S, din)
    y = layers.rmsnorm({"scale": p["norm"]}, y * jax.nn.silu(z), cfg.norm_eps)
    out = layers.dense(p["out_proj"], y)
    return out, new_state


def init_ssm_state(cfg, B: int, dtype) -> SSMState:
    s = cfg.ssm
    conv_dim = cfg.d_inner + 2 * s.n_groups * s.d_state
    return SSMState(
        conv=jnp.zeros((B, s.d_conv - 1, conv_dim), dtype),
        h=jnp.zeros((B, cfg.ssm_heads, s.head_dim, s.d_state), jnp.float32),
    )
