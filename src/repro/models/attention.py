"""Attention: GQA, RoPE / M-RoPE, sliding-window, memory-efficient chunked
softmax (pure-XLA flash-attention analog used by the distributed lowering),
and KV-cache decode.

The Pallas flash-attention kernel in ``repro.kernels.flash_attention`` is the
TPU hot-path implementation of the same contraction; ``attention_core`` here
is both the XLA production path (it lowers on any backend and keeps peak
memory to O(chunk²)) and the reference the kernel is validated against.
"""
from __future__ import annotations

import functools
import math
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical
from repro.models import layers

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def _rope_angles(positions: jnp.ndarray, half: int, theta: float) -> jnp.ndarray:
    """positions (..., S) -> angles (..., S, half)  [f32]."""
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    return positions.astype(jnp.float32)[..., None] * freqs


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x (B, S, H, dh); positions (B, S) int32."""
    half = x.shape[-1] // 2
    ang = _rope_angles(positions, half, theta)  # (B, S, half)
    cos = jnp.cos(ang)[..., None, :]  # (B, S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
                sections: Tuple[int, ...]) -> jnp.ndarray:
    """Qwen2-VL M-RoPE.  positions (B, S, 3) = (t, h, w) ids.

    The head_dim//2 frequency slots are partitioned into ``sections`` (t,h,w);
    each slot uses the position component of its section.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    # per-frequency-slot section id: (half,)
    sec_id = jnp.repeat(jnp.arange(len(sections)), jnp.array(sections),
                        total_repeat_length=half)
    # (B, S, half): pick the position component per slot
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),
        jnp.broadcast_to(sec_id, positions.shape[:-1] + (half,)).astype(jnp.int32),
        axis=-1,
    )
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos * freqs  # (B, S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention core
# ---------------------------------------------------------------------------


def _mask(q_pos, k_pos, causal: bool, window: Optional[int]):
    """q_pos (Sq,), k_pos (Sk,) -> bool (Sq, Sk), True = attend."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return m


def _plain_attention(q, k, v, q_pos, k_pos, causal, window, scale):
    """Materialized-logits path (small Sq·Sk).  GQA via head grouping."""
    B, Sq, H, dh = q.shape
    KVH = k.shape[2]
    G = H // KVH
    qg = q.reshape(B, Sq, KVH, G, dh)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    m = _mask(q_pos, k_pos, causal, window)  # (Sq, Sk)
    s = jnp.where(m[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, dh).astype(q.dtype)


_BIAS_NEG = -1e9   # additive mask bias (finite: keeps exp() well-defined)
_M_INIT = -1e4     # running-max floor; masked rows renormalize to 0


def _chunked_attention(q, k, v, q_pos, k_pos, causal, window, scale,
                       chunk_q: int, chunk_kv: int):
    """Online-softmax double loop (scan over q chunks × scan over kv chunks).

    Peak live memory is O(B · chunk_q · chunk_kv) logits — this is what makes
    32k-token prefill lowerable.  Fully-masked chunk pairs are skipped with
    ``lax.cond`` (runtime savings on causal lower-triangle).

    Masking is ADDITIVE (a (cq, ck) f32 bias), not a ``where`` over the
    (B, cq, KVH, G, ck) score tensor: the where's pred operand becomes a
    per-kv-step scan residual in the backward pass — a hoisted
    (nk, B, cq, KVH, G, ck) stack that cost ~8 GB/layer before this change
    (EXPERIMENTS.md §Perf, iteration 1).  The bias adds with a trivial
    backward and leaves masked lanes at exp(-1e9 − m) ≡ 0, with the running
    max floored at ``_M_INIT`` so fully-masked rows stay exactly zero.
    """
    B, Sq, H, dh = q.shape
    Skv, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    assert Sq % chunk_q == 0 and Skv % chunk_kv == 0, (Sq, chunk_q, Skv, chunk_kv)
    nq, nk = Sq // chunk_q, Skv // chunk_kv

    # Shard the grouped layout on G (= H/KVH): H-sharding cannot survive
    # the (KVH, G) split when KVH < tp (GSPMD would replicate the whole
    # microbatch — a 12 GB/step involuntary-remat all-reduce on the 405B
    # lowering, §Perf iteration B); G is the tp-divisible factor.
    qc = q.reshape(B, nq, chunk_q, KVH, G, dh)
    qc = logical(qc, ("act_batch", None, None, "act_kv_heads",
                      "act_heads", None)).transpose(1, 0, 2, 3, 4, 5)
    kc = k.reshape(B, nk, chunk_kv, KVH, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, chunk_kv, KVH, dh).transpose(1, 0, 2, 3, 4)
    qp = q_pos.reshape(nq, chunk_q)
    kp = k_pos.reshape(nk, chunk_kv)

    def q_chunk_body(qi, q_blk):
        q_blk = q_blk.astype(jnp.float32)
        qpos = qp[qi]

        def kv_step(carry, inp):
            m_run, l_run, acc = carry
            ki, k_blk, v_blk = inp
            kpos = kp[ki]

            def compute(args):
                m_run, l_run, acc = args
                s = jnp.einsum("bqkgd,bskd->bqkgs", q_blk,
                               k_blk.astype(jnp.float32)) * scale
                msk = _mask(qpos, kpos, causal, window)  # (cq, ck)
                bias = jnp.where(msk, 0.0, _BIAS_NEG).astype(jnp.float32)
                s = s + bias[None, :, None, None, :]
                m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
                m_new = jnp.maximum(m_new, _M_INIT)  # masked-row floor
                alpha = jnp.exp(m_run - m_new)
                p = jnp.exp(s - m_new[..., None])    # masked lanes -> 0
                l_new = l_run * alpha + jnp.sum(p, axis=-1)
                acc_new = acc * alpha[..., None] + jnp.einsum(
                    "bqkgs,bskd->bqkgd", p, v_blk.astype(jnp.float32))
                return m_new, l_new, acc_new

            # skip chunk pairs that are fully masked
            needed = jnp.logical_and(
                (kpos[0] <= qpos[-1]) if causal else True,
                (qpos[0] - kpos[-1] < window) if window is not None else True,
            )
            carry = jax.lax.cond(needed, compute, lambda a: a,
                                 (m_run, l_run, acc))
            return carry, None

        m0 = jnp.full((B, chunk_q, KVH, G), _M_INIT, jnp.float32)
        l0 = jnp.zeros((B, chunk_q, KVH, G), jnp.float32)
        a0 = jnp.zeros((B, chunk_q, KVH, G, dh), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kc, vc))
        out = acc / jnp.maximum(l_f, 1e-20)[..., None]
        lse = m_f + jnp.log(jnp.maximum(l_f, 1e-20))  # (B, cq, KVH, G)
        return out.astype(q.dtype), lse

    outs, lses = jax.lax.map(lambda args: q_chunk_body(*args),
                             (jnp.arange(nq), qc))  # (nq, B, cq, KVH, G, dh)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, dh)
    lse = lses.transpose(1, 0, 2, 3, 4).reshape(B, Sq, KVH, G)
    return out, lse


# ---------------------------------------------------------------------------
# Flash-style custom VJP for the chunked path.
#
# The naive scan backward stacks per-kv-step residuals — the recomputed
# probability tensors p of every (q-chunk, kv-chunk) pair, a
# (nq·nk, B, cq, KVH, G, ck) monster that cost ~100s of GB/device on the
# 32k-prefill lowering (EXPERIMENTS.md §Perf iteration 1).  The flash
# backward saves only (o, lse) — O(B·S·H·dh) — and re-derives each p tile
# inside the gradient loops, exactly like the TPU kernel would in VMEM.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _flash_xla(q, k, v, q_start, causal, window, scale, chunk_q, chunk_kv):
    """``q_start``: (traced) absolute position of q[0] — context-parallel
    slices pass their own offset."""
    out, _ = _chunked_attention(q, k, v,
                                q_start + jnp.arange(q.shape[1]),
                                jnp.arange(k.shape[1]),
                                causal, window, scale, chunk_q, chunk_kv)
    return out


def _flash_xla_fwd(q, k, v, q_start, causal, window, scale, chunk_q,
                   chunk_kv):
    out, lse = _chunked_attention(q, k, v,
                                  q_start + jnp.arange(q.shape[1]),
                                  jnp.arange(k.shape[1]),
                                  causal, window, scale, chunk_q, chunk_kv)
    return out, (q, k, v, q_start, out, lse)


def _flash_xla_bwd(causal, window, scale, chunk_q, chunk_kv, res, do):
    q, k, v, q_start, o, lse = res
    B, Sq, H, dh = q.shape
    Skv, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    nq, nk = Sq // chunk_q, Skv // chunk_kv

    grp = ("act_batch", None, None, "act_kv_heads", "act_heads", None)
    qf = logical(q.reshape(B, nq, chunk_q, KVH, G, dh), grp
                 ).astype(jnp.float32)
    dof = logical(do.reshape(B, nq, chunk_q, KVH, G, dh), grp
                  ).astype(jnp.float32)
    of = logical(o.reshape(B, nq, chunk_q, KVH, G, dh), grp
                 ).astype(jnp.float32)
    lsef = logical(lse.reshape(B, nq, chunk_q, KVH, G), grp[:-1])
    kf = k.reshape(B, nk, chunk_kv, KVH, dh).astype(jnp.float32)
    vf = v.reshape(B, nk, chunk_kv, KVH, dh).astype(jnp.float32)
    # D_i = rowsum(do ⊙ o)  (B, nq, cq, KVH, G)
    Dmat = jnp.sum(dof * of, axis=-1)
    qpos_all = q_start + jnp.arange(Sq).reshape(nq, chunk_q)
    kpos_all = jnp.arange(Skv).reshape(nk, chunk_kv)

    def kv_chunk_body(dq_acc, ki):
        k_blk = kf[:, ki]  # (B, ck, KVH, dh)
        v_blk = vf[:, ki]
        kpos = kpos_all[ki]

        def q_step(carry, qi):
            dq_acc, dk_blk, dv_blk = carry
            qpos = qpos_all[qi]

            def compute(args):
                dq_acc, dk_blk, dv_blk = args
                q_blk = qf[:, qi]      # (B, cq, KVH, G, dh)
                s = jnp.einsum("bqkgd,bskd->bqkgs", q_blk, k_blk) * scale
                msk = _mask(qpos, kpos, causal, window)
                bias = jnp.where(msk, 0.0, _BIAS_NEG).astype(jnp.float32)
                s = s + bias[None, :, None, None, :]
                p = jnp.exp(s - lsef[:, qi][..., None])  # re-derived tile
                do_blk = dof[:, qi]
                dv_new = dv_blk + jnp.einsum("bqkgs,bqkgd->bskd", p, do_blk)
                dp = jnp.einsum("bqkgd,bskd->bqkgs", do_blk, v_blk)
                ds = p * (dp - Dmat[:, qi][..., None])
                dq_new = dq_acc.at[:, qi].add(
                    jnp.einsum("bqkgs,bskd->bqkgd", ds, k_blk) * scale)
                dk_new = dk_blk + jnp.einsum(
                    "bqkgs,bqkgd->bskd", ds, q_blk) * scale
                return dq_new, dk_new, dv_new

            needed = jnp.logical_and(
                (kpos[0] <= qpos[-1]) if causal else True,
                (qpos[0] - kpos[-1] < window) if window is not None else True,
            )
            carry = jax.lax.cond(needed, compute, lambda a: a,
                                 (dq_acc, dk_blk, dv_blk))
            return carry, None

        dk0 = jnp.zeros((B, chunk_kv, KVH, dh), jnp.float32)
        dv0 = jnp.zeros((B, chunk_kv, KVH, dh), jnp.float32)
        (dq_acc, dk_blk, dv_blk), _ = jax.lax.scan(
            q_step, (dq_acc, dk0, dv0), jnp.arange(nq))
        return dq_acc, (dk_blk, dv_blk)

    dq0 = jnp.zeros((B, nq, chunk_q, KVH, G, dh), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(kv_chunk_body, dq0, jnp.arange(nk))
    dq = dq.reshape(B, Sq, H, dh).astype(q.dtype)
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, Skv, KVH, dh).astype(k.dtype)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, Skv, KVH, dh).astype(v.dtype)
    return dq, dk, dv, jnp.zeros_like(q_start)  # positions carry no grad


_flash_xla.defvjp(_flash_xla_fwd, _flash_xla_bwd)


def attention_core(q, k, v, *, causal: bool = True,
                   window: Optional[int] = None,
                   q_offset: int = 0,
                   chunk_q: int = 1024, chunk_kv: int = 1024,
                   force_chunked: bool = False) -> jnp.ndarray:
    """q (B,Sq,H,dh) × k,v (B,Skv,KVH,dh) -> (B,Sq,H,dh).

    ``q_offset``: absolute position of q[0] (decode: cache length).
    Dispatches to the materialized path for small problems and the
    online-softmax chunked path for long sequences.
    """
    B, Sq, H, dh = q.shape
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(dh)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Skv)
    big = Sq * Skv > 2048 * 2048
    if (big or force_chunked) and Sq % 512 == 0 and Skv % 512 == 0 \
            and Sq > 1:
        cq = min(chunk_q, Sq)
        ck = min(chunk_kv, Skv)
        start = jnp.asarray(q_offset, jnp.float32) \
            if not isinstance(q_offset, jax.Array) else q_offset
        return _flash_xla(q, k, v, start, causal, window, scale, cq, ck)
    return _plain_attention(q, k, v, q_pos, k_pos, causal, window, scale)


# ---------------------------------------------------------------------------
# GQA attention block (param init + apply, with KV cache support)
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: jnp.ndarray  # (B, Smax, KVH, dh)
    v: jnp.ndarray


def attn_init(key, cfg, dtype):
    d, H, KVH, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["wq"], a["wq"] = layers.dense_init(ks[0], d, H * dh, dtype, "embed", "heads",
                                         bias=cfg.use_qkv_bias)
    p["wk"], a["wk"] = layers.dense_init(ks[1], d, KVH * dh, dtype, "embed",
                                         "kv_heads", bias=cfg.use_qkv_bias)
    p["wv"], a["wv"] = layers.dense_init(ks[2], d, KVH * dh, dtype, "embed",
                                         "kv_heads", bias=cfg.use_qkv_bias)
    p["wo"], a["wo"] = layers.dense_init(ks[3], H * dh, d, dtype, "heads", "embed")
    return p, a


def _positions_for(cfg, B, S, offset=0):
    pos = offset + jnp.arange(S)
    pos = jnp.broadcast_to(pos, (B, S))
    if cfg.m_rope:
        return jnp.broadcast_to(pos[..., None], (B, S, 3))  # stub: t=h=w
    return pos


def attn_apply(p, x, cfg, *, positions=None,
               cache: Optional[KVCache] = None,
               cache_pos: Optional[jnp.ndarray] = None):
    """x (B, S, d).  If ``cache`` is given, S is the decode step width (1),
    k/v are written at ``cache_pos`` and attention runs over the cache."""
    B, S, d = x.shape
    H, KVH, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    if positions is None:
        offset = 0 if cache is None else cache_pos
        positions = _positions_for(cfg, B, S, offset)

    q = layers.dense(p["wq"], x).reshape(B, S, H, dh)
    k = layers.dense(p["wk"], x).reshape(B, S, KVH, dh)
    v = layers.dense(p["wv"], x).reshape(B, S, KVH, dh)
    # Megatron SP: the residual stream is sequence-sharded, but attention
    # itself is HEAD-sharded over the full sequence — annotating q/k/v with
    # act_seq would hand the model axis to the seq dim and leave the head
    # dim replicated (≈tp× redundant attention compute; §Perf iteration 2)
    q = logical(q, ("act_batch", None, "act_heads", None))
    k = logical(k, ("act_batch", None, "act_kv_heads", None))
    v = logical(v, ("act_batch", None, "act_kv_heads", None))

    if cfg.m_rope:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is None:
        from repro.distributed.sharding import context_parallel_factor
        from repro.runtime import flags
        cp = context_parallel_factor(H, S)
        if flags.attention_stubbed():  # cost-attribution mode
            o = jnp.repeat(v, H // KVH, axis=2)
        elif flags.pallas_enabled():
            from repro.kernels import ops as kops
            o = kops.flash_attention(
                q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                v.transpose(0, 2, 1, 3), causal=True,
                window=cfg.sliding_window,
                block_sizes="auto",  # cost-model-chosen tiling (autotune)
            ).transpose(0, 2, 1, 3)
        elif cp > 1:
            # context parallelism: n_heads % tp != 0, so attention divides
            # over the model axis by q-SLICE instead of by head; k/v stay
            # whole (they were replicated anyway) and each slice runs flash
            # with its own absolute offset
            Scp = S // cp
            qs = q.reshape(B, cp, Scp, H, dh)
            qs = logical(qs, ("act_batch", "act_cp", None, None, None))
            offs = jnp.arange(cp, dtype=jnp.float32) * Scp
            o = jax.vmap(
                lambda qq, off: attention_core(
                    qq, k, v, causal=True, window=cfg.sliding_window,
                    q_offset=off),
                in_axes=(1, 0), out_axes=1)(qs, offs)
            o = logical(o, ("act_batch", "act_cp", None, None, None))
            o = o.reshape(B, S, H, dh)
        else:
            o = attention_core(q, k, v, causal=True,
                               window=cfg.sliding_window)
    else:
        # decode: write into the cache ring/window and attend over it
        Smax = cache.k.shape[1]
        if cfg.sliding_window is not None and Smax <= cfg.sliding_window:
            slot = cache_pos % Smax  # ring buffer for SWA
        else:
            slot = cache_pos
        ck = jax.lax.dynamic_update_slice(cache.k, k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache.v, v, (0, slot, 0, 0))
        ck = logical(ck, ("act_batch", "act_seq_dp", "act_kv_heads", None))
        cv = logical(cv, ("act_batch", "act_seq_dp", "act_kv_heads", None))
        new_cache = KVCache(ck, cv)
        o = _decode_attention(q, ck, cv, cfg, cache_pos)

    o = logical(o, ("act_batch", "act_seq", "act_heads", None))
    out = layers.dense(p["wo"], o.reshape(B, S, H * dh))
    return out, new_cache


def _decode_attention(q, ck, cv, cfg, cache_pos):
    """Single-token decode over a (possibly seq-sharded) cache.

    Materializes (B, H, Smax) logits — O(S) per token, fine at 524k — and
    lets GSPMD turn the S-dim reductions into cheap scalar all-reduces when
    the cache is sequence-sharded.
    """
    B, S, H, dh = q.shape  # S == 1
    Smax, KVH = ck.shape[1], ck.shape[2]
    G = H // KVH
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(B, S, KVH, G, dh).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qg, ck.astype(jnp.float32)) * scale
    k_pos = jnp.arange(Smax)
    if cfg.sliding_window is not None and Smax <= cfg.sliding_window:
        valid = jnp.ones((Smax,), bool)  # ring buffer: all slots valid
    else:
        valid = k_pos <= cache_pos
        if cfg.sliding_window is not None:
            valid &= cache_pos - k_pos < cfg.sliding_window
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgs,bskd->bqkgd", p, cv.astype(jnp.float32))
    return o.reshape(B, S, H, dh).astype(q.dtype)


def init_cache(cfg, B: int, max_len: int, dtype) -> KVCache:
    KVH, dh = cfg.n_kv_heads, cfg.head_dim_
    if cfg.sliding_window is not None:
        max_len = min(max_len, cfg.sliding_window)
    shape = (B, max_len, KVH, dh)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
