"""Mixture-of-Experts layer: top-k token-choice routing with GShard-style
dense dispatch (capacity-bounded, einsum dispatch/combine tensors).

TP mode shards expert FFN dims over the model axis; EP mode additionally
shards the expert dim (applied when it divides the axis — see Plan.moe_mode).
The dispatch einsum over (tokens × experts × capacity) is grouped to bound
the dispatch-tensor size.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical
from repro.models import layers

GROUP_TOKENS = 2048  # dispatch group size (tokens)


def moe_init(key, cfg, dtype):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["router"] = (jax.random.normal(ks[0], (d, E), jnp.float32) * 0.02).astype(dtype)
    a["router"] = ("embed", "expert")

    def ew(k, shape, axes):
        return (jax.random.normal(k, shape, jnp.float32) * 0.02).astype(dtype), axes

    p["gate"], a["gate"] = ew(ks[1], (E, d, ff), ("expert", "embed", "ff"))
    p["up"], a["up"] = ew(ks[2], (E, d, ff), ("expert", "embed", "ff"))
    p["down"], a["down"] = ew(ks[3], (E, ff, d), ("expert", "ff", "embed"))
    return p, a


def _capacity(tokens_per_group: int, E: int, top_k: int, factor: float) -> int:
    c = int(math.ceil(top_k * tokens_per_group * factor / E))
    return max(c, 4)


def moe_apply(p, x: jnp.ndarray, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    mcfg = cfg.moe
    B, S, d = x.shape
    E, K = mcfg.n_experts, mcfg.top_k
    T = B * S
    tg = min(GROUP_TOKENS, T)
    assert T % tg == 0, (T, tg)
    G = T // tg
    C = _capacity(tg, E, K, mcfg.capacity_factor)

    xg = x.reshape(G, tg, d)
    logits = (xg.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # (G,t,E)
    probs = jax.nn.softmax(logits, axis=-1)

    # --- token-choice top-K with capacity (GShard 'tokens choose') ---
    combine = jnp.zeros((G, tg, E, C), jnp.float32)
    expert_usage = jnp.zeros((G, E), jnp.float32)  # tokens already assigned
    remaining = probs
    gates_sum = jnp.zeros((G, tg), jnp.float32)
    picked_masks = []
    for _ in range(K):
        idx = jnp.argmax(remaining, axis=-1)  # (G,t)
        mask = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # (G,t,E)
        gate = jnp.sum(probs * mask, axis=-1)  # (G,t)
        # position within expert buffer (0-indexed)
        pos = jnp.cumsum(mask, axis=1) - 1.0 + expert_usage[:, None, :]
        pos = jnp.sum(pos * mask, axis=-1)  # (G,t)
        keep = pos < C
        gate = gate * keep
        onehot_c = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=jnp.float32)
        combine = combine + (gate[..., None] * mask)[..., None] * onehot_c[:, :, None, :]
        expert_usage = expert_usage + jnp.sum(mask * keep[..., None], axis=1)
        gates_sum = gates_sum + gate
        picked_masks.append(mask)
        remaining = remaining * (1.0 - mask)  # exclude chosen expert

    # normalize combine weights over the K picks (Mixtral renormalizes top-k)
    combine = combine / jnp.maximum(gates_sum, 1e-9)[..., None, None]
    dispatch = (combine > 0.0).astype(x.dtype)

    # --- aux load-balancing loss (Switch/GShard style, over first choice) ---
    frac_tokens = jnp.mean(picked_masks[0], axis=1)  # (G,E)
    frac_probs = jnp.mean(probs, axis=1)  # (G,E)
    aux = E * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))

    # --- dispatch -> expert FFN -> combine ---
    xe = jnp.einsum("gtec,gtd->egcd", dispatch, xg)  # (E,G,C,d)
    xe = logical(xe, ("act_expert", "act_batch", None, "act_embed"))
    h_g = jnp.einsum("egcd,edf->egcf", xe, p["gate"])
    h_u = jnp.einsum("egcd,edf->egcf", xe, p["up"])
    h = jax.nn.silu(h_g) * h_u
    h = logical(h, ("act_expert", "act_batch", None, "act_ff"))
    ye = jnp.einsum("egcf,efd->egcd", h, p["down"])  # (E,G,C,d)
    y = jnp.einsum("egcd,gtec->gtd", ye, combine.astype(x.dtype))
    return y.reshape(B, S, d), aux.astype(jnp.float32)
