"""Model assembly for every assigned architecture family.

All families share one parameter/layout convention: layer parameters are
*stacked* along a leading ``layers`` axis and iterated with ``jax.lax.scan``
(keeping HLO compact — essential for the 80 dry-run compiles), with
``jax.checkpoint`` remat per block.

Families:
  dense / moe / vlm / audio : pre-norm attention + (FFN | MoE) blocks
  ssm                       : Mamba2 (SSD) blocks
  hybrid                    : Zamba2 — SSD blocks + one *shared* attention+MLP
                              block applied after every k-th SSD layer
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import logical
from repro.models import attention as attn
from repro.models import layers, moe, ssm

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _stacked_init(init_fn, key, n: int):
    """vmap an init over n layer keys -> stacked params (+ axes w/ 'layers')."""
    keys = jax.random.split(key, n)
    p0, a0 = init_fn(keys[0])
    p = jax.vmap(lambda k: init_fn(k)[0])(keys)
    a = jax.tree.map(lambda ax: ("layers",) + ax, a0,
                     is_leaf=lambda x: isinstance(x, tuple))
    return p, a


def _dense_block_init(key, cfg: ArchConfig, dtype):
    k1, k2 = jax.random.split(key)
    p, a = {}, {}
    p["ln1"], a["ln1"] = layers.rmsnorm_init(cfg.d_model, dtype)
    p["attn"], a["attn"] = attn.attn_init(k1, cfg, dtype)
    p["ln2"], a["ln2"] = layers.rmsnorm_init(cfg.d_model, dtype)
    if cfg.moe is not None:
        p["moe"], a["moe"] = moe.moe_init(k2, cfg, dtype)
    else:
        p["ffn"], a["ffn"] = layers.ffn_init(k2, cfg.d_model, cfg.d_ff, dtype)
    return p, a


def _ssm_block_init(key, cfg: ArchConfig, dtype):
    p, a = {}, {}
    p["ln"], a["ln"] = layers.rmsnorm_init(cfg.d_model, dtype)
    p["mixer"], a["mixer"] = ssm.ssm_init(key, cfg, dtype)
    return p, a


def init_params(cfg: ArchConfig, key) -> Tuple[Params, Params]:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["embed"], a["embed"] = layers.embedding_init(
        ks[0], cfg.vocab_size, cfg.d_model, dtype, cfg.n_input_codebooks)

    if cfg.family == "ssm":
        p["blocks"], a["blocks"] = _stacked_init(
            lambda k: _ssm_block_init(k, cfg, dtype), ks[1], cfg.n_layers)
    elif cfg.family == "hybrid":
        k_every = cfg.hybrid.attn_every
        assert cfg.n_layers % k_every == 0
        p["blocks"], a["blocks"] = _stacked_init(
            lambda k: _ssm_block_init(k, cfg, dtype), ks[1], cfg.n_layers)
        p["shared"], a["shared"] = _dense_block_init(ks[3], cfg, dtype)
    else:
        p["blocks"], a["blocks"] = _stacked_init(
            lambda k: _dense_block_init(k, cfg, dtype), ks[1], cfg.n_layers)

    p["final_ln"], a["final_ln"] = layers.rmsnorm_init(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        p["head"], a["head"] = layers.lm_head_init(
            ks[2], cfg.d_model, cfg.vocab_size, dtype, cfg.n_output_heads)
    return p, a


def param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def param_axes(cfg: ArchConfig) -> Params:
    """Logical-axes tree for ``init_params(cfg, ·)[0]`` WITHOUT allocating
    the full model: axes depend only on the tree structure, which the
    reduced same-family config shares exactly."""
    _, axes = init_params(cfg.reduced(), jax.random.PRNGKey(0))
    return axes


def param_shapes(cfg: ArchConfig) -> Params:
    """ShapeDtypeStruct tree of the full parameter pytree (no allocation)."""
    return jax.eval_shape(
        lambda k: init_params(cfg, k)[0],
        jax.ShapeDtypeStruct((2,), jnp.uint32))


def decode_state_axes(cfg: ArchConfig) -> Dict[str, Any]:
    """Logical-axes tree mirroring ``init_decode_state`` output."""
    from repro.models.attention import KVCache
    from repro.models.ssm import SSMState
    kv_axes = KVCache(
        k=("act_layers", "act_batch", "act_seq_dp", "act_kv_heads", None),
        v=("act_layers", "act_batch", "act_seq_dp", "act_kv_heads", None))
    ssm_axes = SSMState(
        conv=("act_layers", "act_batch", None, "act_ssm_inner"),
        h=("act_layers", "act_batch", "act_ssm_heads", None, None))
    axes: Dict[str, Any] = {"pos": ()}
    if cfg.family == "ssm":
        axes["ssm"] = ssm_axes
    elif cfg.family == "hybrid":
        axes["ssm"] = ssm_axes
        axes["kv"] = kv_axes
    else:
        axes["kv"] = kv_axes
    return axes


# ---------------------------------------------------------------------------
# Remat
# ---------------------------------------------------------------------------


def _remat(fn, policy: str):
    if policy in (None, "none"):
        return fn
    if policy == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:  # "full" / "nothing": save only block boundaries
        pol = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=pol)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _annotate_resid(h):
    return logical(h, ("act_batch", "act_seq", "act_embed"))


def _dense_block_apply(bp, h, cfg, positions):
    x = layers.rmsnorm(bp["ln1"], h, cfg.norm_eps)
    a_out, _ = attn.attn_apply(bp["attn"], x, cfg, positions=positions)
    h = _annotate_resid(h + a_out)
    x = layers.rmsnorm(bp["ln2"], h, cfg.norm_eps)
    if cfg.moe is not None:
        f_out, aux = moe.moe_apply(bp["moe"], x, cfg)
    else:
        f_out, aux = layers.ffn(bp["ffn"], x), jnp.float32(0.0)
    h = _annotate_resid(h + f_out)
    return h, aux


def _ssm_block_apply(bp, h, cfg):
    x = layers.rmsnorm(bp["ln"], h, cfg.norm_eps)
    m_out, _ = ssm.ssm_apply(bp["mixer"], x, cfg)
    return _annotate_resid(h + m_out)


def embed_inputs(params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray]):
    h = layers.embed(params["embed"], batch["tokens"])
    if cfg.vision_tokens:
        ve = batch["vision_embeds"].astype(h.dtype)  # (B, vt, d)
        h = jax.lax.dynamic_update_slice(h, ve, (0, 0, 0))
    return _annotate_resid(h)


def logits_from_hidden(params, cfg: ArchConfig, h):
    h = layers.rmsnorm(params["final_ln"], h, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = layers.tied_lm_head(params["embed"], h)
        names = ("act_batch", "act_seq", "act_vocab")
    else:
        logits = layers.lm_head(params["head"], h)
        names = (("act_batch", "act_seq", "act_vocab")
                 if cfg.n_output_heads == 1
                 else ("act_batch", "act_seq", None, "act_vocab"))
    return logical(logits, names)


def forward(params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray],
            remat_policy: Optional[str] = None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (logits, aux_loss).  Train/prefill path (full sequence)."""
    policy = remat_policy or cfg.remat_policy
    h = embed_inputs(params, cfg, batch)
    B, S = h.shape[0], h.shape[1]
    positions = attn._positions_for(cfg, B, S)

    if cfg.family == "ssm":
        def body(hc, bp):
            return _ssm_block_apply(bp, hc, cfg), None
        h, _ = jax.lax.scan(_remat(body, policy), h, params["blocks"])
        aux = jnp.float32(0.0)
    elif cfg.family == "hybrid":
        k_every = cfg.hybrid.attn_every
        n_super = cfg.n_layers // k_every
        blocks = jax.tree.map(
            lambda x: x.reshape((n_super, k_every) + x.shape[1:]),
            params["blocks"])
        shared = params["shared"]

        def super_body(hc, bp_chunk):
            def inner(hh, bp):
                return _ssm_block_apply(bp, hh, cfg), None
            hc, _ = jax.lax.scan(inner, hc, bp_chunk)
            hc, _ = _dense_block_apply(shared, hc, cfg, positions)
            return hc, None

        h, _ = jax.lax.scan(_remat(super_body, policy), h, blocks)
        aux = jnp.float32(0.0)
    else:
        def body(carry, bp):
            hc, aux_acc = carry
            hc, aux = _dense_block_apply(bp, hc, cfg, positions)
            return (hc, aux_acc + aux), None
        (h, aux), _ = jax.lax.scan(_remat(body, policy),
                                   (h, jnp.float32(0.0)), params["blocks"])

    return logits_from_hidden(params, cfg, h), aux


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def loss_fn(params, cfg: ArchConfig, batch, remat_policy=None):
    logits, aux = forward(params, cfg, batch, remat_policy)
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if cfg.n_output_heads > 1:  # (B,S,heads,V) vs (B,S,heads)
        ce = layers.softmax_xent(logits, labels,
                                 mask[..., None] if mask is not None else None)
    else:
        ce = layers.softmax_xent(logits, labels, mask)
    total = ce
    if cfg.moe is not None:
        total = total + cfg.moe.aux_loss_weight * aux
    return total, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ArchConfig, B: int, max_len: int,
                      dtype=None) -> Dict[str, Any]:
    """Stacked per-layer decode caches (KV and/or SSM state) + position."""
    dtype = dtype or jnp.dtype(cfg.compute_dtype)

    def stack(tree, n):
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), tree)

    state: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.family == "ssm":
        state["ssm"] = stack(ssm.init_ssm_state(cfg, B, dtype), cfg.n_layers)
    elif cfg.family == "hybrid":
        n_sites = cfg.n_layers // cfg.hybrid.attn_every
        state["ssm"] = stack(ssm.init_ssm_state(cfg, B, dtype), cfg.n_layers)
        state["kv"] = stack(attn.init_cache(cfg, B, max_len, dtype), n_sites)
    else:
        state["kv"] = stack(attn.init_cache(cfg, B, max_len, dtype),
                            cfg.n_layers)
    return state


def decode_step(params, cfg: ArchConfig, state: Dict[str, Any],
                tokens: jnp.ndarray, batch_extras: Optional[dict] = None
                ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One-token decode.  tokens (B, 1[, n_codebooks]) -> logits, new state."""
    pos = state["pos"]
    h = layers.embed(params["embed"], tokens)
    h = _annotate_resid(h)
    B = h.shape[0]
    positions = attn._positions_for(cfg, B, 1, offset=pos)
    new_state: Dict[str, Any] = {"pos": pos + 1}

    if cfg.family == "ssm":
        def body(hc, inp):
            bp, st = inp
            x = layers.rmsnorm(bp["ln"], hc, cfg.norm_eps)
            m, st_new = ssm.ssm_apply(bp["mixer"], x, cfg, state=st)
            return hc + m, st_new
        h, new_ssm = jax.lax.scan(body, h, (params["blocks"], state["ssm"]))
        new_state["ssm"] = new_ssm
    elif cfg.family == "hybrid":
        k_every = cfg.hybrid.attn_every
        n_super = cfg.n_layers // k_every
        blocks = jax.tree.map(
            lambda x: x.reshape((n_super, k_every) + x.shape[1:]),
            params["blocks"])
        ssm_states = jax.tree.map(
            lambda x: x.reshape((n_super, k_every) + x.shape[1:]),
            state["ssm"])
        shared = params["shared"]

        def super_body(hc, inp):
            bp_chunk, st_chunk, kv = inp

            def inner(hh, i2):
                bp, st = i2
                x = layers.rmsnorm(bp["ln"], hh, cfg.norm_eps)
                m, st_new = ssm.ssm_apply(bp["mixer"], x, cfg, state=st)
                return hh + m, st_new
            hc, st_new = jax.lax.scan(inner, hc, (bp_chunk, st_chunk))
            x = layers.rmsnorm(shared["ln1"], hc, cfg.norm_eps)
            a_out, kv_new = attn.attn_apply(shared["attn"], x, cfg,
                                            positions=positions,
                                            cache=kv, cache_pos=pos)
            hc = hc + a_out
            x = layers.rmsnorm(shared["ln2"], hc, cfg.norm_eps)
            hc = hc + layers.ffn(shared["ffn"], x)
            return hc, (st_new, kv_new)

        h, (new_ssm, new_kv) = jax.lax.scan(
            super_body, h, (blocks, ssm_states, state["kv"]))
        new_state["ssm"] = jax.tree.map(
            lambda x: x.reshape((cfg.n_layers,) + x.shape[2:]), new_ssm)
        new_state["kv"] = new_kv
    else:
        def body(hc, inp):
            bp, kv = inp
            x = layers.rmsnorm(bp["ln1"], hc, cfg.norm_eps)
            a_out, kv_new = attn.attn_apply(bp["attn"], x, cfg,
                                            positions=positions,
                                            cache=kv, cache_pos=pos)
            hc = _annotate_resid(hc + a_out)
            x = layers.rmsnorm(bp["ln2"], hc, cfg.norm_eps)
            if cfg.moe is not None:
                f_out, _ = moe.moe_apply(bp["moe"], x, cfg)
            else:
                f_out = layers.ffn(bp["ffn"], x)
            return _annotate_resid(hc + f_out), kv_new
        h, new_kv = jax.lax.scan(body, h, (params["blocks"], state["kv"]))
        new_state["kv"] = new_kv

    logits = logits_from_hidden(params, cfg, h)
    return logits, new_state
