"""Deterministic fault injection — the chaos half of the fault-tolerant
runtime.

A ``FaultPlan`` is a seeded, serializable schedule of faults (device loss
at step N, per-host slowdown windows, one-off timing spikes, poisoned
telemetry samples, corrupted registry/checkpoint/compile-cache files);
a ``FaultInjector`` replays that schedule against the live run through
four hook families:

  * **step hooks** — ``step_begin(step)`` raises ``DeviceLossError`` and
    lands file corruption *before* the step runs (trainer loop,
    ``runtime/trainer.py``); ``decode_begin(it)`` is the serving twin
    (``runtime/server.py``, iteration-indexed);
  * **timing hooks** — ``perturb_step_time`` / ``perturb_decode_time``
    multiply the *observed* wall time by slowdown/spike factors, so a
    "3× straggler for 10 steps" is injected deterministically without
    sleeping;
  * **telemetry hooks** — ``perturb_telemetry`` replaces the sample fed
    to the online calibrator with a non-finite/non-positive value at the
    scheduled step (the sink must quarantine it, not crash);
  * **file hooks** — ``corrupt_file`` truncates or garbage-stamps the
    registry model file, the newest checkpoint, or disk compile-cache
    entries (the hardened readers must fall back, quarantining the bad
    artifact).

Determinism contract: every fault is a pure function of (plan, seed,
step index).  Timing/telemetry perturbations are idempotent by step —
a post-recovery replay of step N sees the same perturbation — while
device-loss and file-corruption faults are one-shot (they model events,
not conditions).  With an EMPTY plan every hook is an identity
passthrough: a run under an armed-but-empty injector is bit-identical
to an uninstrumented run (pinned in ``tests/test_faults.py``).

Nothing here imports the trainer or server; the hooks are called by
them, guarded by ``if injector is not None`` so the hot path pays
nothing when chaos is off.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field, replace
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.obs import metrics as _obs_metrics
from repro.obs import report as _obs_report
from repro.obs import trace as _obs_trace

__all__ = [
    "FAULT_KINDS", "Fault", "FaultPlan", "FaultInjector",
    "DeviceLossError", "corrupt_file", "corrupt_checkpoint",
]

_INJECTED = _obs_metrics.REGISTRY.counter(
    "repro_faults_injected_total",
    "faults the injector landed on the run, by kind (slowdown windows "
    "count once per affected step)")

#: every kind the plan grammar accepts
FAULT_KINDS = (
    "device_loss",            # raise DeviceLossError(count) at step N
    "slowdown",               # observed time ×factor for [step, step+duration)
    "timing_spike",           # observed time ×factor at exactly step N
    "telemetry_nan",          # calibrator sample replaced by `value` at step N
    "corrupt_registry",       # registry model file truncated/garbaged
    "corrupt_checkpoint",     # newest checkpoint manifest/leaf corrupted
    "corrupt_compile_cache",  # every disk compile-cache entry corrupted
    "pool_shrink",            # fleet: pool=NAME loses count devices at step N
    "pool_grow",              # fleet: pool=NAME gains count devices at step N
)

_FILE_KINDS = ("corrupt_registry", "corrupt_checkpoint",
               "corrupt_compile_cache")
_TIMING_KINDS = ("slowdown", "timing_spike")
#: fleet-scoped churn kinds: consumed by ``FleetSupervisor.fleet_events``,
#: never by the per-trainer ``step_begin`` hook.  A ``device_loss`` with
#: ``pool=`` set is fleet-scoped too — it names WHICH pool lost the
#: devices, which only the fleet layer can act on.
_POOL_KINDS = ("pool_shrink", "pool_grow")


class DeviceLossError(RuntimeError):
    """An injected (or real) loss of ``count`` devices at ``step``.

    Raised out of the trainer step loop; the ``Supervisor`` catches it
    and runs the replan → checkpoint-restore → resume failover.
    """

    def __init__(self, count: int = 1, step: Optional[int] = None):
        self.count = int(count)
        self.step = step
        super().__init__(f"lost {self.count} device(s) at step {step}")


@dataclass(frozen=True, eq=False)
class Fault:
    """One scheduled fault.  Unused fields keep their defaults (e.g. a
    ``device_loss`` ignores ``factor``); see ``FAULT_KINDS`` for the
    per-kind meaning of ``step``/``count``/``factor``/``duration``/
    ``value``/``mode``/``target``."""

    kind: str
    step: int
    count: int = 1                    # device_loss / pool_*: devices moved
    factor: float = 4.0               # slowdown / timing_spike multiplier
    duration: int = 1                 # slowdown window length, in steps
    value: float = float("nan")       # telemetry_nan poison value
    mode: str = "truncate"            # file corruption: truncate | garbage
    target: Optional[str] = None      # file corruption: explicit path
    pool: Optional[str] = None        # fleet faults: the device pool hit

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if self.step < 0:
            raise ValueError(f"fault step must be >= 0: {self.step}")
        if self.mode not in ("truncate", "garbage"):
            raise ValueError(f"fault mode must be truncate|garbage: "
                             f"{self.mode!r}")
        if self.pool is not None and self.kind not in _POOL_KINDS \
                and self.kind != "device_loss":
            raise ValueError(f"pool= only applies to {_POOL_KINDS} "
                             f"and device_loss, not {self.kind!r}")

    @property
    def fleet_scoped(self) -> bool:
        """True for pool-churn faults the ``FleetSupervisor`` consumes
        (``pool_shrink``/``pool_grow``, and ``device_loss`` carrying a
        ``pool=`` attribution)."""
        return self.kind in _POOL_KINDS or \
            (self.kind == "device_loss" and self.pool is not None)

    def _key(self):
        # repr() makes nan compare equal to nan — a plan carrying a NaN
        # poison value must still be a value object (tests pin that equal
        # seeds build EQUAL plans)
        return (self.kind, self.step, self.count, repr(self.factor),
                self.duration, repr(self.value), self.mode, self.target,
                self.pool)

    def __eq__(self, other):
        return isinstance(other, Fault) and self._key() == other._key()

    def __hash__(self):
        return hash(self._key())

    # -- serialization -----------------------------------------------------
    def to_json_dict(self) -> Dict[str, object]:
        d: Dict[str, object] = {"kind": self.kind, "step": self.step}
        defaults = Fault(kind=self.kind, step=self.step)
        for f in ("count", "factor", "duration", "value", "mode", "target",
                  "pool"):
            v = getattr(self, f)
            dv = getattr(defaults, f)
            if v != dv and not (isinstance(v, float) and isinstance(dv, float)
                                and np.isnan(v) and np.isnan(dv)):
                d[f] = v
        return d

    @classmethod
    def from_json_dict(cls, d: Mapping[str, object]) -> "Fault":
        kw = {k: d[k] for k in ("count", "factor", "duration", "value",
                                "mode", "target", "pool") if k in d}
        return cls(kind=str(d["kind"]), step=int(d["step"]), **kw)


def _parse_scalar(s: str):
    for conv in (int, float):
        try:
            return conv(s)
        except ValueError:
            pass
    return s


@dataclass(frozen=True)
class FaultPlan:
    """An ordered, seeded fault schedule.

    Plans are value objects: equal plans inject identical fault streams
    (``tests/test_faults.py`` pins bit-for-bit reproducibility of
    ``FaultPlan.random`` and the JSON round trip).  ``seed`` feeds the
    injector's rng (garbage bytes for file corruption) so even the
    corruption payloads are reproducible.
    """

    faults: Tuple[Fault, ...] = ()
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(
            self, "faults",
            tuple(sorted(self.faults,
                         key=lambda f: (f.step, FAULT_KINDS.index(f.kind)))))

    def __bool__(self) -> bool:
        return bool(self.faults)

    def describe(self) -> str:
        if not self.faults:
            return "<empty plan>"
        return "; ".join(f"{f.kind}@{f.step}" for f in self.faults)

    # -- construction ------------------------------------------------------
    @classmethod
    def parse(cls, spec: str, seed: int = 0) -> "FaultPlan":
        """Parse the CLI mini-grammar ``kind@step[:k=v,k=v];kind@step…``
        (e.g. ``"corrupt_registry@7;device_loss@12:count=2"``), or load a
        JSON plan when ``spec`` is a path to an existing file."""
        spec = spec.strip()
        if os.path.exists(spec):
            return cls.load(spec)
        faults: List[Fault] = []
        for part in filter(None, (p.strip() for p in spec.split(";"))):
            head, _, kvs = part.partition(":")
            kind, _, step = head.partition("@")
            if not step:
                raise ValueError(f"fault spec {part!r} needs kind@step")
            kw: Dict[str, object] = {}
            for kv in filter(None, (x.strip() for x in kvs.split(","))):
                k, _, v = kv.partition("=")
                k = k.strip()
                if k == "k":          # fleet shorthand: pool_shrink@5:k=2
                    k = "count"
                kw[k] = _parse_scalar(v.strip())
            faults.append(Fault(kind=kind.strip(), step=int(step), **kw))
        return cls(faults=tuple(faults), seed=seed)

    @classmethod
    def random(cls, seed: int, n_steps: int, n_faults: int = 4,
               kinds: Sequence[str] = _TIMING_KINDS + ("telemetry_nan",)
               ) -> "FaultPlan":
        """A deterministic random schedule: same (seed, n_steps, n_faults,
        kinds) → bit-identical plan.  Defaults to the non-destructive
        kinds; pass ``kinds`` explicitly to include device loss or file
        corruption."""
        rng = np.random.default_rng(seed)
        faults = []
        for _ in range(n_faults):
            kind = kinds[int(rng.integers(len(kinds)))]
            step = int(rng.integers(n_steps))
            f = Fault(kind=kind, step=step)
            if kind == "slowdown":
                f = replace(f, factor=float(np.round(
                    rng.uniform(2.0, 8.0), 6)),
                    duration=int(rng.integers(1, 8)))
            elif kind == "timing_spike":
                f = replace(f, factor=float(np.round(
                    rng.uniform(4.0, 32.0), 6)))
            elif kind == "telemetry_nan":
                f = replace(f, value=float(
                    rng.choice([float("nan"), float("inf"), -1.0, 0.0])))
            faults.append(f)
        return cls(faults=tuple(faults), seed=seed)

    # -- serialization -----------------------------------------------------
    def to_json_dict(self) -> Dict[str, object]:
        return {"kind": "fault_plan", "schema": 1, "seed": self.seed,
                "faults": [f.to_json_dict() for f in self.faults]}

    @classmethod
    def from_json_dict(cls, d: Mapping[str, object]) -> "FaultPlan":
        if d.get("kind") != "fault_plan":
            raise ValueError(f"not a fault_plan record: {d.get('kind')!r}")
        return cls(faults=tuple(Fault.from_json_dict(f)
                                for f in d["faults"]),
                   seed=int(d.get("seed", 0)))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json_dict(), f, indent=1)

    @classmethod
    def load(cls, path: str) -> "FaultPlan":
        with open(path) as f:
            return cls.from_json_dict(json.load(f))


# ---------------------------------------------------------------------------
# File corruption primitives (also used directly by tests)
# ---------------------------------------------------------------------------


def corrupt_file(path: str, rng: Optional[np.random.Generator] = None,
                 mode: str = "truncate") -> bool:
    """Corrupt one file in place: ``truncate`` chops it to half length
    (an interrupted write), ``garbage`` overwrites the head with random
    bytes (bit rot).  Returns False when the file doesn't exist."""
    try:
        size = os.path.getsize(path)
    except OSError:
        return False
    if mode == "truncate":
        with open(path, "r+b") as f:
            f.truncate(size // 2)
    else:
        rng = rng or np.random.default_rng(0)
        junk = rng.integers(0, 256, size=min(max(size, 1), 64),
                            dtype=np.uint8).tobytes()
        with open(path, "r+b") as f:
            f.seek(0)
            f.write(junk)
    return True


def corrupt_checkpoint(ckpt_dir: str,
                       rng: Optional[np.random.Generator] = None,
                       mode: str = "truncate") -> Optional[str]:
    """Corrupt the NEWEST checkpoint under ``ckpt_dir``: ``truncate``
    chops the manifest (unreadable metadata), ``garbage`` stomps the
    first leaf (crc mismatch).  Returns the corrupted file path, or None
    when no checkpoint exists."""
    from repro.checkpoint import store
    step = store.latest_step(ckpt_dir)
    if step is None:
        return None
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    target = os.path.join(d, "manifest.json" if mode == "truncate"
                          else "leaf_00000.npy")
    return target if corrupt_file(target, rng, mode) else None


# ---------------------------------------------------------------------------
# The injector
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InjectedFault:
    """Audit record of one landed fault."""
    step: int
    kind: str
    detail: str = ""


class FaultInjector:
    """Replays a ``FaultPlan`` against a live run.

    Construction wires the file-layer targets (checkpoint dir, registry
    dir + device name, compile-cache dir); the runtime hooks are then
    pure functions of the plan and the step index.  All hooks are
    no-ops under an empty plan.
    """

    def __init__(self, plan: FaultPlan, *,
                 ckpt_dir: Optional[str] = None,
                 registry_dir: Optional[str] = None,
                 registry_device: Optional[str] = None,
                 compile_cache_dir: Optional[str] = None,
                 seed: Optional[int] = None):
        self.plan = plan
        self.ckpt_dir = ckpt_dir
        self.registry_dir = registry_dir
        self.registry_device = registry_device
        self.compile_cache_dir = compile_cache_dir
        self.rng = np.random.default_rng(plan.seed if seed is None else seed)
        self.injected: List[InjectedFault] = []
        self._fired: set = set()          # one-shot fault indices
        self._seen_timing: set = set()    # (fault idx, step) audit dedupe
        # hot-path pre-splits: the trainer consults these every step
        self._timing = [(i, f) for i, f in enumerate(plan.faults)
                        if f.kind in _TIMING_KINDS]
        self._telemetry = [(i, f) for i, f in enumerate(plan.faults)
                           if f.kind == "telemetry_nan"]
        self._oneshot: Dict[int, List[Tuple[int, Fault]]] = {}
        self._fleet: Dict[int, List[Tuple[int, Fault]]] = {}
        for i, f in enumerate(plan.faults):
            if f.fleet_scoped:
                # pool churn is the fleet supervisor's to consume; the
                # per-trainer step hook must never raise it
                self._fleet.setdefault(f.step, []).append((i, f))
            elif f.kind in _FILE_KINDS or f.kind == "device_loss":
                self._oneshot.setdefault(f.step, []).append((i, f))

    def armed(self) -> bool:
        return bool(self.plan.faults)

    def counts(self) -> Dict[str, int]:
        """Injected-fault tally by kind (for the supervisor's rollup)."""
        out: Dict[str, int] = {}
        for rec in self.injected:
            out[rec.kind] = out.get(rec.kind, 0) + 1
        return out

    # -- bookkeeping -------------------------------------------------------
    def _record(self, step: int, fault: Fault, detail: str = "") -> None:
        self.injected.append(InjectedFault(step, fault.kind, detail))
        _INJECTED.inc(1, kind=fault.kind)
        _obs_trace.get_tracer().instant("fault_injected", step=step,
                                        kind=fault.kind, detail=detail)
        _obs_report.emit("faults", {"step": step, "kind": fault.kind,
                                    **({"detail": detail} if detail else {})})

    # -- step hooks --------------------------------------------------------
    def step_begin(self, step: int) -> None:
        """Trainer-side hook, called before the step executes.  Lands any
        file corruption scheduled for this step, then raises device loss
        (corruption first, so a same-step failover reads the corrupted
        state — the harder scenario)."""
        due = self._oneshot.get(step)
        if not due:
            return
        loss: Optional[Fault] = None
        for i, f in due:
            if i in self._fired:
                continue
            if f.kind == "device_loss":
                loss = f
                continue
            self._fired.add(i)
            self._corrupt(step, f)
        if loss is not None:
            i = next(i for i, f in due if f is loss)
            self._fired.add(i)
            self._record(step, loss, detail=f"count={loss.count}")
            raise DeviceLossError(loss.count, step)

    def decode_begin(self, it: int) -> None:
        """Serving-side twin of ``step_begin`` (iteration-indexed)."""
        self.step_begin(it)

    def fleet_events(self, step: int) -> List[Fault]:
        """Fleet-scoped pool-churn faults due at ``step`` (``pool_shrink``,
        ``pool_grow``, pool-attributed ``device_loss``), fired one-shot and
        returned in plan order for the ``FleetSupervisor`` to apply.  An
        empty plan (or a step with no churn) returns ``[]`` without any
        bookkeeping — the supervised fleet loop pays one dict probe."""
        due = self._fleet.get(step)
        if not due:
            return []
        out: List[Fault] = []
        for i, f in due:
            if i in self._fired:
                continue
            self._fired.add(i)
            detail = f"pool={f.pool or '<first>'},k={f.count}"
            self._record(step, f, detail=detail)
            out.append(f)
        return out

    def _corrupt(self, step: int, f: Fault) -> None:
        detail = ""
        if f.target is not None:
            ok = corrupt_file(f.target, self.rng, f.mode)
            detail = f.target if ok else "<missing>"
        elif f.kind == "corrupt_registry":
            from repro.calibration import registry as _registry
            if self.registry_device is None:
                detail = "<no registry device wired>"
            else:
                path = _registry._model_path(
                    self.registry_dir or _registry.default_registry_dir(),
                    self.registry_device)
                ok = corrupt_file(path, self.rng, f.mode)
                detail = path if ok else "<missing>"
        elif f.kind == "corrupt_checkpoint":
            if self.ckpt_dir is None:
                detail = "<no ckpt dir wired>"
            else:
                detail = corrupt_checkpoint(self.ckpt_dir, self.rng,
                                            f.mode) or "<missing>"
        elif f.kind == "corrupt_compile_cache":
            from repro.core import exprops as _exprops
            cdir = self.compile_cache_dir or _exprops.compile_cache_dir()
            n = 0
            if cdir and os.path.isdir(cdir):
                for fn in sorted(os.listdir(cdir)):
                    if fn.endswith(".json"):
                        n += corrupt_file(os.path.join(cdir, fn),
                                          self.rng, f.mode)
            detail = f"entries={n}"
        self._record(step, f, detail=detail)

    # -- timing hooks ------------------------------------------------------
    def perturb_step_time(self, step: int, dt: float) -> float:
        """Observed step seconds after scheduled slowdowns/spikes.  A pure
        function of (plan, step): replayed steps see identical values."""
        if not self._timing:
            return dt
        out = dt
        for i, f in self._timing:
            hit = (step == f.step if f.kind == "timing_spike"
                   else f.step <= step < f.step + max(f.duration, 1))
            if hit:
                out = out * f.factor
                if (i, step) not in self._seen_timing:
                    self._seen_timing.add((i, step))
                    self._record(step, f, detail=f"factor={f.factor}")
        return out

    def perturb_decode_time(self, it: int, dt: float) -> float:
        """Serving twin of ``perturb_step_time`` (iteration-indexed)."""
        return self.perturb_step_time(it, dt)

    # -- telemetry hooks ---------------------------------------------------
    def perturb_telemetry(self, step: int, seconds: float) -> float:
        """The sample handed to the online calibrator at ``step`` — the
        scheduled poison value when a ``telemetry_nan`` fault matches,
        the measurement untouched otherwise."""
        if not self._telemetry:
            return seconds
        out = seconds
        for i, f in self._telemetry:
            if step == f.step:
                out = f.value
                if (i, step) not in self._seen_timing:
                    self._seen_timing.add((i, step))
                    self._record(step, f, detail=f"value={f.value}")
        return out
