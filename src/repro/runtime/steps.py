"""Step functions: train_step / prefill_step / serve_step factories.

These are the units the dry-run lowers and the trainer/server jit.
Microbatched gradient accumulation runs as a ``lax.scan`` so only one
microbatch's activations are live (and on real hardware the grad
all-reduce of microbatch i overlaps the compute of i+1).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.plan import Plan
from repro.models import transformer
from repro.optim import optimizers as opt


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray


def init_train_state(cfg: ArchConfig, key, optimizer: opt.Optimizer) -> TrainState:
    params, _ = transformer.init_params(cfg, key)
    return TrainState(params, optimizer.init(params), jnp.zeros((), jnp.int32))


def make_train_step(cfg: ArchConfig, optimizer: opt.Optimizer,
                    plan: Optional[Plan] = None,
                    lr_schedule=None, clip_norm: float = 1.0):
    plan = plan or Plan()
    lr_schedule = lr_schedule or (lambda s: 3e-4)
    remat = plan.remat_policy or cfg.remat_policy
    M = plan.microbatches

    def loss(params, batch):
        return transformer.loss_fn(params, cfg, batch, remat_policy=remat)

    def _pin_grads(g):
        """Constrain gradients to the parameter sharding (no-op without a
        sharding context): keeps GSPMD reduce-scattering dW partials into
        the sharded accumulator instead of materializing them replicated
        (8–12 GB/layer all-reduces on the 405B lowering; §Perf iter B)."""
        from repro.distributed import sharding as shard
        if shard.current() is None:
            return g
        return shard.constrain_like_params(g, transformer.param_axes(cfg))

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        if M > 1:
            # reshape leading batch dim into (M, b/M) microbatches
            mb = jax.tree.map(
                lambda x: x.reshape((M, x.shape[0] // M) + x.shape[1:]), batch)

            def acc_body(carry, one):
                g_acc, l_acc = carry
                (l, metrics), g = jax.value_and_grad(loss, has_aux=True)(
                    state.params, one)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc,
                    _pin_grads(g))
                return (_pin_grads(g_acc), l_acc + l), None

            g0 = _pin_grads(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params))
            (grads, l_sum), _ = jax.lax.scan(acc_body, (g0, jnp.float32(0.0)),
                                             mb)
            grads = jax.tree.map(lambda g: g / M, grads)
            loss_val = l_sum / M
        else:
            (loss_val, metrics), grads = jax.value_and_grad(
                loss, has_aux=True)(state.params, batch)
            grads = _pin_grads(grads)

        grads, gnorm = opt.clip_by_global_norm(grads, clip_norm)
        lr = lr_schedule(state.step)
        new_params, new_opt = optimizer.update(grads, state.opt_state,
                                               state.params, lr)
        new_state = TrainState(new_params, new_opt, state.step + 1)
        return new_state, {"loss": loss_val, "grad_norm": gnorm,
                           "lr": jnp.float32(lr)}

    return train_step


def make_prefill_step(cfg: ArchConfig, plan: Optional[Plan] = None):
    plan = plan or Plan()
    remat = plan.remat_policy or cfg.remat_policy

    def prefill_step(params, batch):
        logits, _ = transformer.forward(params, cfg, batch, remat_policy=remat)
        return logits

    return prefill_step


def make_serve_step(cfg: ArchConfig, plan: Optional[Plan] = None,
                    sample: bool = True, temperature: float = 1.0):
    """One decode iteration: token in, (next token | logits) + new cache."""

    def serve_step(params, state, tokens, rng):
        if rng.dtype == jnp.uint32:  # raw key data (dry-run specs)
            rng = jax.random.wrap_key_data(rng)
        logits, new_state = transformer.decode_step(params, cfg, state, tokens)
        last = logits[:, -1]
        if sample:
            next_tok = jax.random.categorical(
                rng, last.astype(jnp.float32) / temperature, axis=-1)
        else:
            next_tok = jnp.argmax(last, axis=-1)
        return next_tok.astype(jnp.int32), new_state

    return serve_step


def make_step(cfg: ArchConfig, workload, plan: Optional[Plan] = None,
              optimizer: Optional[opt.Optimizer] = None, **kw):
    """One entry point for any workload phase: the ``WorkloadSpec`` (or a
    ``ShapeConfig`` / deprecated phase string — ``repro.core.workload``
    normalizes) picks the step family; extra keywords pass through to the
    underlying ``make_*_step``.  ``optimizer`` defaults to the config's for
    train workloads."""
    from repro.core import workload as wl
    spec = wl.as_spec(workload)
    if spec.phase == "train":
        optimizer = optimizer or opt.get_optimizer(cfg.optimizer)
        return make_train_step(cfg, optimizer, plan, **kw)
    if spec.phase == "prefill":
        return make_prefill_step(cfg, plan, **kw)
    return make_serve_step(cfg, plan, **kw)


# ---------------------------------------------------------------------------
# Manual-DP train step (shard_map) — explicit collective control
# ---------------------------------------------------------------------------


def make_manual_dp_train_step(cfg: ArchConfig, optimizer: opt.Optimizer,
                              mesh, axis: str = "data",
                              compression: Optional[str] = None,
                              lr_schedule=None, clip_norm: float = 1.0):
    """Pure-DP train step with the gradient all-reduce written *explicitly*
    (shard_map), so the wire format is controllable: ``compression=
    'int8_ef'`` swaps the fp32 psum for the int8 error-feedback collective
    (distributed/compression.py) — 4× fewer DP collective bytes, visible in
    the lowered HLO.  Params replicated; batch sharded over ``axis``.

    The error-feedback residual rides in the extended opt state.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.distributed import compression as comp

    lr_schedule = lr_schedule or (lambda s: 3e-4)
    n_dev = mesh.shape[axis]

    def loss(params, batch):
        return transformer.loss_fn(params, cfg, batch)

    def local_body(params, ef, batch, step):
        (l, _), grads = jax.value_and_grad(loss, has_aux=True)(params, batch)
        if compression == "int8_ef":
            def one(g, r):
                codes, scales, r_new = comp.ef_compress(g, r)
                n = g.size
                deq = comp.dequantize(codes, scales, n, g.shape)
                return comp.psum_compressed(deq, axis) / n_dev, r_new
            out = jax.tree.map(one, grads, ef)
            tup = lambda x: isinstance(x, tuple)
            grads = jax.tree.map(lambda o: o[0], out, is_leaf=tup)
            ef = jax.tree.map(lambda o: o[1], out, is_leaf=tup)
        else:
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, axis), grads)
        l = jax.lax.pmean(l, axis)
        return grads, ef, l

    def train_step(state: TrainState, ef, batch):
        p_spec = jax.tree.map(lambda _: P(), state.params)
        ef_spec = jax.tree.map(lambda _: P(), ef)
        b_spec = jax.tree.map(lambda _: P(axis), batch)
        grads, ef_new, l = shard_map(
            local_body, mesh=mesh,
            in_specs=(p_spec, ef_spec, b_spec, P()),
            out_specs=(p_spec, ef_spec, P()),
            check_rep=False,
        )(state.params, ef, batch, state.step)
        grads, gnorm = opt.clip_by_global_norm(grads, clip_norm)
        lr = lr_schedule(state.step)
        new_params, new_opt = optimizer.update(grads, state.opt_state,
                                               state.params, lr)
        return (TrainState(new_params, new_opt, state.step + 1), ef_new,
                {"loss": l, "grad_norm": gnorm})

    def init_ef(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    return train_step, init_ef
