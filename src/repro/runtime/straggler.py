"""Straggler detection + mitigation.

The monitor compares *observed* per-host step times against the cost
model's *predicted* step time (core/predictor.py) — the paper's §6.1 'load
balancing' application.  A host is a straggler when its EWMA exceeds
``k × max(predicted, fleet median)``.  ``StragglerMonitor.from_model``
derives the predicted step time from a cost model directly — an in-memory
``LinearCostModel``, a registry device name (``repro.calibration``), or the
analytic v5e seed.

Mitigations (policy chosen by the trainer):
  * ``report``   — log only;
  * ``rescale``  — drop the host's microbatch contribution this step and
                   rescale the gradient (synchronous skip-and-rescale);
  * ``replan``   — hand off to distributed/elastic.py for a smaller mesh.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core import exprops
from repro.obs import metrics as _obs_metrics

#: incremental-rescore cache for monitor re-anchoring (see ``from_model``)
_BASIS_CACHE = exprops.BasisCache(maxsize=2048)

_STRAGGLER_EVENTS = _obs_metrics.REGISTRY.counter(
    "repro_straggler_events_total",
    "hosts flagged over the predicted-step threshold, by action")


@dataclass
class StragglerEvent:
    step: int
    host: int
    observed_s: float
    threshold_s: float
    action: str


@dataclass
class StragglerMonitor:
    n_hosts: int
    predicted_step_s: float
    k: float = 2.0              # threshold multiplier
    ewma: float = 0.5           # smoothing for per-host times
    policy: str = "rescale"     # report | rescale | replan
    _state: np.ndarray = field(default=None)  # per-host EWMA
    events: List[StragglerEvent] = field(default_factory=list)

    def __post_init__(self):
        if self._state is None:
            self._state = np.full(self.n_hosts, self.predicted_step_s)

    @classmethod
    def from_model(cls, cfg, workload, plan, mesh_shape, n_hosts: int,
                   model=None, **kw) -> "StragglerMonitor":
        """Build a monitor whose threshold is anchored to the cost model's
        predicted step time for (cfg × workload × plan × mesh).

        ``workload`` is any ``repro.core.workload.WorkloadLike`` — a
        ``WorkloadSpec``, a ``ShapeConfig``, or the deprecated phase
        string (``predict_plans`` normalizes).

        ``model`` is anything ``predictor.resolve_model`` accepts: None (the
        analytic v5e seed), a registry device name, or a ``LinearCostModel``.

        The threshold is a pure step-time scalar, so it goes through the
        same batched engine as plan search (``predictor.predict_plans`` →
        ``core.planspace``) rather than the heavier ``predict_step``
        (which also assembles the per-property breakdown and MFU).
        Scoring passes the module's ``exprops.BasisCache``, so re-anchoring
        a monitor after a mesh/shape delta (e.g. post-``elastic.replan``)
        recomputes only the basis columns the delta touches.
        """
        from repro.core import predictor  # runtime sits above core
        secs = predictor.predict_plans(cfg, workload, [plan], mesh_shape,
                                       model, cache=_BASIS_CACHE)
        return cls(n_hosts=n_hosts, predicted_step_s=float(secs[0]), **kw)

    def threshold(self) -> float:
        return self.k * max(self.predicted_step_s,
                            float(np.median(self._state)))

    def reanchor(self, predicted_step_s: float) -> None:
        """Move the threshold anchor to a new predicted step time.

        Called after an online-calibration refit (``calibration/online.py``)
        so the straggler threshold tracks the refit model instead of the
        diverged one; the per-host EWMA state is kept — observed behavior
        didn't change, the model of it did."""
        self.predicted_step_s = float(predicted_step_s)

    def observe(self, step: int, host_times_s) -> List[StragglerEvent]:
        """Feed one step's per-host times; returns new straggler events."""
        t = np.asarray(host_times_s, dtype=np.float64)
        assert t.shape == (self.n_hosts,)
        self._state = self.ewma * self._state + (1 - self.ewma) * t
        thr = self.threshold()
        new = []
        for h in np.nonzero(self._state > thr)[0]:
            ev = StragglerEvent(step, int(h), float(self._state[h]), thr,
                                self.policy)
            new.append(ev)
            _STRAGGLER_EVENTS.inc(1, action=self.policy)
        self.events.extend(new)
        return new

    def healthy_mask(self) -> np.ndarray:
        return self._state <= self.threshold()

    def rescale_weight(self) -> float:
        """Gradient rescale for skip-and-rescale: N / N_healthy."""
        h = int(self.healthy_mask().sum())
        return self.n_hosts / max(h, 1)
