"""Batched decode server: continuous batching over fixed decode slots.

A fixed (B, max_len) KV/SSM state is allocated once; finished sequences
free their slot, which is refilled from the request queue (prefill of the
new prompt writes into that slot's cache rows).  This is the standard
slot-based continuous-batching layout adapted to JAX's static shapes:
the *shapes* never change, only slot occupancy masks do.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer
from repro.runtime import steps


@dataclass
class Request:
    rid: int
    prompt: np.ndarray        # (S,) int32
    max_new: int = 32
    out: List[int] = field(default_factory=list)
    done: bool = False


class DecodeServer:
    def __init__(self, cfg: ArchConfig, params, *, slots: int = 4,
                 max_len: int = 512, eos_id: int = 0, seed: int = 0,
                 calibrator=None):
        assert cfg.n_input_codebooks == 1, "codebook serving via examples/"
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.rng = jax.random.PRNGKey(seed)
        self.state = transformer.init_decode_state(cfg, slots, max_len)
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self.remaining = np.zeros(slots, np.int32)

        self._decode = jax.jit(
            lambda p, s, t: transformer.decode_step(p, cfg, s, t))

        # ---- online calibration: feed per-iteration decode timings ----
        self.calibrator = calibrator
        self._decode_pv = None
        if calibrator is not None:
            from repro.configs.base import ShapeConfig
            from repro.core import predictor
            from repro.distributed.plan import Plan
            live = ShapeConfig("decode_live", max_len, slots, "decode")
            self._decode_pv = predictor.plan_property_vector(
                cfg, live, Plan(dp_axes=(), tp_axis=None, fsdp=False,
                                sequence_parallel=False), {"data": 1})

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _prefill_slot(self, slot: int, req: Request) -> None:
        """Feed the prompt token-by-token into this slot's cache rows.

        (A production server prefills with one chunked forward; the decode
        loop here is the clear-and-correct path for the CPU example, and
        prefill_step covers the fast path in the dry-run/bench.)"""
        for t in req.prompt:
            tok = np.zeros((self.slots, 1), np.int32)
            tok[slot, 0] = t
            logits, self.state = self._decode(
                self.params, self.state, jnp.asarray(tok))
        self.active[slot] = req
        self.remaining[slot] = req.max_new

    def _refill(self) -> None:
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                self._prefill_slot(s, self.queue.pop(0))

    def step(self) -> None:
        """One decode iteration across all occupied slots."""
        tok = np.zeros((self.slots, 1), np.int32)
        for s, req in enumerate(self.active):
            if req is not None:
                tok[s, 0] = req.out[-1] if req.out else req.prompt[-1]
        t0 = time.perf_counter()
        logits, self.state = self._decode(self.params, self.state,
                                          jnp.asarray(tok))
        if self.calibrator is not None:
            jax.block_until_ready(logits)
            self.calibrator.observe(self._decode_pv,
                                    time.perf_counter() - t0, tag="decode")
        self.rng, sub = jax.random.split(self.rng)
        nxt = np.asarray(jax.random.categorical(
            sub, jnp.asarray(logits[:, -1], jnp.float32), axis=-1))
        for s, req in enumerate(self.active):
            if req is None:
                continue
            t = int(nxt[s])
            req.out.append(t)
            self.remaining[s] -= 1
            if t == self.eos_id or self.remaining[s] <= 0:
                req.done = True
                self.active[s] = None

    def run(self, max_iters: int = 10_000) -> List[Request]:
        """Serve until queue + slots drain; returns completed requests."""
        done: List[Request] = []
        pending = lambda: self.queue or any(self.active)
        it = 0
        while pending() and it < max_iters:
            self._refill()
            before = [r for r in self.active if r]
            self.step()
            done.extend(r for r in before if r.done)
            it += 1
        return done
