"""Batched decode server: continuous batching over fixed decode slots,
with cost-model-informed admission.

A fixed (B, max_len) KV/SSM state is allocated once; finished sequences
free their slot, which is refilled from the request queue (prefill of the
new prompt writes into that slot's cache rows).  This is the standard
slot-based continuous-batching layout adapted to JAX's static shapes:
the *shapes* never change, only slot occupancy masks do.

Admission is where the unified cost model pays off at serving time: an
``AdmissionScorer`` compiles TWO fused basis programs once —

  * the decode-iteration program for ``WorkloadSpec(phase="decode",
    active_slots=…, cache_tokens=…)``, whose occupancy (``AS``) and
    context-load (``CT``) free variables rescore a whole sweep of
    candidate admissions as array ops, and
  * the prefill program, vectorized over prompt length ``S``,

and every refill decision scores `prefill + remaining_tokens ×
marginal-decode-cost` per queued candidate through one GEMV each.  The
``admission="model"`` policy admits the argmin (shortest-predicted-job
first); ``admission="fifo"`` keeps the arrival-order baseline.
``simulate_serving`` runs both policies through a discrete-event replay
of the model's own predictions, so the win is demonstrable without
hardware.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer
from repro.obs import metrics as _obs_metrics
from repro.obs import report as _obs_report
from repro.obs import trace as _obs_trace
from repro.runtime import steps

_ADMISSIONS = _obs_metrics.REGISTRY.counter(
    "repro_admission_decisions_total",
    "admission outcomes at slot refill, by policy and outcome "
    "(admit / slo_defer)")
_SLO_VIOLATIONS = _obs_metrics.REGISTRY.counter(
    "repro_slo_violations_total",
    "measured decode iterations that exceeded the decode-latency SLO")
_DECODE_SECONDS = _obs_metrics.REGISTRY.histogram(
    "repro_decode_step_seconds", "measured decode-iteration wall seconds")


@dataclass
class Request:
    rid: int
    prompt: np.ndarray        # (S,) int32
    max_new: int = 32
    out: List[int] = field(default_factory=list)
    done: bool = False
    # --- supervised-degradation bookkeeping (runtime/supervisor.py) ---
    shed: bool = False                      # dropped to preserve the SLO
    retry_after_s: Optional[float] = None   # stamped when shed
    evictions: int = 0                      # slot evictions survived


class AdmissionScorer:
    """Scores admission candidates through the fused step programs.

    Compiled once per (cfg × slot geometry); after that every call is a
    basis-program GEMV over array environments — microseconds per sweep,
    cheap enough to run inside the serving loop on every refill.

    Single-host serving (no collectives): a cell's seconds are the fused
    step score divided over ``n_dev`` plus the model's per-dispatch
    constant, exactly the ``planspace.scores`` composition with the
    collective term dropped (DP = TP = 1 ⇒ zero collective bytes).
    """

    def __init__(self, cfg: ArchConfig, *, slots: int, max_len: int,
                 model=None, n_dev: int = 1):
        from repro.core import predictor
        from repro.core import properties as props
        from repro.core import workload as wl
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.n_dev = max(int(n_dev), 1)
        self.model = predictor.resolve_model(model)
        self._w1 = 0.0
        for k, w in zip(self.model.keys, self.model.weights):
            if k == props.CONST1:
                self._w1 = float(w)
        # occupancy-refined decode spec: AS/CT become free variables of the
        # compiled program (structure ('decode','ct','as')); the env values
        # set here are placeholders — score calls pin them per candidate
        decode = wl.WorkloadSpec(
            phase="decode", global_batch=slots, seq_len=max_len,
            active_slots=0, cache_tokens=0.0, name="admission_decode")
        self._decode_prog = predictor.step_program(cfg, decode)
        prefill = wl.WorkloadSpec(
            phase="prefill", global_batch=1, seq_len=max_len,
            name="admission_prefill")
        self._prefill_prog = predictor.step_program(cfg, prefill)

    # -- primitives --------------------------------------------------------
    def prefill_seconds(self, prompt_lens) -> np.ndarray:
        """Predicted seconds to prefill one prompt of each given length
        (vectorized over ``S``)."""
        lens = np.asarray(prompt_lens, dtype=np.float64)
        env = {"B": 1.0, "S": lens, "M": 1.0}
        s = np.asarray(self._prefill_prog.score(env, self.model),
                       dtype=np.float64)
        return self._w1 + np.broadcast_to(s, lens.shape) / self.n_dev

    def decode_step_seconds(self, active, cache_tokens) -> np.ndarray:
        """Predicted seconds of one decode iteration at the given slot
        occupancy (``AS``) and total cached context (``CT``) — both may be
        arrays (one entry per candidate admission)."""
        a = np.asarray(active, dtype=np.float64)
        ct = np.asarray(cache_tokens, dtype=np.float64)
        a, ct = np.broadcast_arrays(a, ct)
        env = {"B": float(self.slots), "S": float(self.max_len), "M": 1.0,
               "AS": a, "CT": ct, "SL": 1.0, "MI": 1.0}
        s = np.asarray(self._decode_prog.score(env, self.model),
                       dtype=np.float64)
        return self._w1 + np.broadcast_to(s, a.shape) / self.n_dev

    # -- the admission decision -------------------------------------------
    def admission_scores(self, prompt_lens, remaining_tokens, *,
                         active: int, cache_tokens: float) -> Dict[str, np.ndarray]:
        """Score each queued candidate for the next free slot.

        score_i = prefill(len_i) + remaining_i × Δdecode_i, where Δdecode_i
        is the marginal per-iteration cost of running with this candidate
        resident (occupancy +1, context +min(len_i, window)) over the
        current occupancy — i.e. the predicted serving time this admission
        ADDS.  Argmin is shortest-predicted-job-first.
        """
        lens = np.asarray(prompt_lens, dtype=np.float64)
        rem = np.asarray(remaining_tokens, dtype=np.float64)
        pf = self.prefill_seconds(lens)
        win = self.cfg.sliding_window
        ctx = np.minimum(lens, win) if win is not None else lens
        base = self.decode_step_seconds(active, cache_tokens)
        with_c = self.decode_step_seconds(active + 1, cache_tokens + ctx)
        delta = np.maximum(with_c - base, 0.0)
        return {"prefill_s": pf, "decode_delta_s": delta,
                "score_s": pf + rem * delta}


def _context_cap(cfg: ArchConfig, max_len: int) -> int:
    return min(max_len, cfg.sliding_window) if cfg.sliding_window \
        else max_len


class DecodeServer:
    def __init__(self, cfg: ArchConfig, params, *, slots: int = 4,
                 max_len: int = 512, eos_id: int = 0, seed: int = 0,
                 calibrator=None, admission: str = "fifo", model=None,
                 slo_decode_s: Optional[float] = None, injector=None):
        assert cfg.n_input_codebooks == 1, "codebook serving via examples/"
        if admission not in ("fifo", "model"):
            raise ValueError(f"admission must be 'fifo' or 'model', "
                             f"got {admission!r}")
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.rng = jax.random.PRNGKey(seed)
        self.state = transformer.init_decode_state(cfg, slots, max_len)
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self.remaining = np.zeros(slots, np.int32)
        self._ctx = np.zeros(slots, np.int64)   # cached tokens per slot
        self.injector = injector                # FaultInjector or None
        self._iters = 0                         # decode iterations served

        self._decode = jax.jit(
            lambda p, s, t: transformer.decode_step(p, cfg, s, t))

        # ---- model-informed admission ----
        self.admission = admission
        self.slo_decode_s = slo_decode_s
        self.scorer: Optional[AdmissionScorer] = None
        if admission == "model" or slo_decode_s is not None:
            self.scorer = AdmissionScorer(cfg, slots=slots, max_len=max_len,
                                          model=model)

        # ---- online calibration: feed per-iteration decode timings ----
        self.calibrator = calibrator
        self._decode_pv = None
        if calibrator is not None:
            from repro.core import predictor
            from repro.core.workload import WorkloadSpec
            from repro.distributed.plan import Plan
            live = WorkloadSpec(phase="decode", global_batch=slots,
                                seq_len=max_len, name="decode_live")
            self._decode_pv = predictor.plan_property_vector(
                cfg, live, Plan(dp_axes=(), tp_axis=None, fsdp=False,
                                sequence_parallel=False), {"data": 1})

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _cache_tokens(self) -> float:
        """Total context tokens the next decode iteration streams — per
        occupied slot, capped at the attention window (``CT``'s meaning)."""
        cap = _context_cap(self.cfg, self.max_len)
        return float(np.minimum(self._ctx, cap)
                     [[r is not None for r in self.active]].sum())

    def _n_active(self) -> int:
        return sum(r is not None for r in self.active)

    def _prefill_slot(self, slot: int, req: Request) -> None:
        """Feed the prompt token-by-token into this slot's cache rows.

        (A production server prefills with one chunked forward; the decode
        loop here is the clear-and-correct path for the CPU example, and
        prefill_step covers the fast path in the dry-run/bench.)"""
        tracer = _obs_trace.get_tracer()
        pred = None
        if tracer.enabled and self.scorer is not None:
            pred = float(self.scorer.prefill_seconds([len(req.prompt)])[0])
        with tracer.span("prefill", predicted_s=pred, rid=req.rid,
                         plen=len(req.prompt), slot=slot):
            # re-admission after a supervisor eviction resumes from the
            # generated prefix: feed prompt + already-produced tokens, owe
            # only the still-missing ones
            for t in list(req.prompt) + list(req.out):
                tok = np.zeros((self.slots, 1), np.int32)
                tok[slot, 0] = t
                logits, self.state = self._decode(
                    self.params, self.state, jnp.asarray(tok))
        self.active[slot] = req
        self.remaining[slot] = req.max_new - len(req.out)
        self._ctx[slot] = len(req.prompt) + len(req.out)

    def evict_slot(self, slot: int) -> Optional[Request]:
        """Evict ``slot``'s request back to the FRONT of the queue (it has
        seniority) — the supervisor's degradation primitive.  The request
        keeps its generated prefix and resumes from it on re-admission."""
        req = self.active[slot]
        if req is None:
            return None
        req.evictions += 1
        self.active[slot] = None
        self.remaining[slot] = 0
        self._ctx[slot] = 0
        self.queue.insert(0, req)
        return req

    def _pick(self) -> Optional[int]:
        """Index into ``self.queue`` of the next request to admit, or None
        to defer admission this iteration (SLO guard)."""
        if self.admission == "fifo" or self.scorer is None:
            if not self.queue:
                return None
            _ADMISSIONS.inc(1, policy="fifo", outcome="admit")
            return 0
        if not self.queue:
            return None
        active, ct = self._n_active(), self._cache_tokens()
        sc = self.scorer.admission_scores(
            [len(r.prompt) for r in self.queue],
            [r.max_new for r in self.queue],
            active=active, cache_tokens=ct)
        i = int(np.argmin(sc["score_s"]))
        if self.slo_decode_s is not None and active > 0:
            cap = _context_cap(self.cfg, self.max_len)
            nxt = self.scorer.decode_step_seconds(
                active + 1, ct + min(len(self.queue[i].prompt), cap))
            if float(nxt) > self.slo_decode_s:
                _ADMISSIONS.inc(1, policy="model", outcome="slo_defer")
                _obs_trace.get_tracer().instant(
                    "slo_defer", rid=self.queue[i].rid,
                    predicted_next_s=float(nxt), slo_s=self.slo_decode_s)
                return None     # admitting would break the decode SLO
        req = self.queue[i]
        _ADMISSIONS.inc(1, policy="model", outcome="admit")
        _obs_report.emit("admit", {
            "rid": req.rid, "plen": len(req.prompt),
            "pred_prefill": f"{sc['prefill_s'][i]*1e3:.3f}ms",
            "decode_delta": f"{sc['decode_delta_s'][i]*1e6:.3f}us",
            "score": f"{sc['score_s'][i]*1e3:.3f}ms", "policy": "model"})
        return i

    def _refill(self) -> None:
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                i = self._pick()
                if i is None:
                    break
                self._prefill_slot(s, self.queue.pop(i))

    def step(self) -> float:
        """One decode iteration across all occupied slots; returns the
        measured (injector-perturbed, when armed) wall seconds — the
        supervisor's watchdog currency."""
        tok = np.zeros((self.slots, 1), np.int32)
        for s, req in enumerate(self.active):
            if req is not None:
                tok[s, 0] = req.out[-1] if req.out else req.prompt[-1]
        tracer = _obs_trace.get_tracer()
        pred = None
        active = self._n_active()
        if tracer.enabled and self.scorer is not None and active:
            pred = float(self.scorer.decode_step_seconds(
                active, self._cache_tokens()))
        t0 = time.perf_counter()
        with tracer.span("decode_step", predicted_s=pred, active=active):
            logits, self.state = self._decode(self.params, self.state,
                                              jnp.asarray(tok))
            if self.calibrator is not None or tracer.enabled \
                    or self.slo_decode_s is not None:
                jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        if self.injector is not None:
            dt = self.injector.perturb_decode_time(self._iters, dt)
        self._iters += 1
        _DECODE_SECONDS.observe(dt)
        if self.slo_decode_s is not None and active \
                and dt > self.slo_decode_s:
            _SLO_VIOLATIONS.inc()
        if self.calibrator is not None:
            self.calibrator.observe(self._decode_pv, dt, tag="decode",
                                    phase="decode")
        self.rng, sub = jax.random.split(self.rng)
        nxt = np.asarray(jax.random.categorical(
            sub, jnp.asarray(logits[:, -1], jnp.float32), axis=-1))
        for s, req in enumerate(self.active):
            if req is None:
                continue
            t = int(nxt[s])
            req.out.append(t)
            self.remaining[s] -= 1
            self._ctx[s] += 1
            if t == self.eos_id or self.remaining[s] <= 0:
                req.done = True
                self.active[s] = None
                self._ctx[s] = 0
        return dt

    def run(self, max_iters: int = 10_000) -> List[Request]:
        """Serve until queue + slots drain; returns completed requests."""
        done: List[Request] = []
        pending = lambda: self.queue or any(self.active)
        it = 0
        while pending() and it < max_iters:
            self._refill()
            before = [r for r in self.active if r]
            self.step()
            done.extend(r for r in before if r.done)
            it += 1
        return done


# ---------------------------------------------------------------------------
# Discrete-event serving simulation — the admission policies compared under
# the cost model's own physics (no hardware, no weights, deterministic)
# ---------------------------------------------------------------------------


def simulate_serving(cfg: ArchConfig, prompt_lens: Sequence[int],
                     max_new: int = 32, *, slots: int = 4,
                     max_len: int = 512, policy: str = "model",
                     model=None, scorer: Optional[AdmissionScorer] = None,
                     seed: int = 0, noise: float = 0.0
                     ) -> Dict[str, object]:
    """Replay the slot server's schedule with the scorer's predictions as
    the clock: prefills serialize (the example server feeds prompts through
    the decode step), decode iterations cost ``decode_step_seconds`` at the
    instantaneous (occupancy, context) point.  All requests arrive at t=0,
    so a request's completion time IS its latency and the policies differ
    only in admission order — exactly the decision the scorer ranks.

    Returns mean/max latency, makespan and the admission order; run with
    ``policy="model"`` and ``policy="fifo"`` (sharing one ``scorer``) to
    compare.

    ``seed``/``noise`` make perturbed replays deterministic (ISSUE 9
    satellite): with ``noise > 0`` every event duration is scaled by
    ``exp(noise · z)``, z standard normal from ``default_rng(seed)`` —
    same seed, same trajectory, every CI run.  ``noise=0`` (default) is
    the exact predicted-time replay, bit-identical to the pre-seed
    behavior.
    """
    if policy not in ("fifo", "model"):
        raise ValueError(f"policy must be 'fifo' or 'model', got {policy!r}")
    scorer = scorer or AdmissionScorer(cfg, slots=slots, max_len=max_len,
                                       model=model)
    rng = np.random.default_rng(seed)
    jit = (lambda: float(np.exp(noise * rng.standard_normal()))) \
        if noise > 0.0 else (lambda: 1.0)
    cap = _context_cap(cfg, max_len)
    queue = list(range(len(prompt_lens)))          # rids in arrival order
    lens = [int(l) for l in prompt_lens]
    slot_rid = [None] * slots
    slot_rem = np.zeros(slots, np.int64)
    slot_ctx = np.zeros(slots, np.int64)
    t = 0.0
    latency: Dict[int, float] = {}
    order: List[int] = []

    def occupancy():
        act = [s for s in range(slots) if slot_rid[s] is not None]
        return len(act), float(np.minimum(slot_ctx[act], cap).sum())

    while queue or any(r is not None for r in slot_rid):
        for s in range(slots):
            if slot_rid[s] is not None or not queue:
                continue
            if policy == "fifo":
                i = 0
            else:
                active, ct = occupancy()
                sc = scorer.admission_scores(
                    [lens[r] for r in queue], [max_new] * len(queue),
                    active=active, cache_tokens=ct)
                i = int(np.argmin(sc["score_s"]))
            rid = queue.pop(i)
            t += float(scorer.prefill_seconds([lens[rid]])[0]) * jit()
            slot_rid[s], slot_rem[s], slot_ctx[s] = rid, max_new, lens[rid]
            order.append(rid)
        active, ct = occupancy()
        if active == 0:
            break
        t += float(scorer.decode_step_seconds(active, ct)) * jit()
        for s in range(slots):
            if slot_rid[s] is None:
                continue
            slot_rem[s] -= 1
            slot_ctx[s] += 1
            if slot_rem[s] <= 0:
                latency[slot_rid[s]] = t
                slot_rid[s] = None
                slot_ctx[s] = 0

    lat = np.asarray([latency[r] for r in sorted(latency)])
    return {"policy": policy, "order": order,
            "mean_latency_s": float(lat.mean()) if len(lat) else 0.0,
            "max_latency_s": float(lat.max()) if len(lat) else 0.0,
            "makespan_s": t, "n_done": len(lat)}
