"""Trainer: the fault-tolerant training loop.

Wires together data pipeline → train_step → async checkpointing →
straggler monitor, with resume-from-latest on construction, so a restart
after preemption (or an elastic re-plan) continues exactly where the dead
run stopped: the data pipeline is addressed by the checkpointed step and
the RNG by a (seed, step) fold — no iterator state to recover.

With ``online_calibrate`` the per-step ``time.perf_counter`` timings also
feed an ``OnlineCalibrator`` (``calibration/online.py``): each step
records (the step's property vector, measured seconds) into the telemetry
sink, the streaming RLS tracks the fit, and a drift event triggers a
refit + straggler-threshold re-anchor.  A ``[calib]`` report line (sample
counts, windowed relative error, drift status, refit epochs) prints every
``log_every`` steps.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.configs.base import ArchConfig, ShapeConfig
from repro.data.pipeline import DataConfig, PackedLoader
from repro.distributed.plan import Plan
from repro.models import transformer
from repro.obs import metrics as _obs_metrics
from repro.obs import report as _obs_report
from repro.obs import trace as _obs_trace
from repro.optim import optimizers as opt
from repro.runtime import steps
from repro.runtime.straggler import StragglerMonitor

_STEP_SECONDS = _obs_metrics.REGISTRY.histogram(
    "repro_train_step_seconds", "measured trainer step wall seconds")


@dataclass
class TrainerConfig:
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep_ckpts: int = 3
    log_every: int = 10
    seed: int = 0
    lr: float = 3e-4
    warmup: int = 20
    total_steps: int = 1000
    async_ckpt: bool = True
    save_on_exit: bool = True  # False simulates preemption mid-interval
    # --- online calibration (calibration/online.py) ---
    online_calibrate: bool = False
    calib_device: Optional[str] = None      # registry name for refit models
    calib_registry: Optional[str] = None    # registry dir override
    calib_auto_register: bool = False       # write refits into the registry


class Trainer:
    def __init__(self, cfg: ArchConfig, data_cfg: DataConfig,
                 tc: TrainerConfig, plan: Optional[Plan] = None,
                 predicted_step_s: Optional[float] = None,
                 calibrator=None, injector=None):
        self.cfg = cfg
        self.tc = tc
        # optional FaultInjector (runtime/faults.py); every hook below is
        # behind `is not None`, so the hot path is untouched when chaos
        # is off
        self.injector = injector
        self.loader = PackedLoader(data_cfg)
        self.optimizer = opt.get_optimizer(cfg.optimizer)
        lr = opt.warmup_cosine(tc.lr, tc.warmup, tc.total_steps)
        plan = plan or Plan(dp_axes=())
        self.step_fn = jax.jit(steps.make_train_step(
            cfg, self.optimizer, plan, lr_schedule=lr))
        self.state = steps.init_train_state(
            cfg, jax.random.PRNGKey(tc.seed), self.optimizer)
        self.monitor = StragglerMonitor(
            n_hosts=1, predicted_step_s=predicted_step_s or 1.0)
        self.ckpt = (store.AsyncCheckpointer(tc.ckpt_dir, tc.keep_ckpts)
                     if tc.ckpt_dir and tc.async_ckpt else None)
        self.history: List[Dict[str, float]] = []

        # ---- online calibration ----
        self.calibrator = calibrator
        if self.calibrator is None and tc.online_calibrate:
            from repro.calibration.online import OnlineCalibrator
            self.calibrator = OnlineCalibrator(
                None, device=tc.calib_device or f"{cfg.name}-online",
                registry_dir=tc.calib_registry,
                auto_register=tc.calib_auto_register)
        self._step_pv = None
        if self.calibrator is not None:
            # the live step's property vector: this trainer runs the whole
            # batch on the local substrate, so the pv is the single-device
            # cell of (cfg × the ACTUAL data workload × the jitted plan)
            from repro.core import predictor
            from repro.core.workload import WorkloadSpec
            live = WorkloadSpec(phase="train",
                                global_batch=data_cfg.global_batch,
                                seq_len=data_cfg.seq_len,
                                name="train_live")
            self._step_pv = predictor.plan_property_vector(
                cfg, live, plan, {"data": 1})

        # ---- resume (newest VALID checkpoint: an invalid one — e.g. a
        # write the preemption itself interrupted — is quarantined and
        # the next-older step restored instead of crashing the restart)
        if tc.ckpt_dir:
            restored = store.restore_latest_valid(tc.ckpt_dir, self.state)
            if restored is not None:
                self.state, _, latest = restored
                _obs_report.emit("trainer", text=f"resumed from step "
                                                 f"{latest}")

    # ------------------------------------------------------------------
    @property
    def step(self) -> int:
        return int(self.state.step)

    def _save(self, blocking: bool = False):
        if not self.tc.ckpt_dir:
            return
        if self.ckpt is not None and not blocking:
            self.ckpt.save(self.step, self.state)
        else:
            if self.ckpt is not None:
                self.ckpt.wait()
            store.save(self.tc.ckpt_dir, self.step, self.state)
            store.prune(self.tc.ckpt_dir, self.tc.keep_ckpts)

    def train(self, n_steps: int,
              on_metrics: Optional[Callable[[int, Dict], None]] = None
              ) -> List[Dict[str, float]]:
        tracer = _obs_trace.get_tracer()
        for _ in range(n_steps):
            step = self.step
            if self.injector is not None:
                # may corrupt state files or raise DeviceLossError — BEFORE
                # the step runs, so the supervisor resumes at exactly this
                # step and batch semantics stay exact
                self.injector.step_begin(step)
            batch = {k: jnp.asarray(v)
                     for k, v in self.loader.batch(step).items()}
            # the model's prediction for THIS step — the straggler monitor
            # carries it (re-anchored on every refit), so the span's
            # predicted overlay tracks the live model, not the launch one
            pred_s = self.monitor.predicted_step_s
            t0 = time.perf_counter()
            with tracer.span("train_step", predicted_s=pred_s, step=step):
                self.state, metrics = self.step_fn(self.state, batch)
                jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            if self.injector is not None:
                # scheduled slowdowns/spikes scale the OBSERVED time: the
                # monitor, watchdog, histogram and calibrator all see the
                # same perturbed measurement, as they would a real straggler
                dt = self.injector.perturb_step_time(step, dt)
            _STEP_SECONDS.observe(dt)
            self.monitor.observe(step, [dt])
            if self.calibrator is not None:
                sample = dt
                if self.injector is not None:
                    sample = self.injector.perturb_telemetry(step, dt)
                ev = self.calibrator.observe(self._step_pv, sample,
                                             step=step,
                                             tag="train", phase="train")
                if ev is not None:
                    # refit already happened inside observe(); re-anchor the
                    # straggler threshold to the refit model's prediction
                    self.monitor.reanchor(
                        self.calibrator.model.predict(self._step_pv))
                    _obs_report.emit(
                        "calib",
                        text=f"drift detected at step {step} "
                             f"(direction={ev.direction}, onset seq "
                             f"{ev.onset_seq}): refit epoch "
                             f"{self.calibrator.refits}, revision "
                             f"{self.calibrator.revision}")

            m = {"step": step, "loss": float(metrics["loss"]),
                 "grad_norm": float(metrics["grad_norm"]),
                 "lr": float(metrics["lr"]), "time_s": dt}
            self.history.append(m)
            if on_metrics:
                on_metrics(step, m)
            elif step % self.tc.log_every == 0:
                _obs_report.emit(
                    "trainer",
                    text=f"step {step:5d} loss {m['loss']:.4f} "
                         f"gnorm {m['grad_norm']:.3f} {dt*1e3:.0f}ms")
            if self.calibrator is not None \
                    and step % self.tc.log_every == 0:
                _obs_report.emit("calib",
                                 text=self.calibrator.report_line())
            if self.tc.ckpt_dir and (step + 1) % self.tc.ckpt_every == 0:
                self._save()
        if self.tc.ckpt_dir and self.tc.save_on_exit:
            self._save(blocking=True)
        elif self.ckpt is not None:
            self.ckpt.wait()
        return self.history
