"""Trainer: the fault-tolerant training loop.

Wires together data pipeline → train_step → async checkpointing →
straggler monitor, with resume-from-latest on construction, so a restart
after preemption (or an elastic re-plan) continues exactly where the dead
run stopped: the data pipeline is addressed by the checkpointed step and
the RNG by a (seed, step) fold — no iterator state to recover.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.configs.base import ArchConfig
from repro.data.pipeline import DataConfig, PackedLoader
from repro.distributed.plan import Plan
from repro.models import transformer
from repro.optim import optimizers as opt
from repro.runtime import steps
from repro.runtime.straggler import StragglerMonitor


@dataclass
class TrainerConfig:
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep_ckpts: int = 3
    log_every: int = 10
    seed: int = 0
    lr: float = 3e-4
    warmup: int = 20
    total_steps: int = 1000
    async_ckpt: bool = True
    save_on_exit: bool = True  # False simulates preemption mid-interval


class Trainer:
    def __init__(self, cfg: ArchConfig, data_cfg: DataConfig,
                 tc: TrainerConfig, plan: Optional[Plan] = None,
                 predicted_step_s: Optional[float] = None):
        self.cfg = cfg
        self.tc = tc
        self.loader = PackedLoader(data_cfg)
        self.optimizer = opt.get_optimizer(cfg.optimizer)
        lr = opt.warmup_cosine(tc.lr, tc.warmup, tc.total_steps)
        self.step_fn = jax.jit(steps.make_train_step(
            cfg, self.optimizer, plan or Plan(dp_axes=()), lr_schedule=lr))
        self.state = steps.init_train_state(
            cfg, jax.random.PRNGKey(tc.seed), self.optimizer)
        self.monitor = StragglerMonitor(
            n_hosts=1, predicted_step_s=predicted_step_s or 1.0)
        self.ckpt = (store.AsyncCheckpointer(tc.ckpt_dir, tc.keep_ckpts)
                     if tc.ckpt_dir and tc.async_ckpt else None)
        self.history: List[Dict[str, float]] = []

        # ---- resume ----
        if tc.ckpt_dir:
            latest = store.latest_step(tc.ckpt_dir)
            if latest is not None:
                self.state, _ = store.restore(tc.ckpt_dir, self.state,
                                              latest)[0], None
                print(f"[trainer] resumed from step {latest}")

    # ------------------------------------------------------------------
    @property
    def step(self) -> int:
        return int(self.state.step)

    def _save(self, blocking: bool = False):
        if not self.tc.ckpt_dir:
            return
        if self.ckpt is not None and not blocking:
            self.ckpt.save(self.step, self.state)
        else:
            if self.ckpt is not None:
                self.ckpt.wait()
            store.save(self.tc.ckpt_dir, self.step, self.state)
            store.prune(self.tc.ckpt_dir, self.tc.keep_ckpts)

    def train(self, n_steps: int,
              on_metrics: Optional[Callable[[int, Dict], None]] = None
              ) -> List[Dict[str, float]]:
        for _ in range(n_steps):
            step = self.step
            batch = {k: jnp.asarray(v)
                     for k, v in self.loader.batch(step).items()}
            t0 = time.perf_counter()
            self.state, metrics = self.step_fn(self.state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.monitor.observe(step, [dt])

            m = {"step": step, "loss": float(metrics["loss"]),
                 "grad_norm": float(metrics["grad_norm"]),
                 "lr": float(metrics["lr"]), "time_s": dt}
            self.history.append(m)
            if on_metrics:
                on_metrics(step, m)
            elif step % self.tc.log_every == 0:
                print(f"[trainer] step {step:5d} loss {m['loss']:.4f} "
                      f"gnorm {m['grad_norm']:.3f} {dt*1e3:.0f}ms")
            if self.tc.ckpt_dir and (step + 1) % self.tc.ckpt_every == 0:
                self._save()
        if self.tc.ckpt_dir and self.tc.save_on_exit:
            self._save(blocking=True)
        elif self.ckpt is not None:
            self.ckpt.wait()
        return self.history
