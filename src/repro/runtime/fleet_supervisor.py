"""FleetSupervisor: churn-tolerant supervision of a multi-job fleet.

Consumes fleet-scoped pool-churn events from the deterministic fault
injector (``runtime/faults.py``: ``pool_shrink`` / ``pool_grow`` /
pool-attributed ``device_loss``) and drives a **degradation ladder** over
the ``FleetAllocator``'s placements (``launch/fleet.py``):

  1. **warm incremental replan** — a job that still fits the shrunken
     pool rescores its (count × plan × mesh) space through the same
     per-(job, pool) ``BasisCache`` allocation warmed, so only the
     device-count-dependent basis columns recompute;
  2. **migrate** — a job the pool can no longer hold moves to the best
     other pool, cheapest-to-move first (checkpoint handoff bytes); a
     trainer-backed job rebuilds from ``restore_latest_valid`` and
     replays the steps since its last checkpoint with exact batch
     semantics (the loader is addressed by step);
  3. **shrink** — if no pool has room, lower-priority placements on the
     best candidate pool halve down (power-of-two, never below their
     ``min_devices``) to make room;
  4. **pause/shed** — when nothing frees enough devices the job pauses
     with a retry-after stamp and re-attempts placement periodically
     (and immediately on ``pool_grow``).

Voluntary moves (on ``pool_grow``) are **hysteresis-gated**: a job only
rebalances when the predicted step time improves by more than the
``hysteresis`` fraction AND its ``cooldown_steps`` have elapsed — repeated
churn cannot thrash placements (pinned in ``tests/test_fleet.py``).

Every decision is deterministic: same manifest + same ``FaultPlan`` seed
⇒ byte-identical ``history_json()``.  With an EMPTY plan the supervised
run's placements are identical to the bare allocator's (the fleet twin of
the empty-injector identity in ``tests/test_faults.py``).
"""
from __future__ import annotations

import json
import time
from typing import Callable, Dict, List, Optional

from repro.launch.fleet import (FleetAllocator, FleetAssignment, JobSpec,
                                Placement)
from repro.obs import metrics as _obs_metrics
from repro.obs import report as _obs_report
from repro.obs import trace as _obs_trace

_CHURN = _obs_metrics.REGISTRY.counter(
    "repro_fleet_churn_events_total",
    "pool-churn events the fleet supervisor consumed, by kind")
_REPLANS = _obs_metrics.REGISTRY.counter(
    "repro_fleet_replans_total",
    "fleet placement changes, by ladder action "
    "(replan|migrate|shrink|pause|resume|rebalance)")
_REPLAN_SECONDS = _obs_metrics.REGISTRY.histogram(
    "repro_fleet_replan_seconds",
    "wall seconds one churn event's ladder repair took (warm replans)")
_JOBS = _obs_metrics.REGISTRY.gauge(
    "repro_fleet_jobs", "fleet jobs by state (active|paused)")


# ---------------------------------------------------------------------------
# Job runners
# ---------------------------------------------------------------------------


class SimJobRunner:
    """Deterministic no-JAX runner: each tick records the placement it ran
    under — what the CLI chaos smoke and the byte-identical-history tests
    drive (real training is ``TrainerJobRunner``'s job)."""

    def __init__(self, job: JobSpec, target: Optional[int] = None):
        self.job = job
        self.target = target
        self.placement: Optional[Placement] = None
        self.ticks = 0
        self._history: List[Dict[str, object]] = []

    @classmethod
    def factory(cls, target: Optional[int] = None
                ) -> Callable[[JobSpec], "SimJobRunner"]:
        return lambda job: cls(job, target)

    def set_target(self, n: int) -> None:
        if self.target is None:
            self.target = n

    def bind(self, placement: Placement) -> None:
        self.placement = placement

    def tick(self, step: int) -> None:
        p = self.placement
        self._history.append({
            "step": self.ticks, "pool": p.pool, "devices": p.devices,
            "step_s": p.predicted_step_s})
        self.ticks += 1

    @property
    def done(self) -> bool:
        return self.target is not None and self.ticks >= self.target

    @property
    def history(self) -> List[Dict[str, object]]:
        return list(self._history)


class TrainerJobRunner:
    """A real training job under fleet supervision.

    ``trainer_factory(job, placement)`` builds a ``runtime.trainer.Trainer``
    for a placement; construction restores from the newest VALID checkpoint
    (``store.restore_latest_valid``), so a migration = drain the old
    trainer's async checkpointer, rebuild, and replay the steps since the
    last checkpoint — the loader is addressed by the checkpointed step, so
    the replayed batches are exactly the lost ones.  History merges
    last-write-wins by trainer step: after recovery it is step-for-step
    comparable to a fault-free run (the rtol 1e-5 contract)."""

    def __init__(self, job: JobSpec, trainer_factory,
                 target: Optional[int] = None):
        self.job = job
        self.target = target
        self._factory = trainer_factory
        self.trainer = None
        self.placement: Optional[Placement] = None
        self._history: Dict[int, Dict[str, float]] = {}

    @classmethod
    def factory(cls, trainer_factory, target: Optional[int] = None
                ) -> Callable[[JobSpec], "TrainerJobRunner"]:
        return lambda job: cls(job, trainer_factory, target)

    def set_target(self, n: int) -> None:
        if self.target is None:
            self.target = n

    def _drain(self) -> None:
        ckpt = getattr(self.trainer, "ckpt", None)
        if ckpt is not None:
            try:
                ckpt.wait()
            except Exception:
                pass   # an in-flight save error must not block the rebind

    def bind(self, placement: Placement) -> None:
        if self.trainer is not None:
            self._drain()
        self.placement = placement
        self.trainer = self._factory(self.job, placement)

    def tick(self, step: int) -> None:
        if self.done:
            return
        self.trainer.train(
            1, on_metrics=lambda s, m: self._history.__setitem__(s, m))

    @property
    def done(self) -> bool:
        return self.target is not None and self.trainer is not None \
            and int(self.trainer.step) >= self.target

    @property
    def history(self) -> List[Dict[str, float]]:
        return [self._history[k] for k in sorted(self._history)]

    def finish(self) -> None:
        self._drain()


# ---------------------------------------------------------------------------
# The supervisor
# ---------------------------------------------------------------------------


class FleetSupervisor:
    """Supervises a ``FleetAllocator`` assignment through pool churn.

    ``runner_factory(job) -> runner`` builds one runner per placed job
    (``SimJobRunner.factory()`` default).  ``injector`` is a
    ``runtime.faults.FaultInjector`` whose ``fleet_events(step)`` feeds the
    churn; None (or an empty plan) supervises without perturbing —
    placements then never change from the initial allocation."""

    def __init__(self, allocator: FleetAllocator, *,
                 injector=None,
                 runner_factory: Optional[Callable] = None,
                 hysteresis: float = 0.15,
                 cooldown_steps: int = 3,
                 retry_after_steps: int = 5,
                 assignment: Optional[FleetAssignment] = None):
        self.allocator = allocator
        self.injector = injector
        self.hysteresis = float(hysteresis)
        self.cooldown_steps = int(cooldown_steps)
        self.retry_after_steps = int(retry_after_steps)
        self.capacity: Dict[str, int] = {
            p.name: p.count for p in allocator.manifest.pools}
        self.assignment = assignment if assignment is not None \
            else allocator.allocate()
        factory = runner_factory or SimJobRunner.factory()
        self.runners = {name: factory(allocator.jobs[name])
                        for name in sorted(allocator.jobs)}
        for name, p in self.assignment.placements.items():
            self.runners[name].bind(p)
        self._paused_at: Dict[str, int] = {
            name: 0 for name in self.assignment.paused}
        self._last_move: Dict[str, int] = {}
        self.placement_history: List[Dict[str, object]] = []
        self.actions: Dict[str, int] = {}
        self._record(-1, "allocate")

    # -- ledger ------------------------------------------------------------
    def used(self, pool: str) -> int:
        return sum(p.devices for p in self.assignment.placements.values()
                   if p.pool == pool)

    def free_map(self) -> Dict[str, int]:
        return {name: self.capacity[name] - self.used(name)
                for name in sorted(self.capacity)}

    def _record(self, step: int, event: str) -> None:
        self.assignment.free = self.free_map()
        self.placement_history.append({
            "step": step, "event": event,
            "assignment": self.assignment.to_json_dict()})
        _JOBS.set(len(self.assignment.placements), state="active")
        _JOBS.set(len(self.assignment.paused), state="paused")

    def history_json(self) -> str:
        return json.dumps(self.placement_history, sort_keys=True, indent=1)

    def _act(self, action: str) -> None:
        self.actions[action] = self.actions.get(action, 0) + 1
        _REPLANS.inc(1, action=action)

    #: ladder action -> the past-tense report token the CI smoke greps
    _DONE = {"replan": "replanned", "migrate": "migrated",
             "shrink": "shrunk", "resume": "resumed",
             "rebalance": "rebalanced"}

    # -- the ladder --------------------------------------------------------
    def _replace(self, name: str, p: Placement, step: int,
                 action: str, detail: str) -> None:
        old = self.assignment.placements.get(name)
        self.assignment.placements[name] = p
        self.assignment.paused.pop(name, None)
        self._paused_at.pop(name, None)
        self.runners[name].bind(p)
        self._act(action)
        frm = f"{old.pool}:{old.devices}" if old else "<paused>"
        _obs_report.emit("fleet", {
            "step": step, "job": name, "action": self._DONE[action],
            "from": frm, "to": f"{p.pool}:{p.devices}",
            "pred_ms": f"{p.predicted_step_s * 1e3:.3f}"}, text=detail)

    def _pause(self, name: str, step: int, reason: str) -> None:
        self.assignment.placements.pop(name, None)
        self.assignment.paused[name] = reason
        self._paused_at[name] = step
        self._act("pause")
        _obs_report.emit("fleet", {
            "step": step, "job": name, "action": "paused",
            "reason": reason,
            "retry_after": step + self.retry_after_steps},
            text="shed until capacity returns")

    def _repair_pool(self, pool_name: str, step: int, kind: str) -> None:
        """Run the degradation ladder until ``pool_name`` fits its
        capacity.  Terminates: every rung strictly decreases the pool's
        used-device count (replan/migrate/pause all shed devices)."""
        t0 = time.perf_counter()
        cap = self.capacity[pool_name]
        on_pool = sorted(
            (n for n, p in self.assignment.placements.items()
             if p.pool == pool_name),
            key=lambda n: (-self.allocator.jobs[n].priority, n))
        summary: List[str] = []
        remaining = cap
        displaced: List[str] = []
        for name in on_pool:
            job = self.allocator.jobs[name]
            cur = self.assignment.placements[name]
            grant = self.allocator.candidate_counts(
                job, min(remaining, cur.devices))
            if not grant:
                displaced.append(name)
                continue
            if grant[0] == cur.devices:
                remaining -= cur.devices
                summary.append(f"{name} kept {cur.devices}")
                continue
            # rung 1: warm incremental replan inside the shrunken pool —
            # same (job, pool) BasisCache the allocation warmed
            p = self.allocator.score_job(
                job, self.allocator.pools[pool_name], grant[0])
            if p is None:
                displaced.append(name)
                continue
            remaining -= p.devices
            self._replace(name, p, step, "replan",
                          f"pool {pool_name} shrank; warm replan "
                          f"{cur.devices} -> {p.devices} devices")
            summary.append(f"{name} replanned {cur.devices}->{p.devices}")
        # rung 2: migrate displaced jobs, cheapest checkpoint handoff first
        for name in sorted(displaced,
                           key=lambda n: (self.allocator.jobs[n]
                                          .move_cost_bytes(), n)):
            job = self.allocator.jobs[name]
            cur = self.assignment.placements.pop(name)
            target = self.allocator.place_job(job, self.free_map(),
                                              exclude_pools=(pool_name,))
            if target is None and self._shrink_for(job, pool_name, step,
                                                   summary):
                target = self.allocator.place_job(
                    job, self.free_map(), exclude_pools=(pool_name,))
            if target is not None:
                self.assignment.placements[name] = cur  # for the from= log
                self._replace(name, target, step, "migrate",
                              f"pool {pool_name} cannot hold "
                              f"{job.min_devices}+ devices; checkpoint "
                              f"handoff and exact-batch replay")
                summary.append(f"{name} migrated -> {target.pool}")
            else:
                self._pause(name, step, f"churn:{pool_name}")
                summary.append(f"{name} paused")
        dt = time.perf_counter() - t0
        _REPLAN_SECONDS.observe(dt)
        _obs_trace.get_tracer().instant("fleet_replan", step=step,
                                        pool=pool_name, kind=kind,
                                        repair_s=dt)
        _obs_report.emit("fleet", {
            "step": step, "pool": pool_name, "cap": cap,
            "repair_ms": f"{dt * 1e3:.3f}"},
            text=f"replanned: {'; '.join(summary) or 'no jobs affected'}")

    def _shrink_for(self, job: JobSpec, exclude: str, step: int,
                    summary: List[str]) -> bool:
        """Rung 3: halve lower-priority placements (power-of-two, floored
        at their ``min_devices``) on the pool closest to fitting ``job``,
        until it has ``min_devices`` free.  Returns True if room opened."""
        candidates = sorted(
            (n for n in self.capacity if n != exclude),
            key=lambda n: (-(self.capacity[n] - self.used(n)), n))
        for pname in candidates:
            victims = sorted(
                (n for n, p in self.assignment.placements.items()
                 if p.pool == pname
                 and self.allocator.jobs[n].priority < job.priority),
                key=lambda n: (self.allocator.jobs[n].priority, n))
            for vname in victims:
                if self.capacity[pname] - self.used(pname) \
                        >= job.min_devices:
                    break
                vjob = self.allocator.jobs[vname]
                vcur = self.assignment.placements[vname]
                new_n = vcur.devices // 2
                if new_n < vjob.min_devices:
                    continue
                p = self.allocator.score_job(
                    vjob, self.allocator.pools[pname], new_n)
                if p is None:
                    continue
                self._replace(vname, p, step, "shrink",
                              f"making room on {pname} for higher-"
                              f"priority {job.name}")
                summary.append(f"{vname} shrunk {vcur.devices}->"
                               f"{p.devices}")
            if self.capacity[pname] - self.used(pname) >= job.min_devices:
                return True
        return False

    def _try_resume(self, step: int, on_grow: bool) -> None:
        """Resume paused jobs (priority-descending) whose retry-after
        elapsed — or immediately when a pool just grew."""
        paused = sorted(self.assignment.paused,
                        key=lambda n: (-self.allocator.jobs[n].priority, n))
        for name in paused:
            if not on_grow and step - self._paused_at.get(name, 0) \
                    < self.retry_after_steps:
                continue
            job = self.allocator.jobs[name]
            p = self.allocator.place_job(job, self.free_map())
            if p is not None:
                self._replace(name, p, step, "resume",
                              "capacity returned; resuming from latest "
                              "valid checkpoint")
            else:
                self._paused_at[name] = step   # re-stamp retry-after
                if on_grow:
                    _obs_report.emit("fleet", {
                        "step": step, "job": name, "action": "paused",
                        "retry_after": step + self.retry_after_steps},
                        text="still no room after pool_grow")

    def _rebalance(self, step: int) -> None:
        """Hysteresis-gated voluntary moves after a ``pool_grow``: a job
        relocates only for a > ``hysteresis`` fractional step-time win,
        at most once per ``cooldown_steps`` — churn cannot thrash."""
        for name in sorted(self.assignment.placements,
                           key=lambda n: (-self.allocator.jobs[n].priority,
                                          n)):
            if step - self._last_move.get(name, -10 ** 9) \
                    < self.cooldown_steps:
                continue
            cur = self.assignment.placements[name]
            free = self.free_map()
            free[cur.pool] += cur.devices   # its own devices come back
            best = self.allocator.place_job(self.allocator.jobs[name], free)
            if best is None or (best.pool == cur.pool
                                and best.devices == cur.devices):
                continue
            gain = (cur.predicted_step_s - best.predicted_step_s) \
                / cur.predicted_step_s
            if gain <= self.hysteresis:
                continue
            self._last_move[name] = step
            self._replace(name, best, step, "rebalance",
                          f"{gain * 100:.1f}% predicted win clears "
                          f"{self.hysteresis * 100:.0f}% hysteresis")

    # -- churn entry -------------------------------------------------------
    def _apply_event(self, fault, step: int) -> None:
        _CHURN.inc(1, kind=fault.kind)
        pool = fault.pool or self.allocator.manifest.pools[0].name
        if pool not in self.capacity:
            _obs_report.emit("fleet", {"step": step, "pool": pool},
                             text=f"ignoring {fault.kind} for unknown pool")
            return
        _obs_trace.get_tracer().instant("pool_churn", step=step,
                                        kind=fault.kind, pool=pool,
                                        count=fault.count)
        if fault.kind == "pool_grow":
            self.capacity[pool] += fault.count
            _obs_report.emit("fleet", {
                "step": step, "pool": pool, "event": "pool_grow",
                "cap": self.capacity[pool]}, text="capacity added")
            self._try_resume(step, on_grow=True)
            self._rebalance(step)
        else:   # pool_shrink, or device_loss attributed to a pool
            self.capacity[pool] = max(0, self.capacity[pool] - fault.count)
            _obs_report.emit("fleet", {
                "step": step, "pool": pool, "event": fault.kind,
                "cap": self.capacity[pool]}, text="capacity lost")
            if self.used(pool) > self.capacity[pool]:
                self._repair_pool(pool, step, fault.kind)
        self._record(step, f"{fault.kind}:{pool}")

    # -- main loop ---------------------------------------------------------
    def run(self, n_steps: int, drain: bool = True) -> FleetAssignment:
        """Tick every active job ``n_steps`` fleet steps, consuming churn
        events between ticks.  With ``drain`` the loop then keeps ticking
        (churn-free) until every runner reports done — a migrated
        trainer's checkpoint replay gets the extra ticks it needs to
        reach the same final step as a fault-free run."""
        for r in self.runners.values():
            if hasattr(r, "set_target"):
                r.set_target(n_steps)
        for step in range(n_steps):
            if self.injector is not None:
                for fault in self.injector.fleet_events(step):
                    self._apply_event(fault, step)
            if self._paused_at and self.retry_after_steps > 0:
                self._try_resume(step, on_grow=False)
            for name in sorted(self.assignment.placements):
                self.runners[name].tick(step)
        if drain:
            extra, budget = 0, max(4 * n_steps, 64)
            while extra < budget and any(
                    not getattr(self.runners[n], "done", True)
                    for n in self.assignment.placements):
                for name in sorted(self.assignment.placements):
                    r = self.runners[name]
                    if not getattr(r, "done", True):
                        r.tick(n_steps + extra)
                extra += 1
        for r in self.runners.values():
            if hasattr(r, "finish"):
                r.finish()
        self._record(n_steps, "final")
        return self.assignment

    def report(self) -> None:
        acts = ",".join(f"{k}={v}" for k, v in sorted(self.actions.items())) \
            or "none"
        churn = ",".join(f"{k}={v}" for k, v in sorted(
            (self.injector.counts() if self.injector else {}).items())) \
            or "none"
        _obs_report.emit("fleet", {
            "jobs": len(self.runners),
            "active": len(self.assignment.placements),
            "paused": len(self.assignment.paused),
            "actions": acts, "churn": churn},
            text="run complete")
