"""Supervised recovery: watchdogs, backoff, and the escalation ladder.

The ``Supervisor`` wraps a ``Trainer`` factory with the recovery loop the
paper's "rapid evaluation" property makes viable: when a step raises
``DeviceLossError`` (injected or real) or breaches its watchdog deadline
repeatedly, the supervisor sleeps a bounded exponentially-backed-off
delay, asks ``distributed/elastic.py`` for the best surviving mesh (a
microseconds-scale model query against the warm ``BasisCache``), rebuilds
the trainer — which resumes from the newest *valid* checkpoint — and
replays forward.  Exact global-batch semantics survive the failover
because the data pipeline is addressed by step and the RNG by a
(seed, step) fold: replayed steps recompute bit-identical batches.

Watchdog currency matches ``StragglerMonitor``: the deadline is
``k × max(model-predicted step seconds, median of recent measured
steps)`` — the prediction anchors the first steps, the median keeps the
deadline honest when the prediction is off (reduced-config CPU runs).
Breaches escalate a ladder, one rung per *consecutive* breach:

    1. **report** — emit a ``[supervisor]`` line + trace instant;
    2. **rescale** — widen the deadline (accept the new normal once);
    3. **replan** — kill the segment and fail over through
       ``elastic.replan`` (training) / shed-and-throttle (serving).

``ServingSupervisor`` is the serving twin: no replan target exists, so
degradation is graceful instead — evict the heaviest slot back to the
queue, throttle admissions for a few iterations, and shed queue overflow
with a ``retry_after_s`` stamp so the caller can come back (the
SLO-preserving behaviors from the LLMPerf regime).

Every recovery lands in ``repro_recovery_seconds`` (the MTTR histogram),
``repro_supervisor_recoveries_total{cause,action}``, and the final
``report()`` rollup.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.obs import metrics as _obs_metrics
from repro.obs import report as _obs_report
from repro.obs import trace as _obs_trace
from repro.runtime.faults import DeviceLossError, FaultInjector

__all__ = ["BackoffPolicy", "Watchdog", "WatchdogTimeout", "RecoveryEvent",
           "Supervisor", "ServingPolicy", "ServingSupervisor"]

_RECOVERIES = _obs_metrics.REGISTRY.counter(
    "repro_supervisor_recoveries_total",
    "completed supervised recoveries, by cause and action taken")
_ESCALATIONS = _obs_metrics.REGISTRY.counter(
    "repro_supervisor_escalations_total",
    "watchdog escalation-ladder rungs fired, by action")
_RECOVERY_SECONDS = _obs_metrics.REGISTRY.histogram(
    "repro_recovery_seconds",
    "wall seconds from failure detection to a resumed trainer (MTTR)")
_EVICTIONS = _obs_metrics.REGISTRY.counter(
    "repro_slots_evicted_total",
    "decode slots evicted back to the queue by the serving supervisor")
_SHED = _obs_metrics.REGISTRY.counter(
    "repro_requests_shed_total",
    "queued requests shed with retry-after to preserve the serving SLO")
_THROTTLED = _obs_metrics.REGISTRY.counter(
    "repro_admission_throttled_total",
    "serving iterations whose slot refill was throttled by the supervisor")


@dataclass
class BackoffPolicy:
    """Bounded exponential backoff with seeded jitter.

    delay(attempt) = min(base·factor^attempt, max) × (1 + jitter·u),
    u ~ Uniform[-1, 1) from a generator seeded at construction — chaos
    runs sleep the same schedule every time (ISSUE 9 satellite: explicit
    ``seed=``)."""

    base_s: float = 0.05
    factor: float = 2.0
    max_s: float = 2.0
    jitter: float = 0.5
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def delay(self, attempt: int) -> float:
        raw = min(self.base_s * self.factor ** max(attempt, 0), self.max_s)
        u = 2.0 * self._rng.random() - 1.0
        return max(raw * (1.0 + self.jitter * u), 0.0)

    def sequence(self, n: int) -> List[float]:
        """The first ``n`` delays of a FRESH policy with this seed (pure —
        does not advance this instance's generator)."""
        probe = BackoffPolicy(self.base_s, self.factor, self.max_s,
                              self.jitter, self.seed)
        return [probe.delay(i) for i in range(n)]


class WatchdogTimeout(RuntimeError):
    """The watchdog ladder reached its replan rung: ``breaches``
    consecutive steps exceeded ``deadline_s`` (last measured: ``dt``)."""

    def __init__(self, step: int, dt: float, deadline_s: float,
                 breaches: int):
        self.step = step
        self.dt = dt
        self.deadline_s = deadline_s
        self.breaches = breaches
        super().__init__(
            f"step {step}: {breaches} consecutive breaches, last "
            f"{dt*1e3:.0f}ms > deadline {deadline_s*1e3:.0f}ms")


#: the ladder, one rung per consecutive breach (3+ stays on "replan")
_LADDER = ("report", "rescale", "replan")


class Watchdog:
    """Per-step deadline tracker in the ``StragglerMonitor`` currency:
    deadline = k × max(predicted_step_s, median of recent measured).

    The first ``warmup`` observations never breach (jit compile lands
    there) but do seed the median.  ``observe`` returns the ladder action
    for this step (None when within deadline) and the deadline it was
    judged against; the caller performs the action (the watchdog itself
    only widens ``k`` on ``rescale()``)."""

    def __init__(self, k: float = 6.0, warmup: int = 2, window: int = 16,
                 max_k: float = 64.0):
        self.k = float(k)
        self.warmup = int(warmup)
        self.max_k = float(max_k)
        self.recent: deque = deque(maxlen=window)
        self.breaches = 0       # consecutive
        self.n = 0

    def deadline_s(self, predicted_s: Optional[float]) -> float:
        med = float(np.median(self.recent)) if self.recent else 0.0
        base = max(float(predicted_s or 0.0), med)
        return self.k * base if base > 0.0 else float("inf")

    def observe(self, dt: float, predicted_s: Optional[float] = None):
        self.n += 1
        dl = self.deadline_s(predicted_s)
        breach = self.n > self.warmup and dt > dl
        self.recent.append(dt)
        if not breach:
            self.breaches = 0
            return None, dl
        self.breaches += 1
        return _LADDER[min(self.breaches, len(_LADDER)) - 1], dl

    def rescale(self, factor: float = 2.0) -> float:
        """Widen the deadline multiplier (the ladder's middle rung —
        accept the new normal instead of failing over)."""
        self.k = min(self.k * factor, self.max_k)
        self.breaches = 0
        return self.k

    def reset(self) -> None:
        self.breaches = 0
        self.recent.clear()
        self.n = 0


@dataclass(frozen=True)
class RecoveryEvent:
    """Audit record of one completed recovery."""
    step: int
    cause: str          # device_loss | watchdog
    action: str         # replan | keep
    mttr_s: float
    n_devices: int
    detail: str = ""


class Supervisor:
    """Runs a ``Trainer`` to ``total_steps`` through failures.

    ``factory(mesh_option_or_None) -> Trainer`` builds (and on recovery
    REbuilds) the trainer; pointing it at a persistent ``ckpt_dir`` is
    what makes recovery resume instead of restart — the trainer's own
    constructor restores the newest valid checkpoint.  ``cfg``/
    ``workload`` enable the model-guided replan (skipped, mesh kept,
    when absent); ``model``/``registry_dir`` name the cost model whose
    weights price the surviving meshes — resolved lazily at recovery
    time through the hardened registry, so a corrupt model file degrades
    to the previous revision rather than aborting the failover.
    """

    def __init__(self, factory: Callable[[Optional[Any]], Any],
                 total_steps: int, *, cfg=None, workload=None,
                 n_devices: int = 1, model=None,
                 registry_dir: Optional[str] = None,
                 injector: Optional[FaultInjector] = None,
                 watchdog_k: float = 6.0, warmup_steps: int = 2,
                 backoff: Optional[BackoffPolicy] = None,
                 max_recoveries: int = 8,
                 sleep: Callable[[float], None] = time.sleep):
        self.factory = factory
        self.total_steps = int(total_steps)
        self.cfg = cfg
        self.workload = workload
        self.n_devices = int(n_devices)
        self.model = model
        self.registry_dir = registry_dir
        self.injector = injector
        self.backoff = backoff or BackoffPolicy()
        self.max_recoveries = int(max_recoveries)
        self.sleep = sleep
        self.watchdog = Watchdog(k=watchdog_k, warmup=warmup_steps)
        self.mesh = None                       # current MeshOption (or None)
        self.recoveries: List[RecoveryEvent] = []
        self.steps_run = 0                     # executions incl. replays
        self._history: Dict[int, Dict[str, float]] = {}
        self.trainer = None

    # ------------------------------------------------------------------
    @property
    def history(self) -> List[Dict[str, float]]:
        """Per-step metrics, replays collapsed last-write-wins — directly
        comparable against an unsupervised run's ``trainer.history``."""
        return [self._history[s] for s in sorted(self._history)]

    def _weights(self):
        """The replan cost model, through the hardened registry (corrupt
        active file → previous revision).  None on any failure: elastic
        falls back to its default analytic model."""
        if self.model is None or not isinstance(self.model, str):
            return self.model
        from repro.calibration import registry
        try:
            return registry.load_model(self.model, self.registry_dir)
        except Exception:
            return None

    def _on_metrics(self, step: int, m: Dict[str, float]) -> None:
        self.steps_run += 1
        self._history[step] = m
        predicted = None
        if self.trainer is not None:
            predicted = getattr(self.trainer, "monitor", None)
            predicted = predicted.predicted_step_s if predicted else None
        action, dl = self.watchdog.observe(m["time_s"], predicted)
        if action is None:
            return
        _ESCALATIONS.inc(1, action=action)
        _obs_trace.get_tracer().instant("watchdog_" + action, step=step,
                                        dt_s=m["time_s"], deadline_s=dl)
        if action == "replan":
            raise WatchdogTimeout(step, m["time_s"], dl,
                                  self.watchdog.breaches)
        if action == "rescale":
            k = self.watchdog.rescale()
            _obs_report.emit("supervisor", {
                "step": step, "action": "rescale", "k": f"{k:g}",
                "dt_ms": f"{m['time_s']*1e3:.0f}",
                "deadline_ms": f"{dl*1e3:.0f}"})
        else:  # report
            _obs_report.emit("supervisor", {
                "step": step, "action": "report",
                "dt_ms": f"{m['time_s']*1e3:.0f}",
                "deadline_ms": f"{dl*1e3:.0f}"},
                text="step exceeded watchdog deadline")

    # ------------------------------------------------------------------
    def run(self) -> List[Dict[str, float]]:
        """Train to ``total_steps`` through failures; returns the
        collapsed per-step history (see ``history``)."""
        self.trainer = self.factory(self.mesh)
        while True:
            remaining = self.total_steps - self.trainer.step
            if remaining <= 0:
                break
            try:
                self.trainer.train(remaining, on_metrics=self._on_metrics)
            except DeviceLossError as e:
                step = e.step if e.step is not None else self.trainer.step
                self._recover("device_loss", step, lost=e.count,
                              detail=f"lost={e.count}")
            except WatchdogTimeout as e:
                self._recover("watchdog", e.step, lost=0,
                              detail=f"breaches={e.breaches}")
        return self.history

    def _recover(self, cause: str, step: int, *, lost: int,
                 detail: str = "") -> None:
        t0 = time.perf_counter()
        attempt = len(self.recoveries)
        if attempt >= self.max_recoveries:
            _obs_report.emit("supervisor", {
                "step": step, "cause": cause, "action": "abort",
                "recoveries": attempt},
                text="recovery budget exhausted")
            raise RuntimeError(
                f"supervisor: recovery budget exhausted "
                f"({attempt} >= {self.max_recoveries}) at step {step}")
        self.sleep(self.backoff.delay(attempt))
        # drain the dead trainer's async checkpointer so its in-flight
        # save lands (or its error is swallowed) before we rebuild on top
        ckpt = getattr(self.trainer, "ckpt", None)
        if ckpt is not None:
            try:
                ckpt.wait()
            except Exception:
                pass

        action = "keep"
        survivors = self.n_devices - (lost if cause == "device_loss" else 0)
        if self.cfg is not None and self.workload is not None:
            from repro.distributed import elastic
            try:
                if cause == "device_loss":
                    self.mesh = elastic.on_failure(
                        self.cfg, self.workload, self.n_devices, lost,
                        self._weights())
                    action = "replan"
                else:
                    opts = elastic.replan(self.cfg, self.workload,
                                          survivors, self._weights())
                    if opts:
                        self.mesh = opts[0]
                        action = "replan"
            except Exception as exc:
                _obs_report.emit("supervisor",
                                 {"step": step, "action": "keep"},
                                 text=f"replan failed ({exc}); keeping "
                                      f"current mesh")
        self.n_devices = survivors
        if cause == "watchdog":
            # don't re-trip on the replayed window: accept the new normal
            self.watchdog.rescale()
        self.watchdog.reset()

        self.trainer = self.factory(self.mesh)
        mttr = time.perf_counter() - t0
        _RECOVERY_SECONDS.observe(mttr)
        _RECOVERIES.inc(1, cause=cause, action=action)
        _obs_trace.get_tracer().instant("recovery", step=step, cause=cause,
                                        action=action, mttr_s=mttr)
        ev = RecoveryEvent(step, cause, action, mttr, self.n_devices,
                           detail)
        self.recoveries.append(ev)
        fields = {"step": step, "cause": cause, "action": action,
                  "mttr_ms": f"{mttr*1e3:.1f}",
                  "devices": self.n_devices,
                  "resume_step": self.trainer.step}
        if self.mesh is not None:
            fields["mesh"] = "x".join(
                str(v) for v in self.mesh.shape.values())
            fields["predicted_ms"] = \
                f"{self.mesh.predicted_step_s*1e3:.3f}"
        if detail:
            fields["detail"] = detail
        _obs_report.emit("supervisor", fields, text="recovered")

    # ------------------------------------------------------------------
    def mttr_s(self) -> float:
        return float(np.mean([r.mttr_s for r in self.recoveries])) \
            if self.recoveries else 0.0

    def report(self, printer=print) -> str:
        """The end-of-run rollup ``[supervisor]`` line (MTTR, recovery and
        injected-fault counts) — what the CI chaos smoke greps."""
        fields: Dict[str, object] = {
            "steps": len(self._history),
            "steps_run": self.steps_run,
            "recoveries": len(self.recoveries),
            "mttr_s": f"{self.mttr_s():.3f}",
            "devices": self.n_devices,
        }
        if self.injector is not None:
            counts = self.injector.counts()
            fields["faults"] = ",".join(
                f"{k}:{v}" for k, v in sorted(counts.items())) or "none"
        return _obs_report.emit("supervisor", fields, text="run complete",
                                printer=printer)


# ---------------------------------------------------------------------------
# Serving-side supervision: graceful degradation, not failover
# ---------------------------------------------------------------------------


@dataclass
class ServingPolicy:
    """Knobs for ``ServingSupervisor``'s degradation ladder."""
    watchdog_k: float = 6.0
    warmup_iters: int = 2
    max_queue: Optional[int] = None    # shed arrivals beyond this depth
    throttle_iters: int = 4            # refill freeze after an eviction
    retry_after_s: float = 1.0         # stamped on shed requests


class ServingSupervisor:
    """Wraps a ``DecodeServer`` with SLO-preserving degradation.

    The training ladder's "replan" rung has no serving analogue (there is
    no better mesh to fail over to mid-request), so rungs 2/3 degrade
    instead: **rescale** → evict the heaviest slot back to the queue
    front and throttle refills for ``throttle_iters`` iterations;
    **replan** → additionally shed queue overflow with a
    ``retry_after_s`` stamp and widen the watchdog.  Device loss from the
    injector evicts every occupied slot (their requests resume from their
    generated prefix on re-admission) and throttles.
    """

    def __init__(self, server, policy: Optional[ServingPolicy] = None,
                 injector: Optional[FaultInjector] = None):
        self.server = server
        self.policy = policy or ServingPolicy()
        self.injector = injector
        self.watchdog = Watchdog(k=self.policy.watchdog_k,
                                 warmup=self.policy.warmup_iters)
        self.shed: List[Any] = []
        self.evictions = 0
        self._throttle = 0
        self._iters = 0

    # -- degradation primitives -------------------------------------------
    def _shed_overflow(self) -> None:
        q = self.server.queue
        if self.policy.max_queue is None or \
                len(q) <= self.policy.max_queue:
            return
        overflow = q[self.policy.max_queue:]
        del q[self.policy.max_queue:]
        for r in overflow:
            r.shed = True
            r.retry_after_s = self.policy.retry_after_s
        self.shed.extend(overflow)
        _SHED.inc(len(overflow))
        _obs_trace.get_tracer().instant(
            "requests_shed", n=len(overflow),
            retry_after_s=self.policy.retry_after_s)
        _obs_report.emit("supervisor", {
            "action": "shed", "n": len(overflow),
            "retry_after_s": self.policy.retry_after_s})

    def _evict(self, slots: List[int], why: str) -> None:
        for s in slots:
            if self.server.active[s] is None:
                continue
            rid = self.server.active[s].rid
            self.server.evict_slot(s)
            self.evictions += 1
            _EVICTIONS.inc()
            _obs_trace.get_tracer().instant("slot_evicted", slot=s,
                                            rid=rid, why=why)
            _obs_report.emit("supervisor", {"action": "evict", "slot": s,
                                            "rid": rid, "why": why})
        self._throttle = max(self._throttle, self.policy.throttle_iters)

    def _heaviest_slot(self) -> Optional[int]:
        occ = [s for s, r in enumerate(self.server.active) if r is not None]
        if not occ:
            return None
        ctx = self.server._ctx
        return max(occ, key=lambda s: int(ctx[s]))

    # -- the supervised serve loop ----------------------------------------
    def run(self, max_iters: int = 10_000) -> List[Any]:
        """Serve until queue + slots drain (shed requests excluded);
        returns completed requests, like ``DecodeServer.run``."""
        srv = self.server
        done: List[Any] = []
        pending = lambda: srv.queue or any(srv.active)
        while pending() and self._iters < max_iters:
            it = self._iters
            self._shed_overflow()
            if self.injector is not None:
                try:
                    self.injector.decode_begin(it)
                except DeviceLossError:
                    occupied = [s for s, r in enumerate(srv.active)
                                if r is not None]
                    self._evict(occupied, why="device_loss")
            if self._throttle > 0:
                self._throttle -= 1
                _THROTTLED.inc()
            else:
                srv._refill()
            before = [r for r in srv.active if r]
            if not before:
                self._iters += 1
                if not srv.queue:
                    break
                continue
            dt = srv.step()
            self._iters += 1
            predicted = None
            if srv.scorer is not None:
                predicted = float(srv.scorer.decode_step_seconds(
                    max(len(before), 1), srv._cache_tokens()))
            action, dl = self.watchdog.observe(dt, predicted)
            if action is not None:
                _ESCALATIONS.inc(1, action=action)
                _obs_trace.get_tracer().instant(
                    "watchdog_" + action, iter=it, dt_s=dt, deadline_s=dl)
                if action == "report":
                    _obs_report.emit("supervisor", {
                        "iter": it, "action": "report",
                        "dt_ms": f"{dt*1e3:.0f}",
                        "deadline_ms": f"{dl*1e3:.0f}"},
                        text="decode exceeded watchdog deadline")
                elif action == "rescale":
                    heavy = self._heaviest_slot()
                    if heavy is not None:
                        self._evict([heavy], why="watchdog")
                    self.watchdog.breaches = 0
                else:  # replan rung: shed + accept the new normal
                    if self.policy.max_queue is not None:
                        self._shed_overflow()
                    heavy = self._heaviest_slot()
                    if heavy is not None:
                        self._evict([heavy], why="watchdog")
                    self.watchdog.rescale()
            done.extend(r for r in before if r.done)
        return done

    def report(self, printer=print) -> str:
        fields = {"iters": self._iters, "evictions": self.evictions,
                  "shed": len(self.shed)}
        if self.injector is not None:
            counts = self.injector.counts()
            fields["faults"] = ",".join(
                f"{k}:{v}" for k, v in sorted(counts.items())) or "none"
        return _obs_report.emit("supervisor", fields,
                                text="serve complete", printer=printer)
