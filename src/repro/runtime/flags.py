"""Runtime feature flags (thread-local, context-managed).

``use_pallas()`` switches the attention / SSD mixers from their XLA
production paths to the Pallas TPU kernels (interpret-mode on CPU).  The
two paths are numerically equivalent (tests assert it); the flag exists so
the dry-run/CPU paths stay fast while TPU deployments take the kernel path.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

_tls = threading.local()


def pallas_enabled() -> bool:
    return getattr(_tls, "pallas", False)


@contextmanager
def use_pallas(enabled: bool = True):
    prev = getattr(_tls, "pallas", False)
    _tls.pallas = enabled
    try:
        yield
    finally:
        _tls.pallas = prev


def attention_stubbed() -> bool:
    return getattr(_tls, "attn_stub", False)


@contextmanager
def stub_attention(enabled: bool = True):
    """Replace the attention contraction with a free pass-through — used to
    ATTRIBUTE which share of a lowering's cost is attention (diff of two
    dry-runs; benchmarks/kernel_roofline.py)."""
    prev = getattr(_tls, "attn_stub", False)
    _tls.attn_stub = enabled
    try:
        yield
    finally:
        _tls.attn_stub = prev
