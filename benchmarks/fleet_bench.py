"""Fleet churn-replan benchmark → repo-root ``BENCH_fleet.json``.

Times the three latencies the churn-tolerant fleet story rests on:

  * **cold allocate** — pricing the full (job × pool × count × plan ×
    mesh) space of the demo manifest from empty ``BasisCache``s;
  * **warm fleet replan** — the ``FleetSupervisor`` degradation-ladder
    repair after a ``pool_shrink``, re-scoring against the caches the
    allocation warmed (the latency a live churn event actually pays);
  * **single-job warm replan** — the PR 8 baseline (one
    ``elastic.replan`` warm rescore, ~0.4 ms), measured in-process so
    the bar is robust to CI machine speed.

    PYTHONPATH=src python -m benchmarks.fleet_bench \
        [--repeats 5] [--out BENCH_fleet.json]

Acceptance bars (CI fails the smoke when either is missed):
  * ``warm_replan_s <= 10 × single_warm_replan_s`` — fleet-wide churn
    repair stays within one order of magnitude of a single job's warm
    replan;
  * ``cache_reuse >= 0.5`` — at least half the basis columns a warm
    fleet replan touches come back from the allocation-warmed caches.
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.calibration import registry
from repro.configs.registry import ARCHS
from repro.core import exprops
from repro.distributed import elastic
from repro.launch.fleet import FleetAllocator, demo_manifest
from repro.runtime.fleet_supervisor import FleetSupervisor, SimJobRunner


def time_fn(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--registry", default=None, metavar="DIR")
    ap.add_argument("--out", default="BENCH_fleet.json")
    args = ap.parse_args(argv)

    manifest = demo_manifest()

    # ---- cold allocate: fresh allocator, empty caches -------------------
    t0 = time.perf_counter()
    allocator = FleetAllocator(manifest, registry_dir=args.registry)
    assignment = allocator.allocate()
    cold_allocate_s = time.perf_counter() - t0
    print(f"cold allocate: {len(assignment.placements)} jobs across "
          f"{len(manifest.pools)} pools in {cold_allocate_s*1e3:.2f} ms")

    # ---- warm fleet replan: the supervisor's pool_shrink repair ---------
    # each repeat rebuilds the supervisor on a fresh allocation (warm
    # caches) and times ONE ladder repair of a 2-device a100 shrink
    def one_repair() -> float:
        sup = FleetSupervisor(allocator,
                              runner_factory=SimJobRunner.factory(),
                              assignment=allocator.allocate())
        sup.capacity["a100"] -= 2
        t = time.perf_counter()
        sup._repair_pool("a100", step=2, kind="pool_shrink")
        return time.perf_counter() - t

    one_repair()                         # first repair may still miss
    h0, m0 = (allocator.cache_stats()["hits"],
              allocator.cache_stats()["misses"])
    warm_replan_s = min(one_repair() for _ in range(args.repeats))
    stats = allocator.cache_stats()
    dh, dm = stats["hits"] - h0, stats["misses"] - m0
    cache_reuse = dh / (dh + dm) if (dh + dm) else 1.0
    print(f"warm fleet replan: {warm_replan_s*1e3:.3f} ms "
          f"(cache reuse {cache_reuse*100:.1f}%: +{dh} hits / +{dm} "
          f"misses over {args.repeats} repairs)")

    # ---- single-job warm replan baseline (PR 8's ~0.4 ms) ---------------
    job = manifest.jobs[0]
    cfg = ARCHS[job.arch]
    model = registry.load_model(manifest.pools[0].device, args.registry)
    cache = exprops.BasisCache(maxsize=4096)
    elastic.replan(cfg, job.workload, 8, model, cache=cache)   # warm it
    single_warm_replan_s = time_fn(
        lambda: elastic.replan(cfg, job.workload, 8, model, cache=cache),
        args.repeats)
    ratio = warm_replan_s / single_warm_replan_s
    print(f"single-job warm replan: {single_warm_replan_s*1e3:.3f} ms "
          f"-> fleet/single ratio {ratio:.1f}x (bar: <= 10x)")

    result = {
        "benchmark": "fleet_bench",
        "manifest": manifest.name,
        "jobs": len(manifest.jobs),
        "pools": len(manifest.pools),
        "repeats": args.repeats,
        "cold_allocate_s": cold_allocate_s,
        "warm_replan_s": warm_replan_s,
        "single_warm_replan_s": single_warm_replan_s,
        "warm_over_single_ratio": ratio,
        "ratio_bar": 10.0,
        "cache_reuse": cache_reuse,
        "cache_reuse_bar": 0.5,
    }
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {args.out}")
    if ratio > 10.0:
        print("WARNING: warm fleet replan above the 10x single-job bar")
    if cache_reuse < 0.5:
        print("WARNING: BasisCache reuse below the 50% bar")
    return result


if __name__ == "__main__":
    main()
