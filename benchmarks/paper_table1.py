"""Paper Table 1 — the faithful reproduction of the paper's pipeline.

1. Run the 9-class measurement-kernel library (paper §4.1) on THIS device
   (the container CPU plays the role of one GPU in the paper's per-device
   fit), timing with the §4.2 protocol (30 runs, drop 4, take min).
2. Extract property vectors automatically from the IR (paper §3).
3. Fit weights by relative-error least squares (paper §4.3).
4. Predict the four held-out test kernels (FD / skinny-MM / conv / N-body,
   paper §5) and report per-kernel predicted-vs-actual plus the
   per-kernel-class and overall geometric means of relative |error|.

Paper's cross-kernel geomeans per device: Titan X 16%, C2070 14%, K40 6%,
R9 Fury 42%.  The comparable quantity here is the single-device geomean on
the CPU; the acceptance band we claim in EXPERIMENTS.md is 6–42%.
"""
from __future__ import annotations

import json
import os
import time
from collections import defaultdict
from typing import Dict, List

import numpy as np

from repro.core import fit, measure, mkernels, tkernels
from repro.core.model import LinearCostModel, geomean, relative_error

OUT_DIR = "experiments"


def run(scale: str = "cpu", runs: int = 30, drop: int = 4,
        ridge: float = 1e-4, verbose: bool = True) -> Dict:
    t_start = time.time()
    launch = measure.measure_launch_overhead()
    if verbose:
        print(f"# launch overhead: {launch*1e6:.1f} µs")

    mcases = mkernels.measurement_cases(scale)
    pvs, times, labels = [], [], []
    for c in mcases:
        pv = c.properties()
        tr = measure.time_kernel(c.jitted(), runs=runs, drop=drop,
                                 min_time_s=4 * launch)
        pvs.append(pv)
        times.append(tr.min_s)
        labels.append(c.name)
    if verbose:
        print(f"# measured {len(mcases)} measurement kernels "
              f"({time.time()-t_start:.0f}s)")

    model = fit.fit_relative(pvs, times, device=f"cpu-{scale}", ridge=ridge)
    train_rep = fit.fit_report(model, pvs, times, labels)

    tcases = tkernels.test_cases(scale)
    rows = []
    per_class: Dict[str, List[float]] = defaultdict(list)
    for c in tcases:
        pv = c.properties()
        tr = measure.time_kernel(c.jitted(), runs=runs, drop=drop,
                                 min_time_s=4 * launch)
        pred = model.predict(pv)
        err = relative_error(pred, tr.min_s)
        per_class[c.klass].append(err)
        rows.append({"kernel": c.name, "class": c.klass,
                     "predicted_ms": pred * 1e3, "actual_ms": tr.min_s * 1e3,
                     "rel_err": err, "spread": tr.spread})

    result = {
        "device": model.device,
        "launch_overhead_us": launch * 1e6,
        "n_measurement_kernels": len(mcases),
        "fit_geomean_rel_err": train_rep["geomean_rel_err"],
        "rows": rows,
        "per_class_geomean": {k: geomean(v) for k, v in per_class.items()},
        "overall_geomean_rel_err": geomean(r["rel_err"] for r in rows),
        "paper_band": [0.06, 0.42],
    }

    if verbose:
        print(f"\n{'kernel':<26} {'class':<18} {'pred ms':>9} "
              f"{'actual ms':>9} {'rel err':>8}")
        for r in rows:
            print(f"{r['kernel']:<26} {r['class']:<18} "
                  f"{r['predicted_ms']:9.3f} {r['actual_ms']:9.3f} "
                  f"{r['rel_err']:8.2f}")
        print("\nper-class geomean rel |err|:")
        for k, v in result["per_class_geomean"].items():
            print(f"  {k:<20} {v:.3f}")
        print(f"overall geomean rel |err|: "
              f"{result['overall_geomean_rel_err']:.3f} "
              f"(paper band {result['paper_band']})")

    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, "paper_table1.json"), "w") as f:
        json.dump(result, f, indent=1)
    model.save(os.path.join(OUT_DIR, f"model_cpu_{scale}.json"))
    # also register it, so load_model("cpu-<scale>") serves this fit
    from repro.calibration import registry
    reg_path = registry.save_model(model)
    if verbose:
        print(f"# model registered at {reg_path}")
    return result


def main(scale: str = "cpu") -> None:
    run(scale=scale)


if __name__ == "__main__":
    import sys
    main(sys.argv[1] if len(sys.argv) > 1 else "cpu")
