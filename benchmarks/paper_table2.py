"""Paper Table 2 — the fitted weights, interpreted.

Prints the per-property weights (seconds/event) for the device fitted by
paper_table1, sorted by |weight|·typical-count salience, next to the
TPU-v5e analytic seed weights — the paper's point that weights 'allow
direct conclusions about sustained typical rates … and are directly
comparable across devices'.
"""
from __future__ import annotations

import json
import os

from repro.core import predictor
from repro.core.model import LinearCostModel

OUT_DIR = "experiments"


def main(scale: str = "cpu") -> None:
    path = os.path.join(OUT_DIR, f"model_cpu_{scale}.json")
    if not os.path.exists(path):
        from benchmarks import paper_table1
        paper_table1.run(scale=scale)
    cpu = LinearCostModel.load(path)
    tpu = predictor.tpu_v5e_weights()

    print(cpu.interpretation_report())
    print()

    # rate interpretation: seconds/event -> sustained rate
    print(f"{'property':<44} {'cpu fit':>12} {'v5e seed':>12}")
    tpu_w = dict(zip(tpu.keys, tpu.weights))
    for k, w in sorted(zip(cpu.keys, cpu.weights), key=lambda kw: -abs(kw[1])):
        tv = tpu_w.get(k)
        print(f"{k:<44} {w:12.3e} "
              f"{tv if tv is None else format(tv, '12.3e')}")

    with open(os.path.join(OUT_DIR, "paper_table2.json"), "w") as f:
        json.dump({"cpu": dict(zip(cpu.keys, map(float, cpu.weights))),
                   "tpu_v5e_seed": {k: float(v) for k, v in tpu_w.items()}},
                  f, indent=1)


if __name__ == "__main__":
    import sys
    main(sys.argv[1] if len(sys.argv) > 1 else "cpu")
