"""Roofline analysis (deliverable g): the three-term table per
(architecture × shape), derived from the compiled dry-run artifacts.

    compute    = HLO_FLOPs  / (chips × 197e12)          [bf16 peak]
    memory     = HLO_bytes  / (chips × 819e9)           [HBM]
    collective = coll_bytes / (chips × 3·50e9)          [ICI links]

``dryrun.json`` records *per-device* flops/bytes of the SPMD-partitioned
module, so chips cancel: term = per_device_quantity / per_chip_rate.
MODEL_FLOPS is the 6·N·D / 2·N_active·D closed form from ``archcount``;
the MODEL/HLO ratio flags remat- or redundancy-driven waste.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Optional

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS
from repro.core import archcount

PEAK = 197e12
HBM = 819e9
ICI = 3 * 50e9   # ~3 usable links per axis-direction on the 2D torus
OUT_DIR = "experiments"


def model_flops(arch: str, shape_name: str) -> float:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    sc = archcount.counts_for(cfg, shape)
    return sc.concrete_model_flops(
        {"B": shape.global_batch, "S": shape.seq_len})


def analyse(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    n = rec["n_devices"]
    compute = rec["flops_per_device"] / PEAK
    memory = rec["bytes_per_device"] / HBM
    coll = sum(rec["collective_bytes_per_device"].values()) / ICI
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_total = rec["flops_per_device"] * n
    bound = max(terms.values())
    total = sum(terms.values())
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": compute, "memory_s": memory, "collective_s": coll,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        # roofline fraction: dominant-term time / additive-model time —
        # 1.0 means perfectly overlapped (the dominant term IS the step)
        "roofline_fraction": bound / total if total else 0.0,
        "step_bound_s": bound,
    }


def main(path: str = "experiments/dryrun.json",
         mesh: str = "16x16") -> List[Dict]:
    with open(path) as f:
        records = json.load(f)
    rows, skips = [], []
    for rec in records:
        if rec["mesh"] != mesh:
            continue
        if rec["status"] == "skip":
            skips.append(rec)
            continue
        r = analyse(rec)
        if r:
            rows.append(r)

    hdr = (f"{'arch':<17}{'shape':<13}{'compute':>10}{'memory':>10}"
           f"{'collect':>10}{'dominant':>11}{'useful':>8}{'roofl%':>8}")
    print(hdr)
    print("-" * len(hdr))
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        print(f"{r['arch']:<17}{r['shape']:<13}"
              f"{r['compute_s']*1e3:9.2f}m{r['memory_s']*1e3:9.2f}m"
              f"{r['collective_s']*1e3:9.2f}m{r['dominant']:>11}"
              f"{r['useful_ratio']:8.2f}{r['roofline_fraction']*100:7.1f}%")
    for s in skips:
        print(f"{s['arch']:<17}{s['shape']:<13}{s['why']}")

    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"roofline_{mesh}.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main(*(sys.argv[1:] or []))
