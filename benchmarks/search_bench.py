"""Search-space sweep benchmark: per-plan interpreted loop vs. the
array-batched engine (``core.planspace``).

Builds a ≥10k-cell (plan × mesh-factorization) candidate space, scores it
twice — once through the pre-engine path (``predictor.predict_plans_loop``:
per-plan ``plan_property_vector`` assembly + one ``predict_many``) and once
through ``PlanSpace.scores`` (compiled property vectors over array
environments) — checks the two agree, and records wall times + speedup.

    PYTHONPATH=src python -m benchmarks.search_bench \
        [--arch glm4-9b] [--shape train_4k] [--target-cells 10000] \
        [--repeats 3] [--out BENCH_search.json]

The JSON lands at the REPO ROOT (so the perf trajectory is visible in the
tree, not buried under experiments/) with the shared benchmark schema —
``cells``, ``us_per_cell``, ``speedup``, ``baseline`` — plus the raw
timings.  CI runs this and uploads the JSON; the acceptance bar is a ≥20×
batched speedup at ≥10k cells.  ``benchmarks/fused_bench.py`` measures
the fused GEMV engine against the column engine the same way →
``BENCH_fused.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import numpy as np

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS
from repro.core import planspace, predictor
from repro.launch.autoshard import candidate_plans

#: chip counts whose factorizations make up the mesh side of the sweep;
#: mixed powers of two and 3·2^k so the dp/tp columns are irregular
DEVICE_LADDER = (256, 384, 512, 768, 1024, 1536, 2048, 3072, 4096, 6144)


def build_space(cfg, shape, target_cells: int):
    plans = candidate_plans(cfg, shape)
    meshes: List[Dict[str, int]] = []
    for n in DEVICE_LADDER:
        meshes.extend(planspace.mesh_factorizations(n))
        if len(plans) * len(meshes) >= target_cells:
            break
    return plans, meshes


def time_fn(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="glm4-9b", choices=sorted(ARCHS))
    ap.add_argument("--shape", default="train_4k", choices=sorted(SHAPES))
    ap.add_argument("--target-cells", type=int, default=10000)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--model", default=None)
    ap.add_argument("--out", default="BENCH_search.json")
    args = ap.parse_args(argv)

    cfg, shape = ARCHS[args.arch], SHAPES[args.shape]
    model = predictor.resolve_model(args.model)
    plans, meshes = build_space(cfg, shape, args.target_cells)
    n_cells = len(plans) * len(meshes)
    print(f"sweep: {len(plans)} plans × {len(meshes)} meshes = "
          f"{n_cells} cells ({args.arch} × {args.shape})")

    # warm the compiled-vector caches so both paths time *evaluation*
    # (the loop path shares step_vector_fn's compiled closures too)
    space = planspace.PlanSpace.from_product(cfg, shape, plans, meshes)
    batched = space.scores(model)
    loop_ref = np.concatenate([
        predictor.predict_plans_loop(cfg, shape, plans, m, model)
        for m in meshes])
    # from_product is plan-major; the loop above is mesh-major per plan set
    np.testing.assert_allclose(
        batched.reshape(len(plans), len(meshes)),
        loop_ref.reshape(len(meshes), len(plans)).T, rtol=1e-9)

    def run_loop():
        for m in meshes:
            predictor.predict_plans_loop(cfg, shape, plans, m, model)

    def run_batched():
        planspace.PlanSpace.from_product(cfg, shape, plans, meshes) \
            .scores(model)

    loop_s = time_fn(run_loop, args.repeats)
    batched_s = time_fn(run_batched, args.repeats)
    speedup = loop_s / batched_s

    result = {
        "benchmark": "search_bench",
        "arch": args.arch,
        "shape": args.shape,
        "n_plans": len(plans),
        "n_meshes": len(meshes),
        "cells": n_cells,
        "n_cells": n_cells,            # legacy alias of "cells"
        "us_per_cell": batched_s / n_cells * 1e6,
        "speedup": speedup,
        "baseline": "predict_plans_loop",
        "repeats": args.repeats,
        "loop_s": loop_s,
        "batched_s": batched_s,
        "loop_us_per_cell": loop_s / n_cells * 1e6,
        "batched_us_per_cell": batched_s / n_cells * 1e6,
        "model": model.device,
        "scores_match_rtol": 1e-9,
    }
    print(f"loop:    {loop_s*1e3:9.1f} ms  "
          f"({result['loop_us_per_cell']:.2f} µs/cell)")
    print(f"batched: {batched_s*1e3:9.1f} ms  "
          f"({result['batched_us_per_cell']:.3f} µs/cell)")
    print(f"speedup: {speedup:.1f}x over {n_cells} cells")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {args.out}")
    if speedup < 20:
        print("WARNING: speedup below the 20x acceptance bar")
    return result


if __name__ == "__main__":
    main()
