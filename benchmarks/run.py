"""Benchmark orchestrator — one module per paper table/figure + the
framework-level analyses.

    PYTHONPATH=src python -m benchmarks.run [--scale cpu|tiny] [--only NAME]

  paper_table1          paper §5 Table 1 (fit + held-out test kernels)
  paper_table2          paper Table 2 (fitted weights, interpreted)
  predictor_validation  beyond-paper: whole-step CPU prediction
  search_bench          beyond-paper: (plan × mesh) sweep, interpreted loop
                        vs. the array-batched engine (core/planspace.py)
  roofline              40-cell roofline table from experiments/dryrun.json
                        (run `python -m repro.launch.dryrun` first; skipped
                        with a notice if the dry-run artifact is absent)
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", default="cpu", choices=("cpu", "tiny"),
                    help="measurement-kernel problem-size ladder")
    ap.add_argument("--only", default=None,
                    help="run a single benchmark by name")
    args = ap.parse_args()

    t0 = time.time()
    names = [args.only] if args.only else [
        "paper_table1", "paper_table2", "predictor_validation",
        "search_bench", "roofline"]

    for name in names:
        print(f"\n{'='*72}\n== benchmarks.{name}\n{'='*72}")
        if name == "paper_table1":
            from benchmarks import paper_table1
            paper_table1.main(args.scale)
        elif name == "paper_table2":
            from benchmarks import paper_table2
            paper_table2.main(args.scale)
        elif name == "predictor_validation":
            from benchmarks import predictor_validation
            predictor_validation.main(args.scale)
        elif name == "search_bench":
            from benchmarks import search_bench
            search_bench.main([])
        elif name == "roofline":
            from benchmarks import roofline
            if os.path.exists("experiments/dryrun.json"):
                for mesh in ("16x16", "2x16x16"):
                    print(f"\n-- mesh {mesh} --")
                    roofline.main("experiments/dryrun.json", mesh)
            else:
                print("experiments/dryrun.json not found — run "
                      "`PYTHONPATH=src python -m repro.launch.dryrun` first")
        else:
            print(f"unknown benchmark {name!r}", file=sys.stderr)
            sys.exit(2)
    print(f"\nall benchmarks done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
